"""Quickstart: the whole of A³GNN, one section per capability.

  §1  data        — synthetic twin of ogbn-products (smoke scale)
  §2  parallelism — GraphSAGE under each pipeline mode (seq/mode2/mode1)
  §3  locality    — the sampling-bias effect: γ=1 vs γ=8 cache hit rates
  §4  autotuning  — the online controller picks (γ, Θ, mode, workers)
  §5  scale-out   — 2 locality-aware partitions, synced gradients
  §6  halo        — bounded boundary-feature exchange across the cut
  §7  serving     — online node predictions through the trainer's plane

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs.gnn import gnn_config, AutotuneConfig
from repro.graph.synthetic import dataset_like
from repro.core.a3gnn import A3GNNTrainer

# §1 DATA: synthetic twin of ogbn-products (smoke scale for the demo),
# with the locality knobs (γ, Θ) fixed by hand — §4 tunes them instead
cfg = gnn_config("products", smoke=True).replace(
    bias_rate=4.0,          # γ: prefer cached neighbors 4×
    cache_volume_mb=0.15,   # Θ: device-side feature cache (~19% of features)
    workers=2)
graph = dataset_like(cfg, seed=0)
print(f"graph: {graph.num_nodes} nodes, {graph.num_edges} edges, "
      f"{graph.num_classes} classes")

# §2 PARALLELISM: one epoch under each pipeline mode (paper §III-B) —
# seq / mode2 / mode1 trade memory for throughput
for mode in ("seq", "mode2", "mode1"):
    trainer = A3GNNTrainer(graph, cfg.replace(parallel_mode=mode), seed=0)
    res = trainer.run_epochs(epochs=1, max_steps_per_epoch=15)
    print(f"[{mode:5s}] thr={res.throughput_steps_s:6.2f} steps/s  "
          f"mem={res.memory_bytes/2**20:7.1f} MiB  "
          f"acc={res.test_acc:.3f}  cache-hit={res.cache_hit_rate:.2f}")

# §3 LOCALITY: γ=1 (uniform sampling) vs γ=8 (strongly cache-biased) —
# the bias raises the cache hit rate at a bounded accuracy cost
for gamma in (1.0, 8.0):
    trainer = A3GNNTrainer(graph, cfg.replace(bias_rate=gamma), seed=0)
    res = trainer.run_epochs(epochs=1, max_steps_per_epoch=15)
    print(f"[γ={gamma:3.0f}] cache-hit={res.cache_hit_rate:.3f}  "
          f"acc={res.test_acc:.3f}")

# §4 AUTOTUNING (paper §III-C): instead of fixing (γ, Θ, mode, workers) by
# hand as above, `fit_autotuned` runs tuning episodes on the live trainer —
# each episode the RL explorer proposes a configuration from the surrogate,
# the pipeline drains and reconfigures (cache resize, γ swap, mode switch),
# a few real steps are measured, and the measurement is fed back into the
# surrogate.  The report holds the measured Pareto front and the
# recommendation the trainer is left running.
trainer = A3GNNTrainer(graph, cfg, seed=0)
report = trainer.fit_autotuned(
    AutotuneConfig(episodes=4, steps_per_episode=8, max_workers=3, seed=0))
for ep in report.episodes:
    c, m = ep.config, ep.metrics
    print(f"[episode {ep.index}] γ={c['bias_rate']:4.1f} "
          f"Θ={c['cache_volume_mb']:5.2f}MB mode={c['parallel_mode']:5s} "
          f"workers={int(c['workers'])}  thr={m['throughput']:6.1f} steps/s "
          f"acc={m['accuracy']:.3f}")
best = report.best
print(f"autotuned: episode {best.index} chosen — "
      f"{best.metrics['throughput']:.1f} steps/s vs fixed seed config "
      f"{report.baseline_metrics['throughput']:.1f} steps/s; "
      f"{len(report.pareto_points())} Pareto-optimal measured points")

# §5 SCALE-OUT (the paper's headline): partition the graph with the
# locality-aware assigner, give every partition its own cache + pipeline,
# and synchronize gradients across the partition mesh (host-simulated on
# one CPU; real devices drop in transparently).  Same smoke run as
#     PYTHONPATH=src python -m repro.launch.train \
#         --arch graphsage-products --smoke --partitions 2 --steps 4
from repro.core.a3gnn import make_trainer

trainer = make_trainer(graph, cfg.replace(partitions=2), seed=0)
plan = trainer.plan
print(f"partitions: sizes={[len(ns) for ns in plan.node_sets]} "
      f"edge_locality={plan.edge_locality(graph):.3f} (locality method)")
res = trainer.run_epochs(epochs=1, max_steps_per_epoch=8)
print(f"[2-part] agg-thr={res.modeled_steps_s:6.1f} steps/s  "
      f"mem={res.memory_bytes/2**20:7.1f} MiB  acc={res.test_acc:.3f}  "
      f"cache-hit={res.cache_hit_rate:.2f}")

# §6 HALO EXCHANGE: §5 dropped every cut edge (the paper's
# no-remote-access setting).  A halo budget keeps each partition's top-k
# boundary nodes by affinity: their feature rows move ONCE through the
# partition mesh (collectives.halo_all_to_all) and sampled batches reach
# one hop across the cut — kept information rises for a measured,
# bounded exchange volume.  Same smoke run as
#     PYTHONPATH=src python -m repro.launch.train \
#         --arch graphsage-products --smoke --partitions 2 \
#         --halo-budget 32 --steps 4
trainer = make_trainer(graph, cfg.replace(partitions=2, halo_budget=32),
                       seed=0)
plan = trainer.plan
print(f"halo: budget=32/partition  "
      f"kept-info={plan.kept_information(graph):.3f} "
      f"(vs {plan.edge_locality(graph):.3f} with cut edges dropped)  "
      f"exchange={trainer.halo_exchange_bytes/2**10:.0f} KiB")
res = trainer.run_epochs(epochs=1, max_steps_per_epoch=8)
print(f"[halo]   acc={res.test_acc:.3f}  "
      f"halo-hit={trainer.halo_hit_rate:.3f} "
      f"(share of batch inputs served across the cut)")

# §7 SERVING: answer online node queries with the SAME FeaturePlane the
# trainer fetched through — the γ/Θ cache (and its hit accounting) carries
# over, and a streamed feature update is visible to the very next query.
# Same smoke run as
#     PYTHONPATH=src python -m repro.launch.serve --gnn \
#         --arch graphsage-products --smoke --queries 8 --batch 4
import numpy as np

from repro.graph.storage import FeatureStore
from repro.serve.gnn_engine import GNNInferenceEngine, GNNRequest

trainer = A3GNNTrainer(graph, cfg, seed=0)
pipe = trainer.make_pipeline()
pipe.run(max_steps=8)                   # warms params AND the cache
pipe.shutdown()
hits_trained = trainer.cache.stats.hits
engine = GNNInferenceEngine.from_trainer(trainer, batch=4, plane=pipe.plane)
nodes = np.where(graph.test_mask)[0][:8]
for rid, v in enumerate(nodes):
    engine.submit(GNNRequest(rid=rid, node=int(v)))
stats = engine.run_to_completion()
print(f"[serve]  {stats['completed']} queries → "
      f"{stats['queries_per_s']:.1f} q/s  p50={stats['p50_ms']:.0f}ms  "
      f"cache-hit={stats['cache_hit_rate']:.2f} "
      f"(train+serve hits {hits_trained} → {trainer.cache.stats.hits})")
store = FeatureStore(graph)             # streaming feature drift
engine.plane.subscribe_to(store)
store.update_rows(nodes[:1], np.ones((1, graph.feat_dim), np.float32))
engine.submit(GNNRequest(rid=99, node=int(nodes[0])))
engine.run_to_completion()
print(f"[stream] node {int(nodes[0])} updated (store v{store.version}) → "
      f"re-query pred {engine.completed[0].pred} → "
      f"{engine.completed[-1].pred} through the live plane")
