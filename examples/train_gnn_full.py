"""End-to-end driver: train GraphSAGE on a products-scale synthetic graph for
a few hundred steps with the FULL A³GNN stack — locality-aware sampling,
feature cache, parallel pipeline, checkpointing, and the auto-tuner choosing
the configuration under a memory constraint.

    PYTHONPATH=src python examples/train_gnn_full.py [--steps 200] [--full]

(--full uses the paper-scale synthetic twin, ~100k nodes / 2.5M edges;
default is a faster mid-scale run.)
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.configs.gnn import gnn_config, AutotuneConfig
from repro.graph.synthetic import dataset_like
from repro.core.a3gnn import A3GNNTrainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--mem-limit-mb", type=float, default=600.0)
    args = ap.parse_args()

    cfg = gnn_config("products")
    if not args.full:
        cfg = cfg.replace(num_nodes=12_000, num_edges=150_000, hidden=128,
                          batch_size=256, fanout=(10, 5), cache_volume_mb=4.0)
    t0 = time.time()
    graph = dataset_like(cfg, seed=0)
    print(f"[data] {graph.name}: {graph.num_nodes} nodes "
          f"{graph.num_edges} edges ({time.time()-t0:.1f}s)")

    # ---- phase 1: short profiling run to fit the perf model ----
    probe = A3GNNTrainer(graph, cfg, seed=0)
    pr = probe.run_epochs(1, max_steps_per_epoch=8)
    st = pr.stats.stage_times()
    print(f"[profile] sample={st.t_sample*1e3:.0f}ms "
          f"batch={st.t_batch*1e3:.0f}ms train={st.t_train*1e3:.0f}ms")

    # ---- phase 2: ONLINE auto-tuning under the memory constraint ----
    # The controller proposes (γ, Θ, mode, workers) from a PPO burst on a
    # pre-warmed surrogate, applies each proposal live (drain → reconfigure
    # → resume) and measures it; infeasible (over-limit) points get the
    # Algo. 3 -inf reward, so the recommendation respects the budget.
    limit = args.mem_limit_mb * 2**20
    tr = A3GNNTrainer(graph, cfg, seed=0)
    report = tr.fit_autotuned(AutotuneConfig(
        episodes=5, steps_per_episode=8, memory_limit_bytes=limit,
        max_workers=4, max_bias_rate=8.0, seed=0))
    best = report.best
    if not report.best_feasible:
        print(f"[autotune] WARNING: no measured config fit "
              f"{args.mem_limit_mb:.0f} MiB — recommending the least-memory "
              f"point ({best.metrics['memory']/2**20:.0f} MiB)")
    print(f"[autotune] chose mode={best.config['parallel_mode']} "
          f"workers={int(best.config['workers'])} "
          f"γ={best.config['bias_rate']:.1f} "
          f"Θ={best.config['cache_volume_mb']:.1f}MB "
          f"(measured mem {best.metrics['memory']/2**20:.0f} MiB, "
          f"budget {args.mem_limit_mb:.0f} MiB; "
          f"{len(report.pareto_points())} Pareto points)")

    # ---- phase 3: the real run — the trainer already carries the tuned
    # configuration (parameters/optimizer state survived the episodes) ----
    res = tr.run_epochs(epochs=50, max_steps_per_epoch=max(args.steps // 50, 1))
    print(f"[train] {res.stats.steps} steps, "
          f"loss {res.stats.losses[0]:.3f} → {np.mean(res.stats.losses[-5:]):.3f}, "
          f"thr={res.throughput_steps_s:.2f} steps/s, "
          f"mem={res.memory_bytes/2**20:.0f} MiB, acc={res.test_acc:.3f}, "
          f"hit={res.cache_hit_rate:.2f}")
    if report.best_feasible:
        assert res.memory_bytes < limit, "tuner violated the memory constraint"


if __name__ == "__main__":
    main()
