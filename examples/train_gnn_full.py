"""End-to-end driver: train GraphSAGE on a products-scale synthetic graph for
a few hundred steps with the FULL A³GNN stack — locality-aware sampling,
feature cache, parallel pipeline, checkpointing, and the auto-tuner choosing
the configuration under a memory constraint.

    PYTHONPATH=src python examples/train_gnn_full.py [--steps 200] [--full]

(--full uses the paper-scale synthetic twin, ~100k nodes / 2.5M edges;
default is a faster mid-scale run.)
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.configs.gnn import gnn_config
from repro.graph.synthetic import dataset_like
from repro.core.a3gnn import A3GNNTrainer
from repro.core.autotune.space import Space
from repro.core.autotune.surrogate import Surrogate
from repro.core.autotune.ppo import PPOAgent, PPOConfig
from repro.core.perf_model import StageTimes, MemoryTerms, predict


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--mem-limit-mb", type=float, default=600.0)
    args = ap.parse_args()

    cfg = gnn_config("products")
    if not args.full:
        cfg = cfg.replace(num_nodes=12_000, num_edges=150_000, hidden=128,
                          batch_size=256, fanout=(10, 5), cache_volume_mb=4.0)
    t0 = time.time()
    graph = dataset_like(cfg, seed=0)
    print(f"[data] {graph.name}: {graph.num_nodes} nodes "
          f"{graph.num_edges} edges ({time.time()-t0:.1f}s)")

    # ---- phase 1: short profiling run to fit the perf model ----
    probe = A3GNNTrainer(graph, cfg, seed=0)
    pr = probe.run_epochs(1, max_steps_per_epoch=8)
    st = pr.stats.stage_times()
    print(f"[profile] sample={st.t_sample*1e3:.0f}ms "
          f"batch={st.t_batch*1e3:.0f}ms train={st.t_train*1e3:.0f}ms")

    # ---- phase 2: auto-tune mode/workers/γ under the memory constraint ----
    sp = Space()
    iters = max(int(graph.train_mask.sum()) // cfg.batch_size, 1)
    mt = MemoryTerms(cache_bytes=cfg.cache_volume_mb * 2**20,
                     batch_bytes=pr.stats.peak_batch_bytes,
                     model_bytes=30e6, runtime_bytes=64e6)

    def evaluate(knobs):
        thr, mem = predict(knobs["parallel_mode"], st, mt,
                           knobs["workers"], iters)
        acc = 0.75 - 0.01 * np.log(max(knobs["bias_rate"], 1.0))
        return {"throughput": thr, "memory": mem, "accuracy": acc}

    limit = args.mem_limit_mb * 2**20
    agent = PPOAgent(sp, evaluate,
                     w={"throughput": 1e3, "memory": 0, "accuracy": 1.0},
                     constraint=lambda m: m["memory"] < limit,
                     cfg=PPOConfig(updates=16, horizon=8, seed=0))
    best = agent.run()
    print(f"[autotune] chose mode={best['parallel_mode']} "
          f"workers={best['workers']} γ={best['bias_rate']:.1f} "
          f"(predicted mem "
          f"{evaluate(best)['memory']/2**20:.0f} MiB < {args.mem_limit_mb} MiB)")

    # ---- phase 3: the real run under the tuned configuration ----
    tuned = cfg.replace(parallel_mode=best["parallel_mode"],
                        workers=min(best["workers"], 4),
                        bias_rate=min(best["bias_rate"], 8.0))
    tr = A3GNNTrainer(graph, tuned, seed=0)
    res = tr.run_epochs(epochs=50, max_steps_per_epoch=max(args.steps // 50, 1))
    print(f"[train] {res.stats.steps} steps, "
          f"loss {res.stats.losses[0]:.3f} → {np.mean(res.stats.losses[-5:]):.3f}, "
          f"thr={res.throughput_steps_s:.2f} steps/s, "
          f"mem={res.memory_bytes/2**20:.0f} MiB, acc={res.test_acc:.3f}, "
          f"hit={res.cache_hit_rate:.2f}")
    assert res.memory_bytes < limit, "tuner violated the memory constraint"


if __name__ == "__main__":
    main()
