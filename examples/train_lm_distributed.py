"""LM training with the production substrate on CPU (reduced config):
host data pipeline (paper mode-1 overlap) + checkpoint/restart supervisor +
fault injection — demonstrates the 1000-chip train loop end to end.

    PYTHONPATH=src python examples/train_lm_distributed.py \
        [--arch llama3.2-3b] [--steps 60] [--inject-failure]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.api import build
from repro.models.params import init_params, param_count
from repro.train.trainer import make_train_step
from repro.train.optimizer import get_optimizer
from repro.train.data import SyntheticTokens, PrefetchLoader
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import TrainSupervisor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--inject-failure", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = build(cfg)
    print(f"[model] {cfg.name}: {param_count(model.decls)/1e6:.2f}M params "
          f"(reduced config of {args.arch})")
    opt = get_optimizer(cfg)
    step_fn, _ = make_train_step(model, cfg, opt)
    jstep = jax.jit(step_fn, donate_argnums=(0, 1))
    params = init_params(model.decls, jax.random.PRNGKey(0))
    state = {"params": params, "opt_state": opt.init(params)}

    data = SyntheticTokens(cfg.vocab_size, args.batch, args.seq,
                           n_batches=args.steps * 2)
    loader = iter(PrefetchLoader(data, workers=args.workers))
    ckpt = CheckpointManager("/tmp/ckpt_example", keep=2, async_save=True)
    losses = []
    fail_once = {args.steps // 2} if args.inject_failure else set()

    def one_step(state, step):
        if step in fail_once:
            fail_once.clear()
            raise RuntimeError("injected node failure")
        batch = {k: jnp.asarray(v) for k, v in next(loader).items()}
        p, o, m = jstep(state["params"], state["opt_state"], batch)
        losses.append(float(m["loss"]))
        if step % 10 == 0:
            print(f"  step {step:4d} loss {losses[-1]:.4f}", flush=True)
        return {"params": p, "opt_state": o}

    sup = TrainSupervisor(ckpt, ckpt_every=10)
    t0 = time.time()
    state, rep = sup.run(state, one_step, args.steps)
    dt = time.time() - t0
    print(f"[done] {rep.steps_run} steps ({rep.failures} failures, "
          f"{rep.restores} restores, {rep.checkpoints} ckpts) in {dt:.1f}s "
          f"→ {args.steps*args.batch*args.seq/dt:.0f} tok/s; "
          f"loss {losses[0]:.3f} → {np.mean(losses[-5:]):.3f}")


if __name__ == "__main__":
    main()
