"""Serve a small LM with batched requests (continuous batching engine).

    PYTHONPATH=src python examples/serve_lm.py [--arch qwen3-4b]

Uses the reduced (smoke) config of the chosen architecture so it runs on
CPU; the same Engine drives the full config on a TPU slice.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.configs import get_config
from repro.serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    eng = Engine(cfg, batch=args.batch, max_len=96, temperature=0.8, seed=0)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        plen = int(rng.integers(3, 10))
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(1, cfg.vocab_size, plen)
                           .astype(np.int32),
                           max_new_tokens=args.max_new))
    stats = eng.run_to_completion()
    ttft = [r.t_first - r.t_submit for r in eng.completed]
    lat = [r.t_done - r.t_submit for r in eng.completed]
    print(f"completed {stats['completed']} requests / "
          f"{stats['tokens']} tokens in {stats['seconds']:.2f}s")
    print(f"throughput {stats['tokens_per_s']:.1f} tok/s | "
          f"TTFT p50 {np.percentile(ttft, 50)*1e3:.0f}ms | "
          f"latency p50 {np.percentile(lat, 50)*1e3:.0f}ms")
    for r in eng.completed[:3]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] → {r.out_tokens}")


if __name__ == "__main__":
    main()
