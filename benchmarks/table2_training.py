"""Tab. II reproduction: A³GNN T*/M* vs PyG-like / Quiver-like baselines on
reddit- and products-like synthetic graphs.  Metrics: throughput (epochs/s —
scaled to the synthetic size), peak modeled memory, test accuracy."""
from __future__ import annotations


from benchmarks.common import emit, save_json, bench_gnn_cfg
from repro.core.a3gnn import run_config
from repro.graph.synthetic import dataset_like

STEPS = 16


def run(quick: bool = False):
    results = {}
    datasets = ["products"] if quick else ["reddit", "products"]
    for ds in datasets:
        base = bench_gnn_cfg(ds)
        graph = dataset_like(base, seed=0)
        rows = {}
        configs = {
            "pyg_like": (base, "pyg_like"),
            "quiver_like": (base, "quiver_like"),
            "ours_T*": (base.replace(parallel_mode="mode1", workers=3,
                                     bias_rate=4.0, cache_volume_mb=8.0),
                        None),
            "ours_M*": (base.replace(parallel_mode="seq", bias_rate=8.0,
                                     cache_volume_mb=1.0, batch_size=128),
                        None),
        }
        for name, (cfg, baseline) in configs.items():
            r = run_config(graph, cfg, baseline=baseline, max_steps=STEPS,
                           epochs=2 if not quick else 1,
                           warmup_steps=3, simulate=True)
            rows[name] = {"thr_steps_s": r.modeled_steps_s,
                          "thr_epochs_s": r.modeled_epochs_s,
                          "mem_bytes": r.memory_bytes,
                          "acc": r.test_acc,
                          "hit_rate": r.cache_hit_rate}
            emit(f"table2/{ds}/{name}",
                 1e6 / max(r.modeled_steps_s, 1e-9),
                 f"ep_s={r.modeled_epochs_s:.4f};mem_MB="
                 f"{r.memory_bytes/2**20:.1f};acc={r.test_acc:.3f}")
        # headline derived claims
        speedup = rows["ours_T*"]["thr_steps_s"] / max(
            rows["pyg_like"]["thr_steps_s"], 1e-9)
        mem_ratio = rows["ours_M*"]["mem_bytes"] / max(
            rows["pyg_like"]["mem_bytes"], 1.0)
        rows["_derived"] = {"tstar_speedup_vs_pyg": speedup,
                            "mstar_mem_ratio_vs_pyg": mem_ratio}
        emit(f"table2/{ds}/derived", 0.0,
             f"T*_speedup={speedup:.2f};M*_mem_ratio={mem_ratio:.2f}")
        results[ds] = rows
    save_json("table2", results)
    return results
