"""Shared benchmark utilities: timing, CSV emission, bench-scale configs."""
from __future__ import annotations

import json
import time
from pathlib import Path

ART = Path(__file__).resolve().parent / "artifacts"
ART.mkdir(parents=True, exist_ok=True)

_rows = []


def emit(name: str, us_per_call: float, derived: str = ""):
    """Print one ``name,us_per_call,derived`` CSV row (the harness contract)."""
    row = f"{name},{us_per_call:.1f},{derived}"
    _rows.append(row)
    print(row, flush=True)


def save_json(name: str, obj):
    (ART / f"{name}.json").write_text(json.dumps(obj, indent=1))


def bench_gnn_cfg(dataset: str, **kw):
    """Mid-scale synthetic twin in the paper's regime: sampling-bound (3-hop
    fanout over a denser graph, small model) so the pipeline modes have the
    bottleneck structure the paper optimizes.  Cache sized ≈12% of features
    (resource-constrained setting)."""
    from repro.configs.gnn import gnn_config, DATASETS
    ds = DATASETS[dataset]
    nodes = 8_000
    scale = nodes / ds["num_nodes"]
    feat_mb = nodes * ds["feat_dim"] * 4 / 2**20
    cfg = gnn_config(dataset).replace(
        num_nodes=nodes,
        num_edges=max(int(ds["num_edges"] * scale), 80_000),
        hidden=32, batch_size=512, fanout=(15, 10, 5),
        cache_volume_mb=max(feat_mb * 0.12, 0.5), **kw)
    return cfg


def timed(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters
