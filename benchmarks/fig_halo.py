"""Bounded halo exchange: kept-information vs. exchange-volume.

Sweeps the halo budget at P ∈ {2, 4, 8} partitions on the synthetic
products twin (locality assigner).  Budget 0 is PR 2's drop-cut-edges
setting; each larger budget recovers more cut edges at a measured
boundary-feature cost — the affordability trade-off the `halo_budget`
autotune knob explores.  A short 2-partition training run confirms the
exchange is live end-to-end (halo hit rate > 0)."""
from __future__ import annotations

from benchmarks.common import emit, save_json, bench_gnn_cfg
from repro.core.a3gnn import make_trainer
from repro.graph.partition import plan_partitions
from repro.graph.synthetic import dataset_like

PARTS = (2, 4, 8)
BUDGETS = (0, 8, 32, 128, 512)
TRAIN_STEPS = 4


def run(quick: bool = False):
    cfg = bench_gnn_cfg("products")
    if quick:
        cfg = cfg.replace(num_nodes=3_000, num_edges=40_000, batch_size=128)
    graph = dataset_like(cfg, seed=0)

    results = {"sweep": {}, "train": {}}
    for parts in PARTS:
        results["sweep"][parts] = {}
        base_kept = None
        for budget in BUDGETS:
            plan = plan_partitions(graph, parts, "locality", seed=0,
                                   halo_budget=budget)
            kept = plan.kept_information(graph)
            vol = plan.exchange_volume_bytes(graph)
            if base_kept is None:
                base_kept = kept                    # budget=0 baseline
            results["sweep"][parts][budget] = {
                "kept_information": kept,
                "exchange_bytes": vol,
                "halo_rows": plan.halo_rows,
                "recovered_edges": plan.recovered_edges,
                "cut_edges": plan.cut_edges,
            }
            emit(f"halo/p{parts}_b{budget}", 0.0,
                 f"kept={kept:.3f} (+{kept - base_kept:.3f}) "
                 f"vol={vol/2**10:.0f}KiB")

    # end-to-end proof: the exchange feeds real sampled batches
    budget = 32 if quick else 128
    tr = make_trainer(graph, cfg.replace(partitions=2, halo_budget=budget),
                      seed=0)
    res = tr.run_epochs(1, max_steps_per_epoch=TRAIN_STEPS)
    results["train"] = {
        "halo_budget": budget,
        "halo_hit_rate": tr.halo_hit_rate,
        "exchange_bytes": tr.halo_exchange_bytes,
        "accuracy": res.test_acc,
        "modeled_steps_s": res.modeled_steps_s,
    }
    emit(f"halo/train_p2_b{budget}", 0.0,
         f"halo_hit={tr.halo_hit_rate:.3f} "
         f"exchange={tr.halo_exchange_bytes/2**10:.0f}KiB")
    save_json("fig_halo", results)
    return results
