"""Fault-injection sweep over the cross-host serving fabric: served
throughput, served p99 and explicit-loss fraction vs injected fault
severity (serve/transport.py).

Every replica sits behind a ``SimHostTransport`` on a shared
``VirtualClock``, so the sweep runs in *virtual* milliseconds — one tick
per fabric step — and is fully deterministic: same seed + same fault
schedule ⇒ the same numbers, independent of container wall-clock noise
(jit compiles, CPU contention) that would swamp a real-time measurement
of millisecond-scale faults.

Two axes of injected trouble, each at rising severity:

  * **response drops** — a fraction of completed responses vanish on the
    return wire; the fabric recovers each one through its per-request
    timeout + retry-on-another-replica path, so the visible cost is
    retries/timeouts and a fatter tail, not silent loss;
  * **replica kill** — one replica goes down mid-load; its in-flight
    work is rerouted to survivors, the SLO door shrinks to the surviving
    capacity, and the conservation ledger still balances.

The headline invariant (the chaos harness proves it request-by-request
in tests/test_transport_faults.py, the sweep records it at benchmark
scale): offered == served + shed + timed_out at every severity — every
admitted query ends somewhere explicit.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json
from repro.core.a3gnn import A3GNNTrainer
from repro.graph.partition import plan_partitions
from repro.graph.synthetic import dataset_like
from repro.serve.fabric import ServingFabric
from repro.serve.gnn_engine import GNNRequest
from repro.serve.transport import FaultSpec, VirtualClock, sim_host_factory

PARTS = 2
REPLICAS = 2
BATCH = 4
HALO = 32
BASE_LATENCY_MS = 5.0       # modeled one-way host cost on every wire
TIMEOUT_MS = 12.0           # per-request budget before retry
SLO_P99_MS = 30.0
PER_STEP = 6                # offered arrivals per virtual tick (saturating)
DROP_RATES = (0.0, 0.1, 0.25, 0.45)
DROP_RATES_QUICK = (0.0, 0.25)
REQUESTS, REQUESTS_QUICK = 240, 96


def _fresh_fabric(graph, cfg, params, faults, seed):
    clock = VirtualClock(tick_s=1e-3)
    plan = plan_partitions(graph, PARTS, "locality", seed=0,
                           halo_budget=HALO)
    fab = ServingFabric.from_plan(
        graph, plan, cfg, params, batch=BATCH, replicas=REPLICAS, seed=0,
        slo_p99_ms=SLO_P99_MS, timeout_ms=TIMEOUT_MS,
        transport_factory=sim_host_factory(
            faults=faults, base=FaultSpec(added_latency_ms=BASE_LATENCY_MS),
            seed=seed),
        clock=clock)
    return fab, clock


def _drive(fab, clock, nodes):
    """Paced open-loop offer (PER_STEP per virtual tick) then drain;
    returns per-level metrics in virtual time."""
    t0 = clock()
    i = 0
    while i < len(nodes):
        for _ in range(min(PER_STEP, len(nodes) - i)):
            fab.submit(GNNRequest(rid=i, node=int(nodes[i])))
            i += 1
        fab.step()
    fab.drain()
    a = fab.audit()
    assert a["pending"] == 0 and a["inflight"] == 0
    assert a["offered"] == a["done"] + a["shed"] + a["timed_out"]
    lat = [(r.t_done - r.t_submit) * 1e3 for r in fab.completed]
    vsec = clock() - t0
    fs = fab.fabric_stats()
    return {
        "requests": a["offered"], "served": a["done"], "shed": a["shed"],
        "timed_out": a["timed_out"],
        "loss_fraction": (a["shed"] + a["timed_out"]) / max(a["offered"], 1),
        "p50_ms": float(np.percentile(lat, 50)) if lat else 0.0,
        "p99_ms": float(np.percentile(lat, 99)) if lat else 0.0,
        "virtual_seconds": vsec,
        "served_qps_virtual": a["done"] / vsec if vsec else 0.0,
        "retries": fs["retries"], "timeouts": fs["timeouts"],
        "reroutes": fs["reroutes"], "fabric_stats": fs,
    }


def run(quick: bool = False):
    from repro.configs.gnn import gnn_config
    cfg = gnn_config("products", smoke=True)
    graph = dataset_like(cfg, seed=0)
    tr = A3GNNTrainer(graph, cfg, seed=0)
    rng = np.random.default_rng(0)
    n_req = REQUESTS_QUICK if quick else REQUESTS
    # distinct nodes: duplicate in-flight seeds serialize (the unique-seed
    # invariant) and would couple the levels' queue dynamics
    nodes = rng.choice(graph.num_nodes, size=n_req, replace=False)

    # -- severity sweep: response drops on every wire --------------------
    sweep = []
    for k, rate in enumerate(DROP_RATES_QUICK if quick else DROP_RATES):
        fab, clock = _fresh_fabric(
            graph, cfg, tr.params,
            faults=None if rate == 0.0 else {
                (p, r): FaultSpec(added_latency_ms=BASE_LATENCY_MS,
                                  drop_rate=rate)
                for p in range(PARTS) for r in range(REPLICAS)},
            seed=11 + k)
        level = _drive(fab, clock, nodes)
        level["drop_rate"] = rate
        sweep.append(level)
        emit(f"faults/drop{rate:g}_p99", level["p99_ms"] * 1e3,
             f"served={level['served']}/{level['requests']} "
             f"loss={level['loss_fraction']:.2f} "
             f"retries={level['retries']}")

    # -- kill one replica mid-load ---------------------------------------
    fab, clock = _fresh_fabric(
        graph, cfg, tr.params,
        faults={(0, 0): FaultSpec(added_latency_ms=BASE_LATENCY_MS,
                                  down_at_ms=20.0)},
        seed=29)
    kill = _drive(fab, clock, nodes)
    kill["killed_replica"] = "0/0"
    emit("faults/kill_replica_p99", kill["p99_ms"] * 1e3,
         f"served={kill['served']}/{kill['requests']} "
         f"loss={kill['loss_fraction']:.2f} "
         f"reroutes={kill['reroutes']} "
         f"health={kill['fabric_stats']['replicas']['0/0']['health']}")

    results = {
        "partitions": PARTS, "replicas": REPLICAS, "batch": BATCH,
        "base_latency_ms": BASE_LATENCY_MS, "timeout_ms": TIMEOUT_MS,
        "slo_p99_ms": SLO_P99_MS, "per_step": PER_STEP,
        "requests": n_req,
        "drop_sweep": sweep, "kill_replica": kill,
    }
    save_json("fig_faults", results)
    return results
