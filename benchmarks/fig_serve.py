"""Online GNN serving: p50/p99 latency + throughput vs. sampling bias γ.

Sweeps the serving engine (serve/gnn_engine.py) over the cache bias rate
on the products twin with a static hotness cache: higher γ steers the
incremental sampler toward cache-resident neighbors, so the gather stage
— the serving-latency bottleneck the paper's feature-movement machinery
attacks — serves more rows from the cache and fewer from the host store.
Reported per γ: cache hit rate, queries/s, and p50/p99 end-to-end
request latency (queue wait included — the continuous-batching number a
client sees).  Same engine, same request stream, only γ moves.

On this 1-CPU container both planes gather from host DRAM, so the
wall-clock γ effect is muted (a saved miss is a saved host read, not a
saved DMA) — the transferable signal is the hit rate and the saved
host-store bytes (``CacheStats.bytes_from_host``, the modeled PCIe
volume); on real silicon every saved miss is a saved host→device DMA.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_gnn_cfg, emit, save_json
from repro.core.a3gnn import A3GNNTrainer
from repro.graph.synthetic import dataset_like
from repro.serve.gnn_engine import GNNInferenceEngine, GNNRequest

GAMMAS = (1.0, 4.0, 16.0)
GAMMAS_QUICK = (1.0, 8.0)
QUERIES, QUERIES_QUICK = 64, 16
BATCH = 8


def run(quick: bool = False):
    cfg = bench_gnn_cfg("products")
    if quick:
        cfg = cfg.replace(num_nodes=3_000, num_edges=40_000)
    graph = dataset_like(cfg, seed=0)
    rng = np.random.default_rng(0)
    n_q = QUERIES_QUICK if quick else QUERIES
    # distinct nodes: duplicate queries serialize (unique-seed invariant)
    # and would fragment the full-batch steps the sweep compares
    nodes = rng.choice(np.where(graph.test_mask)[0], size=n_q, replace=False)

    results = {"batch": BATCH, "queries": n_q, "gammas": {}}
    for gamma in (GAMMAS_QUICK if quick else GAMMAS):
        tr = A3GNNTrainer(graph, cfg.replace(bias_rate=gamma), seed=0)
        eng = GNNInferenceEngine.from_trainer(tr, batch=BATCH, seed=0)
        # warmup wave (one full batch of distinct nodes) absorbs the jit
        # trace for the full-slot signature; run_to_completion metrics
        # are per-call windows, so only the hit accounting needs a reset
        for w in range(BATCH):
            eng.submit(GNNRequest(rid=-1 - w, node=w))
        eng.run_to_completion()
        tr.cache.stats.reset()
        for rid, v in enumerate(nodes):
            eng.submit(GNNRequest(rid=rid, node=int(v)))
        stats = eng.run_to_completion()
        results["gammas"][gamma] = stats
        emit(f"serve/gamma{gamma:g}_p50", stats["p50_ms"] * 1e3,
             f"p99={stats['p99_ms']:.1f}ms qps={stats['queries_per_s']:.1f} "
             f"hit={stats.get('cache_hit_rate', 0.0):.2f}")
    save_json("fig_serve", results)
    return results
