"""Online GNN serving under offered load: the partition-routed fabric vs
the single-engine baseline, and graceful degradation past saturation.

Two measurements over the products twin (serve/fabric.py):

  * **aggregate throughput** — closed-loop drain of the same query set
    through (a) one PR-5-shaped ``GNNInferenceEngine`` over the full
    graph and (b) a ``ServingFabric`` over P locality partitions.
    Routing each query to its owner's partition subgraph shrinks the
    sampled frontier (fewer reachable inputs per seed) and with it every
    downstream stage — sampling, gather, forward — so the fabric's
    aggregate qps beats the single engine well past the acceptance bar
    (≥ 2× at P ≥ 2) on the SAME container, no extra cores involved.
  * **offered-load sweep** — open-loop arrivals at a rising fraction of
    the fabric's measured capacity, with SLO-aware admission ON
    (``GNNConfig.slo_p99_ms``).  Past saturation the fabric sheds load
    instead of queueing it: reported per level are the served p50/p99
    (stays bounded near the target — the graceful half) and the shed
    fraction (rises with overload — the explicit half).

jit discipline: the engines pad every node level to fixed per-engine
caps, so each replica compiles exactly ONE forward signature — a
retrace costs more than twenty steady steps on this container, and one
first seen mid-sweep would stall the fabric long enough to age out its
whole queue.  The single compile is triggered (and the caches touched)
before anything is timed, then every engine is warmed with
measurement-identical closed-loop waves.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_gnn_cfg, emit, save_json
from repro.core.a3gnn import A3GNNTrainer
from repro.graph.partition import plan_partitions
from repro.graph.synthetic import dataset_like
from repro.serve.common import latency_stats
from repro.serve.fabric import ServingFabric
from repro.serve.gnn_engine import GNNInferenceEngine, GNNRequest

PARTS, PARTS_QUICK = 4, 2
BATCH = 16                  # per-engine slots in the fabric
BASE_BATCH = 8              # the PR 5 single-engine baseline shape
HALO = 64
SLO_P99_MS = 60.0
POOL, POOL_QUICK = 256, 96
# offered load as a fraction of the fabric's measured closed-loop capacity
LEVELS = (0.5, 1.0, 1.5, 2.0)
LEVELS_QUICK = (0.8, 1.8)
HORIZON_S, HORIZON_QUICK_S = 2.0, 0.75
WARM_WAVES = 3


def _closed_loop(engine, nodes, waves=1, rid0=0):
    """Drain ``waves`` full passes over ``nodes``; returns the last
    pass's per-call window stats (earlier passes double as jit warmup)."""
    st = None
    for w in range(waves):
        for i, v in enumerate(nodes):
            engine.submit(GNNRequest(rid=rid0 + w * len(nodes) + i,
                                     node=int(v)))
        st = engine.run_to_completion()
    return st


def _warm_sizes(fab, reps=2, seed=1):
    """Trigger each replica's ONE jit compile (the engines pad every
    node level to fixed caps, so the forward signature never varies) and
    pre-touch its partition cache with a couple of random full batches —
    a compile first seen mid-sweep would stall the fabric ~250 ms, long
    enough to age out the whole queue."""
    rng = np.random.default_rng(seed)
    for part in fab.engines:
        for eng in part:
            owned = np.flatnonzero(eng.node_map >= 0)
            for _ in range(reps):
                pick = rng.choice(owned, size=eng.batch, replace=False)
                for j, v in enumerate(pick):
                    eng.submit(GNNRequest(rid=-1 - j, node=int(v)))
                eng.run_to_completion()


def _offered_load(fab, nodes, rate_qps, horizon_s, rid0):
    """Open-loop drive: arrivals at fixed rate for ``horizon_s``, then
    drain.  Queue growth is the fabric's problem — exactly the regime
    SLO admission exists for."""
    n_req = max(int(rate_qps * horizon_s), 8)
    served = []
    fab.retire_hook = served.append
    shed0, off0 = fab.slo.shed, fab.slo.offered
    t0 = time.perf_counter()
    arrivals = t0 + np.arange(n_req) / rate_qps
    i = 0
    while i < n_req or fab.has_work():
        now = time.perf_counter()
        while i < n_req and arrivals[i] <= now:
            fab.submit(GNNRequest(rid=rid0 + i,
                                  node=int(nodes[i % len(nodes)])))
            i += 1
        if fab.has_work():
            fab.step()
        elif i < n_req:
            time.sleep(max(min(arrivals[i] - time.perf_counter(), 1e-3), 0))
    dt = time.perf_counter() - t0
    fab.retire_hook = None
    st = latency_stats(served)
    offered = fab.slo.offered - off0
    shed = fab.slo.shed - shed0
    return {"offered_qps": rate_qps, "requests": n_req, "seconds": dt,
            "served": len(served), "shed": shed,
            "shed_fraction": shed / max(offered, 1),
            "served_qps": len(served) / dt if dt else 0.0,
            "p50_ms": st.p50_ms, "p99_ms": st.p99_ms}


def run(quick: bool = False):
    cfg = bench_gnn_cfg("products")
    if quick:
        cfg = cfg.replace(num_nodes=3_000, num_edges=40_000)
    parts = PARTS_QUICK if quick else PARTS
    batch = BASE_BATCH if quick else BATCH
    graph = dataset_like(cfg, seed=0)
    rng = np.random.default_rng(0)
    # distinct nodes: duplicate in-flight queries serialize (the unique-
    # seed invariant) and would fragment the full-batch steps compared
    pool = rng.choice(graph.num_nodes, size=POOL_QUICK if quick else POOL,
                      replace=False)

    tr = A3GNNTrainer(graph, cfg, seed=0)

    # -- single-engine baseline (the PR 5 serving shape) -----------------
    base = GNNInferenceEngine.from_trainer(tr, batch=BASE_BATCH, seed=0)
    _closed_loop(base, pool, waves=WARM_WAVES)
    base_stats = _closed_loop(base, pool)
    emit("serve/baseline_qps", base_stats["p50_ms"] * 1e3,
         f"qps={base_stats['queries_per_s']:.0f} "
         f"p99={base_stats['p99_ms']:.1f}ms batch={BASE_BATCH}")

    # -- fabric: P locality partitions behind one scheduler --------------
    plan = plan_partitions(graph, parts, "locality", seed=0,
                           halo_budget=HALO)
    # capacity probe runs with shedding OFF (a closed-loop burst IS a
    # deliberately saturated queue — the door would shed it wholesale);
    # the SLO target switches on for the offered-load sweep below
    fab = ServingFabric.from_plan(graph, plan, cfg, tr.params, batch=batch,
                                  replicas=1, slo_p99_ms=0.0, seed=0)
    _warm_sizes(fab)
    _closed_loop(fab, pool, waves=WARM_WAVES)
    fab_stats = _closed_loop(fab, pool)
    capacity = fab_stats["queries_per_s"]
    speedup = capacity / max(base_stats["queries_per_s"], 1e-9)
    emit("serve/fabric_qps", fab_stats["p50_ms"] * 1e3,
         f"qps={capacity:.0f} p99={fab_stats['p99_ms']:.1f}ms "
         f"P={parts} batch={batch} speedup={speedup:.2f}x")

    # -- offered-load sweep: degradation past saturation -----------------
    fab.slo.slo_p99_ms = SLO_P99_MS
    horizon = HORIZON_QUICK_S if quick else HORIZON_S
    levels = LEVELS_QUICK if quick else LEVELS
    # rehearsal pass (discarded): open-loop arrival patterns hit jit
    # signatures the closed-loop warmup cannot reach — absorb them here
    # so a measured level never eats a retrace stall
    for j, frac in enumerate(levels):
        _offered_load(fab, pool, frac * capacity, horizon / 2,
                      rid0=500_000 * (j + 1))
    sweep = []
    for j, frac in enumerate(levels):
        level = _offered_load(fab, pool, frac * capacity, horizon,
                              rid0=100_000 * (j + 1))
        level["load_fraction"] = frac
        sweep.append(level)
        emit(f"serve/load{frac:g}_p99", level["p99_ms"] * 1e3,
             f"shed={level['shed_fraction']:.2f} "
             f"served={level['served_qps']:.0f}q/s of "
             f"{level['offered_qps']:.0f} offered")

    results = {
        "partitions": parts, "batch": batch, "replicas": 1,
        "baseline_batch": BASE_BATCH, "slo_p99_ms": SLO_P99_MS,
        "queries": len(pool),
        "baseline": base_stats, "fabric": fab_stats,
        "aggregate_speedup": speedup,
        "offered_load": sweep,
        # observability satellite: FabricStats + per-replica health/EWMA
        # (all-loopback here, so the transport fault counters are absent)
        "fabric_stats": fab.fabric_stats(),
    }
    save_json("fig_serve", results)
    return results
