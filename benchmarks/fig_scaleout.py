"""Scale-out sweep: multi-partition data-parallel training at 1/2/4
partitions (the paper's seven-affordable-devices-vs-two-A100s claim,
reproduced as modeled aggregate throughput on the host-simulated mesh),
plus the partition-method comparison (hash vs bfs vs locality cut ratio)."""
from __future__ import annotations

from benchmarks.common import emit, save_json, bench_gnn_cfg
from repro.core.a3gnn import make_trainer
from repro.graph.partition import plan_partitions
from repro.graph.synthetic import dataset_like

STEPS = 8
PARTS = (1, 2, 4)
METHODS = ("hash", "bfs", "locality")


def run(quick: bool = False):
    cfg = bench_gnn_cfg("products")
    if quick:
        cfg = cfg.replace(num_nodes=3_000, num_edges=40_000, batch_size=128)
    graph = dataset_like(cfg, seed=0)

    # partition quality: the locality method should keep the most edges
    quality = {}
    for method in METHODS:
        plan = plan_partitions(graph, 4, method, seed=0)
        quality[method] = {"edge_locality": plan.edge_locality(graph),
                           "halo_counts": plan.halo_counts}
        emit(f"scaleout/partition_{method}", 0.0,
             f"edge_locality={plan.edge_locality(graph):.3f}")

    results = {"quality": quality, "sweep": {}}
    base_thr = None
    for parts in PARTS:
        tr = make_trainer(graph, cfg.replace(partitions=parts), seed=0)
        res = tr.run_epochs(1, max_steps_per_epoch=STEPS, warmup_steps=2)
        thr = res.modeled_steps_s                  # aggregate fleet rate
        if base_thr is None:
            base_thr = thr
        speedup = thr / max(base_thr, 1e-9)
        results["sweep"][parts] = {
            "modeled_steps_s": thr,
            "wall_steps_s": res.throughput_steps_s,
            "speedup_vs_1": speedup,
            "memory_bytes": res.memory_bytes,
            "accuracy": res.test_acc,
            "cache_hit_rate": res.cache_hit_rate,
        }
        emit(f"scaleout/p{parts}", 1e6 / max(thr, 1e-9),
             f"speedup={speedup:.2f}")
    save_json("fig_scaleout", results)
    return results
