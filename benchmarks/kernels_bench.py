"""Kernel microbenchmarks: Pallas (interpret) vs XLA reference wall time on
CPU — correctness-oriented here (TPU is the target; interpret mode executes
the kernel body in Python).  The derived column reports allclose deltas."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels.reservoir.ops import reservoir_topm
from repro.kernels.gather.ops import cache_gather
from repro.kernels.segment_agg.ops import neighbor_mean
from repro.kernels.flash_attention.ops import flash_attention


def run(quick: bool = False):
    rng = np.random.default_rng(0)

    # reservoir
    R, N, m = 64, 256, 15
    w = jnp.asarray(rng.uniform(0.5, 4, (R, N)), jnp.float32)
    u = jnp.asarray(rng.random((R, N)), jnp.float32)
    mask = jnp.asarray(rng.random((R, N)) < 0.8)
    i1, k1 = reservoir_topm(w, u, mask, m, use_pallas=True)
    i2, k2 = reservoir_topm(w, u, mask, m, use_pallas=False)
    ok = bool(np.array_equal(np.asarray(i1), np.asarray(i2)))
    t_ref = timed(lambda: jax.block_until_ready(
        reservoir_topm(w, u, mask, m, use_pallas=False)))
    emit("kernel/reservoir/xla_ref", t_ref * 1e6, f"match={ok};R={R};N={N}")

    # gather
    C, F, n = 512, 512, 256
    cache = jnp.asarray(rng.normal(0, 1, (C, F)), jnp.float32)
    slots = jnp.asarray(rng.integers(-1, C, n), jnp.int32)
    o1, _ = cache_gather(slots, cache, use_pallas=True)
    o2, _ = cache_gather(slots, cache, use_pallas=False)
    ok = bool(np.allclose(np.asarray(o1), np.asarray(o2)))
    t_ref = timed(lambda: jax.block_until_ready(
        cache_gather(slots, cache, use_pallas=False)))
    emit("kernel/gather/xla_ref", t_ref * 1e6, f"match={ok};n={n};F={F}")

    # segment aggregation
    Nd, Ns, F = 128, 512, 256
    h = jnp.asarray(rng.normal(0, 1, (Ns, F)), jnp.float32)
    idx = jnp.asarray(rng.integers(-1, Ns, (Nd, 10)), jnp.int32)
    o1 = neighbor_mean(idx, h, use_pallas=True)
    o2 = neighbor_mean(idx, h, use_pallas=False)
    ok = bool(np.allclose(np.asarray(o1), np.asarray(o2), atol=1e-5))
    t_ref = timed(lambda: jax.block_until_ready(
        neighbor_mean(idx, h, use_pallas=False)))
    emit("kernel/segment_agg/xla_ref", t_ref * 1e6, f"match={ok};Nd={Nd}")

    # flash attention
    B, S, H, Dh = 2, 256, 4, 64
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, H, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, H, Dh)), jnp.float32)
    o1 = flash_attention(q, k, v, use_pallas=True)
    o2 = flash_attention(q, k, v, use_pallas=False)
    err = float(np.abs(np.asarray(o1) - np.asarray(o2)).max())
    t_ref = timed(lambda: jax.block_until_ready(
        flash_attention(q, k, v, use_pallas=False)))
    emit("kernel/flash_attention/xla_ref", t_ref * 1e6,
         f"max_err={err:.2e};S={S}")
