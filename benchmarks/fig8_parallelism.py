"""Fig. 8 reproduction: parallelism-mode scatter — (throughput, memory) for
every (mode × workers × batch) setting, per-mode Pareto front."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json, bench_gnn_cfg
from repro.core.a3gnn import run_config
from repro.core.autotune.pareto import pareto_front
from repro.graph.synthetic import dataset_like

STEPS = 12


def run(quick: bool = False):
    cfg0 = bench_gnn_cfg("reddit")
    graph = dataset_like(cfg0, seed=0)
    settings = []
    worker_opts = (1, 3) if quick else (1, 2, 4)
    batch_opts = (256,) if quick else (128, 256)
    for mode in ("seq", "mode1", "mode2"):
        for w in worker_opts:
            for b in batch_opts:
                if mode == "seq" and w > 1:
                    continue
                settings.append((mode, w, b))
    pts = []
    for mode, w, b in settings:
        cfg = cfg0.replace(parallel_mode=mode, workers=w, batch_size=b)
        r = run_config(graph, cfg, max_steps=STEPS, warmup_steps=3,
                       simulate=True)
        pts.append({"mode": mode, "workers": w, "batch": b,
                    "thr": r.modeled_steps_s,
                    "mem": r.memory_bytes, "acc": r.test_acc})
        emit(f"fig8/{mode}/w{w}/b{b}", 1e6 / max(r.modeled_steps_s, 1e-9),
             f"mem_MB={r.memory_bytes/2**20:.1f}")
    arr = np.array([[p["thr"], -p["mem"]] for p in pts])
    front = pareto_front(arr)
    for i in front:
        pts[i]["pareto"] = True
    # per-paper claims: mode1 max-thr; seq min-mem
    thr_by_mode = {m: max(p["thr"] for p in pts if p["mode"] == m)
                   for m in ("seq", "mode1", "mode2")}
    mem_by_mode = {m: min(p["mem"] for p in pts if p["mode"] == m)
                   for m in ("seq", "mode1", "mode2")}
    emit("fig8/derived", 0.0,
         f"front_size={len(front)};"
         f"max_thr_mode={max(thr_by_mode, key=thr_by_mode.get)};"
         f"min_mem_mode={min(mem_by_mode, key=mem_by_mode.get)}")
    save_json("fig8", {"points": pts, "front": [int(i) for i in front]})
    return pts
