"""Fig. 7 reproduction: bias-rate γ sweep — cache hit rate ↑, epoch time ↓,
accuracy cost ~1 point (sequential mode, static 40 MB-scaled cache)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json, bench_gnn_cfg
from repro.core.a3gnn import A3GNNTrainer
from repro.graph.synthetic import dataset_like

GAMMAS = (1.0, 2.0, 4.0, 8.0)
STEPS = 14


def run(quick: bool = False):
    results = {}
    datasets = ["products"] if quick else ["reddit", "products"]
    for ds in datasets:
        # paper's ablation setting: sequential mode, small static cache,
        # 2-hop fanout (3-hop×512-seed neighborhoods saturate the scaled
        # graph and mask the bias effect — hubs get sampled regardless)
        cfg0 = bench_gnn_cfg(ds).replace(parallel_mode="seq",
                                         batch_size=256, fanout=(10, 5),
                                         cache_volume_mb=1.0)
        graph = dataset_like(cfg0, seed=0)
        sweep = {}
        for g in GAMMAS:
            tr = A3GNNTrainer(graph, cfg0.replace(bias_rate=g), seed=0)
            r = tr.run_epochs(1, max_steps_per_epoch=STEPS, warmup_steps=3)
            sweep[g] = {"hit_rate": r.cache_hit_rate,
                        "epoch_time_s": 1.0 / max(r.throughput_epochs_s, 1e-9),
                        "steps_s": r.throughput_steps_s,
                        "acc": r.test_acc,
                        "pred_acc_drop": tr.predicted_accuracy_drop(),
                        "input_nodes": float(np.mean(
                            [r.stats.peak_batch_bytes]))}
            emit(f"fig7/{ds}/gamma={g}", 1e6 / max(r.throughput_steps_s, 1e-9),
                 f"hit={r.cache_hit_rate:.3f};acc={r.test_acc:.3f}")
        dh = sweep[GAMMAS[-1]]["hit_rate"] - sweep[1.0]["hit_rate"]
        emit(f"fig7/{ds}/derived", 0.0,
             f"hit_gain={dh:.3f};thr_gain="
             f"{sweep[GAMMAS[-1]]['steps_s']/max(sweep[1.0]['steps_s'],1e-9):.2f}")
        results[ds] = sweep
    save_json("fig7", results)
    return results
