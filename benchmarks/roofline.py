"""Roofline analysis over the dry-run artifacts (deliverable g).

Per (arch × shape × mesh):
    compute    = HLO_FLOPs_per_device / peak_FLOP/s          [s]
    memory     = HLO_bytes_per_device / HBM_bw               [s]
    collective = collective_bytes_per_device / ICI_bw        [s]

plus the dominant term, MODEL_FLOPS = 6·N·D (train; 2·N_active·D per decoded
token), the useful-compute ratio MODEL_FLOPS / HLO_FLOPS, and the roofline
fraction = model-compute-time / max(term)s — the score we hillclimb in §Perf.

Usage:
    PYTHONPATH=src python -m benchmarks.roofline [--mesh single] [--tag opt]
    (also invoked by benchmarks.run)
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

ART = Path(__file__).resolve().parent / "artifacts"
DRY = ART / "dryrun"

HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9,
      "hbm_bytes": 16 * 1024**3}


# (seq_len, global_batch) per shape — tokens are recomputed here so stale
# artifacts with the old prefill token-count bug stay correct.
SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,            # one new token per sequence
    "long_500k": 1,
}


def model_flops_per_device(rec) -> float:
    """6·N·D for train (N active params); 2·N per processed token for
    prefill/decode."""
    n_active = rec["params_active"]
    toks = SHAPE_TOKENS.get(rec["shape"], rec["tokens_per_step"])
    factor = 6.0 if rec["kind"] == "train" else 2.0
    return factor * n_active * toks / rec["n_devices"]


def analyze_record(rec) -> dict:
    c = rec["cost"]
    t_compute = c["flops_per_device"] / HW["peak_flops"]
    t_memory = c["bytes_per_device"] / HW["hbm_bw"]
    t_coll = c["collective_bytes_per_device"] / HW["ici_bw"]
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec)
    useful_ratio = mf / max(c["flops_per_device"], 1e-9)
    t_model = mf / HW["peak_flops"]
    bound = max(terms.values())
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"], "tag": rec.get("tag", ""),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_per_device": mf,
        "useful_flops_ratio": useful_ratio,
        "roofline_fraction": t_model / max(bound, 1e-30),
        "peak_gib": rec["memory"]["peak_device_bytes"] / 2**30,
        "fits_hbm": rec["memory"]["peak_device_bytes"] < HW["hbm_bytes"],
        "step_lower_bound_s": bound,
    }


def load_records(mesh: str = "single", tag: str = ""):
    out = []
    d = DRY / mesh
    if not d.exists():
        return out
    for f in sorted(d.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("skipped") or "error" in rec:
            continue
        if rec.get("tag", "") != tag:
            continue
        out.append(rec)
    return out


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | dom | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
           "useful | roofline | peak GiB | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (f"| {r['arch']} | {r['shape']} | {r['dominant'][:4]} | "
                 f"{r['t_compute_s']*1e3:.2f} | {r['t_memory_s']*1e3:.2f} | "
                 f"{r['t_collective_s']*1e3:.2f} | "
                 f"{r['useful_flops_ratio']:.2f} | "
                 f"{r['roofline_fraction']*100:.1f}% | "
                 f"{r['peak_gib']:.1f} | "
                 f"{'Y' if r['fits_hbm'] else 'N'} |\n")
    return hdr + body


def run(quick: bool = False, mesh: str = "single", tag: str = ""):
    from benchmarks.common import emit, save_json
    recs = load_records(mesh, tag)
    rows = [analyze_record(r) for r in recs]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    for r in rows:
        emit(f"roofline/{mesh}/{r['arch']}/{r['shape']}" +
             (f"/{tag}" if tag else ""),
             r["step_lower_bound_s"] * 1e6,
             f"dom={r['dominant']};roofline={r['roofline_fraction']*100:.1f}%;"
             f"useful={r['useful_flops_ratio']:.2f};peak_GiB={r['peak_gib']:.1f}")
    save_json(f"roofline_{mesh}" + (f"_{tag}" if tag else ""), rows)
    (ART / f"roofline_{mesh}{'_' + tag if tag else ''}.md").write_text(
        markdown_table(rows))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    rows = run(mesh=args.mesh, tag=args.tag)
    print(markdown_table(rows))


if __name__ == "__main__":
    main()
