"""Autotuned vs fixed-config training (paper §III-C closed-loop claim).

For each dataset twin, runs the online auto-tuning controller
(core/autotune/controller.py) against the three fixed baselines of
core/a3gnn.py (a3gnn seed config, pyg_like, quiver_like) on the SAME graph
and reports measured throughput / memory / accuracy plus the knobs the
controller settled on.  The paper's claim under test: the adaptive loop
finds a configuration at least as good as the hand-fixed one.
"""
from __future__ import annotations


from benchmarks.common import emit, save_json, bench_gnn_cfg
from repro.configs.gnn import AutotuneConfig
from repro.core.a3gnn import A3GNNTrainer, run_config
from repro.graph.synthetic import dataset_like

BASELINES = ("a3gnn", "pyg_like", "quiver_like")


def run(quick: bool = False):
    datasets = ["products"] if quick else ["products", "arxiv"]
    steps = 6 if quick else 10
    episodes = 4 if quick else 6
    results = {}
    for ds in datasets:
        cfg = bench_gnn_cfg(ds)
        graph = dataset_like(cfg, seed=0)
        row = {"fixed": {}, "autotuned": None}

        for baseline in BASELINES:
            r = run_config(graph, cfg, baseline=baseline, max_steps=steps,
                           warmup_steps=2, simulate=True)
            row["fixed"][baseline] = {"throughput": r.modeled_steps_s,
                                      "memory": r.memory_bytes,
                                      "accuracy": r.test_acc}
            emit(f"table4/{ds}/{baseline}", 0.0,
                 f"thr={r.modeled_steps_s:.2f};mem_mb="
                 f"{r.memory_bytes/2**20:.1f};acc={r.test_acc:.3f}")

        tr = A3GNNTrainer(graph, cfg, seed=0)
        acfg = AutotuneConfig(episodes=episodes, steps_per_episode=steps,
                              presample=48 if quick else 96,
                              max_workers=4, seed=0)
        rep = tr.fit_autotuned(acfg)
        m = rep.best.metrics
        row["autotuned"] = {
            "throughput": m["throughput"], "memory": m["memory"],
            "accuracy": m["accuracy"], "best_config": rep.best.config,
            "episodes": [{"config": e.config, "metrics": e.metrics,
                          "reward": e.reward} for e in rep.episodes],
            "pareto_size": len(rep.pareto_points()),
            "speedup_vs_seed": (m["throughput"]
                                / max(rep.baseline_metrics["throughput"],
                                      1e-9)),
        }
        emit(f"table4/{ds}/autotuned", 0.0,
             f"thr={m['throughput']:.2f};mem_mb={m['memory']/2**20:.1f};"
             f"acc={m['accuracy']:.3f};"
             f"speedup={row['autotuned']['speedup_vs_seed']:.2f}x")
        results[ds] = row
    save_json("table4", results)
    return results
