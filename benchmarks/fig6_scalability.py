"""Fig. 6 reproduction: A³GNN speedup vs the PyG-like baseline across the
five paper datasets (arxiv / products / amazon / yelp / reddit twins)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json, bench_gnn_cfg
from repro.core.a3gnn import run_config
from repro.graph.synthetic import dataset_like

STEPS = 10
DATASETS = ("arxiv", "products", "amazon", "yelp", "reddit")


def run(quick: bool = False):
    results = {}
    datasets = DATASETS[:2] if quick else DATASETS
    speedups = []
    for ds in datasets:
        cfg = bench_gnn_cfg(ds)
        graph = dataset_like(cfg, seed=0)
        base = run_config(graph, cfg, baseline="pyg_like", max_steps=STEPS,
                          warmup_steps=3, simulate=True)
        ours = run_config(graph, cfg.replace(parallel_mode="mode1", workers=3,
                                             bias_rate=4.0,
                                             cache_volume_mb=8.0),
                          max_steps=STEPS, warmup_steps=3, simulate=True)
        sp = ours.modeled_steps_s / max(base.modeled_steps_s, 1e-9)
        speedups.append(sp)
        results[ds] = {"baseline_steps_s": base.modeled_steps_s,
                       "ours_steps_s": ours.modeled_steps_s,
                       "speedup": sp, "density": graph.density()}
        emit(f"fig6/{ds}", 1e6 / max(ours.modeled_steps_s, 1e-9),
             f"speedup={sp:.2f}")
    emit("fig6/derived", 0.0, f"avg_speedup={np.mean(speedups):.2f}")
    save_json("fig6", results)
    return results
