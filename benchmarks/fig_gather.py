"""Feature-plane gather: host (numpy cache) vs device (Pallas) µs/row.

Sweeps the batch-generation gather over batch sizes on the products twin
with a static hotness cache: the SAME request stream is served by
``HostFeaturePlane`` (FeatureCache.fetch) and ``DeviceFeaturePlane``
(slot lookup + ``kernels/gather.cache_gather`` on the device-resident
table, host fallback for misses).  Parity is asserted bit-exactly before
timing, so the numbers compare identical work.  On this CPU container
the device plane runs the kernel in interpret mode — the comparison
shows the seam and the crossover shape, not TPU silicon.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_gnn_cfg, emit, save_json, timed
from repro.core.cache import FeatureCache
from repro.core.feature_plane import DeviceFeaturePlane, HostFeaturePlane
from repro.graph.synthetic import dataset_like

BATCH_ROWS = (256, 1024, 4096)
BATCH_ROWS_QUICK = (128, 512)


def run(quick: bool = False):
    cfg = bench_gnn_cfg("products")
    if quick:
        cfg = cfg.replace(num_nodes=3_000, num_edges=40_000)
    graph = dataset_like(cfg, seed=0)
    rng = np.random.default_rng(0)

    results = {"feat_dim": graph.feat_dim, "rows": {}}
    for n in (BATCH_ROWS_QUICK if quick else BATCH_ROWS):
        ids = rng.integers(0, graph.num_nodes, n)
        host = HostFeaturePlane(graph, FeatureCache(
            graph, cfg.cache_volume_mb, "static"))
        dev = DeviceFeaturePlane(graph, FeatureCache(
            graph, cfg.cache_volume_mb, "static"))
        a, b = host.fetch(ids), dev.fetch(ids)        # parity + jit warmup
        assert np.array_equal(a, b), "host/device plane parity broke"
        t_host = timed(host.fetch, ids)
        t_dev = timed(dev.fetch, ids)
        hit = host.cache.stats.hit_rate
        results["rows"][n] = {
            "host_us_per_row": t_host / n * 1e6,
            "device_us_per_row": t_dev / n * 1e6,
            "hit_rate": hit,
        }
        emit(f"gather/host_n{n}", t_host / n * 1e6,
             f"hit={hit:.2f} total={t_host*1e3:.2f}ms")
        emit(f"gather/device_n{n}", t_dev / n * 1e6,
             f"hit={hit:.2f} total={t_dev*1e3:.2f}ms")
    save_json("fig_gather", results)
    return results
