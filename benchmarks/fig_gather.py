"""Feature-plane gather: host (numpy cache) vs device (Pallas) µs/row.

Sweeps the batch-generation gather over batch sizes on the products twin
with a static hotness cache: the SAME request stream is served by
``HostFeaturePlane`` (FeatureCache.fetch) and ``DeviceFeaturePlane``
(slot lookup + ``kernels/gather.cache_gather`` on the device-resident
table, host fallback for misses).  Parity is asserted bit-exactly before
timing, so the numbers compare identical work.  The ``streamed`` section
measures the mirror-sync pathology this repo fixed: a feature stream
dirties a few resident rows between every fetch, and the device plane is
timed with incremental sync (per-row delta scatter) against the old
behavior (``incremental_sync=False`` — whole-mirror re-upload on every
version bump), with the sync counters reported alongside.  On this CPU
container the comparison shows the seam and the crossover shape, not
TPU silicon.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_gnn_cfg, emit, save_json, timed
from repro.core.cache import FeatureCache
from repro.core.feature_plane import DeviceFeaturePlane, HostFeaturePlane
from repro.graph.synthetic import dataset_like

BATCH_ROWS = (256, 1024, 4096)
BATCH_ROWS_QUICK = (128, 512)
STREAM_ROUNDS = 20
STREAM_DIRTY_ROWS = 8


def _sync_counters(dev):
    return {"full_uploads": dev.sync_full_uploads,
            "row_scatters": dev.sync_row_scatters,
            "rows_scattered": dev.sync_rows_scattered,
            "bytes_uploaded": dev.sync_bytes_uploaded}


def _streamed_device(graph, ids, rounds, incremental, seed=1):
    """µs/row for fetches interleaved with streamed row updates.  The
    mirror holds half the feature set, the realistic regime where a
    whole-table re-upload per streamed row actually hurts."""
    from repro.graph.storage import FeatureStore
    volume_mb = graph.num_nodes * graph.feat_dim * 4 / 2**20 * 0.5
    cache = FeatureCache(graph, volume_mb, "static")
    dev = DeviceFeaturePlane(graph, cache, incremental_sync=incremental)
    store = FeatureStore(graph)
    dev.subscribe_to(store)
    rng = np.random.default_rng(seed)
    resident = np.where(cache.device_map >= 0)[0]

    def one_round():
        upd = rng.choice(resident, STREAM_DIRTY_ROWS, replace=False)
        store.update_rows(upd, graph.features[upd] + 0.125)
        dev.fetch(ids)

    dev.fetch(ids)          # upload + gather jit warmup
    one_round()             # sync-path (scatter / re-upload) jit warmup
    t0 = time.perf_counter()
    for _ in range(rounds):
        one_round()
    dt = (time.perf_counter() - t0) / rounds
    return dt / len(ids) * 1e6, _sync_counters(dev)


def run(quick: bool = False):
    cfg = bench_gnn_cfg("products")
    if quick:
        cfg = cfg.replace(num_nodes=3_000, num_edges=40_000)
    graph = dataset_like(cfg, seed=0)
    rng = np.random.default_rng(0)

    results = {"feat_dim": graph.feat_dim, "rows": {}, "streamed": {}}
    for n in (BATCH_ROWS_QUICK if quick else BATCH_ROWS):
        ids = rng.integers(0, graph.num_nodes, n)
        host = HostFeaturePlane(graph, FeatureCache(
            graph, cfg.cache_volume_mb, "static"))
        dev = DeviceFeaturePlane(graph, FeatureCache(
            graph, cfg.cache_volume_mb, "static"))
        a, b = host.fetch(ids), dev.fetch(ids)        # parity + jit warmup
        assert np.array_equal(a, b), "host/device plane parity broke"
        t_host = timed(host.fetch, ids)
        t_dev = timed(dev.fetch, ids)
        hit = host.cache.stats.hit_rate
        results["rows"][n] = {
            "host_us_per_row": t_host / n * 1e6,
            "device_us_per_row": t_dev / n * 1e6,
            "hit_rate": hit,
            "sync": _sync_counters(dev),              # static cache: 1 upload
        }
        emit(f"gather/host_n{n}", t_host / n * 1e6,
             f"hit={hit:.2f} total={t_host*1e3:.2f}ms")
        emit(f"gather/device_n{n}", t_dev / n * 1e6,
             f"hit={hit:.2f} total={t_dev*1e3:.2f}ms "
             f"full_uploads={dev.sync_full_uploads}")

    # --- streamed updates: incremental delta scatter vs whole-mirror ---
    rounds = 5 if quick else STREAM_ROUNDS
    n = BATCH_ROWS_QUICK[-1] if quick else BATCH_ROWS[1]
    ids = rng.integers(0, graph.num_nodes, n)
    us_inc, sync_inc = _streamed_device(graph, ids, rounds,
                                        incremental=True)
    us_full, sync_full = _streamed_device(graph, ids, rounds,
                                          incremental=False)
    results["streamed"] = {
        "batch_rows": n, "rounds": rounds,
        "dirty_rows_per_round": STREAM_DIRTY_ROWS,
        "incremental_us_per_row": us_inc,
        "full_reupload_us_per_row": us_full,
        "speedup": us_full / us_inc,
        "sync_traffic_ratio": (sync_full["bytes_uploaded"]
                               / max(sync_inc["bytes_uploaded"], 1)),
        "incremental_sync": sync_inc,
        "full_reupload_sync": sync_full,
    }
    emit(f"gather/streamed_incremental_n{n}", us_inc,
         f"full_uploads={sync_inc['full_uploads']} "
         f"rows_scattered={sync_inc['rows_scattered']} "
         f"bytes={sync_inc['bytes_uploaded']}")
    emit(f"gather/streamed_full_reupload_n{n}", us_full,
         f"full_uploads={sync_full['full_uploads']} "
         f"bytes={sync_full['bytes_uploaded']} "
         f"traffic_ratio={results['streamed']['sync_traffic_ratio']:.0f}x")
    save_json("fig_gather", results)
    return results
