"""Feature-plane gather: host (numpy cache) vs device (Pallas) µs/row.

Sweeps the batch-generation gather over batch sizes on the products twin
with a static hotness cache: the SAME request stream is served by
``HostFeaturePlane`` (FeatureCache.fetch) and ``DeviceFeaturePlane``
(slot lookup + ``kernels/gather.cache_gather`` on the device-resident
table, host fallback for misses).  Parity is asserted bit-exactly before
timing, so the numbers compare identical work.  The ``streamed`` section
measures the mirror-sync pathology this repo fixed: a feature stream
dirties a few resident rows between every fetch, and the device plane is
timed with incremental sync (per-row delta scatter) against the old
behavior (``incremental_sync=False`` — whole-mirror re-upload on every
version bump), with the sync counters reported alongside.

The ``fused`` section is the all-hop fused pipeline's batch-size ×
feat_dim sweep: the per-batch feature read of the UNFUSED path
(``fetch`` — every input-hop row materializes on the host) against the
FUSED step-time read (``fused_inputs`` — resident rows stay addressed by
cache slot, only miss rows move, into a persistent donated sideband), on
both planes.  This is the device-plane small-batch gap the fused
pipeline closes: ``fetch`` pays a device gather dispatch + host copy per
batch regardless of n, ``fused_inputs`` pays O(miss rows).  On this CPU
container the comparison shows the seam and the crossover shape, not
TPU silicon.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_gnn_cfg, emit, save_json, timed
from repro.core.cache import FeatureCache
from repro.core.feature_plane import DeviceFeaturePlane, HostFeaturePlane
from repro.graph.synthetic import dataset_like

BATCH_ROWS = (256, 1024, 4096)
BATCH_ROWS_QUICK = (128, 512)
# fused sweep feature widths: products-native plus the reddit width
FEAT_DIMS = (100, 602)
FEAT_DIMS_QUICK = (100,)
STREAM_ROUNDS = 20
STREAM_DIRTY_ROWS = 8


def _sync_counters(dev):
    return {"full_uploads": dev.sync_full_uploads,
            "row_scatters": dev.sync_row_scatters,
            "rows_scattered": dev.sync_rows_scattered,
            "bytes_uploaded": dev.sync_bytes_uploaded}


def _streamed_device(graph, ids, rounds, incremental, seed=1):
    """µs/row for fetches interleaved with streamed row updates.  The
    mirror holds half the feature set, the realistic regime where a
    whole-table re-upload per streamed row actually hurts."""
    from repro.graph.storage import FeatureStore
    volume_mb = graph.num_nodes * graph.feat_dim * 4 / 2**20 * 0.5
    cache = FeatureCache(graph, volume_mb, "static")
    dev = DeviceFeaturePlane(graph, cache, incremental_sync=incremental)
    store = FeatureStore(graph)
    dev.subscribe_to(store)
    rng = np.random.default_rng(seed)
    resident = np.where(cache.device_map >= 0)[0]

    def one_round():
        upd = rng.choice(resident, STREAM_DIRTY_ROWS, replace=False)
        store.update_rows(upd, graph.features[upd] + 0.125)
        dev.fetch(ids)

    dev.fetch(ids)          # upload + gather jit warmup
    one_round()             # sync-path (scatter / re-upload) jit warmup
    t0 = time.perf_counter()
    for _ in range(rounds):
        one_round()
    dt = (time.perf_counter() - t0) / rounds
    return dt / len(ids) * 1e6, _sync_counters(dev)


def _fused_sweep(quick: bool, rng):
    """batch-size × feat_dim: unfused fetch vs fused_inputs, host vs
    device.  The fused read resolves the SAME rows (asserted through the
    encoded-slot oracle before timing) without materializing resident
    rows on the host.

    Two deliberate differences from the ``rows`` sweep above (which keeps
    measuring the cache-hostile floor: uniform ids, 12%-of-features
    cache): ids are drawn DEGREE-biased — a training batch's input level
    is the sampler's neighbor expansion, where a node's appearance rate
    tracks its degree, exactly the pattern the static hotness cache is
    provisioned for — and the cache is sized at the PAPER CONFIG's
    volume (GNNConfig.cache_volume_mb, under which the products feature
    table is device-resident at full scale too: 37.4 MB of features
    vs a 40 MB cache).  That is the regime the fused pipeline actually
    trains in; the measured hit rate is committed alongside the
    timings."""
    from repro.configs.gnn import gnn_config
    from repro.kernels.fused_gather_agg.ref import resolve_rows_ref
    out = {}
    vol = gnn_config("products").cache_volume_mb
    for F in (FEAT_DIMS_QUICK if quick else FEAT_DIMS):
        cfg = bench_gnn_cfg("products").replace(feat_dim=F)
        if quick:
            cfg = cfg.replace(num_nodes=3_000, num_edges=40_000)
        graph = dataset_like(cfg, seed=0)
        deg = graph.degrees().astype(np.float64)
        p_deg = deg / deg.sum()
        out[F] = {}
        for n in (BATCH_ROWS_QUICK if quick else BATCH_ROWS):
            ids = rng.choice(graph.num_nodes, n, p=p_deg)
            host = HostFeaturePlane(graph, FeatureCache(graph, vol,
                                                        "static"))
            dev = DeviceFeaturePlane(graph, FeatureCache(graph, vol,
                                                         "static"))
            # parity: both planes' encoded inputs resolve to the raw rows
            for plane in (host, dev):
                enc, aux, table = plane.fused_inputs(ids, n)
                rows = np.asarray(resolve_rows_ref(enc, table, aux))
                assert np.array_equal(rows[:n], graph.features[ids]), \
                    "fused_inputs row resolution broke"
            t = {"host_fetch": timed(host.fetch, ids, iters=10),
                 "device_fetch": timed(dev.fetch, ids, iters=10),
                 "host_fused": timed(host.fused_inputs, ids, n, iters=10),
                 "device_fused": timed(dev.fused_inputs, ids, n, iters=10)}
            d0 = dev.gather_dispatches
            dev.fused_inputs(ids, n)
            out[F][n] = {f"{k}_us_per_row": v / n * 1e6
                         for k, v in t.items()}
            out[F][n]["hit_rate"] = host.cache.stats.hit_rate
            out[F][n]["fused_dispatches_per_batch"] = \
                dev.gather_dispatches - d0
            emit(f"gather/fused_F{F}_n{n}",
                 out[F][n]["device_fused_us_per_row"],
                 f"host_fetch={out[F][n]['host_fetch_us_per_row']:.3f} "
                 f"dev_fetch={out[F][n]['device_fetch_us_per_row']:.3f} "
                 f"host_fused={out[F][n]['host_fused_us_per_row']:.3f}")
    return out


def run(quick: bool = False):
    cfg = bench_gnn_cfg("products")
    if quick:
        cfg = cfg.replace(num_nodes=3_000, num_edges=40_000)
    graph = dataset_like(cfg, seed=0)
    rng = np.random.default_rng(0)

    results = {"feat_dim": graph.feat_dim, "rows": {}, "streamed": {}}
    for n in (BATCH_ROWS_QUICK if quick else BATCH_ROWS):
        ids = rng.integers(0, graph.num_nodes, n)
        host = HostFeaturePlane(graph, FeatureCache(
            graph, cfg.cache_volume_mb, "static"))
        dev = DeviceFeaturePlane(graph, FeatureCache(
            graph, cfg.cache_volume_mb, "static"))
        a, b = host.fetch(ids), dev.fetch(ids)        # parity + jit warmup
        assert np.array_equal(a, b), "host/device plane parity broke"
        t_host = timed(host.fetch, ids)
        t_dev = timed(dev.fetch, ids)
        hit = host.cache.stats.hit_rate
        results["rows"][n] = {
            "host_us_per_row": t_host / n * 1e6,
            "device_us_per_row": t_dev / n * 1e6,
            "hit_rate": hit,
            "sync": _sync_counters(dev),              # static cache: 1 upload
        }
        emit(f"gather/host_n{n}", t_host / n * 1e6,
             f"hit={hit:.2f} total={t_host*1e3:.2f}ms")
        emit(f"gather/device_n{n}", t_dev / n * 1e6,
             f"hit={hit:.2f} total={t_dev*1e3:.2f}ms "
             f"full_uploads={dev.sync_full_uploads}")

    # --- fused pipeline: batch-size × feat_dim, fetch vs fused_inputs ---
    results["fused"] = _fused_sweep(quick, rng)

    # --- streamed updates: incremental delta scatter vs whole-mirror ---
    rounds = 5 if quick else STREAM_ROUNDS
    n = BATCH_ROWS_QUICK[-1] if quick else BATCH_ROWS[1]
    ids = rng.integers(0, graph.num_nodes, n)
    us_inc, sync_inc = _streamed_device(graph, ids, rounds,
                                        incremental=True)
    us_full, sync_full = _streamed_device(graph, ids, rounds,
                                          incremental=False)
    results["streamed"] = {
        "batch_rows": n, "rounds": rounds,
        "dirty_rows_per_round": STREAM_DIRTY_ROWS,
        "incremental_us_per_row": us_inc,
        "full_reupload_us_per_row": us_full,
        "speedup": us_full / us_inc,
        "sync_traffic_ratio": (sync_full["bytes_uploaded"]
                               / max(sync_inc["bytes_uploaded"], 1)),
        "incremental_sync": sync_inc,
        "full_reupload_sync": sync_full,
    }
    emit(f"gather/streamed_incremental_n{n}", us_inc,
         f"full_uploads={sync_inc['full_uploads']} "
         f"rows_scattered={sync_inc['rows_scattered']} "
         f"bytes={sync_inc['bytes_uploaded']}")
    emit(f"gather/streamed_full_reupload_n{n}", us_full,
         f"full_uploads={sync_full['full_uploads']} "
         f"bytes={sync_full['bytes_uploaded']} "
         f"traffic_ratio={results['streamed']['sync_traffic_ratio']:.0f}x")
    save_json("fig_gather", results)
    return results
