"""Dynamic graphs: cut-fraction drift vs. edges streamed, and the cost of
incremental re-balancing vs. a full repartition.

Two sweeps on the synthetic products twin:

  * **drift** — stream random edge batches into a partitioned graph and
    track how far the assignment's cut fraction degrades past the
    plan-time baseline (the signal `MultiPartitionTrainer.cut_drift`
    triggers on);
  * **rebalance** — at each drift point, compare `incremental_rebalance`
    (boundary-node migration) against a from-scratch locality partition
    of the mutated graph: wall-clock cost, fraction of nodes moved, and
    how close the incremental cut gets to the fresh one.  The committed
    artifact records the acceptance envelope: < 25% of nodes moved and
    cut fraction within 10% of fresh.

Also times the overlay's adjacency costs: mutation + first merged-view
build vs. `compact()` (amortization argument for lazy merging).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json, bench_gnn_cfg
from repro.graph.partition import (assignment_cut_fraction,
                                   incremental_rebalance, plan_partitions)
from repro.graph.synthetic import dataset_like

PARTS = 4
STREAM_BATCHES = (1000, 2000, 4000, 8000)


def run(quick: bool = False):
    cfg = bench_gnn_cfg("products")
    if quick:
        cfg = cfg.replace(num_nodes=3_000, num_edges=40_000)
    rng = np.random.default_rng(0)

    results = {"parts": PARTS, "drift": {}, "rebalance": {}, "overlay": {}}
    base_graph = dataset_like(cfg, seed=0)
    plan0 = plan_partitions(base_graph, PARTS, "locality", seed=0)
    cut0 = assignment_cut_fraction(base_graph, plan0.owner)
    results["cut_baseline"] = cut0

    for n_stream in STREAM_BATCHES:
        g = dataset_like(cfg, seed=0)
        g.add_edges(rng.integers(0, g.num_nodes, n_stream),
                    rng.integers(0, g.num_nodes, n_stream))
        cut_drifted = assignment_cut_fraction(g, plan0.owner)
        results["drift"][n_stream] = {
            "cut_fraction": cut_drifted,
            "drift": cut_drifted - cut0,
        }
        emit(f"dynamic/drift_e{n_stream}", 0.0,
             f"cut={cut_drifted:.4f} (+{cut_drifted - cut0:.4f})")

        t0 = time.perf_counter()
        res = incremental_rebalance(g, plan0)
        t_inc = time.perf_counter() - t0
        t0 = time.perf_counter()
        fresh = plan_partitions(g, PARTS, "locality", seed=0)
        t_full = time.perf_counter() - t0
        fresh_cut = assignment_cut_fraction(g, fresh.owner)
        results["rebalance"][n_stream] = {
            "moved_nodes": res.moved_nodes,
            "moved_frac": res.moved_frac,
            "cut_before": res.cut_before,
            "cut_after": res.cut_after,
            "cut_fresh": fresh_cut,
            "cut_vs_fresh": res.cut_after / max(fresh_cut, 1e-12),
            "incremental_s": t_inc,
            "full_repartition_s": t_full,
            "speedup": t_full / max(t_inc, 1e-12),
            "meets_envelope": bool(res.moved_frac < 0.25
                                   and res.cut_after <= fresh_cut * 1.10),
        }
        emit(f"dynamic/rebalance_e{n_stream}", t_inc * 1e6,
             f"moved={res.moved_frac:.3f} cut {res.cut_before:.4f}->"
             f"{res.cut_after:.4f} (fresh {fresh_cut:.4f}) "
             f"{t_full / max(t_inc, 1e-12):.1f}x faster than full")

    # overlay mechanics: merge build vs. compaction fold
    g = dataset_like(cfg, seed=0)
    n_mut = STREAM_BATCHES[-1]
    t0 = time.perf_counter()
    g.add_edges(rng.integers(0, g.num_nodes, n_mut),
                rng.integers(0, g.num_nodes, n_mut))
    t_mutate = time.perf_counter() - t0
    t0 = time.perf_counter()
    g.adj()                                     # first merged-view build
    t_merge = time.perf_counter() - t0
    t0 = time.perf_counter()
    g.adj()                                     # memoized
    t_memo = time.perf_counter() - t0
    t0 = time.perf_counter()
    g.compact()
    t_compact = time.perf_counter() - t0
    results["overlay"] = {
        "mutations": n_mut,
        "mutate_s": t_mutate,
        "merge_s": t_merge,
        "memoized_s": t_memo,
        "compact_s": t_compact,
    }
    emit(f"dynamic/overlay_m{n_mut}", t_merge * 1e6,
         f"mutate={t_mutate*1e3:.1f}ms merge={t_merge*1e3:.1f}ms "
         f"memoized={t_memo*1e6:.0f}us compact={t_compact*1e3:.1f}ms")

    save_json("fig_dynamic", results)
    return results
