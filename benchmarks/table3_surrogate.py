"""Tab. III reproduction: surrogate R² for throughput & memory prediction on
reddit/yelp/products twins + PPO-vs-grid exploration efficiency (the 2.1×
claim).  Ground truth comes from REAL pipeline profiling runs."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json, bench_gnn_cfg
from repro.core.a3gnn import run_config
from repro.core.autotune.space import Space
from repro.core.autotune.surrogate import Surrogate
from repro.core.autotune.ppo import PPOAgent, PPOConfig
from repro.core.autotune.pareto import grid_search
from repro.graph.synthetic import dataset_like

STEPS = 6


def profile_dataset(ds: str, n_samples: int, seed=0):
    """Ground-truth profiling: run real configs, record (X, metrics)."""
    cfg0 = bench_gnn_cfg(ds)
    graph = dataset_like(cfg0, seed=0)
    sp = Space()
    rng = np.random.default_rng(seed)
    X, thr, mem, acc = [], [], [], []
    for u in sp.sample(rng, n_samples):
        knobs = sp.decode(u)
        cfg = cfg0.replace(
            batch_size=min(knobs["batch_size"], 512),
            bias_rate=knobs["bias_rate"],
            workers=min(knobs["workers"], 4),
            cache_volume_mb=min(knobs["cache_volume_mb"], 16.0),
            parallel_mode=knobs["parallel_mode"])
        r = run_config(graph, cfg, max_steps=STEPS, warmup_steps=2,
                       simulate=True)
        X.append(u)
        thr.append(r.modeled_steps_s)
        mem.append(r.memory_bytes)
        acc.append(r.test_acc)
    return (np.array(X), {"throughput": np.array(thr),
                          "memory": np.array(mem),
                          "accuracy": np.array(acc)})


def run(quick: bool = False):
    results = {}
    datasets = ["products"] if quick else ["reddit", "yelp", "products"]
    n = 24 if quick else 48
    for ds in datasets:
        X, Y = profile_dataset(ds, n)
        k = int(0.75 * len(X))
        s = Surrogate(n_trees=40).fit(X[:k], {m: v[:k] for m, v in Y.items()})
        r2 = s.r2(X[k:], {m: v[k:] for m, v in Y.items()})
        results[ds] = {"r2": r2, "n_profiles": n}
        emit(f"table3/{ds}", 0.0,
             f"r2_thr={r2['throughput']:.3f};r2_mem={r2['memory']:.3f};"
             f"r2_acc={r2['accuracy']:.3f}")

    # ---- PPO vs grid on the fitted surrogate (paper: 2.1× faster) ----
    ds = datasets[-1]
    X, Y = profile_dataset(ds, n)
    sur = Surrogate(n_trees=40).fit(X, Y)
    sp = Space()

    def evaluate(cfg):
        u = sp.encode(cfg)[None]
        p = sur.predict(u)
        return {k: float(v[0]) for k, v in p.items()}

    w = {"throughput": 1.0, "memory": 1e-9, "accuracy": 0.5}
    agent = PPOAgent(sp, evaluate, w, lambda m: True,
                     PPOConfig(updates=24, horizon=8, seed=0))
    t0 = time.perf_counter()
    agent.run()
    t_ppo = time.perf_counter() - t0
    t0 = time.perf_counter()
    _, grid_best, grid_evals, _ = grid_search(sp, evaluate, agent.reward,
                                              points_per_dim=3)
    t_grid = time.perf_counter() - t0
    to_match = next((i + 1 for i, (_, m, r) in enumerate(agent.history)
                     if r >= grid_best * 0.9), None)
    ratio = (grid_evals / to_match) if to_match else 0.0
    results["ppo_vs_grid"] = {
        "ppo_best": agent.best_reward, "grid_best": grid_best,
        "ppo_evals": agent.evals, "grid_evals": grid_evals,
        "ppo_evals_to_0.9grid": to_match, "explore_speedup": ratio,
        "t_ppo_s": t_ppo, "t_grid_s": t_grid}
    emit("table3/ppo_vs_grid", t_ppo * 1e6,
         f"explore_speedup={ratio:.1f}x;ppo_best={agent.best_reward:.3f};"
         f"grid_best={grid_best:.3f}")
    save_json("table3", results)
    return results
