"""Benchmark driver — one module per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only table2,fig7]

Emits ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = ["table2", "fig6", "fig7", "fig8", "scaleout", "halo", "gather",
          "serve", "faults", "dynamic", "table3", "table4", "kernels",
          "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced datasets/configs (CI)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(SUITES))
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(SUITES)

    from benchmarks import (table2_training, fig6_scalability, fig7_sampling,
                            fig8_parallelism, fig_scaleout, fig_halo,
                            fig_gather, fig_serve, fig_faults, fig_dynamic,
                            table3_surrogate, table4_autotune, kernels_bench,
                            roofline)
    mods = {"table2": table2_training, "fig6": fig6_scalability,
            "fig7": fig7_sampling, "fig8": fig8_parallelism,
            "scaleout": fig_scaleout, "halo": fig_halo,
            "gather": fig_gather, "serve": fig_serve,
            "faults": fig_faults, "dynamic": fig_dynamic,
            "table3": table3_surrogate, "table4": table4_autotune,
            "kernels": kernels_bench, "roofline": roofline}

    print("name,us_per_call,derived")
    failures = []
    for name in SUITES:
        if name not in only:
            continue
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            mods[name].run(quick=args.quick)
        except Exception:  # noqa: BLE001 — run every suite
            failures.append(name)
            traceback.print_exc()
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if failures:
        print(f"# FAILED suites: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
