#!/usr/bin/env bash
# Tuned host runtime for wall-clock perf runs (SNIPPETS §3 idioms).
#
# Wraps any command with the host-level tuning a real CPU-GPU training
# box would ship with:
#
#   * tcmalloc preloaded (LD_PRELOAD) when the library is installed —
#     the gather/scatter hot path is allocation-heavy and glibc malloc's
#     central free-list lock serializes the pipeline's worker threads.
#     TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD is raised so feature-table
#     sized allocations don't spam stderr mid-benchmark.
#   * XLA host flags pinned: one host platform device, so jit dispatch
#     cost is not skewed by device-count probing between runs.
#
# Every knob degrades gracefully: a container without tcmalloc runs the
# command untuned (and core/autotune/controller.tuned_runtime_status()
# reports which knobs were live, so wall-clock MEASURE numbers are
# comparable only against numbers taken under the same runtime).
#
# Usage:  bash scripts/env_tuned.sh <command> [args...]
#   e.g.  bash scripts/env_tuned.sh python -m benchmarks.run --only gather
set -eu

if [ "$#" -eq 0 ]; then
    echo "usage: $0 <command> [args...]" >&2
    exit 2
fi

# -- tcmalloc preload (probe common install paths; skip when absent) ------
for _cand in /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
             /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
             /usr/lib/libtcmalloc_minimal.so.4 \
             /usr/lib/libtcmalloc.so; do
    if [ -e "${_cand}" ]; then
        export LD_PRELOAD="${_cand}${LD_PRELOAD:+:${LD_PRELOAD}}"
        # feature tables are legitimately large; don't report them
        export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=17179869184
        break
    fi
done

# -- XLA host platform: exactly one device, stable dispatch cost ----------
export XLA_FLAGS="--xla_force_host_platform_device_count=1${XLA_FLAGS:+ ${XLA_FLAGS}}"

exec "$@"
