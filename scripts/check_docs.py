#!/usr/bin/env python
"""Docs anchor checker — offline-safe, stdlib-only (like lint_fallback.py).

Every backticked ``path/to/module.py:symbol`` anchor in the docs tree
(recursively auto-discovered — ``docs/**/*.md`` — plus README.md) must
resolve: the path exists relative to the repo root and the symbol occurs
in that file as a word. Bare backticked ``*.py`` / ``*.md`` / ``*.sh``
paths are checked for existence. This keeps the docs' module map from
silently drifting as code moves.

The default run also requires every discovered doc to be LINKED from
README.md's documentation index — a new doc used to be checkable but
findable by nobody; now an unreferenced ``docs/*.md`` fails the lane.

    python scripts/check_docs.py [docs_dir ...]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

# `path/to/file.py:symbol` — the path must contain a slash, so prose
# placeholders like a backticked "file.py:symbol" never match
ANCHOR_RE = re.compile(r"`((?:[\w.-]+/)+[\w.-]+\.py):([A-Za-z_]\w*)`")
# bare backticked paths: slashed ones must exist; slash-less ones (e.g.
# `ROADMAP.md`, but also generic placeholders) are checked only if they
# resolve from the repo root, otherwise treated as prose
PATH_RE = re.compile(r"`([\w./-]+\.(?:py|md|sh|yml|toml))`")


def check_doc(doc: Path):
    """Returns (problems, anchor_count) for one markdown file."""
    text = doc.read_text()
    problems = []
    anchors = 0
    for m in ANCHOR_RE.finditer(text):
        anchors += 1
        rel, symbol = m.group(1), m.group(2)
        target = ROOT / rel
        if not target.is_file():
            problems.append(f"{doc.name}: `{rel}:{symbol}` — no such file")
            continue
        if not re.search(rf"\b{re.escape(symbol)}\b", target.read_text()):
            problems.append(f"{doc.name}: `{rel}:{symbol}` — symbol not "
                            f"found in {rel}")
    for m in PATH_RE.finditer(text):
        rel = m.group(1)
        if "/" not in rel and not (ROOT / rel).is_file():
            continue                   # slash-less prose placeholder
        if not (ROOT / rel).is_file():
            problems.append(f"{doc.name}: `{rel}` — no such file")
    return problems, anchors


def main(argv):
    dirs = [Path(a) for a in argv] or [ROOT / "docs"]
    # recursive auto-discovery: a doc added anywhere under docs/ (or a
    # passed dir) is checked without touching this script or the CI lane
    docs = [p for d in dirs for p in sorted(d.rglob("*.md"))]
    readme = ROOT / "README.md"
    if readme.is_file() and readme not in docs:
        docs.append(readme)
    if not docs:
        print("check_docs: no markdown files found", file=sys.stderr)
        return 2
    problems = []
    anchors = 0
    for doc in docs:
        doc_problems, doc_anchors = check_doc(doc)
        problems.extend(doc_problems)
        anchors += doc_anchors
    # README index guard (default run only): every discovered doc must be
    # reachable from README.md, so a new doc cannot land unreferenced
    if not argv and readme.is_file():
        readme_text = readme.read_text()
        for doc in docs:
            if doc == readme:
                continue
            rel = doc.relative_to(ROOT).as_posix()
            if rel not in readme_text:
                problems.append(f"{doc.name}: `{rel}` not linked from "
                                f"README.md's documentation index")
    for p in problems:
        print(f"check_docs: {p}", file=sys.stderr)
    print(f"check_docs: {len(docs)} docs, {anchors} code anchors, "
          f"{len(problems)} broken")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
