#!/usr/bin/env bash
# CI entry point — used by .github/workflows/ci.yml and runnable locally.
#
#     scripts/ci.sh [lint|docs|kernels|fast|full|all]     (default: all)
#
# Lanes:
#   lint:  `ruff check src tests benchmarks` (config in pyproject.toml);
#          falls back to scripts/lint_fallback.py (same rule subset) on
#          hosts without ruff, so the lane is meaningful offline.
#   docs:  scripts/check_docs.py — every `path.py:symbol` code anchor in
#          the auto-discovered docs tree (docs/**/*.md + README.md) must
#          resolve, and every doc must be linked from README.md
#          (offline-safe, stdlib).  Runs in lane 1 (the fast job)
#          alongside the fast tests.
#   kernels: the Pallas kernel oracles (fused gather+aggregate and the
#          per-hop neighbor_agg families included) + the all-hop fused
#          pipeline sweeps in tests/test_fused_agg.py (fused-vs-unfused
#          parity for graphsage/gcn/gat/gin on host+device planes,
#          single- and multi-partition, one-jit-signature dispatch
#          counters, and the small-batch µs/row regression guard)
#          + the FeaturePlane host/device parity tests (incremental
#          mirror sync) + the streaming-update mirror re-sync tests —
#          the focused signal for accelerator-path changes
#          (also part of the fast job, as its own JUnit artifact).
#   fast:  everything except tests marked `slow` — the sub-minute signal
#          for every push; this is where the serving-engine tests
#          (tests/test_gnn_serve.py), the serving-fabric tests
#          (tests/test_fabric.py — ServingEngine conformance, partition
#          routing, replica weight refresh, SLO shedding; the saturation
#          sweep is `slow`-marked and runs in `full`), the cross-host
#          chaos harness (tests/test_transport_faults.py — transport-seam
#          conformance, kill/delay/drop fault schedules on a VirtualClock,
#          conservation + bit-exactness + recovery + determinism; the
#          peak-load p99 and severity-sweep cases are `slow`-marked), the
#          SLO admission property tests (tests/test_slo_properties.py)
#          and the dynamic-graph differential harness
#          (tests/test_dynamic_graph.py — delta-CSR overlay vs. compacted
#          sampling parity, incremental re-balance, topology-consistent
#          serving; the long interleaving sweep is `slow`-marked) run.
#          The CI fast job does NOT install `hypothesis`, keeping the
#          tests/_hypothesis_compat.py shim path covered.  The kernel/plane/streaming files are
#          skipped here (the kernels lane owns them) so the fast job
#          never runs the interpret-mode Pallas sweeps twice; `full`
#          still runs everything in one invocation.
#   full:  the tier-1 command from ROADMAP.md, including the slow
#          pipeline/system tests.  This is the merge bar.
#
# Every lane writes artifacts/ (JUnit XML per pytest lane + a cumulative
# timing.csv of per-lane wall-clock), uploaded by the workflow so test-
# runtime regressions are visible PR-over-PR.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

LANE="${1:-all}"
ART="artifacts"
mkdir -p "$ART"
[ -f "$ART/timing.csv" ] || echo "lane,seconds" > "$ART/timing.csv"

run_lane() {  # run_lane <name> <cmd...>
    local name="$1"; shift
    echo "=== lane: $name ==="
    local t0 t1
    t0=$(date +%s.%N)
    "$@"
    t1=$(date +%s.%N)
    awk -v n="$name" -v a="$t0" -v b="$t1" \
        'BEGIN { printf "%s,%.2f\n", n, b - a }' >> "$ART/timing.csv"
    awk -v a="$t0" -v b="$t1" \
        'BEGIN { printf "=== lane %s done in %.1fs ===\n", "'"$name"'", b - a }'
}

lint_cmd() {
    if python -m ruff --version >/dev/null 2>&1; then
        python -m ruff check src tests benchmarks
    else
        echo "(ruff unavailable — offline fallback, same rule subset)"
        python scripts/lint_fallback.py src tests benchmarks
    fi
}

case "$LANE" in
    lint)
        run_lane lint lint_cmd ;;
    docs)
        run_lane docs python scripts/check_docs.py ;;
    kernels)
        run_lane kernels python -m pytest -x -q \
            tests/test_kernels.py tests/test_fused_agg.py \
            tests/test_feature_plane.py tests/test_streaming.py \
            --junitxml "$ART/junit_kernels.xml" ;;
    fast)
        run_lane fast python -m pytest -x -q -m "not slow" \
            --ignore tests/test_kernels.py \
            --ignore tests/test_fused_agg.py \
            --ignore tests/test_feature_plane.py \
            --ignore tests/test_streaming.py \
            --junitxml "$ART/junit_fast.xml" ;;
    full)
        run_lane full python -m pytest -x -q \
            --junitxml "$ART/junit_full.xml" ;;
    all)
        run_lane lint lint_cmd
        run_lane docs python scripts/check_docs.py
        run_lane kernels python -m pytest -x -q \
            tests/test_kernels.py tests/test_fused_agg.py \
            tests/test_feature_plane.py tests/test_streaming.py \
            --junitxml "$ART/junit_kernels.xml"
        run_lane fast python -m pytest -x -q -m "not slow" \
            --ignore tests/test_kernels.py \
            --ignore tests/test_fused_agg.py \
            --ignore tests/test_feature_plane.py \
            --ignore tests/test_streaming.py \
            --junitxml "$ART/junit_fast.xml"
        run_lane full python -m pytest -x -q \
            --junitxml "$ART/junit_full.xml" ;;
    *)
        echo "usage: scripts/ci.sh [lint|docs|kernels|fast|full|all]" >&2
        exit 2 ;;
esac
echo "--- $ART/timing.csv ---"
cat "$ART/timing.csv"
