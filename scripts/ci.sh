#!/usr/bin/env bash
# CI entry point.
#
# Lane 1 (fast):  everything except tests marked `slow` — the
#                 sub-minute signal for every push.
# Lane 2 (full):  the tier-1 command from ROADMAP.md, including the slow
#                 pipeline/system tests.  This is the merge bar.
#
# Optional test extra: `hypothesis` enables real property-based search in
# test_autotune/test_cache/test_kernels/test_sampling; without it the
# deterministic fallback in tests/_hypothesis_compat.py runs a fixed-case
# sweep, so CI works offline either way.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== lane 1: fast (-m 'not slow') ==="
python -m pytest -x -q -m "not slow"

echo "=== lane 2: full tier-1 ==="
python -m pytest -x -q
