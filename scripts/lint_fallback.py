#!/usr/bin/env python
"""Offline lint fallback for environments without ruff.

Mirrors the rule subset committed in pyproject.toml ([tool.ruff.lint]):
E9 (syntax errors), F401 (unused imports; __init__.py re-exports exempt)
and F811 (redefinition of a top-level def/class by another def/class).
CI installs real ruff; this keeps `scripts/ci.sh lint` meaningful on
air-gapped hosts.

    python scripts/lint_fallback.py src tests benchmarks examples
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path


def _imported_names(node):
    if isinstance(node, ast.Import):
        for a in node.names:
            yield (a.asname or a.name.split(".")[0]), node.lineno
    elif isinstance(node, ast.ImportFrom) and node.module != "__future__":
        for a in node.names:
            if a.name != "*":
                yield (a.asname or a.name), node.lineno


def check_file(path: Path):
    src = path.read_text()
    try:
        tree = ast.parse(src, filename=str(path))
    except SyntaxError as e:
        return [(e.lineno or 0, f"E999 syntax error: {e.msg}")]
    lines = src.splitlines()
    problems = []
    imports = {}
    for node in ast.walk(tree):
        for name, lineno in _imported_names(node):
            if "noqa" in lines[lineno - 1]:       # ruff-style suppression
                continue
            imports.setdefault(name, lineno)
    used = {n.id for n in ast.walk(tree) if isinstance(n, ast.Name)}
    used |= {n.attr for n in ast.walk(tree) if isinstance(n, ast.Attribute)}
    # names referenced inside string annotations / __all__ exports count
    for node in ast.walk(tree):
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            used.add(node.value)
    if path.name != "__init__.py":
        for name, lineno in sorted(imports.items(), key=lambda kv: kv[1]):
            if name not in used:
                problems.append((lineno, f"F401 `{name}` imported but unused"))
    toplevel = {}
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            if node.name in toplevel:
                problems.append((node.lineno,
                                 f"F811 redefinition of `{node.name}` "
                                 f"(first defined line {toplevel[node.name]})"))
            toplevel[node.name] = node.lineno
    return problems


def main(argv):
    roots = [Path(p) for p in (argv or ["src", "tests", "benchmarks"])]
    failed = 0
    for root in roots:
        files = [root] if root.is_file() else sorted(root.rglob("*.py"))
        for f in files:
            for lineno, msg in check_file(f):
                print(f"{f}:{lineno}: {msg}")
                failed += 1
    if failed:
        print(f"lint_fallback: {failed} problem(s)")
        return 1
    print(f"lint_fallback: clean ({', '.join(str(r) for r in roots)})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
