"""MoE layer: capacity semantics, padding masks, dense-equivalence oracle."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.moe import decls_moe, moe_mlp, capacity
from repro.models import layers as L
from repro.models.params import init_params

RNG = np.random.default_rng(5)


def _cfg(**kw):
    return get_config("qwen2-moe-a2.7b", smoke=True).replace(
        compute_dtype="float32", **kw)


def test_single_expert_equals_dense_mlp():
    """E=1, top-1, ample capacity ⇒ MoE == plain SwiGLU with that expert."""
    cfg = _cfg(num_experts=1, num_experts_padded=1, moe_top_k=1,
               capacity_factor=8.0, shared_expert_ff=0)
    p = init_params(decls_moe(cfg), jax.random.PRNGKey(0))
    x = jnp.asarray(RNG.normal(0, 1, (2, 8, cfg.d_model)), jnp.float32)
    y, aux = moe_mlp(p, x, cfg)
    dense_p = {"w_gate": p["w_gate"][0], "w_up": p["w_up"][0],
               "w_down": p["w_down"][0]}
    y_ref = L.mlp(dense_p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5,
                               rtol=1e-4)


def test_padded_experts_never_selected():
    cfg = _cfg()     # 6 real, padded to 8
    p = init_params(decls_moe(cfg), jax.random.PRNGKey(1))
    x = jnp.asarray(RNG.normal(0, 1, (4, 16, cfg.d_model)), jnp.float32)
    # recompute routing exactly as the layer does
    xt = x.reshape(1, -1, cfg.d_model)
    logits = jnp.einsum("ntd,de->nte", xt, p["router"])
    E = cfg.num_experts_padded
    logits = jnp.where(jnp.arange(E)[None, None] < cfg.num_experts, logits,
                       -1e30)
    _, topi = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.moe_top_k)
    assert int(jnp.max(topi)) < cfg.num_experts


def test_capacity_drop_keeps_residual_path_shape():
    cfg = _cfg(capacity_factor=0.1)      # aggressive drops
    p = init_params(decls_moe(cfg), jax.random.PRNGKey(2))
    x = jnp.asarray(RNG.normal(0, 1, (2, 32, cfg.d_model)), jnp.float32)
    y, aux = moe_mlp(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) >= 0.0


def test_capacity_formula():
    cfg = _cfg(capacity_factor=1.25)
    c = capacity(cfg, 1024)
    expect = int(1.25 * cfg.moe_top_k * 1024 / cfg.num_experts_padded)
    assert c >= expect and c % 8 == 0
    assert capacity(cfg, 4) <= 8           # tiny shards clamp


def test_aux_loss_balanced_vs_skewed():
    """Uniform routing gives aux ≈ 1; collapsed routing gives aux ≈ E/K·me0."""
    cfg = _cfg()
    p = init_params(decls_moe(cfg), jax.random.PRNGKey(3))
    # balanced: zero router → uniform probs → aux = 1 exactly
    p_bal = dict(p, router=jnp.zeros_like(p["router"]))
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(0, 1, (2, 64, cfg.d_model)), jnp.float32)
    _, aux_bal = moe_mlp(p_bal, x, cfg)
    assert np.isclose(float(aux_bal), 1.0, atol=1e-3)
    # collapsed: positive activations + one-hot router column ⇒ every token's
    # top-1 is expert 0
    x_pos = jnp.abs(x) + 0.1
    router_skew = jnp.zeros_like(p["router"]).at[:, 0].set(100.0)
    _, aux_skew = moe_mlp(dict(p, router=router_skew), x_pos, cfg)
    assert float(aux_skew) > float(aux_bal) * 1.5


def test_shared_expert_contributes():
    cfg = _cfg()
    p = init_params(decls_moe(cfg), jax.random.PRNGKey(4))
    x = jnp.asarray(RNG.normal(0, 1, (1, 8, cfg.d_model)), jnp.float32)
    y1, _ = moe_mlp(p, x, cfg)
    p0 = dict(p, shared=jax.tree.map(jnp.zeros_like, p["shared"]))
    y0, _ = moe_mlp(p0, x, cfg)
    assert not np.allclose(np.asarray(y1), np.asarray(y0))
