"""Mamba2 SSD: chunked algorithm vs naive recurrence oracle; decode step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import ssd_chunked, mamba2_block, mamba2_decode, ssm_dims
from repro.models.ssm import decls_mamba2
from repro.models.params import init_params
from repro.configs import get_config

RNG = np.random.default_rng(3)


def ssd_naive(x, dt, A, Bm, Cm):
    """Token-by-token linear recurrence (the definition)."""
    Bsz, S, nh, P = x.shape
    N = Bm.shape[-1]
    state = np.zeros((Bsz, nh, P, N), np.float64)
    ys = []
    x64, dt64 = np.asarray(x, np.float64), np.asarray(dt, np.float64)
    A64, B64, C64 = (np.asarray(A, np.float64), np.asarray(Bm, np.float64),
                     np.asarray(Cm, np.float64))
    for t in range(S):
        dA = np.exp(dt64[:, t] * A64[None, :])                   # (B,nh)
        dBx = np.einsum("bh,bhp,bn->bhpn", dt64[:, t], x64[:, t], B64[:, t])
        state = state * dA[..., None, None] + dBx
        ys.append(np.einsum("bhpn,bn->bhp", state, C64[:, t]))
    return np.stack(ys, 1), state


@pytest.mark.parametrize("S,chunk", [(32, 8), (64, 16), (24, 24), (16, 4)])
def test_ssd_chunked_matches_naive(S, chunk):
    Bsz, nh, P, N = 2, 3, 4, 8
    x = jnp.asarray(RNG.normal(0, 1, (Bsz, S, nh, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (Bsz, S, nh)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, nh), jnp.float32)
    Bm = jnp.asarray(RNG.normal(0, 1, (Bsz, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(0, 1, (Bsz, S, N)), jnp.float32)
    y, fstate = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    y_ref, state_ref = ssd_naive(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y, np.float32), y_ref, atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(fstate), state_ref, atol=1e-4,
                               rtol=1e-4)


def test_ssd_chunk_invariance():
    """Same result regardless of chunk size (associativity of the scan)."""
    Bsz, S, nh, P, N = 1, 48, 2, 4, 6
    x = jnp.asarray(RNG.normal(0, 1, (Bsz, S, nh, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (Bsz, S, nh)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, nh), jnp.float32)
    Bm = jnp.asarray(RNG.normal(0, 1, (Bsz, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(0, 1, (Bsz, S, N)), jnp.float32)
    y1, _ = ssd_chunked(x, dt, A, Bm, Cm, 8)
    y2, _ = ssd_chunked(x, dt, A, Bm, Cm, 16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-4)


def test_mamba_decode_matches_block():
    """Step-by-step decode == full-sequence block output at each position."""
    cfg = get_config("mamba2-1.3b", smoke=True).replace(
        compute_dtype="float32")
    p = init_params(decls_mamba2(cfg), jax.random.PRNGKey(0))
    B, S = 2, 10
    h = jnp.asarray(RNG.normal(0, 0.5, (B, S, cfg.d_model)), jnp.float32)
    full = mamba2_block(p, h, cfg)

    d_inner, nheads, N, conv_dim = ssm_dims(cfg)
    cache = {"ssm": jnp.zeros((B, nheads, cfg.ssm_head_dim, N), jnp.float32),
             "conv": jnp.zeros((B, cfg.ssm_conv_width - 1, conv_dim),
                               jnp.float32)}
    outs = []
    for t in range(S):
        y, cache = mamba2_decode(p, h[:, t:t + 1], cfg, cache)
        outs.append(y[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-4,
                               rtol=2e-3)
