"""Locality-aware sampling: Algo. 2 oracle vs vectorized ES, bias effects,
property-based invariants."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.sampling import (reservoir_sample_ref, es_sample,
                                 NeighborSampler, seed_loader)
from repro.core.cache import FeatureCache
from repro.core.locality import bias_weight_fn


def test_reservoir_returns_all_when_small():
    rng = np.random.default_rng(0)
    nb = np.arange(5)
    w = np.ones(5)
    out = reservoir_sample_ref(nb, w, 10, rng)
    assert set(out) == set(nb)
    out = es_sample(nb, w, 10, rng)
    assert set(out) == set(nb)


@given(n=st.integers(6, 60), m=st.integers(1, 5), seed=st.integers(0, 999))
@settings(max_examples=30, deadline=None)
def test_sample_size_and_uniqueness(n, m, seed):
    rng = np.random.default_rng(seed)
    nb = np.arange(n) * 3
    w = rng.uniform(0.5, 5.0, n)
    for fn in (reservoir_sample_ref, es_sample):
        out = fn(nb, w, m, np.random.default_rng(seed))
        assert len(out) == m
        assert len(set(out.tolist())) == m          # no duplicates
        assert set(out.tolist()) <= set(nb.tolist())


def test_reservoir_and_es_same_distribution():
    """Both implement Efraimidis–Spirakis: selection frequencies match."""
    nb = np.arange(8)
    w = np.array([4.0, 4.0, 1, 1, 1, 1, 1, 1])
    m, trials = 2, 4000
    counts = {"ref": np.zeros(8), "es": np.zeros(8)}
    rng1, rng2 = np.random.default_rng(1), np.random.default_rng(2)
    for _ in range(trials):
        for key, fn, rng in (("ref", reservoir_sample_ref, rng1),
                             ("es", es_sample, rng2)):
            out = fn(nb, w, m, rng)
            counts[key][out] += 1
    f_ref = counts["ref"] / (trials * m)
    f_es = counts["es"] / (trials * m)
    # the two implementations agree within sampling noise
    np.testing.assert_allclose(f_ref, f_es, atol=0.03)
    # heavy nodes selected more often
    assert f_es[:2].mean() > 2.0 * f_es[2:].mean()


def test_bias_increases_cached_selection(smoke_graph):
    """γ > 1 must raise the fraction of sampled neighbors that are cached —
    the paper's core mechanism (Fig. 2b / Fig. 7)."""
    cache = FeatureCache(smoke_graph, volume_mb=0.05, policy="static")
    frac = {}
    for gamma in (1.0, 8.0):
        wfn = bias_weight_fn(cache, gamma)
        s = NeighborSampler(smoke_graph, (10,), weight_fn=wfn, seed=3)
        seeds = np.arange(200)
        mb = s.sample(seeds)
        picked = mb.blocks[0].src_ids
        frac[gamma] = cache.is_cached(picked).mean()
    assert frac[8.0] > frac[1.0]


def test_gamma_one_equals_uniform(smoke_graph):
    """γ=1 reverts to plain random sampling (same RNG → same picks)."""
    cache = FeatureCache(smoke_graph, volume_mb=0.05, policy="static")
    wfn = bias_weight_fn(cache, 1.0)
    s1 = NeighborSampler(smoke_graph, (5, 5), weight_fn=wfn, seed=7)
    s2 = NeighborSampler(smoke_graph, (5, 5), weight_fn=None, seed=7)
    seeds = np.arange(64)
    b1, b2 = s1.sample(seeds), s2.sample(seeds)
    for blk1, blk2 in zip(b1.blocks, b2.blocks):
        assert np.array_equal(blk1.src_ids, blk2.src_ids)
        assert np.array_equal(blk1.neigh_idx, blk2.neigh_idx)


def test_blocks_wellformed(smoke_graph):
    s = NeighborSampler(smoke_graph, (5, 3), seed=0)
    seeds = np.arange(32)
    mb = s.sample(seeds)
    assert len(mb.blocks) == 2
    # output hop: dst == seeds
    assert np.array_equal(mb.blocks[-1].dst_ids, seeds)
    for blk in mb.blocks:
        # dst ids form the prefix of src ids
        assert np.array_equal(blk.src_ids[:len(blk.dst_ids)], blk.dst_ids)
        # neighbor indices inside range
        v = blk.neigh_idx[blk.neigh_idx >= 0]
        assert v.size == 0 or v.max() < len(blk.src_ids)
        # sampled ids resolve to actual graph neighbors
        for i in range(min(5, len(blk.dst_ids))):
            nbrs = set(smoke_graph.neighbors(blk.dst_ids[i]).tolist())
            got = blk.neigh_idx[i][blk.neigh_idx[i] >= 0]
            assert set(blk.src_ids[got].tolist()) <= nbrs
    # chain: hop i src == hop i-1 ... (blocks input-first)
    for a, b in zip(mb.blocks[:-1], mb.blocks[1:]):
        assert np.array_equal(a.dst_ids, b.src_ids)


def test_seed_loader_partitions_train_nodes(smoke_graph):
    batches = list(seed_loader(smoke_graph, 64, seed=0))
    allv = np.concatenate(batches)
    assert len(np.unique(allv)) == len(allv)          # no repeats
    assert smoke_graph.train_mask[allv].all()
