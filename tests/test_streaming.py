"""Streaming feature updates: FeatureStore.update_rows → FeatureCache
version bump → DeviceFeaturePlane mirror re-sync → bounded periodic halo
re-fill, with updated rows observed bit-exactly on cpu AND device planes."""
import numpy as np
import pytest

from repro.core.a3gnn import A3GNNTrainer
from repro.core.cache import FeatureCache
from repro.core.feature_plane import DeviceFeaturePlane, HostFeaturePlane
from repro.core.multipart import MultiPartitionTrainer
from repro.graph.storage import FeatureStore


def _fresh_graph(seed=0):
    """Streaming tests mutate features — never share the session fixture."""
    from repro.configs.gnn import gnn_config
    from repro.graph.synthetic import dataset_like
    return dataset_like(gnn_config("products", smoke=True), seed=seed)


def _smoke_cfg():
    from repro.configs.gnn import gnn_config
    return gnn_config("products", smoke=True)


# ---------------------------------------------------------------------------
# store → cache → mirror invalidation chain
# ---------------------------------------------------------------------------

def test_update_rows_bumps_versions_and_resyncs_mirror():
    graph = _fresh_graph()
    host = HostFeaturePlane(graph, FeatureCache(graph, 0.05, "static"))
    dev = DeviceFeaturePlane(graph, FeatureCache(graph, 0.05, "static"))
    store = FeatureStore(graph)
    host.subscribe_to(store)
    dev.subscribe_to(store)

    resident = int(np.where(dev.cache.device_map >= 0)[0][0])
    absent = int(np.where(dev.cache.device_map < 0)[0][0])
    ids = np.array([resident, absent])
    host.fetch(ids)
    dev.fetch(ids)                          # forces a device mirror upload
    mirror_v = dev._version
    cache_v = dev.cache.version

    rows = np.stack([np.full(graph.feat_dim, 1.5, np.float32),
                     np.full(graph.feat_dim, -3.0, np.float32)])
    assert store.update_rows(ids, rows) == 1
    assert store.rows_updated == 2
    # the resident row invalidates the device mirror through the version
    assert dev.cache.version > cache_v
    assert host.cache.version == dev.cache.version  # same chain on both
    # both planes serve the updated rows bit-exactly (resident AND missed)
    np.testing.assert_array_equal(host.fetch(ids), rows)
    np.testing.assert_array_equal(dev.fetch(ids), rows)
    assert dev._version > mirror_v                  # mirror re-uploaded


def test_update_rows_validates_shape():
    graph = _fresh_graph()
    store = FeatureStore(graph)
    with pytest.raises(ValueError):
        store.update_rows(np.array([0, 1]),
                          np.zeros((2, graph.feat_dim + 1), np.float32))


def test_cache_refresh_rows_pull_side():
    """refresh_rows is the pull twin of fill_rows: a consumer that only
    learns WHICH rows moved re-copies them from the store."""
    graph = _fresh_graph()
    cache = FeatureCache(graph, 0.05, "static")
    resident = int(np.where(cache.device_map >= 0)[0][0])
    absent = int(np.where(cache.device_map < 0)[0][0])
    graph.features[resident] = 7.25                 # direct store write
    graph.features[absent] = 7.25
    v = cache.version
    assert cache.refresh_rows(np.array([resident, absent])) == 1
    assert cache.version == v + 1
    np.testing.assert_array_equal(cache.fetch(np.array([resident]))[0],
                                  graph.features[resident])
    # no resident rows → no version churn (mirrors must not re-upload)
    assert cache.refresh_rows(np.array([absent])) == 0
    assert cache.version == v + 1


# ---------------------------------------------------------------------------
# multi-partition: owned routing + bounded periodic halo re-fill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sampling_device", ["cpu", "device"])
def test_multipart_stream_update_and_halo_refresh(sampling_device):
    """update_rows routes owned rows into the owner's plane immediately;
    the stale halo copy on the other partition catches up at the periodic
    refresh boundary, bit-exactly, on both backends."""
    cfg = _smoke_cfg().replace(partitions=2, halo_budget=32,
                               halo_refresh_interval=2,
                               sampling_device=sampling_device)
    graph = _fresh_graph()
    tr = MultiPartitionTrainer(graph, cfg, seed=0)
    try:
        store = tr.attach_feature_store()
        assert tr.feature_store is store
        # a halo node of partition 1 that partition 0 owns
        node = next(int(c) for c in tr.plan.halo_sets[1]
                    if tr.plan.owner[c] == 0)
        loc0 = tr._local_id(0, node)
        loc1 = tr._local_id(1, node)
        assert 0 <= loc0 < tr.slots[0].n_owned <= loc1

        rows = np.full((1, graph.feat_dim), 9.5, np.float32)
        store.update_rows(np.array([node]), rows)
        # owner partition observes the row NOW, through its plane
        np.testing.assert_array_equal(
            tr.slots[0].pipe.plane.fetch(np.array([loc0])), rows)
        # partition 1's halo copy is stale until the bounded refresh
        assert not np.array_equal(tr.slots[1].graph.features[loc1], rows[0])
        assert tr._halo_dirty

        tr.global_step()                     # step 1: interval not reached
        assert tr.halo_refreshes == 0
        tr.global_step()                     # step 2: refresh fires
        assert tr.halo_refreshes == 1 and not tr._halo_dirty
        np.testing.assert_array_equal(
            tr.slots[1].pipe.plane.fetch(np.array([loc1])), rows)

        # quiescent stores don't trigger refreshes
        tr.global_step()
        tr.global_step()
        assert tr.halo_refreshes == 1
    finally:
        for s in tr.slots:
            s.pipe.shutdown()


def test_multipart_refresh_is_explicit_without_interval():
    """interval=0: stale halo rows wait for refresh_halo_features()."""
    cfg = _smoke_cfg().replace(partitions=2, halo_budget=16)
    graph = _fresh_graph()
    tr = MultiPartitionTrainer(graph, cfg, seed=0)
    try:
        store = tr.attach_feature_store()
        node = next(int(c) for c in tr.plan.halo_sets[1]
                    if tr.plan.owner[c] == 0)
        loc1 = tr._local_id(1, node)
        rows = np.full((1, graph.feat_dim), -4.5, np.float32)
        store.update_rows(np.array([node]), rows)
        tr.global_step()
        assert tr.halo_refreshes == 0 and tr._halo_dirty
        volume = tr.refresh_halo_features()
        assert volume == tr.plan.exchange_volume_bytes(graph) > 0
        np.testing.assert_array_equal(tr.slots[1].graph.features[loc1],
                                      rows[0])
    finally:
        for s in tr.slots:
            s.pipe.shutdown()


def test_plane_tracks_at_most_one_store_subscription():
    """Repeated subscribe_to must not leave un-removable stale
    subscriptions: a plane tracks exactly one store, and re-subscribing
    moves it."""
    graph = _fresh_graph()
    plane = HostFeaturePlane(graph, FeatureCache(graph, 0.05, "static"))
    s1, s2 = FeatureStore(graph), FeatureStore(graph)
    plane.subscribe_to(s1)
    plane.subscribe_to(s1)                       # idempotent, not doubled
    assert len(s1._subscribers) == 1
    plane.subscribe_to(s2)                       # moves the subscription
    assert len(s1._subscribers) == 0 and len(s2._subscribers) == 1
    assert plane.store is s2
    plane.detach_store()
    assert len(s2._subscribers) == 0 and plane.store is None


def test_plane_swap_migrates_store_subscription():
    """Pipeline.reconfigure replaces the plane object (cache swap or
    cpu↔device migration); an attached store must follow the LIVE plane
    — the dead plane unsubscribes, the successor observes updates."""
    from repro.core.pipeline import Pipeline
    graph = _fresh_graph()
    cfg = _smoke_cfg().replace(cache_volume_mb=0.0)     # start cacheless
    tr = A3GNNTrainer(graph, cfg, seed=0)
    pipe = Pipeline(graph, cfg, tr._train_fn, cache=None, seed=0)
    try:
        store = FeatureStore(graph)
        old_plane = pipe.plane.subscribe_to(store)
        new_cache = FeatureCache(graph, 0.05, "static")
        pipe.reconfigure(cache=new_cache)               # plane rebuilt
        assert pipe.plane is not old_plane
        assert old_plane.store is None                  # dead plane detached
        assert pipe.plane.store is store                # successor attached
        assert len(store._subscribers) == 1             # exactly one writer
        resident = int(np.where(new_cache.device_map >= 0)[0][0])
        rows = np.full((1, graph.feat_dim), 8.5, np.float32)
        store.update_rows(np.array([resident]), rows)
        np.testing.assert_array_equal(
            pipe.plane.fetch(np.array([resident])), rows)
    finally:
        pipe.shutdown()


def test_single_partition_attach_refreshes_cache_and_detach_stops():
    graph = _fresh_graph()
    tr = A3GNNTrainer(graph, _smoke_cfg(), seed=0)
    store = tr.attach_feature_store()
    node = int(np.where(tr.cache.device_map >= 0)[0][0])
    rows = np.full((1, graph.feat_dim), 5.5, np.float32)
    v = tr.cache.version
    store.update_rows(np.array([node]), rows)
    assert tr.cache.version > v                  # resident copy refreshed
    np.testing.assert_array_equal(tr.cache.fetch(np.array([node])), rows)
    tr.detach_feature_store()
    assert tr.feature_store is None
    store.update_rows(np.array([node]),
                      np.full((1, graph.feat_dim), -1.0, np.float32))
    # detached: the resident copy intentionally no longer tracks the store
    np.testing.assert_array_equal(tr.cache.fetch(np.array([node])), rows)
    # a worker-partition trainer has no global view to subscribe
    tr2 = A3GNNTrainer(graph, _smoke_cfg().replace(partitions=2), seed=0)
    with pytest.raises(ValueError):
        tr2.attach_feature_store()


def test_partitions_restart_migrates_feature_store():
    """The autotune restart path re-homes an attached store: the dead
    trainer detaches, the rebuilt trainer observes subsequent updates."""
    from repro.configs.gnn import AutotuneConfig
    from repro.core.autotune.controller import AutotuneController
    cfg = _smoke_cfg().replace(partitions=2, halo_budget=8)
    graph = _fresh_graph()
    tr = MultiPartitionTrainer(graph, cfg, seed=0)
    ctrl = AutotuneController(tr, tr.make_pipeline(),
                              AutotuneConfig(max_partitions=2, seed=0))
    store = tr.attach_feature_store()
    try:
        ctrl._restart(1)                         # rebuild single-partition
        new_tr = ctrl.tr
        assert new_tr is not tr
        assert tr.feature_store is None          # old trainer detached
        assert new_tr.feature_store is store     # same store, new consumer
        node = int(np.where(new_tr.cache.device_map >= 0)[0][0])
        rows = np.full((1, graph.feat_dim), 6.5, np.float32)
        store.update_rows(np.array([node]), rows)
        np.testing.assert_array_equal(new_tr.cache.fetch(np.array([node])),
                                      rows)
    finally:
        ctrl.pipe.shutdown()


def test_multipart_update_of_unowned_halo_free_node_is_local():
    """An update touching no halo copy must not mark the fleet dirty."""
    cfg = _smoke_cfg().replace(partitions=2, halo_budget=8)
    graph = _fresh_graph()
    tr = MultiPartitionTrainer(graph, cfg, seed=0)
    try:
        store = tr.attach_feature_store()
        in_halo = np.zeros(graph.num_nodes, bool)
        for hs in tr.plan.halo_sets:
            in_halo[hs] = True
        node = int(np.where(~in_halo)[0][0])
        store.update_rows(np.array([node]),
                          np.full((1, graph.feat_dim), 2.0, np.float32))
        assert not tr._halo_dirty
        p = int(tr.plan.owner[node])
        loc = tr._local_id(p, node)
        np.testing.assert_array_equal(
            tr.slots[p].pipe.plane.fetch(np.array([loc]))[0],
            np.full(graph.feat_dim, 2.0, np.float32))
    finally:
        for s in tr.slots:
            s.pipe.shutdown()


# ---------------------------------------------------------------------------
# subscriber lifecycle edges (dynamic-graph PR regressions)
# ---------------------------------------------------------------------------

def test_detach_during_fanout_skips_the_detached_subscriber():
    """A subscriber that detaches ANOTHER subscriber mid-fanout (a teardown
    callback replacing a plane) must prevent delivery to the dead one —
    update_rows re-checks membership per subscriber."""
    graph = _fresh_graph()
    store = FeatureStore(graph)
    calls = []

    def late(ids, rows):
        calls.append("late")

    def early(ids, rows):
        calls.append("early")
        store.unsubscribe(late)          # tears its sibling down mid-fanout

    store.subscribe(early)
    store.subscribe(late)
    store.update_rows(np.array([0]),
                      np.zeros((1, graph.feat_dim), np.float32))
    assert calls == ["early"]            # late never ran
    # self-detach mid-fanout is equally safe, and later subscribers run
    calls.clear()

    def selfish(ids, rows):
        calls.append("selfish")
        store.unsubscribe(selfish)

    store.subscribe(selfish)
    store.update_rows(np.array([0]),
                      np.zeros((1, graph.feat_dim), np.float32))
    assert calls == ["early", "selfish"]
    store.update_rows(np.array([0]),
                      np.zeros((1, graph.feat_dim), np.float32))
    assert calls == ["early", "selfish", "early"]   # selfish stayed gone


@pytest.mark.parametrize("plane_cls", [HostFeaturePlane, DeviceFeaturePlane])
def test_update_of_rows_outside_subscribed_plane_universe_is_noop(plane_cls):
    """A plane over a SUBGRAPH subscribed to a full-graph store: streamed
    ids outside the subgraph's node universe have no copy there — the
    fanout must drop them (no IndexError), and in-universe ids in the
    same batch still land."""
    full = _fresh_graph()
    sub = full.subgraph(np.arange(64, dtype=np.int32))
    plane = plane_cls(sub, FeatureCache(sub, 0.05, "static"))
    store = FeatureStore(full)
    plane.subscribe_to(store)
    resident = int(np.where(plane.cache.device_map >= 0)[0][0])
    plane.fetch(np.array([resident]))
    outside = full.num_nodes - 1
    rows = np.stack([np.full(full.feat_dim, 9.0, np.float32),
                     np.full(full.feat_dim, -4.0, np.float32)])
    store.update_rows(np.array([resident, outside]), rows)   # must not raise
    np.testing.assert_array_equal(plane.fetch(np.array([resident]))[0],
                                  rows[0])
    # an all-outside batch is a clean no-op too
    v = plane.cache.version
    store.update_rows(np.array([outside]), rows[1:])
    assert plane.cache.version == v
