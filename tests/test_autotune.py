"""Auto-tuning: design space, surrogate R², PPO vs grid, Pareto props."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.autotune.space import Space
from repro.core.autotune.surrogate import Surrogate, GBDT, Ridge, r2_score
from repro.core.autotune.ppo import PPOAgent, PPOConfig, VIOLATION_REWARD
from repro.core.autotune.pareto import (pareto_front, select_endpoints,
                                        grid_search)


# ---------------------------------------------------------------------------
# Space
# ---------------------------------------------------------------------------

@given(st.lists(st.floats(0, 1), min_size=7, max_size=7))
@settings(max_examples=40, deadline=None)
def test_space_decode_in_range(u):
    sp = Space()
    cfg = sp.decode(np.array(u))
    assert 64 <= cfg["batch_size"] <= 1024
    assert 1.0 <= cfg["bias_rate"] <= 16.0
    assert cfg["parallel_mode"] in ("seq", "mode1", "mode2")
    assert cfg["sampling_device"] in ("cpu", "device")


def test_space_encode_decode_roundtrip():
    sp = Space()
    rng = np.random.default_rng(0)
    for u in sp.sample(rng, 20):
        cfg = sp.decode(u)
        u2 = sp.encode(cfg)
        cfg2 = sp.decode(u2)
        assert cfg == cfg2


# ---------------------------------------------------------------------------
# Surrogate
# ---------------------------------------------------------------------------

def _synthetic_perf(u):
    """Ground-truth-ish response surface for surrogate tests."""
    thr = 0.1 + 0.5 * u[:, 0] + 0.8 * u[:, 4] * u[:, 6] + 0.2 * u[:, 2]
    mem = 50e6 * (1 + 3 * u[:, 4] * (u[:, 6] > 0.33) + 2 * u[:, 5] + u[:, 0])
    acc = 0.75 - 0.05 * u[:, 2] ** 2 + 0.01 * u[:, 5]
    return {"throughput": thr, "memory": mem, "accuracy": acc}


def test_surrogate_r2_reasonable():
    """Tab. III analogue: R² comfortably above chance on held-out data."""
    rng = np.random.default_rng(0)
    sp = Space()
    Xtr, Xte = sp.sample(rng, 400), sp.sample(rng, 100)
    noise = lambda n: rng.normal(0, 0.01, n)
    Ytr = _synthetic_perf(Xtr)
    Ytr = {k: v * (1 + 0.02 * rng.normal(size=len(v))) for k, v in Ytr.items()}
    Yte = _synthetic_perf(Xte)
    s = Surrogate(n_trees=40).fit(Xtr, Ytr)
    r2 = s.r2(Xte, Yte)
    assert r2["throughput"] > 0.6
    assert r2["memory"] > 0.6
    assert r2["accuracy"] > 0.5


def test_gbdt_beats_linear_on_nonlinear():
    rng = np.random.default_rng(1)
    X = rng.random((300, 4))
    y = np.sin(6 * X[:, 0]) + (X[:, 1] > 0.5) * 2 + X[:, 2] * X[:, 3]
    Xte = rng.random((100, 4))
    yte = np.sin(6 * Xte[:, 0]) + (Xte[:, 1] > 0.5) * 2 + Xte[:, 2] * Xte[:, 3]
    g = GBDT(n_trees=60).fit(X, y)
    l = Ridge().fit(X, y)
    assert r2_score(yte, g.predict(Xte)) > r2_score(yte, l.predict(Xte))
    assert r2_score(yte, g.predict(Xte)) > 0.7


# ---------------------------------------------------------------------------
# Pareto
# ---------------------------------------------------------------------------

def test_pareto_front_definition():
    pts = np.array([[1, 1], [2, 0.5], [0.5, 2], [0.9, 0.9], [2, 2]])
    idx = set(pareto_front(pts))
    assert idx == {4}                      # (2,2) dominates everything
    pts2 = np.array([[1, 0], [0, 1], [0.5, 0.5]])
    assert set(pareto_front(pts2)) == {0, 1, 2}


@given(st.integers(10, 60), st.integers(0, 99))
@settings(max_examples=20, deadline=None)
def test_pareto_no_dominated_points(n, seed):
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 3))
    idx = pareto_front(pts)
    front = pts[idx]
    for i, p in enumerate(front):
        dom = np.all(front >= p, axis=1) & np.any(front > p, axis=1)
        assert not dom.any()


def test_select_endpoints():
    hist = []
    for thr, mem, acc in [(1.0, 100.0, 0.7), (0.2, 10.0, 0.75),
                          (0.6, 50.0, 0.72), (0.1, 90.0, 0.5)]:
        hist.append(({"thr": thr}, {"throughput": thr, "memory": mem,
                                    "accuracy": acc}, 0.0))
    ep = select_endpoints(hist)
    assert ep["T*"][1]["throughput"] == 1.0
    assert ep["M*"][1]["memory"] == 10.0


# ---------------------------------------------------------------------------
# PPO (Algo. 3)
# ---------------------------------------------------------------------------

def _make_agent(w=None, constraint=None, updates=6):
    sp = Space()

    def evaluate(cfg):
        u = sp.encode(cfg)[None]
        m = _synthetic_perf(u)
        return {k: float(v[0]) for k, v in m.items()}

    w = w or {"throughput": 1.0, "memory": 1e-9, "accuracy": 0.5}
    constraint = constraint or (lambda m: True)
    return PPOAgent(sp, evaluate, w, constraint,
                    PPOConfig(updates=updates, horizon=8, seed=0)), sp, evaluate


def test_ppo_improves_over_random():
    agent, sp, evaluate = _make_agent(updates=32)
    best = agent.run()
    assert best is not None
    # PPO's incumbent beats the 90th percentile of a 200-point random sweep
    rng = np.random.default_rng(0)
    rand = sorted(agent.reward(evaluate(sp.decode(u)))
                  for u in sp.sample(rng, 200))
    assert agent.best_reward >= rand[int(0.9 * len(rand))]


def test_ppo_respects_constraints():
    """Algo. 3 line 7-8: constraint violations get the -inf reward and are
    never selected as the recommendation."""
    limit = 150e6
    agent, sp, evaluate = _make_agent(
        constraint=lambda m: m["memory"] < limit, updates=6)
    best = agent.run()
    assert evaluate(best)["memory"] < limit
    viol = [r for _, m, r in agent.history if m["memory"] >= limit]
    assert all(r == VIOLATION_REWARD for r in viol)


def test_ppo_faster_than_grid():
    """The paper's 2.1× exploration-efficiency claim, measured as
    evaluations needed to reach (near-)grid-best reward."""
    agent, sp, evaluate = _make_agent(updates=32)
    agent.run()
    reward = lambda m: agent.reward(m)
    _, grid_best, grid_evals, _ = grid_search(sp, evaluate, reward,
                                              points_per_dim=3)
    to_match = None
    for i, (_, m, r) in enumerate(agent.history):
        if r >= grid_best * 0.9:
            to_match = i + 1
            break
    assert to_match is not None, \
        f"PPO never reached 0.9×grid ({agent.best_reward} vs {grid_best})"
    assert to_match < grid_evals / 2, (to_match, grid_evals)
