"""GNN serving engine: continuous batching over the FeaturePlane —
admission/eviction, train→serve plane sharing, cpu/device parity, and
streaming feature updates reflected in predictions (the acceptance bar)."""
from collections import deque

import numpy as np
import pytest

from repro.core.a3gnn import A3GNNTrainer
from repro.core.cache import FeatureCache
from repro.core.feature_plane import (DeviceFeaturePlane, HostFeaturePlane,
                                      make_feature_plane)
from repro.graph.storage import FeatureStore
from repro.serve.common import admit_pending, latency_stats
from repro.serve.gnn_engine import GNNInferenceEngine, GNNRequest


def _fresh_graph(seed=0):
    """Function-local graph: streaming tests mutate features, so they
    must not share the session-scoped fixture."""
    from repro.configs.gnn import gnn_config
    from repro.graph.synthetic import dataset_like
    return dataset_like(gnn_config("products", smoke=True), seed=seed)


# ---------------------------------------------------------------------------
# continuous batching: admission, completion, slot recycling
# ---------------------------------------------------------------------------

def test_engine_completes_all_requests(smoke_graph, smoke_gnn_cfg):
    tr = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
    eng = GNNInferenceEngine.from_trainer(tr, batch=3, seed=0)
    rng = np.random.default_rng(0)
    nodes = rng.integers(0, smoke_graph.num_nodes, 8)   # > slots
    for rid, v in enumerate(nodes):
        eng.submit(GNNRequest(rid=rid, node=int(v)))
    stats = eng.run_to_completion()
    assert stats["completed"] == 8
    assert eng.free_slots() == [0, 1, 2]                # all slots recycled
    assert eng.utilization() == 0.0
    assert stats["engine_steps"] >= 3                   # 8 queries / 3 slots
    for req in eng.completed:
        assert 0 <= req.pred < smoke_graph.num_classes
        assert req.logits.shape == (smoke_graph.num_classes,)
        assert req.t_done >= req.t_submit
    assert stats["p99_ms"] >= stats["p50_ms"] > 0.0


def test_engine_duplicate_nodes_stay_fifo(smoke_graph, smoke_gnn_cfg):
    """Seeds must be unique per step: same-node queries serialize across
    engine iterations instead of corrupting the sampled batch."""
    tr = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
    eng = GNNInferenceEngine.from_trainer(tr, batch=4, seed=0)
    for rid in range(5):
        eng.submit(GNNRequest(rid=rid, node=17))
    stats = eng.run_to_completion()
    assert stats["completed"] == 5
    assert stats["engine_steps"] == 5                   # one per duplicate
    rids = [r.rid for r in eng.completed]
    assert rids == sorted(rids)                         # FIFO preserved
    # (predictions may differ across duplicates — each engine step samples
    # the node's neighborhood afresh, by design)


def test_engine_rejects_bad_node_and_oversized_batch(smoke_graph,
                                                     smoke_gnn_cfg):
    tr = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
    eng = GNNInferenceEngine.from_trainer(tr, batch=2, seed=0)
    with pytest.raises(ValueError):
        eng.submit(GNNRequest(rid=0, node=smoke_graph.num_nodes))
    with pytest.raises(ValueError):
        GNNInferenceEngine.from_trainer(tr,
                                        batch=smoke_graph.num_nodes + 1)


def test_engine_bounds_completed_history(smoke_graph, smoke_gnn_cfg):
    """Online serving must not grow per-query state forever: the retained
    result history is capped while the per-call stats stay correct."""
    tr = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
    eng = GNNInferenceEngine(smoke_graph, smoke_gnn_cfg, tr.params,
                             batch=2, seed=0, keep_completed=3)
    for rid in range(7):
        eng.submit(GNNRequest(rid=rid, node=rid + 50))
    stats = eng.run_to_completion()
    assert stats["completed"] == 7 and eng.total_completed == 7
    assert len(eng.completed) == 3                      # bounded history
    assert [r.rid for r in eng.completed] == [4, 5, 6]  # most recent kept
    assert stats["p50_ms"] > 0.0                        # window still sane


def test_admission_seam_shared_semantics():
    """The serve/common.py helper keeps the pre-seam engine semantics:
    FIFO order, head-of-line blocking on an unplaceable request.  The
    queue is a deque (O(1) head pop) — semantics unchanged."""
    pending = deque(["a", "b", "c"])
    running = {}
    slots = [0, 1]
    admitted = admit_pending(pending, running,
                             lambda r: slots.pop(0) if slots else None)
    assert admitted == 2 and list(pending) == ["c"]
    assert running == {0: "a", 1: "b"}
    # no capacity → head blocks, nothing admitted
    assert admit_pending(pending, running, lambda r: None) == 0
    assert list(pending) == ["c"]
    assert latency_stats([]).p50_ms == 0.0     # typed, zeroed empty window


def test_admission_order_is_submission_order():
    """Requests admitted across multiple admission rounds retire in the
    exact submission order — the deque swap must not perturb FIFO."""
    pending = deque(range(10))
    running = {}
    order = []
    free = deque(range(3))

    def alloc(r):
        return free.popleft() if free else None

    def on_admit(req, slot):
        order.append(req)

    while pending:
        want = min(3, len(pending))
        n = admit_pending(pending, running, alloc, on_admit)
        assert n == len(running) == want
        for slot in sorted(running):             # retire the whole wave
            free.append(slot)
        running.clear()
    assert order == list(range(10))
    assert admit_pending(pending, running, alloc) == 0   # empty queue no-op


# ---------------------------------------------------------------------------
# the FeaturePlane is SHARED between training and serving
# ---------------------------------------------------------------------------

def test_serving_through_the_trainer_plane_shares_stats(smoke_graph,
                                                        smoke_gnn_cfg):
    """Acceptance: the engine serves through the same FeaturePlane
    instance the trainer's pipeline built — one accounting stream."""
    tr = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
    pipe = tr.make_pipeline()
    try:
        pipe.run(max_steps=2)
        trained_hits = tr.cache.stats.hits
        assert trained_hits > 0
        eng = GNNInferenceEngine.from_trainer(tr, batch=4, plane=pipe.plane,
                                              seed=0)
        assert eng.plane is pipe.plane                  # the instance, not a copy
        for rid in range(6):
            eng.submit(GNNRequest(rid=rid, node=rid + 100))
        stats = eng.run_to_completion()
        assert stats["completed"] == 6
        # serving pushed the trainer's own hit/miss accounting forward
        assert tr.cache.stats.hits > trained_hits
        assert stats["cache_hit_rate"] == tr.cache.stats.hit_rate
    finally:
        pipe.shutdown()


@pytest.mark.parametrize("policy", ["static", "fifo"])
def test_serving_cpu_device_parity(smoke_graph, smoke_gnn_cfg, policy):
    """Same request stream, same sampler seed: the host and device planes
    produce bit-exact logits, identical predictions, identical stats."""
    cfg = smoke_gnn_cfg.replace(cache_policy=policy)
    tr = A3GNNTrainer(smoke_graph, cfg, seed=0)
    planes = (HostFeaturePlane(smoke_graph,
                               FeatureCache(smoke_graph, 0.05, policy)),
              DeviceFeaturePlane(smoke_graph,
                                 FeatureCache(smoke_graph, 0.05, policy)))
    engines = [GNNInferenceEngine(smoke_graph, cfg, tr.params, plane=p,
                                  batch=3, seed=7) for p in planes]
    rng = np.random.default_rng(1)
    nodes = rng.integers(0, smoke_graph.num_nodes, 7)
    for eng in engines:
        for rid, v in enumerate(nodes):
            eng.submit(GNNRequest(rid=rid, node=int(v)))
        eng.run_to_completion()
    host_eng, dev_eng = engines
    for a, b in zip(host_eng.completed, dev_eng.completed):
        assert a.rid == b.rid and a.pred == b.pred
        assert np.array_equal(a.logits, b.logits)       # bit-exact
    sh, sd = planes[0].cache.stats, planes[1].cache.stats
    assert (sh.hits, sh.misses) == (sd.hits, sd.misses)


# ---------------------------------------------------------------------------
# streaming updates mid-serving (the acceptance bar)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sampling_device", ["cpu", "device"])
def test_stream_update_reflected_in_predictions(smoke_gnn_cfg,
                                                sampling_device):
    """A FeatureStore update made mid-serving is observed bit-exactly by
    the live plane and reflected in subsequent predictions, on both
    backends.  Controlled by a twin engine with identical seeds that
    receives NO update: its second query isolates the drift effect from
    sampler-RNG advancement."""
    cfg = smoke_gnn_cfg.replace(sampling_device=sampling_device)

    def build():
        graph = _fresh_graph()              # identical content per seed
        tr = A3GNNTrainer(graph, cfg, seed=0)
        plane = make_feature_plane(graph, tr.cache, sampling_device)
        eng = GNNInferenceEngine(graph, cfg, tr.params, plane=plane,
                                 batch=2, seed=11)
        return graph, tr, eng

    graph_u, tr_u, updated = build()
    graph_c, _, control = build()
    # serve a cache-RESIDENT node before the update (forces a mirror sync)
    node = int(np.where(tr_u.cache.device_map >= 0)[0][0])
    for eng in (updated, control):
        eng.submit(GNNRequest(rid=0, node=node))
        eng.run_to_completion()
    assert np.array_equal(updated.completed[0].logits,
                          control.completed[0].logits)   # twins agree

    store = FeatureStore(graph_u)
    updated.plane.subscribe_to(store)
    rows = np.full((1, graph_u.feat_dim), 4.25, np.float32)
    v_cache = tr_u.cache.version
    store.update_rows(np.array([node]), rows)
    assert store.version == 1
    assert tr_u.cache.version > v_cache      # resident copy → mirrors re-sync
    # the plane serves the updated row bit-exactly (this IS the feature
    # the next sampled batch gathers for the seed)
    np.testing.assert_array_equal(updated.plane.fetch(np.array([node])),
                                  rows)
    np.testing.assert_array_equal(
        control.plane.fetch(np.array([node])), graph_c.features[[node]])

    for eng in (updated, control):
        eng.submit(GNNRequest(rid=1, node=node))
        eng.run_to_completion()
    # same RNG sequence, same params — ONLY the streamed row differs,
    # so diverging logits prove the prediction consumed the drift
    assert not np.array_equal(updated.completed[1].logits,
                              control.completed[1].logits)


def test_stream_update_parity_across_backends(smoke_gnn_cfg):
    """Post-update predictions agree bit-exactly between cpu and device
    engines driven with the same seed."""
    results = []
    for dev in ("cpu", "device"):
        graph = _fresh_graph()
        cfg = smoke_gnn_cfg.replace(sampling_device=dev)
        tr = A3GNNTrainer(graph, cfg, seed=0)
        plane = make_feature_plane(graph, tr.cache, dev)
        eng = GNNInferenceEngine(graph, cfg, tr.params, plane=plane,
                                 batch=2, seed=3)
        store = FeatureStore(graph)
        eng.plane.subscribe_to(store)
        node = int(np.where(tr.cache.device_map >= 0)[0][1])
        store.update_rows(np.array([node]),
                          np.full((1, graph.feat_dim), -2.5, np.float32))
        eng.submit(GNNRequest(rid=0, node=node))
        eng.run_to_completion()
        results.append(eng.completed[0])
    assert results[0].pred == results[1].pred
    assert np.array_equal(results[0].logits, results[1].logits)
