"""Multi-device tests (subprocess with 8 forced host devices): sharding
rules, pipeline parallelism, flash-decoding combine, compressed psum,
cost-analysis calibration."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from jax.sharding import PartitionSpec as P

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_py(code: str, devices: int = 8, timeout: int = 360) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


# ---------------------------------------------------------------------------
# sharding rules (no subprocess needed)
# ---------------------------------------------------------------------------

def test_rules_divisibility(smoke_graph):
    import jax
    from repro.distributed.sharding import make_rules, enforce_divisible
    from repro.configs import get_config
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        axis_names = ("data", "model")

        class devices:
            shape = (16, 16)
    fm = FakeMesh()

    cfg = get_config("minitron-8b")     # kv=8 not divisible by 16
    rules = make_rules(cfg, fm)
    assert rules["tp_kv"] is None and rules["qheads"] == "model"
    cfg2 = get_config("qwen2-moe-a2.7b")  # kv=16 divisible
    rules2 = make_rules(cfg2, fm)
    assert rules2["tp_kv"] == "model"
    cfg3 = get_config("mamba2-1.3b")    # vocab 50280 not divisible
    assert make_rules(cfg3, fm)["vocab"] is None
    # enforce_divisible drops bad dims
    sp = enforce_divisible(P("model", "data"), (51865, 1024), fm)
    assert sp == P(None, "data")


def test_physical_specs_all_archs_divide():
    """Every resolved param sharding divides its dim on the 16×16 mesh."""
    from repro.distributed.sharding import physical_specs, _axis_size
    from repro.configs import get_config, list_archs
    from repro.models.api import build
    from repro.models.params import ParamDecl
    import jax

    class FakeMesh:
        axis_names = ("data", "model")

        class devices:
            shape = (16, 16)
    fm = FakeMesh()
    for arch in [a for a in list_archs() if not a.startswith("graphsage")]:
        cfg = get_config(arch)
        model = build(cfg)
        specs = physical_specs(model.decls, cfg, fm)
        decls_flat = jax.tree.leaves(model.decls,
                                     is_leaf=lambda x: isinstance(x, ParamDecl))
        specs_flat = jax.tree.leaves(specs,
                                     is_leaf=lambda x: isinstance(x, P))
        for d, s in zip(decls_flat, specs_flat):
            for i, dim in enumerate(d.shape):
                ax = s[i] if i < len(s) else None
                assert dim % _axis_size(fm, ax) == 0, (arch, d.shape, s)


# ---------------------------------------------------------------------------
# subprocess multi-device
# ---------------------------------------------------------------------------

def test_cost_analysis_known_matmul():
    """Calibrate: per-device flops of a sharded matmul == 2MNK/devices."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        mesh = jax.make_mesh((8,), ("d",))
        M = N = K = 512
        f = jax.jit(lambda a, b: a @ b,
                    in_shardings=(NamedSharding(mesh, P("d", None)),
                                  NamedSharding(mesh, P(None, None))),
                    out_shardings=NamedSharding(mesh, P("d", None)))
        import numpy as np
        from repro.launch.xla_compat import cost_analysis_dict
        c = f.lower(jax.ShapeDtypeStruct((M, K), jnp.float32),
                    jax.ShapeDtypeStruct((K, N), jnp.float32)).compile()
        ca = cost_analysis_dict(c)
        assert ca, "backend produced no cost analysis"
        fl = ca["flops"]
        want = 2 * M * N * K / 8
        assert abs(fl - want) / want < 0.05, (fl, want)
        print("CALIBRATED", fl, want)
    """)
    assert "CALIBRATED" in out


def test_collective_parse_known_psum():
    """Collective-bytes parser sees the all-reduce of a known psum."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.dryrun import parse_collectives
        mesh = jax.make_mesh((8,), ("d",))
        f = jax.jit(lambda a: a.sum(axis=0),
                    in_shardings=NamedSharding(mesh, P("d", None)),
                    out_shardings=NamedSharding(mesh, P()))
        c = f.lower(jax.ShapeDtypeStruct((8, 1024), jnp.float32)).compile()
        per_op, total = parse_collectives(c.as_text())
        assert "all-reduce" in per_op, per_op
        # result is (1024,) f32 → 4096 B × factor 2
        assert per_op["all-reduce"]["bytes"] >= 8192, per_op
        print("PARSED", json.dumps(per_op))
        """.replace("import jax,", "import json, jax,"))
    assert "PARSED" in out


def test_flash_decode_shardmap_matches_ref():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.collectives import flash_decode_attention
        mesh = jax.make_mesh((8,), ("model",))
        B, T, H, Dh = 2, 64, 4, 32
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(0, 1, (B, H, Dh)), jnp.float32)
        k = jnp.asarray(rng.normal(0, 1, (B, T, H, Dh)), jnp.float32)
        v = jnp.asarray(rng.normal(0, 1, (B, T, H, Dh)), jnp.float32)
        pos = jnp.asarray([17, 63], jnp.int32)
        fn = jax.jit(flash_decode_attention(mesh, "model"))
        o = fn(q, k, v, pos)
        # reference: full attention with causal-position mask
        s = jnp.einsum("bhe,bthe->bht", q, k)
        mask = jnp.arange(T)[None, :] <= pos[:, None]
        s = jnp.where(mask[:, None, :], s, -1e30)
        p = jax.nn.softmax(s, -1)
        ref = jnp.einsum("bht,bthe->bhe", p, v)
        np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                                   atol=2e-5, rtol=1e-4)
        print("FLASH_DECODE_OK")
    """)
    assert "FLASH_DECODE_OK" in out


def test_compressed_psum_shardmap():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.train.compression import compressed_psum_int8
        mesh = jax.make_mesh((8,), ("pod",))
        x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (8, 128)),
                        jnp.float32)
        fn = shard_map(lambda t: compressed_psum_int8(t, "pod"), mesh=mesh,
                       in_specs=P("pod", None), out_specs=P("pod", None),
                       check_rep=False)
        out = jax.jit(fn)(x)
        want = jnp.mean(x, axis=0)      # mean over the pod axis
        got = np.asarray(out)[0]
        err = np.abs(got - np.asarray(want)).max()
        scale = np.abs(np.asarray(x)).max() / 127
        assert err <= scale + 1e-5, (err, scale)
        print("CPSUM_OK", err)
    """)
    assert "CPSUM_OK" in out


def test_pipeline_parallel_matches_sequential():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pp import make_pipeline_fn, split_microbatches
        mesh = jax.make_mesh((4,), ("stage",))
        S, M, mb, D = 4, 8, 4, 16
        rng = np.random.default_rng(0)
        Ws = jnp.asarray(rng.normal(0, 0.5, (S, D, D)), jnp.float32)

        def layer_fn(w, x):
            return jnp.tanh(x @ w)

        pipe = make_pipeline_fn(lambda p, x: layer_fn(p, x), S, M, mesh)
        x = jnp.asarray(rng.normal(0, 1, (M * mb, D)), jnp.float32)
        xs = split_microbatches(x, M)
        got = jax.jit(pipe)(Ws, xs).reshape(M * mb, D)
        ref = x
        for s in range(S):
            ref = layer_fn(Ws[s], ref)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)
        print("PP_OK")
    """)
    assert "PP_OK" in out


@pytest.mark.slow
def test_dryrun_cell_tiny_mesh():
    """run_cell machinery works end-to-end on a small forced-device mesh
    (uses the real 256/512-device path in launch/dryrun.py; here we only
    validate the single-cell JSON plumbing on 512 devices but the smallest
    arch/shape)."""
    out = run_py("""
        from repro.launch.dryrun import run_cell
        res = run_cell("whisper-medium", "decode_32k", "single")
        assert not res.get("skipped") and "error" not in res, res
        assert res["cost"]["flops_per_device"] > 0
        assert res["memory"]["peak_device_bytes"] > 0
        assert res["cost"]["collective_bytes_per_device"] >= 0
        print("CELL_OK")
    """, devices=512, timeout=900)
    assert "CELL_OK" in out
