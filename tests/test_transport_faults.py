"""Chaos harness for the cross-host serving fabric (serve/transport.py).

Seeded fault schedules — kill a replica mid-burst, delay one host 10×,
drop a fraction of responses — driven through ``SimHostTransport`` on a
``VirtualClock``, asserting the invariants the fabric promises:

  * **conservation** — no admitted query is silently lost: every request
    ends served / shed / timed-out, explicitly, and the buckets sum to
    the offered count;
  * **bit-exactness** — predictions from surviving replicas equal a
    fault-free twin fabric's, bit for bit (load nodes come from a "calm
    pool" whose 2-hop frontier fits inside the fanout, so sampling never
    consumes randomness and a pred depends only on (node, params));
  * **graceful degradation** — served p99 stays bounded while shed
    fraction rises with injected fault severity;
  * **recovery** — a replica that comes back is probed after its
    cooldown and rejoins dispatch;
  * **determinism** — same seed + same fault schedule ⇒ the identical
    per-request (replica, status, pred) trace, twice.
"""
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.a3gnn import A3GNNTrainer
from repro.graph.partition import plan_partitions
from repro.serve.fabric import ServingFabric
from repro.serve.gnn_engine import GNNRequest
from repro.serve.transport import (FaultSpec, LoopbackTransport,
                                   ReplicaTransport, SimHostTransport,
                                   VirtualClock, sim_host_factory)

FANOUT = 64


@pytest.fixture(scope="module")
def env(smoke_graph):
    from repro.configs.gnn import gnn_config
    cfg = gnn_config("products", smoke=True).replace(fanout=(FANOUT, FANOUT))
    tr = A3GNNTrainer(smoke_graph, cfg, seed=0)
    # calm pool: seed AND every neighbor fit inside the fanout, so the
    # sampler's take-everything path runs (no rng draw) and a prediction
    # is a pure function of (node, params) — comparable across replicas,
    # retries and differently-batched runs
    indptr, indices = smoke_graph.adj()
    deg = np.diff(indptr)
    calm = np.array([deg[v] <= FANOUT and
                     (deg[indices[indptr[v]:indptr[v + 1]]].max(initial=0)
                      <= FANOUT)
                     for v in range(smoke_graph.num_nodes)])
    pool = np.where(calm)[0]
    assert len(pool) >= 400
    return SimpleNamespace(graph=smoke_graph, cfg=cfg, params=tr.params,
                           pool=pool)


def _sim_fabric(env, faults=None, base=None, parts=2, replicas=2, batch=4,
                seed=0, tick_s=1e-3, **kw):
    clock = VirtualClock(tick_s=tick_s)
    plan = plan_partitions(env.graph, parts, "locality", seed=0,
                           halo_budget=32)
    fab = ServingFabric.from_plan(
        env.graph, plan, env.cfg, env.params, batch=batch, replicas=replicas,
        seed=0,
        transport_factory=sim_host_factory(faults=faults, base=base,
                                           seed=seed),
        clock=clock, **kw)
    return plan, fab, clock


def _offer(fab, nodes, per_step=0, rid0=0):
    """Submit ``nodes`` (burst, or paced ``per_step`` per fabric step)
    and drain.  Returns the submitted rids."""
    rids = list(range(rid0, rid0 + len(nodes)))
    if per_step <= 0:
        for rid, v in zip(rids, nodes):
            fab.submit(GNNRequest(rid=rid, node=int(v)))
        fab.drain()
        return rids
    i = 0
    while i < len(nodes):
        for _ in range(per_step):
            if i >= len(nodes):
                break
            fab.submit(GNNRequest(rid=rids[i], node=int(nodes[i])))
            i += 1
        fab.step()
    fab.drain()
    return rids


def _buckets(fab):
    return ({r.rid: r for r in fab.completed},
            {r.rid: r for r in fab.shed_requests},
            {r.rid: r for r in fab.timeout_requests})


def _assert_conserved(fab, rids):
    """The no-silent-loss invariant: queues empty, the audit ledger
    balances, and every submitted rid sits in exactly one terminal
    bucket with the matching explicit status."""
    done, shed, tout = _buckets(fab)
    a = fab.audit()
    assert a["pending"] == 0 and a["inflight"] == 0
    assert a["offered"] == a["done"] + a["shed"] + a["timed_out"]
    assert a["offered"] == len(rids)
    for rid in rids:
        assert (rid in done) + (rid in shed) + (rid in tout) == 1
    assert all(r.status == "done" for r in done.values())
    assert all(r.status == "shed" and r.pred == -1 for r in shed.values())
    assert all(r.status == "timeout" and r.pred == -1
               for r in tout.values())
    return done, shed, tout


def _served_p99_ms(done):
    lat = [(r.t_done - r.t_submit) * 1e3 for r in done.values()]
    return float(np.percentile(lat, 99)) if lat else 0.0


# ---------------------------------------------------------------------------
# the seam itself
# ---------------------------------------------------------------------------

def test_transports_conform_to_protocol(env):
    _, fab, _ = _sim_fabric(env, parts=2, replicas=1)
    for t in fab.all_transports:
        assert isinstance(t, ReplicaTransport)
        assert isinstance(t, SimHostTransport)
    plan = plan_partitions(env.graph, 2, "locality", seed=0, halo_budget=32)
    fab2 = ServingFabric.from_plan(env.graph, plan, env.cfg, env.params,
                                   batch=2)
    for t in fab2.all_transports:
        assert isinstance(t, ReplicaTransport)
        assert isinstance(t, LoopbackTransport)


def test_clean_simhost_matches_loopback_preds(env):
    """A host boundary with zero modeled cost changes nothing observable
    but timing: same preds per rid as the default in-process fabric."""
    nodes = env.pool[:24]
    plan = plan_partitions(env.graph, 2, "locality", seed=0, halo_budget=32)
    loop = ServingFabric.from_plan(env.graph, plan, env.cfg, env.params,
                                   batch=4, replicas=2, seed=0)
    rids = _offer(loop, nodes)
    _, sim, _ = _sim_fabric(env)
    _offer(sim, nodes)
    done_l = {r.rid: r for r in loop.completed}
    done_s, _, _ = _assert_conserved(sim, rids)
    assert set(done_l) == set(done_s)
    for rid in rids:
        assert done_l[rid].pred == done_s[rid].pred
        assert np.array_equal(done_l[rid].logits, done_s[rid].logits)


# ---------------------------------------------------------------------------
# chaos: kill, delay, drop
# ---------------------------------------------------------------------------

def test_kill_replica_mid_burst_no_silent_loss(env):
    """Replica (0,0) dies after its 3rd delivered response, mid-burst.
    Its in-flight work times out, retries land on the surviving replica,
    and every request still ends in an explicit terminal state."""
    _, fab, _ = _sim_fabric(
        env, faults={(0, 0): FaultSpec(added_latency_ms=2,
                                       down_after_responses=3)},
        base=FaultSpec(added_latency_ms=2), timeout_ms=8)
    rids = _offer(fab, env.pool[:48], per_step=4)
    done, shed, tout = _assert_conserved(fab, rids)
    assert fab.replica_state[(0, 0)].state == "down"
    assert fab.fstats.timeouts > 0 and fab.fstats.retries > 0
    assert len(done) >= 40                       # survivors carried the load
    assert all(0 <= r.pred < env.graph.num_classes for r in done.values())
    snap = fab.fabric_stats()
    assert snap["replicas"]["0/0"]["health"] == "down"
    assert snap["replicas"]["0/0"]["lost_on_disconnect"] >= 0
    assert snap["timeouts"] == fab.fstats.timeouts


def test_slow_host_organically_drains(env):
    """One host 10× slower (20 ms vs 2 ms wire+service): the EWMA-
    weighted least-loaded dispatch routes the bulk of the load to the
    fast replica without any explicit weight configuration."""
    slow = FaultSpec(added_latency_ms=20.0)
    _, fab, _ = _sim_fabric(env, faults={(0, 1): slow, (1, 1): slow},
                            base=FaultSpec(added_latency_ms=2.0))
    rids = _offer(fab, env.pool[:90], per_step=3)
    _assert_conserved(fab, rids)
    for p in range(2):
        fast, slow_st = fab.replica_state[(p, 0)], fab.replica_state[(p, 1)]
        assert fast.completed >= 3 * max(slow_st.completed, 1)
        assert slow_st.state == "up"             # slow ≠ unhealthy
        if fast.ewma_ms is not None and slow_st.ewma_ms is not None:
            assert slow_st.ewma_ms > fast.ewma_ms


def test_dropped_responses_recovered_by_retry(env):
    """An 8% response-drop rate: the remote computed the answer but the
    fabric never saw it — timeouts fire, retries recover the requests,
    nothing is silently lost."""
    _, fab, _ = _sim_fabric(
        env, base=FaultSpec(drop_rate=0.08, added_latency_ms=2),
        timeout_ms=8, seed=3)
    rids = _offer(fab, env.pool[:80], per_step=4)
    done, shed, tout = _assert_conserved(fab, rids)
    dropped = sum(t.dropped_responses for t in fab.all_transports)
    assert dropped > 0
    assert fab.fstats.timeouts >= dropped        # every drop surfaced
    assert fab.fstats.retries > 0
    assert len(done) >= len(rids) - dropped      # retries recovered them


@pytest.mark.slow
def test_kill_at_peak_load_p99_bounded_and_bitexact(env):
    """The acceptance schedule: kill one replica at peak offered load,
    with SLO admission on.  Zero silently-lost requests; predictions
    that completed in BOTH runs are bit-exact; served p99 stays within
    1.5× the fault-free twin at the same offered load (capacity loss is
    paid in shed fraction, not tail latency)."""
    nodes = env.pool[:240]

    def run(faults):
        _, fab, _ = _sim_fabric(env, faults=faults,
                                base=FaultSpec(added_latency_ms=5),
                                timeout_ms=8, slo_p99_ms=25.0, seed=7)
        rids = _offer(fab, nodes, per_step=6)
        return fab, rids

    clean, rids = run(None)
    # the override REPLACES the base spec, so it must carry the base
    # wire cost too — otherwise the doomed replica is also magically fast
    chaos, _ = run({(0, 0): FaultSpec(added_latency_ms=5, down_at_ms=30.0)})
    done_c, shed_c, _ = _assert_conserved(clean, rids)
    done_f, shed_f, _ = _assert_conserved(chaos, rids)

    both = set(done_c) & set(done_f)
    assert len(both) >= 20
    for rid in both:                             # survivors bit-exact
        assert done_c[rid].pred == done_f[rid].pred
        assert np.array_equal(done_c[rid].logits, done_f[rid].logits)

    p99_c, p99_f = _served_p99_ms(done_c), _served_p99_ms(done_f)
    assert p99_f <= 1.5 * p99_c + 1e-9           # bounded tail
    assert chaos.slo.shed_fraction >= clean.slo.shed_fraction
    assert chaos.fstats.reroutes + chaos.fstats.retries > 0


@pytest.mark.slow
def test_fault_severity_sweep_sheds_monotonically(env):
    """Same offered load, rising injected drop rate: shed fraction rises
    monotonically while served p99 stays bounded — degradation is paid
    at the door, not in the tail."""
    fractions, p99s = [], []
    for rate in (0.0, 0.2, 0.45):
        _, fab, _ = _sim_fabric(
            env, base=FaultSpec(drop_rate=rate, added_latency_ms=5),
            timeout_ms=8, slo_p99_ms=25.0, seed=11)
        rids = _offer(fab, env.pool[:180], per_step=6)
        done, _, _ = _assert_conserved(fab, rids)
        fractions.append((fab.slo.shed + fab.fstats.timed_out) / len(rids))
        p99s.append(_served_p99_ms(done))
    assert fractions == sorted(fractions)
    assert fractions[-1] > fractions[0]
    for p in p99s:
        assert p <= 1.6 * 25.0                   # SLO envelope holds


# ---------------------------------------------------------------------------
# recovery + determinism
# ---------------------------------------------------------------------------

def test_recovered_replica_rejoins_dispatch(env):
    """down_at 10 ms, up_at 30 ms: the replica is marked down, probed
    after its cooldown, and completes fresh work after recovery."""
    plan, fab, _ = _sim_fabric(
        env, faults={(0, 0): FaultSpec(added_latency_ms=2, down_at_ms=10.0,
                                       up_at_ms=30.0)},
        base=FaultSpec(added_latency_ms=2), timeout_ms=6, down_retry_ms=8.0)
    pool0 = [v for v in env.pool if int(plan.owner_of([int(v)])[0]) == 0]
    assert len(pool0) >= 240
    completed_at_down = None
    i, rid = 0, 0
    for _ in range(150):
        for _ in range(2):
            if i < 240:
                fab.submit(GNNRequest(rid=rid, node=int(pool0[i])))
                i, rid = i + 1, rid + 1
        fab.step()
        st = fab.replica_state[(0, 0)]
        if st.state == "down" and completed_at_down is None:
            completed_at_down = st.completed
    fab.drain()
    _assert_conserved(fab, list(range(rid)))
    st = fab.replica_state[(0, 0)]
    assert completed_at_down is not None         # it DID go down
    assert st.state == "up"                      # and rejoined
    assert st.completed > completed_at_down      # with fresh work served
    assert fab.fstats.health_transitions >= 3    # up→suspect→down→up


def test_same_seed_same_schedule_identical_trace(env):
    """Same seed + same fault schedule ⇒ the identical per-request
    (replica, status, pred) trace across two fabric runs — dispatch,
    EWMA tie-breaks, drops and retries are all deterministic."""
    faults = {(0, 0): FaultSpec(added_latency_ms=3, jitter_ms=2,
                                drop_rate=0.1, down_at_ms=40.0)}

    def run():
        _, fab, _ = _sim_fabric(env, faults=faults,
                                base=FaultSpec(added_latency_ms=3,
                                               jitter_ms=1),
                                timeout_ms=9, seed=5, record_trace=True)
        _offer(fab, env.pool[:60], per_step=3)
        return fab

    a, b = run(), run()
    assert a.request_trace == b.request_trace
    assert len(a.request_trace) == 60
    assert a.fstats.asdict() == b.fstats.asdict()
    assert a.fabric_stats() == b.fabric_stats()


# ---------------------------------------------------------------------------
# refresh_topology × in-flight retries (the regression the seam exposed)
# ---------------------------------------------------------------------------

def test_refresh_topology_restamps_inflight_retries(env):
    """A request in flight on a replica that dies is reclaimed during
    ``refresh_topology``'s drain, lands back in the fabric queue, and is
    RE-STAMPED against the rebuilt fleet — new owner, new topology
    version — instead of being dropped or dispatched to a torn-down
    replica."""
    plan, fab, _ = _sim_fabric(env, base=FaultSpec(added_latency_ms=4),
                               timeout_ms=6)
    for rid, v in enumerate(env.pool[:12]):
        fab.submit(GNNRequest(rid=rid, node=int(v)))
    fab.step()                                   # dispatch onto the fleet
    assert fab.inflight
    fab.transports[0][0].kill()                  # host dies mid-flight
    new_plan = plan_partitions(env.graph, 2, "locality", seed=1,
                               halo_budget=32)
    fab.refresh_topology(new_plan)
    assert fab.fstats.retries > 0                # reclaimed, not dropped
    a = fab.audit()
    assert a["inflight"] == 0
    assert a["offered"] == (a["done"] + a["shed"] + a["timed_out"]
                            + a["pending"])
    for req in fab.pending:                      # re-stamped for the new plan
        assert req.topology_version == new_plan.topology_version
        assert req.partition == int(new_plan.owner_of([req.node])[0])
    fab.drain()
    done, shed, tout = _assert_conserved(fab, list(range(12)))
    assert len(done) == 12 and not shed and not tout
    for req in done.values():
        assert req.partition == int(new_plan.owner_of([req.node])[0])


def test_refresh_topology_pullback_without_timeouts(env):
    """Timeouts disabled + a dead host holding in-flight work: the
    refresh drain cannot resolve them, so they are pulled back and
    re-queued (retry budget untouched) rather than spinning forever or
    being dropped."""
    plan, fab, _ = _sim_fabric(env, base=FaultSpec(added_latency_ms=4),
                               timeout_ms=0.0)
    for rid, v in enumerate(env.pool[:12]):
        fab.submit(GNNRequest(rid=rid, node=int(v)))
    fab.step()
    stuck = [rid for rid, rec in fab.inflight.items()
             if rec.key == (0, 0)]
    assert stuck
    fab.transports[0][0].kill()
    fab.refresh_topology(plan)                   # same plan, rebuilt fleet
    assert fab.audit()["inflight"] == 0
    retries = {r.rid: r.retries for r in fab.pending}
    for rid in stuck:
        assert retries.get(rid) == 0             # pulled back, budget intact
    fab.drain()
    done, shed, tout = _assert_conserved(fab, list(range(12)))
    assert len(done) == 12
