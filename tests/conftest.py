import sys
from pathlib import Path

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; only launch/dryrun.py forces 512.
SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

import pytest

# the `slow` marker is registered in pyproject.toml ([tool.pytest.ini_options])
# so `pytest --strict-markers` passes without conftest-side registration


@pytest.fixture(scope="session")
def smoke_graph():
    from repro.configs.gnn import gnn_config
    from repro.graph.synthetic import dataset_like
    cfg = gnn_config("products", smoke=True)
    return dataset_like(cfg, seed=0)


@pytest.fixture(scope="session")
def smoke_gnn_cfg():
    from repro.configs.gnn import gnn_config
    return gnn_config("products", smoke=True)
