import os
import sys
from pathlib import Path

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; only launch/dryrun.py forces 512.
SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long pipeline/system tests — excluded from the fast lane "
        "(scripts/ci.sh runs them in the full tier-1 pass)")


@pytest.fixture(scope="session")
def smoke_graph():
    from repro.configs.gnn import gnn_config
    from repro.graph.synthetic import dataset_like
    cfg = gnn_config("products", smoke=True)
    return dataset_like(cfg, seed=0)


@pytest.fixture(scope="session")
def smoke_gnn_cfg():
    from repro.configs.gnn import gnn_config
    return gnn_config("products", smoke=True)
