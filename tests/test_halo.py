"""Bounded halo-feature exchange (graph/partition.py halo sets,
distributed/collectives.halo_all_to_all, core/multipart.py threading).

Covers: budget cap/ownership/adjacency invariants, budget monotonicity
(larger budget keeps a prefix-superset), the budget=0 regression anchor
(bit-identical to the drop-cut-edges plan AND to the single-partition
step), feature routing through the collective, halo-hit accounting and
its checkpoint round-trip, the live ``halo_budget`` swap, the autotune
knob, and the kept-information claim ``benchmarks/fig_halo.py`` reports."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.gnn import AutotuneConfig
from repro.core.a3gnn import A3GNNTrainer, make_trainer
from repro.core.autotune.controller import AutotuneController, episode_space
from repro.core.multipart import MultiPartitionTrainer
from repro.core.sampling import NeighborSampler, seed_loader
from repro.distributed.collectives import halo_all_to_all
from repro.graph.batch import generate_batch, batch_device_arrays
from repro.graph.partition import plan_partitions
from repro.launch.mesh import HostSimMesh, make_partition_mesh
from repro.train.checkpoint import CheckpointManager


# ---------------------------------------------------------------------------
# plan-level halo sets
# ---------------------------------------------------------------------------

def test_halo_sets_respect_budget_ownership_and_reachability(smoke_graph):
    budget = 8
    plan = plan_partitions(smoke_graph, 4, "locality", seed=0,
                           halo_budget=budget)
    assert plan.halo_budget == budget
    for p, hs in enumerate(plan.halo_sets):
        assert len(hs) <= budget
        assert len(np.unique(hs)) == len(hs)
        # every halo node is owned elsewhere...
        assert (plan.owner[hs] != p).all()
        # ...and REACHABLE: an out-neighbor of some owned node (graphs
        # here are directed — a remote→owned edge recovers nothing, so a
        # candidate with only those must never consume a budget slot)
        owned = plan.node_sets[p]
        out_nb = np.concatenate(
            [smoke_graph.neighbors(int(v)) for v in owned])
        assert np.isin(hs, out_nb).all(), \
            f"partition {p} budgeted an unreachable halo node"


def test_halo_budget_monotonicity(smoke_graph):
    """Affinity ranking with id tie-break: a larger budget keeps every
    node a smaller budget kept, in the same order (prefix superset)."""
    plans = {b: plan_partitions(smoke_graph, 4, "locality", seed=0,
                                halo_budget=b) for b in (2, 8, 32, 10**9)}
    for small, large in ((2, 8), (8, 32), (32, 10**9)):
        for hs_s, hs_l in zip(plans[small].halo_sets, plans[large].halo_sets):
            assert np.array_equal(hs_s, hs_l[:len(hs_s)])
    # the uncapped budget keeps every REACHABLE candidate (a subset of
    # halo_counts, which still reports the either-direction pool), and
    # then recovers every single cut edge its partitions can traverse
    uncapped = plans[10**9]
    for hs, pool in zip(uncapped.halo_sets, uncapped.halo_counts):
        assert 0 < len(hs) <= pool
    assert uncapped.recovered_edges == sum(
        int(a.sum()) for a in uncapped.halo_ranked_aff)


def test_budget_zero_is_the_drop_cut_edges_plan(smoke_graph):
    """Regression anchor: halo_budget=0 (the default) reproduces PR 2's
    subgraphs bit-exactly — same CSR, same features, same masks."""
    plan = plan_partitions(smoke_graph, 3, "locality", seed=0, halo_budget=0)
    assert plan.halo_rows == 0 and plan.recovered_edges == 0
    assert plan.kept_information(smoke_graph) == pytest.approx(
        plan.edge_locality(smoke_graph))
    for sub, ns in zip(plan.subgraphs, plan.node_sets):
        ref = smoke_graph.subgraph(ns)
        assert np.array_equal(sub.indptr, ref.indptr)
        assert np.array_equal(sub.indices, ref.indices)
        assert np.array_equal(sub.features, ref.features)
        assert np.array_equal(sub.train_mask, ref.train_mask)


def test_halo_subgraph_structure(smoke_graph):
    """Halo nodes are appended feature-only leaves: no local adjacency,
    all-False masks, reachable from owned nodes in one hop."""
    plan = plan_partitions(smoke_graph, 4, "locality", seed=0, halo_budget=16)
    for sub, ns, hs in zip(plan.subgraphs, plan.node_sets, plan.halo_sets):
        n_own = len(ns)
        assert sub.num_nodes == n_own + len(hs)
        # halo rows: empty adjacency + excluded from every split
        for i in range(n_own, sub.num_nodes):
            assert len(sub.neighbors(i)) == 0
        assert not sub.train_mask[n_own:].any()
        assert not sub.test_mask[n_own:].any()
        # EVERY halo leaf is reachable: each local halo id appears as an
        # out-neighbor of some owned node (budget is never wasted on rows
        # the sampler cannot reach)
        if len(hs):
            halo_ids = np.arange(n_own, sub.num_nodes)
            assert np.isin(halo_ids, sub.indices).all()


def test_kept_information_strictly_improves_at_p4(smoke_graph):
    """Acceptance: with halo_budget>0 at P=4 the kept-information fraction
    strictly exceeds the budget=0 baseline."""
    base = plan_partitions(smoke_graph, 4, "locality", seed=0)
    halo = plan_partitions(smoke_graph, 4, "locality", seed=0, halo_budget=32)
    assert base.cut_edges > 0          # the assigner does cut at P=4
    assert halo.kept_information(smoke_graph) > base.kept_information(
        smoke_graph)
    assert halo.recovered_edges > 0
    assert halo.exchange_volume_bytes(smoke_graph) == (
        halo.halo_rows * smoke_graph.feat_dim * 4)


def test_fig_halo_benchmark_reports_strict_improvement():
    from benchmarks.fig_halo import run
    results = run(quick=True)
    for parts, sweep in results["sweep"].items():
        base = sweep[0]["kept_information"]
        for budget, row in sweep.items():
            if budget > 0:
                assert row["kept_information"] > base, (parts, budget)
                assert row["exchange_bytes"] > 0
    assert results["train"]["halo_hit_rate"] > 0.0


# ---------------------------------------------------------------------------
# the halo_all_to_all collective
# ---------------------------------------------------------------------------

def test_halo_all_to_all_host_sim_routes_rows(smoke_graph):
    plan = plan_partitions(smoke_graph, 3, "locality", seed=0, halo_budget=12)
    fn = halo_all_to_all(HostSimMesh(3))
    owned = [smoke_graph.features[ns] for ns in plan.node_sets]
    halo_feats, volume = fn(plan, owned)
    assert volume == plan.halo_rows * smoke_graph.feat_dim * 4
    for p, (rows, hs) in enumerate(zip(halo_feats, plan.halo_sets)):
        np.testing.assert_array_equal(rows, smoke_graph.features[hs])


@pytest.mark.slow
def test_halo_all_to_all_real_mesh_matches_host_sim():
    """The shard_map all_to_all path must route the SAME rows as the
    host-sim twin (3 forced host devices — the docstring's bitwise claim,
    exercised beyond the degenerate P=1 case)."""
    import os
    import subprocess
    import sys
    import textwrap
    from pathlib import Path
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=3"
    src = str(Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    code = textwrap.dedent("""
        import numpy as np
        from jax.sharding import Mesh
        from repro.configs.gnn import gnn_config
        from repro.graph.synthetic import dataset_like
        from repro.graph.partition import plan_partitions
        from repro.distributed.collectives import halo_all_to_all
        from repro.launch.mesh import HostSimMesh, make_partition_mesh
        g = dataset_like(gnn_config("products", smoke=True), seed=0)
        plan = plan_partitions(g, 3, "locality", seed=0, halo_budget=12)
        owned = [g.features[ns] for ns in plan.node_sets]
        mesh = make_partition_mesh(3)
        assert isinstance(mesh, Mesh), mesh          # real 3-device mesh
        real, vol_r = halo_all_to_all(mesh)(plan, owned)
        sim, vol_s = halo_all_to_all(HostSimMesh(3))(plan, owned)
        assert vol_r == vol_s > 0
        for p, (a, b) in enumerate(zip(real, sim)):
            np.testing.assert_array_equal(a, b), p
            np.testing.assert_array_equal(a, g.features[plan.halo_sets[p]])
        print("PARITY-OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=360, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    assert "PARITY-OK" in r.stdout


def test_halo_all_to_all_real_mesh_empty_at_p1(smoke_graph):
    """P=1 on the real single-device mesh: no halo, zero volume — the
    degenerate case both code paths must agree on."""
    plan = plan_partitions(smoke_graph, 1, "locality", seed=0, halo_budget=8)
    mesh = make_partition_mesh(1)
    assert not isinstance(mesh, HostSimMesh)
    halo_feats, volume = halo_all_to_all(mesh)(
        plan, [smoke_graph.features])
    assert volume == 0 and len(halo_feats) == 1 and len(halo_feats[0]) == 0


# ---------------------------------------------------------------------------
# trainer threading: fill, accounting, live swap, bit-exact anchor
# ---------------------------------------------------------------------------

def test_trainer_fills_halo_features_through_exchange(smoke_graph,
                                                      smoke_gnn_cfg):
    tr = make_trainer(smoke_graph,
                      smoke_gnn_cfg.replace(partitions=3, halo_budget=16),
                      seed=0)
    assert tr.halo_exchange_bytes == tr.plan.halo_rows * \
        smoke_graph.feat_dim * 4 > 0
    for sub, ns, hs in zip(tr.plan.subgraphs, tr.plan.node_sets,
                           tr.plan.halo_sets):
        np.testing.assert_array_equal(sub.features[len(ns):],
                                      smoke_graph.features[hs])


def test_two_partition_step_bit_exact_at_budget_zero(smoke_graph,
                                                     smoke_gnn_cfg):
    """The PR 2 invariant survives the halo refactor: with halo_budget=0
    the 2-partition synced step matches the single-partition step."""
    cfg = smoke_gnn_cfg.replace(partitions=2, halo_budget=0)
    single = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
    multi = make_trainer(smoke_graph, cfg, seed=0)
    assert multi.halo_exchange_bytes == 0
    multi.load_state_dict(single.state_dict())
    sampler = NeighborSampler(smoke_graph, smoke_gnn_cfg.fanout, seed=7)
    seeds = next(seed_loader(smoke_graph, smoke_gnn_cfg.batch_size, 7))
    arrays = batch_device_arrays(
        generate_batch(sampler.sample(seeds), None, smoke_graph))
    p1, _, _, _ = single._step(single.params, single.opt_state,
                               arrays["features"], arrays["neigh_idxs"],
                               arrays["labels"])
    multi.synced_update([arrays, arrays])
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(multi.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_halo_hit_accounting_counts_sampled_halo_inputs(smoke_graph,
                                                        smoke_gnn_cfg):
    tr = make_trainer(smoke_graph,
                      smoke_gnn_cfg.replace(partitions=2, halo_budget=64),
                      seed=0)
    for _ in range(3):
        tr.global_step()
    assert all(h.batches == 3 and h.inputs > 0 for h in tr.halo_stats)
    # with 64 high-affinity boundary nodes per partition the sampler
    # reaches across the cut in practice, not just in principle
    assert tr.halo_hit_rate > 0.0
    assert sum(h.halo_hits for h in tr.halo_stats) < \
        sum(h.inputs for h in tr.halo_stats)


def test_halo_accounting_roundtrips_through_checkpoint(smoke_graph,
                                                       smoke_gnn_cfg,
                                                       tmp_path):
    cfg = smoke_gnn_cfg.replace(partitions=2, halo_budget=64)
    tr = make_trainer(smoke_graph, cfg, seed=0)
    for _ in range(2):
        tr.global_step()
    stats = [dataclasses.asdict(s.halo_stats) for s in tr.slots]
    assert any(st["halo_hits"] > 0 for st in stats)
    mgr = CheckpointManager(tmp_path / "ckpt", async_save=False)
    tr.save(mgr, step=2)
    extra = mgr.read_manifest(2)["extra"]
    assert extra["halo_budget"] == 64
    assert extra["halo_stats"] == stats     # next to cache_stats
    assert "cache_stats" in extra
    tr2 = make_trainer(smoke_graph, cfg, seed=1)
    assert tr2.restore(mgr) == 2
    assert [dataclasses.asdict(s.halo_stats) for s in tr2.slots] == stats
    tr2.global_step()                       # and training resumes
    assert all(s.halo_stats.batches == st["batches"] + 1
               for s, st in zip(tr2.slots, stats))


def test_live_halo_budget_swap_preserves_state(smoke_graph, smoke_gnn_cfg):
    """halo_budget swaps live (no restart path): slots rebuild in place,
    params/cache accounting/halo accounting carry over."""
    tr = make_trainer(smoke_graph,
                      smoke_gnn_cfg.replace(partitions=2, halo_budget=0),
                      seed=0)
    pipe = tr.make_pipeline()
    try:
        stats = pipe.run(max_steps=2)
        assert stats.steps == 4
        params_before = [np.asarray(x).copy()
                         for x in jax.tree.leaves(tr.params)]
        cache_hits = [s.cache.stats.hits for s in tr.slots]
        base_nodes = [s.graph.num_nodes for s in tr.slots]

        tr.apply_live_config({"halo_budget": 32}, pipe)
        assert tr.cfg.halo_budget == 32 and tr.plan.halo_budget == 32
        for s, n in zip(tr.slots, base_nodes):        # halo rows appended
            assert s.graph.num_nodes == n + 32
        for s, h in zip(tr.slots, cache_hits):
            assert s.cache.stats.hits >= h            # accounting survived
            # halo accounting restarts with the new halo topology (the
            # same invariant the checkpoint restore path enforces)
            assert s.halo_stats.inputs == 0
        for a, b in zip(params_before, jax.tree.leaves(tr.params)):
            np.testing.assert_allclose(a, np.asarray(b))   # params untouched
        stats = pipe.run(max_steps=2)                 # training continues
        assert stats.steps == 4

        tr.apply_live_config({"halo_budget": 0}, pipe)
        for s, n in zip(tr.slots, base_nodes):        # back to PR 2 shape
            assert s.graph.num_nodes == n
    finally:
        pipe.shutdown()


# ---------------------------------------------------------------------------
# autotune: the halo_budget knob swaps live in the episode loop
# ---------------------------------------------------------------------------

def test_episode_space_gains_halo_budget_knob():
    assert "halo_budget" not in {k.name for k in
                                 episode_space(AutotuneConfig()).knobs}
    sp = episode_space(AutotuneConfig(max_halo_budget=64))
    assert "halo_budget" in {k.name for k in sp.knobs}
    rng = np.random.default_rng(0)
    decoded = [sp.decode(u)["halo_budget"] for u in sp.sample(rng, 64)]
    assert min(decoded) >= 0 and max(decoded) <= 64 and len(set(decoded)) > 1


def test_controller_reports_and_swaps_halo_budget(smoke_graph,
                                                  smoke_gnn_cfg):
    """The baseline episode reports the trainer's true halo budget and a
    proposed budget is applied without a restart (same trainer object)."""
    tr = make_trainer(smoke_graph,
                      smoke_gnn_cfg.replace(partitions=2, halo_budget=8),
                      seed=0)
    acfg = AutotuneConfig(episodes=1, steps_per_episode=1, warmup_steps=0,
                          presample=8, surrogate_trees=4, ppo_updates=1,
                          ppo_horizon=2, max_halo_budget=32, seed=0)
    ctrl = AutotuneController(tr, tr.make_pipeline(), acfg)
    try:
        assert ctrl._current_config()["halo_budget"] == 8
        ctrl._apply_config({"halo_budget": 24})
        assert ctrl.tr is tr                    # live swap, no rebuild
        assert tr.plan.halo_budget == 24
    finally:
        ctrl.pipe.shutdown()


@pytest.mark.slow
def test_fit_autotuned_with_halo_knob(smoke_graph, smoke_gnn_cfg):
    tr = make_trainer(smoke_graph,
                      smoke_gnn_cfg.replace(partitions=2, halo_budget=16),
                      seed=0)
    assert isinstance(tr, MultiPartitionTrainer)
    acfg = AutotuneConfig(episodes=3, steps_per_episode=2, warmup_steps=0,
                          presample=16, surrogate_trees=8, ppo_updates=1,
                          ppo_horizon=4, max_workers=2, max_halo_budget=32,
                          seed=0)
    rep = tr.fit_autotuned(acfg)
    assert len(rep.episodes) == 3
    assert all("halo_budget" in ep.config for ep in rep.episodes)
    for ep in rep.episodes:
        assert np.isfinite(list(ep.metrics.values())).all()


# ---------------------------------------------------------------------------
# halo sets after node migration (dynamic topology)
# ---------------------------------------------------------------------------

def test_halo_sets_recomputed_after_migration():
    """An incremental re-balance must rebuild the halo machinery against
    the NEW ownership and the NEW adjacency: halo affinity ranks reflect
    post-move cut edges, `kept_information` is recomputed (not carried
    from the stale plan), and the budget invariants all still hold."""
    from repro.configs.gnn import gnn_config
    from repro.graph.partition import (_finalize_plan, incremental_rebalance)
    from repro.graph.synthetic import dataset_like
    g = dataset_like(gnn_config("products", smoke=True), seed=14)
    plan = plan_partitions(g, 3, "locality", seed=0, halo_budget=24)
    rng = np.random.default_rng(3)
    g.add_edges(rng.integers(0, g.num_nodes, 2500),
                rng.integers(0, g.num_nodes, 2500))
    res = incremental_rebalance(g, plan)
    new = res.plan
    # against a fresh finalize of the same assignment over the mutated
    # graph: identical halo sets, stats and kept information — stale
    # anything would diverge here
    fresh = _finalize_plan(g, new.node_sets, new.owner, new.method, 24)
    assert new.cut_edges == fresh.cut_edges
    assert new.recovered_edges == fresh.recovered_edges
    assert new.kept_information(g) == fresh.kept_information(g)
    for a, b in zip(new.halo_sets, fresh.halo_sets):
        np.testing.assert_array_equal(a, b)
    # ...and it differs from the pre-move plan's stale view
    assert new.kept_information(g) != plan.kept_information(g)
    # budget invariants survive the migration
    for p, hs in enumerate(new.halo_sets):
        assert len(hs) <= 24
        assert (new.owner[hs] != p).all()
        # every budgeted halo node is reachable from an owned out-edge
        indptr, indices = g.adj()
        owned = new.node_sets[p]
        src = np.repeat(np.arange(g.num_nodes), np.diff(indptr))
        mine = np.isin(src, owned)
        assert np.isin(hs, indices[mine]).all()


def test_trainer_rebalance_refills_halo_rows():
    """Post-rebalance slots carry freshly-exchanged halo feature rows for
    the NEW halo sets (never zeros, never the old plan's rows)."""
    from repro.configs.gnn import gnn_config
    from repro.graph.synthetic import dataset_like
    cfg = gnn_config("products", smoke=True).replace(partitions=2,
                                                     halo_budget=16)
    g = dataset_like(cfg, seed=15)
    tr = MultiPartitionTrainer(g, cfg, seed=0)
    try:
        rng = np.random.default_rng(6)
        g.add_edges(rng.integers(0, g.num_nodes, 3000),
                    rng.integers(0, g.num_nodes, 3000))
        tr.rebalance_partitions()
        assert tr.plan.halo_budget == 16
        for slot, ns, hs in zip(tr.slots, tr.plan.node_sets,
                                tr.plan.halo_sets):
            if not len(hs):
                continue
            local = np.arange(len(ns), len(ns) + len(hs))
            np.testing.assert_array_equal(
                slot.pipe.plane.fetch(local), g.features[hs])
    finally:
        for s in tr.slots:
            s.pipe.shutdown()
