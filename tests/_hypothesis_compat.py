"""Optional-``hypothesis`` shim for property-based tests.

``hypothesis`` is an *optional* test extra (install with
``pip install hypothesis`` — see scripts/ci.sh).  When it is available the
real library is re-exported unchanged.  When it is missing, a deterministic
fixed-case fallback stands in: ``@given`` re-runs the test body over a
seeded sweep of drawn examples (seeded from the test name, so runs are
reproducible and failures are reportable as a concrete example index).

The fallback implements only the strategy surface this suite uses:
``st.integers``, ``st.floats``, ``st.booleans``, ``st.sampled_from`` and
``st.lists``.  It intentionally does no shrinking — it is a smoke-grade
stand-in, not a replacement; CI with the extra installed gets the real
search.

Usage (drop-in)::

    from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

import functools
import inspect
import zlib

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    _FALLBACK_MAX_EXAMPLES = 12     # cap the fixed-case sweep (speed)

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _St:
        """Deterministic stand-ins for the strategies this suite uses."""

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elem.draw(rng) for _ in range(n)]
            return _Strategy(draw)

    st = _St()

    def settings(max_examples=None, **_kw):
        """Records max_examples for ``given`` to pick up; other hypothesis
        settings (deadline, ...) are meaningless here and ignored."""
        def deco(fn):
            if max_examples is not None:
                fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            n = min(getattr(fn, "_compat_max_examples",
                            _FALLBACK_MAX_EXAMPLES),
                    _FALLBACK_MAX_EXAMPLES)
            # Like hypothesis, positional strategies fill the test's LAST
            # positional parameters; everything is passed by keyword.
            sig = inspect.signature(fn)
            names = list(sig.parameters)
            pos_names = names[len(names) - len(arg_strategies):] \
                if arg_strategies else []
            strat_map = dict(zip(pos_names, arg_strategies))
            strat_map.update(kw_strategies)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for i in range(n):
                    drawn = {k: s.draw(rng) for k, s in strat_map.items()}
                    try:
                        fn(*args, **kwargs, **drawn)
                    except Exception as e:
                        raise AssertionError(
                            f"fallback example {i}/{n} ({drawn}) "
                            f"failed: {e}") from e
            # hide drawn params so pytest doesn't resolve them as fixtures
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strat_map])
            return wrapper
        return deco
