"""Serving engine: continuous batching, slot management, correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.serve.engine import Engine, Request
from repro.serve.kv_cache import KVCacheManager


def test_kv_manager_slots():
    kv = KVCacheManager(caches=None, batch=3, max_len=32)
    s0 = kv.allocate(100, 4)
    s1 = kv.allocate(101, 4)
    assert {s0, s1} == {0, 1}
    assert kv.utilization() == pytest.approx(2 / 3)
    kv.advance(s0)
    assert kv.slots[s0].length == 5
    rid = kv.release(s0)
    assert rid == 100 and not kv.slots[s0].active
    assert kv.allocate(102, 40) is None        # prompt too long


def test_engine_completes_all_requests():
    cfg = get_config("llama3.2-3b", smoke=True)
    eng = Engine(cfg, batch=3, max_len=48, seed=0)
    rng = np.random.default_rng(0)
    for rid in range(7):                       # more requests than slots
        prompt = rng.integers(1, cfg.vocab_size, 5).astype(np.int32)
        eng.submit(Request(rid=rid, prompt=prompt, max_new_tokens=4))
    stats = eng.run_to_completion()
    assert stats["completed"] == 7
    assert all(len(r.out_tokens) == 4 for r in eng.completed)
    assert stats["tokens"] == 28
    # all slots freed at the end
    assert eng.kv.free_slots() == list(range(3))


def test_engine_greedy_matches_model():
    """First generated token == argmax of the model's prefill logits."""
    cfg = get_config("llama3.2-3b", smoke=True).replace(
        compute_dtype="float32")
    eng = Engine(cfg, batch=1, max_len=32, seed=0)
    from repro.models.api import build
    model = build(cfg)
    prompt = np.array([5, 9, 3, 7], np.int32)
    logits, _ = jax.jit(model.prefill)(
        eng.params, {"tokens": jnp.asarray(prompt)[None]})
    want = int(np.argmax(np.asarray(logits)[0]))
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=1))
    eng.run_to_completion()
    assert eng.completed[0].out_tokens[0] == want


def test_engine_eos_stops_early():
    cfg = get_config("llama3.2-3b", smoke=True)
    eng = Engine(cfg, batch=1, max_len=32, seed=0)
    prompt = np.array([1, 2], np.int32)
    # eos = whatever greedy emits first → stops after 1 token
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=8))
    eng.run_to_completion()
    first = eng.completed[0].out_tokens[0]
    eng2 = Engine(cfg, batch=1, max_len=32, seed=0)
    eng2.submit(Request(rid=1, prompt=prompt, max_new_tokens=8,
                        eos_id=first))
    eng2.run_to_completion()
    assert len(eng2.completed[0].out_tokens) == 1
