"""FeaturePlane seam: host/device parity (bit-exact fetch + identical
accounting), halo-leaf fills, resize/γ-swap under the device plane, and
the live ``sampling_device`` swap mid-run."""
import numpy as np
import pytest

from repro.core.a3gnn import A3GNNTrainer
from repro.core.cache import FeatureCache
from repro.core.feature_plane import (DeviceFeaturePlane, HostFeaturePlane,
                                      make_feature_plane)
from repro.core.pipeline import Pipeline
from repro.core.sampling import seed_loader


def _planes(graph, volume_mb=0.05, policy="static"):
    """A (host, device) plane pair over two independent but identically
    seeded caches — parity means the SAME request stream produces
    bit-identical rows and identical accounting on both."""
    ch = FeatureCache(graph, volume_mb, policy)
    cd = FeatureCache(graph, volume_mb, policy)
    return HostFeaturePlane(graph, ch), DeviceFeaturePlane(graph, cd)


def _stats_tuple(c: FeatureCache):
    s = c.stats
    return (s.hits, s.misses, s.evictions, s.bytes_from_cache,
            s.bytes_from_host)


# ---------------------------------------------------------------------------
# fetch parity: hits, misses, accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["static", "fifo"])
def test_fetch_parity_hits_and_misses(smoke_graph, policy):
    host, dev = _planes(smoke_graph, policy=policy)
    rng = np.random.default_rng(0)
    ids = rng.integers(0, smoke_graph.num_nodes, 500)
    a, b = host.fetch(ids), dev.fetch(ids)
    assert a.dtype == b.dtype == np.float32
    assert np.array_equal(a, b)                       # bit-exact
    assert _stats_tuple(host.cache) == _stats_tuple(dev.cache)
    # repeat fetch: static hits the same rows, FIFO hits inserted rows —
    # either way the two planes must keep agreeing
    a, b = host.fetch(ids[:128]), dev.fetch(ids[:128])
    assert np.array_equal(a, b)
    assert _stats_tuple(host.cache) == _stats_tuple(dev.cache)
    np.testing.assert_array_equal(a, smoke_graph.features[ids[:128]])


def test_fetch_parity_pure_hit_and_pure_miss(smoke_graph):
    host, dev = _planes(smoke_graph)
    cached = np.where(host.cache.device_map >= 0)[0][:32]
    uncached = np.where(host.cache.device_map < 0)[0][:32]
    assert np.array_equal(host.fetch(cached), dev.fetch(cached))
    assert dev.cache.stats.misses == 0                # pure-hit batch
    assert np.array_equal(host.fetch(uncached), dev.fetch(uncached))
    assert dev.cache.stats.hits == len(cached)        # no false hits


def test_cacheless_and_zero_capacity_device_plane(smoke_graph):
    ids = np.arange(64)
    dev = DeviceFeaturePlane(smoke_graph, None)
    np.testing.assert_array_equal(dev.fetch(ids), smoke_graph.features[ids])
    assert dev.stats is None
    tiny = FeatureCache(smoke_graph, 0.0)             # capacity 0
    dev0 = DeviceFeaturePlane(smoke_graph, tiny)
    np.testing.assert_array_equal(dev0.fetch(ids), smoke_graph.features[ids])


def test_make_feature_plane_auto_probes_devices(smoke_graph):
    import jax
    plane = make_feature_plane(smoke_graph, None, "auto")
    has_accel = any(d.platform in ("tpu", "gpu") for d in jax.devices())
    assert plane.backend == ("device" if has_accel else "cpu")
    with pytest.raises(ValueError):
        make_feature_plane(smoke_graph, None, "gpu0")


# ---------------------------------------------------------------------------
# writes: halo-leaf rows through the plane
# ---------------------------------------------------------------------------

def test_fill_rows_updates_store_cache_and_mirror(smoke_graph):
    host, dev = _planes(smoke_graph, volume_mb=0.05)
    # pick one cache-resident and one non-resident row to overwrite
    resident = int(np.where(dev.cache.device_map >= 0)[0][0])
    absent = int(np.where(dev.cache.device_map < 0)[0][0])
    ids = np.array([resident, absent])
    host.fetch(ids)                                   # same stream on both;
    dev.fetch(ids)                                    # forces a device sync
    rows = np.full((2, smoke_graph.feat_dim), 7.5, np.float32)
    saved = smoke_graph.features[ids].copy()
    try:
        host.fill_rows(ids, rows)
        dev.fill_rows(ids, rows)
        for plane in (host, dev):
            got = plane.fetch(ids)                    # resident row must NOT
            np.testing.assert_array_equal(got, rows)  # serve the stale copy
        assert _stats_tuple(host.cache) == _stats_tuple(dev.cache)
    finally:
        smoke_graph.features[ids] = saved             # session-scoped fixture


def test_multipartition_halo_fill_parity(smoke_graph, smoke_gnn_cfg):
    """Halo-leaf rows flow through the plane on both backends: the synced
    2-partition step is bit-exact cpu vs device, halo hits included."""
    import jax
    from repro.core.multipart import MultiPartitionTrainer
    cfg = smoke_gnn_cfg.replace(partitions=2, halo_budget=32)
    tc = MultiPartitionTrainer(smoke_graph, cfg.replace(
        sampling_device="cpu"), seed=0)
    td = MultiPartitionTrainer(smoke_graph, cfg.replace(
        sampling_device="device"), seed=0)
    try:
        assert tc.halo_exchange_bytes == td.halo_exchange_bytes > 0
        for _ in range(2):
            tc.global_step()
            td.global_step()
        for a, b in zip(jax.tree_util.tree_leaves(tc.params),
                        jax.tree_util.tree_leaves(td.params)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        assert tc.halo_hit_rate == td.halo_hit_rate > 0.0
        assert tc.cache_hit_rate == td.cache_hit_rate
    finally:
        for s in tc.slots + td.slots:
            s.pipe.shutdown()


# ---------------------------------------------------------------------------
# reconfiguration under the device plane
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["static", "fifo"])
def test_resize_under_device_plane(smoke_graph, policy):
    host, dev = _planes(smoke_graph, volume_mb=0.05, policy=policy)
    ids = np.random.default_rng(1).integers(0, smoke_graph.num_nodes, 300)
    host.fetch(ids)
    dev.fetch(ids)
    old_table = dev._dev_table
    for vol in (0.1, 0.02):                           # grow, then shrink
        host.resize(vol)
        dev.resize(vol)
        assert np.array_equal(host.fetch(ids), dev.fetch(ids))
        assert _stats_tuple(host.cache) == _stats_tuple(dev.cache)
    # the stale device buffers were donated (deleted), not leaked
    assert dev._dev_table is not old_table
    assert old_table.is_deleted()


def test_gamma_swap_under_device_plane(smoke_graph, smoke_gnn_cfg):
    """γ swap + Θ resize through apply_live_config with a device-plane
    pipeline: the bias weights see the SAME cache the device gathers."""
    cfg = smoke_gnn_cfg.replace(sampling_device="device", bias_rate=2.0)
    tr = A3GNNTrainer(smoke_graph, cfg, seed=0)
    pipe = tr.make_pipeline()
    try:
        assert pipe.sampling_device == "device"
        assert isinstance(pipe.plane, DeviceFeaturePlane)
        stats = pipe.run(max_steps=2)
        assert stats.steps == 2 and tr.cache.stats.hits > 0
        plane_before = pipe.plane
        tr.apply_live_config({"bias_rate": 8.0, "cache_volume_mb": 0.5}, pipe)
        assert pipe.plane.cache is tr.cache           # same accounting
        # same cache object + same backend → the plane (and its synced
        # mirror) survives the episode boundary instead of re-uploading
        assert pipe.plane is plane_before
        assert isinstance(pipe.plane, DeviceFeaturePlane)
        cached = np.where(tr.cache.device_map >= 0)[0][:8]
        np.testing.assert_allclose(tr.weight_fn(cached), 8.0)
        stats = pipe.run(max_steps=2)                 # resized mirror serves
        assert stats.steps == 2
    finally:
        pipe.shutdown()


# ---------------------------------------------------------------------------
# live sampling_device swap mid-run
# ---------------------------------------------------------------------------

def test_live_sampling_device_swap_drains_nothing_dropped(smoke_graph,
                                                          smoke_gnn_cfg):
    cfg = smoke_gnn_cfg.replace(parallel_mode="mode2", workers=2)
    tr = A3GNNTrainer(smoke_graph, cfg, seed=0)
    pipe = Pipeline(smoke_graph, cfg, tr._train_fn, cache=tr.cache,
                    weight_fn=tr.weight_fn, seed=0)
    try:
        batches = list(seed_loader(smoke_graph, cfg.batch_size, 0))[:6]
        pipe.begin_stats()
        pipe.submit(batches)
        for _ in range(2):
            assert pipe.step()
        assert pipe.inflight == 4
        pipe.reconfigure(sampling_device="device")    # drain → swap plane
        assert pipe.inflight == 0
        assert pipe.stats.steps == 6                  # nothing dropped
        assert pipe.sampling_device == "device"
        assert isinstance(pipe.plane, DeviceFeaturePlane)
        assert pipe.cache is tr.cache                 # accounting survived
        pipe.submit(batches[:2])                      # resumes on device
        pipe.drain()
        assert pipe.stats.steps == 8
        pipe.reconfigure(sampling_device="cpu")       # and back
        assert isinstance(pipe.plane, HostFeaturePlane)
        assert not isinstance(pipe.plane, DeviceFeaturePlane)
    finally:
        pipe.shutdown()


def test_device_plane_mode1_concurrent_workers(smoke_graph, smoke_gnn_cfg):
    """mode1 batch-gen workers share the device plane from multiple
    threads; the FIFO policy forces mirror re-uploads mid-run, so this
    exercises the sync-vs-gather lock (a lost race kills a worker and
    shows up as a re-issued batch)."""
    cfg = smoke_gnn_cfg.replace(parallel_mode="mode1", workers=3,
                                sampling_device="device",
                                cache_policy="fifo", cache_volume_mb=0.05)
    tr = A3GNNTrainer(smoke_graph, cfg, seed=0)
    pipe = tr.make_pipeline()
    try:
        assert isinstance(pipe.plane, DeviceFeaturePlane)
        stats = pipe.run(max_steps=8)
        assert stats.steps == 8
        assert stats.reissued == 0                    # no worker died
        assert tr.cache.stats.hits + tr.cache.stats.misses > 0
    finally:
        pipe.shutdown()


def test_device_plane_training_bit_exact_with_host(smoke_graph,
                                                   smoke_gnn_cfg):
    """The acceptance bar: same seed, same steps — device-plane training
    reproduces host-plane parameters bit-exactly."""
    import jax
    tc = A3GNNTrainer(smoke_graph, smoke_gnn_cfg.replace(
        sampling_device="cpu"), seed=0)
    td = A3GNNTrainer(smoke_graph, smoke_gnn_cfg.replace(
        sampling_device="device"), seed=0)
    rc = tc.run_epochs(1, max_steps_per_epoch=4)
    rd = td.run_epochs(1, max_steps_per_epoch=4)
    assert rc.stats.losses == rd.stats.losses
    assert rc.cache_hit_rate == rd.cache_hit_rate
    for a, b in zip(jax.tree_util.tree_leaves(tc.params),
                    jax.tree_util.tree_leaves(td.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_autotune_live_swaps_sampling_device(smoke_graph, smoke_gnn_cfg):
    """The controller drives the plane swap end-to-end: with the
    sampling_device knob gated on, episodes run on both backends and the
    trainer ends on the recommendation without dropping a batch."""
    from repro.configs.gnn import AutotuneConfig
    from repro.core.autotune.controller import AutotuneController
    tr = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
    pipe = tr.make_pipeline()
    acfg = AutotuneConfig(episodes=3, steps_per_episode=3, warmup_steps=0,
                          presample=24, surrogate_trees=8, ppo_updates=1,
                          ppo_horizon=4, tune_sampling_device=True, seed=0)
    ctrl = AutotuneController(tr, pipe, acfg)
    try:
        rep = ctrl.run()
    finally:
        ctrl.pipe.shutdown()
    assert all(ep.config["sampling_device"] in ("cpu", "device")
               for ep in rep.episodes)
    assert all(ep.steps == 3 for ep in rep.episodes)  # no dropped batches
    assert tr.cfg.sampling_device == rep.best.config["sampling_device"]
    assert ctrl.pipe.sampling_device == rep.best.config["sampling_device"]


# ---------------------------------------------------------------------------
# incremental mirror sync: O(dirty rows), not O(capacity)
# ---------------------------------------------------------------------------

def test_incremental_sync_parity_and_upload_counters(smoke_graph):
    """Interleaved FIFO inserts + streamed update_rows keep the mirror
    coherent through row-wise scatters: bit-exact and stats-exact with the
    host plane AND with a full-reupload device plane, while full uploads
    happen exactly once (the initial upload) and the scattered-row volume
    stays O(dirty rows) — the whole-mirror re-upload pathology is gone."""
    from repro.graph.storage import FeatureStore
    host = HostFeaturePlane(smoke_graph, FeatureCache(smoke_graph, 0.2, "fifo"))
    dev = DeviceFeaturePlane(smoke_graph, FeatureCache(smoke_graph, 0.2, "fifo"))
    full = DeviceFeaturePlane(smoke_graph, FeatureCache(smoke_graph, 0.2, "fifo"),
                              incremental_sync=False)
    store = FeatureStore(smoke_graph)
    for p in (host, dev, full):
        p.subscribe_to(store)
    rng = np.random.default_rng(3)
    saved = smoke_graph.features.copy()
    try:
        dirty_budget = 0
        for step in range(12):
            ids = rng.integers(0, smoke_graph.num_nodes, 48)
            a, b, c = host.fetch(ids), dev.fetch(ids), full.fetch(ids)
            assert np.array_equal(a, b) and np.array_equal(a, c)
            dirty_budget += 3 * 48          # slots + evicted + inserted ids
            if step % 3 == 1:               # interleave streamed updates
                resident = np.where(dev.cache.device_map >= 0)[0][:4]
                rows = rng.normal(0, 1, (len(resident),
                                         smoke_graph.feat_dim)).astype(np.float32)
                store.update_rows(resident, rows)
                dirty_budget += len(resident)
                for p in (host, dev, full):
                    np.testing.assert_array_equal(p.fetch(resident), rows)
        assert _stats_tuple(host.cache) == _stats_tuple(dev.cache)
        assert _stats_tuple(host.cache) == _stats_tuple(full.cache)
        # THE upload-counter assertion: only the initial mirror upload was
        # a full table move; every version bump after it was a scatter
        assert dev.sync_full_uploads == 1
        assert dev.sync_row_scatters > 0
        assert dev.sync_rows_scattered <= dirty_budget          # O(dirty)
        assert dev.sync_rows_scattered < \
            dev.sync_row_scatters * dev.cache.capacity          # not O(cap)
        # the incremental-off twin re-uploaded the whole table every bump
        assert full.sync_full_uploads > 1 and full.sync_row_scatters == 0
        # ... and moved strictly more host→device bytes for the same stream
        assert dev.sync_bytes_uploaded < full.sync_bytes_uploaded
    finally:
        smoke_graph.features[:] = saved      # session-scoped fixture
        for p in (host, dev, full):
            p.detach_store()


def test_full_reupload_only_on_realloc(smoke_graph):
    """resize/realloc is the ONLY event that re-uploads the full table;
    FIFO-inserting fetches and patch_resident calls scatter rows."""
    dev = DeviceFeaturePlane(smoke_graph, FeatureCache(smoke_graph, 0.2, "fifo"))
    rng = np.random.default_rng(4)
    dev.fetch(rng.integers(0, smoke_graph.num_nodes, 64))
    assert dev.sync_full_uploads == 1        # the initial upload
    dev.fetch(rng.integers(0, smoke_graph.num_nodes, 64))
    assert dev.sync_full_uploads == 1        # FIFO insert → scatter only
    assert dev.sync_row_scatters >= 1
    resident = np.where(dev.cache.device_map >= 0)[0][:3]
    dev.fill_rows(resident, np.zeros((3, smoke_graph.feat_dim), np.float32))
    dev.fetch(resident)
    assert dev.sync_full_uploads == 1        # patch → scatter only
    dev.resize(0.1)
    dev.fetch(resident)
    assert dev.sync_full_uploads == 2        # realloc → full re-upload


def test_incremental_sync_falls_back_when_log_overflows(smoke_graph):
    """More dirty rows than the table holds → replay costs more than a
    full upload; the bounded delta log drops and the mirror re-uploads."""
    dev = DeviceFeaturePlane(smoke_graph, FeatureCache(smoke_graph, 0.01, "fifo"))
    rng = np.random.default_rng(5)
    cap = dev.cache.capacity
    dev.fetch(rng.integers(0, smoke_graph.num_nodes, 8))      # initial upload
    # one fetch inserting far more unique ids than capacity
    big = rng.permutation(smoke_graph.num_nodes)[:4 * cap]
    host = HostFeaturePlane(smoke_graph, FeatureCache(smoke_graph, 0.01, "fifo"))
    host.fetch(rng.integers(0, smoke_graph.num_nodes, 8))
    assert np.array_equal(host.fetch(big), dev.fetch(big))
    # the oversized insert dropped the log; the NEXT sync (triggered by
    # the version bump the insert left behind) must be a full upload
    probe = np.arange(8)
    assert np.array_equal(host.fetch(probe), dev.fetch(probe))
    assert dev.sync_full_uploads == 2        # overflow → full, not scatter
    assert _stats_tuple(host.cache) == _stats_tuple(dev.cache)


def test_device_bytes_reports_resident_buffers(smoke_graph):
    """device_bytes is the ACTUAL HBM footprint: 0 before the first
    upload, table+slot-map bytes while resident, 0 again after delete."""
    dev = DeviceFeaturePlane(smoke_graph, FeatureCache(smoke_graph, 0.05))
    assert dev.device_bytes() == 0           # nothing uploaded yet
    dev.fetch(np.arange(32))
    expect = dev.cache.storage.nbytes + dev.cache.device_map.nbytes
    assert dev.device_bytes() == expect
    for buf in (dev._dev_table, dev._dev_slots):
        buf.delete()
    assert dev.device_bytes() == 0           # deleted buffers don't count
    # cacheless / zero-capacity planes have no mirror at all
    assert DeviceFeaturePlane(smoke_graph, None).device_bytes() == 0
    tiny = DeviceFeaturePlane(smoke_graph, FeatureCache(smoke_graph, 0.0))
    tiny.fetch(np.arange(8))
    assert tiny.device_bytes() == 0
