"""GNN layers/models: shapes, NaNs, learning, kernel-consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.a3gnn import A3GNNTrainer
from repro.core.sampling import NeighborSampler
from repro.graph.batch import generate_batch, batch_device_arrays
from repro.models.gnn import decls_gnn, gnn_forward, _mean_agg
from repro.models.params import init_params
from repro.kernels.segment_agg.ops import neighbor_mean


@pytest.mark.parametrize("model", ["graphsage", "gcn", "gat"])
def test_forward_shapes_and_finite(smoke_graph, smoke_gnn_cfg, model):
    cfg = smoke_gnn_cfg.replace(model=model)
    params = init_params(decls_gnn(cfg), jax.random.PRNGKey(0))
    s = NeighborSampler(smoke_graph, cfg.fanout, seed=0)
    mb = generate_batch(s.sample(np.arange(cfg.batch_size)), None, smoke_graph)
    arrays = batch_device_arrays(mb)
    out = gnn_forward(params, jnp.asarray(arrays["features"]),
                      [jnp.asarray(i) for i in arrays["neigh_idxs"]], cfg)
    assert out.shape == (cfg.batch_size, cfg.num_classes)
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("model,factor", [("graphsage", 0.8), ("gcn", 0.97)])
def test_training_reduces_loss(smoke_graph, smoke_gnn_cfg, model, factor):
    cfg = smoke_gnn_cfg.replace(model=model)
    tr = A3GNNTrainer(smoke_graph, cfg, seed=0)
    res = tr.run_epochs(1, max_steps_per_epoch=20)
    assert np.mean(res.stats.losses[-3:]) < res.stats.losses[0] * factor


def test_accuracy_beats_chance(smoke_graph, smoke_gnn_cfg):
    tr = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
    res = tr.run_epochs(2, max_steps_per_epoch=15)
    chance = 1.0 / smoke_graph.num_classes
    assert res.test_acc > 3 * chance


def test_mean_agg_matches_kernel(smoke_graph):
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.normal(0, 1, (40, 256)), jnp.float32)
    idx = jnp.asarray(rng.integers(-1, 40, (16, 7)), jnp.int32)
    a = _mean_agg(h, idx)
    b = neighbor_mean(idx, h, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                               rtol=1e-5)


def test_chained_padding_invariant(smoke_graph, smoke_gnn_cfg):
    s = NeighborSampler(smoke_graph, smoke_gnn_cfg.fanout, seed=0)
    mb = generate_batch(s.sample(np.arange(64)), None, smoke_graph)
    arrays = batch_device_arrays(mb)
    feats = arrays["features"]
    idxs = arrays["neigh_idxs"]
    # hop i references at most the previous level's padded size
    assert idxs[0].max() < feats.shape[0]
    for a, b in zip(idxs[:-1], idxs[1:]):
        assert b.max() < a.shape[0]
    assert idxs[-1].shape[0] == 64
