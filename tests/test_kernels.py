"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.reservoir.ops import reservoir_topm
from repro.kernels.gather.ops import cache_gather
from repro.kernels.segment_agg.ops import neighbor_mean
from repro.kernels.flash_attention.ops import flash_attention

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
# reservoir
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("R,N,m", [(8, 16, 4), (13, 37, 5), (32, 200, 15),
                                   (8, 128, 25), (1, 5, 3)])
def test_reservoir_matches_ref(R, N, m):
    w = jnp.asarray(RNG.uniform(0.5, 4.0, (R, N)), jnp.float32)
    u = jnp.asarray(RNG.random((R, N)), jnp.float32)
    mask = jnp.asarray(RNG.random((R, N)) < 0.8)
    i1, k1 = reservoir_topm(w, u, mask, m, use_pallas=True)
    i2, k2 = reservoir_topm(w, u, mask, m, use_pallas=False)
    assert np.array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(k1), np.asarray(k2), rtol=1e-6)


def test_reservoir_top_by_key():
    """Kernel selection == numpy top-m of the same ES keys."""
    R, N, m = 6, 50, 7
    w = RNG.uniform(0.5, 4.0, (R, N)).astype(np.float32)
    u = RNG.random((R, N)).astype(np.float32)
    mask = RNG.random((R, N)) < 0.7
    idx, _ = reservoir_topm(jnp.asarray(w), jnp.asarray(u), jnp.asarray(mask), m)
    keys = np.log(np.maximum(u, 1e-30)) / np.maximum(w, 1e-9)
    keys[~mask] = -np.inf
    for r in range(R):
        nv = int(mask[r].sum())
        want = set(np.argsort(-keys[r], kind="stable")[:min(m, nv)].tolist())
        got = np.asarray(idx)[r]
        got = set(got[got < N][:min(m, nv)].tolist())
        assert want == got


def test_reservoir_distribution_matches_sequential():
    """Kernel sampling distribution == Algo. 2 (statistical)."""
    from repro.core.sampling import reservoir_sample_ref
    N, m, trials = 8, 2, 3000
    w = np.array([4, 4, 1, 1, 1, 1, 1, 1], np.float32)
    counts_k = np.zeros(N)
    counts_r = np.zeros(N)
    rng = np.random.default_rng(7)
    us = rng.random((trials, N)).astype(np.float32)
    idx, _ = reservoir_topm(jnp.tile(w, (trials, 1)), jnp.asarray(us),
                            jnp.ones((trials, N), bool), m)
    for row in np.asarray(idx):
        counts_k[row[row < N]] += 1
    rng2 = np.random.default_rng(8)
    for _ in range(trials):
        out = reservoir_sample_ref(np.arange(N), w, m, rng2)
        counts_r[out] += 1
    np.testing.assert_allclose(counts_k / counts_k.sum(),
                               counts_r / counts_r.sum(), atol=0.03)


# ---------------------------------------------------------------------------
# gather
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,C,F", [(8, 16, 256), (37, 64, 512),
                                   (100, 200, 1024), (5, 8, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_matches_ref(n, C, F, dtype):
    cache = jnp.asarray(RNG.normal(0, 1, (C, F))).astype(dtype)
    slots = jnp.asarray(RNG.integers(-1, C, n), jnp.int32)
    o1, m1 = cache_gather(slots, cache, use_pallas=True)
    o2, m2 = cache_gather(slots, cache, use_pallas=False)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32))
    assert np.array_equal(np.asarray(m1), np.asarray(m2))


def test_gather_miss_semantics():
    cache = jnp.ones((8, 128), jnp.float32)
    slots = jnp.asarray([0, -1, 3, -1], jnp.int32)
    out, miss = cache_gather(slots, cache)
    assert np.array_equal(np.asarray(miss), [0, 1, 0, 1])
    assert np.asarray(out)[1].sum() == 0            # miss rows zeroed


@pytest.mark.parametrize("n,C,F", [(37, 16, 602), (5, 8, 300), (3, 4, 700),
                                   (100, 32, 602)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_contract_non_multiple_of_block(n, C, F, dtype):
    """Regression: the miss-path shape/dtype contract must hold for batch
    sizes that are not a multiple of the id block AND feature widths that
    are not a multiple of the feature block (reddit F=602, yelp F=300) —
    the kernel path used to assert out on F % block_f."""
    cache = jnp.asarray(RNG.normal(0, 1, (C, F))).astype(dtype)
    slots = jnp.asarray(RNG.integers(-1, C, n), jnp.int32)
    o1, m1 = cache_gather(slots, cache, use_pallas=True)
    o2, m2 = cache_gather(slots, cache, use_pallas=False)
    for o, m in ((o1, m1), (o2, m2)):
        assert o.shape == (n, F) and m.shape == (n,)
        assert o.dtype == cache.dtype               # no silent promotion
        assert m.dtype == jnp.int32
    assert np.array_equal(np.asarray(o1, np.float32),
                          np.asarray(o2, np.float32))
    assert np.array_equal(np.asarray(m1), np.asarray(m2))
    # padded-row misses never leak into the sliced result
    assert np.array_equal(np.asarray(m1), np.asarray(slots) < 0)


# ---------------------------------------------------------------------------
# segment aggregation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Nd,Ns,F,fan", [(16, 32, 256, 5), (7, 9, 256, 10),
                                         (64, 128, 512, 25), (8, 8, 1024, 3)])
def test_segment_agg_matches_ref(Nd, Ns, F, fan):
    h = jnp.asarray(RNG.normal(0, 1, (Ns, F)), jnp.float32)
    idx = jnp.asarray(RNG.integers(-1, Ns, (Nd, fan)), jnp.int32)
    o1 = neighbor_mean(idx, h, use_pallas=True)
    o2 = neighbor_mean(idx, h, use_pallas=False)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5,
                               rtol=1e-5)


def test_segment_agg_all_padded_row():
    h = jnp.ones((4, 128), jnp.float32)
    idx = jnp.full((2, 5), -1, jnp.int32)
    out = neighbor_mean(idx, h)
    assert np.asarray(out).sum() == 0.0


def test_segment_agg_forwards_interpret_flag(monkeypatch):
    """Regression: the ops wrapper declared ``interpret`` as a static jit
    arg but never forwarded it to the Pallas entry point (which defaults
    to interpret=True) — on a real TPU/GPU the aggregation kernel would
    silently run interpreted.  Spy on the kernel entry point and assert it
    sees the caller's value for both settings."""
    from repro.kernels.segment_agg import ops as agg_ops
    from repro.kernels.segment_agg.kernel import neighbor_agg_pallas
    seen = []

    def spy(idx, h, *args, interpret=True, **kw):
        seen.append(interpret)
        # execute interpreted regardless — compiled Pallas is not
        # available on a CPU test host
        return neighbor_agg_pallas(idx, h, *args, interpret=True, **kw)

    monkeypatch.setattr(agg_ops, "neighbor_agg_pallas", spy)
    h = jnp.ones((11, 128), jnp.float32)          # distinctive shape: the
    idx = jnp.zeros((3, 2), jnp.int32)            # jit cache must retrace
    for flag in (True, False):
        agg_ops.neighbor_mean(idx, h, use_pallas=True, interpret=flag)
    assert seen == [True, False]


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,Dh,H,causal", [(128, 64, 2, True),
                                           (256, 128, 1, True),
                                           (128, 128, 3, False),
                                           (512, 64, 2, True)])
def test_flash_matches_ref_f32(S, Dh, H, causal):
    q = jnp.asarray(RNG.normal(0, 1, (2, S, H, Dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (2, S, H, Dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (2, S, H, Dh)), jnp.float32)
    o1 = flash_attention(q, k, v, causal=causal, use_pallas=True)
    o2 = flash_attention(q, k, v, causal=causal, use_pallas=False)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5,
                               rtol=1e-4)


def test_flash_bf16():
    q = jnp.asarray(RNG.normal(0, 1, (1, 256, 2, 64))).astype(jnp.bfloat16)
    k = jnp.asarray(RNG.normal(0, 1, (1, 256, 2, 64))).astype(jnp.bfloat16)
    v = jnp.asarray(RNG.normal(0, 1, (1, 256, 2, 64))).astype(jnp.bfloat16)
    o1 = flash_attention(q, k, v, use_pallas=True)
    o2 = flash_attention(q, k, v, use_pallas=False)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=3e-2)


def test_flash_matches_model_attention():
    """Kernel == the XLA-native attention used by the LM stack."""
    from repro.models import layers as L
    from repro.configs import get_config
    cfg = get_config("minitron-8b", smoke=True).replace(attn_chunk=0,
                                                        use_rope=False)
    B, S, H, Dh = 2, 128, cfg.num_heads, cfg.head_dim
    q = jnp.asarray(RNG.normal(0, 1, (B, S, H, Dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (B, S, H, Dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (B, S, H, Dh)), jnp.float32)
    o_kernel = flash_attention(q, k, v, causal=True)
    o_model = L._attend(q, k, v,
                        lambda qi, ki: qi[:, None] >= ki[None, :], Dh ** -0.5)
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_model),
                               atol=2e-5, rtol=1e-4)
