"""Property tests for the SLO admission scheduler and its rolling
latency window (serve/common.py) — the decision logic every fabric
dispatch and door verdict runs through.

Runs under the real ``hypothesis`` when installed, or the deterministic
``_hypothesis_compat`` sweep otherwise (CI's fast lane exercises the
shim on purpose).
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.serve.common import LatencyStats, LatencyWindow, SLOAdmission
from repro.serve.gnn_engine import GNNRequest


def _req(submit, first, done):
    return GNNRequest(rid=-1, node=0, t_submit=submit, t_first=first,
                      t_done=done)


def _window(service_ms, n=16, maxlen=64):
    """A window whose service p50 is exactly ``service_ms``."""
    win = LatencyWindow(maxlen)
    for i in range(n):
        t = i * 0.01
        win.record(_req(t, t + 0.001, t + 0.001 + service_ms * 1e-3))
    return win


# ---------------------------------------------------------------------------
# SLOAdmission estimates
# ---------------------------------------------------------------------------

@given(service_ms=st.floats(0.1, 50.0), slots=st.integers(1, 64),
       b0=st.integers(0, 500), db=st.integers(0, 500))
@settings(max_examples=60, deadline=None)
def test_wait_estimate_monotone_in_backlog(service_ms, slots, b0, db):
    """More queued work can never SHRINK the wait estimate — the door
    must get strictly harder to pass as the backlog grows."""
    slo = SLOAdmission(10.0, _window(service_ms), slots=slots)
    lo, hi = slo.wait_estimate_ms(b0), slo.wait_estimate_ms(b0 + db)
    assert hi >= lo
    if db > 0:
        assert hi > lo                       # strictly, with real service time


@given(service_ms=st.floats(0.1, 50.0), backlog=st.integers(0, 500),
       slo_ms=st.floats(0.5, 100.0))
@settings(max_examples=60, deadline=None)
def test_on_offer_consistent_with_estimates(service_ms, backlog, slo_ms):
    """The door verdict is exactly the estimate inequality — no hidden
    state, so an admitted request really was projected to fit."""
    slo = SLOAdmission(slo_ms, _window(service_ms), slots=4)
    projected = slo.wait_estimate_ms(backlog) + slo.service_estimate_ms()
    verdict = slo.on_offer(backlog)
    assert verdict == ("shed" if projected > slo_ms else "admit")
    assert slo.offered == 1
    assert slo.shed == (1 if verdict == "shed" else 0)


@given(service_ms=st.floats(0.1, 50.0), slo_ms=st.floats(0.5, 100.0),
       over_ms=st.floats(0.0, 1000.0), has_capacity=st.booleans())
@settings(max_examples=60, deadline=None)
def test_on_dispatch_never_admits_aged_out(service_ms, slo_ms, over_ms,
                                           has_capacity):
    """A request whose queue age has already crossed the target (age +
    projected service > SLO) is NEVER admitted — completing it late
    would blow the very p99 the scheduler protects.  Aged-out beats
    capacity: even a free slot doesn't resurrect it."""
    slo = SLOAdmission(slo_ms, _window(service_ms), slots=4)
    aged_out = slo_ms - slo.service_estimate_ms() + 1e-6 + over_ms
    assert slo.on_dispatch(aged_out, has_capacity) == "shed"
    assert slo.admitted == 0


@given(age_frac=st.floats(0.0, 0.99), has_capacity=st.booleans())
@settings(max_examples=40, deadline=None)
def test_on_dispatch_inside_deadline_never_sheds(age_frac, has_capacity):
    """Inside the deadline the verdict is capacity-only: admit with a
    slot, defer without — shedding a still-viable request would be
    throwing away latency budget."""
    slo = SLOAdmission(20.0, _window(2.0), slots=4)
    age = age_frac * (20.0 - slo.service_estimate_ms())
    verdict = slo.on_dispatch(age, has_capacity)
    assert verdict == ("admit" if has_capacity else "defer")


@given(backlog=st.integers(0, 10_000), age_ms=st.floats(0.0, 10_000.0))
@settings(max_examples=40, deadline=None)
def test_disabled_slo_is_defer_only(backlog, age_ms):
    """slo_p99_ms ≤ 0: unconditional admission (the pre-SLO fabric) —
    nothing is ever shed, no matter the backlog or age."""
    slo = SLOAdmission(0.0, _window(25.0), slots=1)
    assert slo.on_offer(backlog) == "admit"
    assert slo.on_dispatch(age_ms, True) == "admit"
    assert slo.on_dispatch(age_ms, False) == "defer"
    assert slo.shed == 0


def test_cold_window_admits_everything():
    """No history → no estimate → admit (a cold fabric must learn its
    regime, not shed on superstition)."""
    slo = SLOAdmission(1.0, LatencyWindow(16), slots=1)
    assert slo.service_estimate_ms() == 0.0
    assert slo.on_offer(10_000) == "admit"


# ---------------------------------------------------------------------------
# LatencyWindow memoization
# ---------------------------------------------------------------------------

@given(n=st.integers(1, 40), maxlen=st.integers(1, 16))
@settings(max_examples=40, deadline=None)
def test_stats_memoized_until_record_or_reset(n, maxlen):
    """``stats()`` is cached between mutations (the scheduler consults
    it per offered request), and BOTH mutation paths invalidate it."""
    win = LatencyWindow(maxlen)
    for i in range(n):
        win.record(_req(i * 0.01, i * 0.01 + 0.001, i * 0.01 + 0.004))
    st1 = win.stats()
    assert st1 is win.stats()                # cached: identical object
    assert st1.window == min(n, maxlen)      # rolled to maxlen
    win.record(_req(1.0, 1.001, 1.004))
    st2 = win.stats()
    assert st2 is not st1                    # record() invalidated
    win.reset()
    assert len(win) == 0
    assert win.stats() == LatencyStats()     # reset() invalidated too


@given(vals=st.lists(st.floats(1e-4, 0.5), min_size=1, max_size=24))
@settings(max_examples=40, deadline=None)
def test_window_stats_match_fresh_computation(vals):
    """The memo is an optimization, never a semantic: cached stats equal
    a fresh computation over the same samples."""
    win = LatencyWindow(64)
    for i, total in enumerate(vals):
        win.record(_req(i * 1.0, i * 1.0 + total / 2, i * 1.0 + total))
    cached = win.stats()
    fresh = LatencyWindow(64)
    for i, total in enumerate(vals):
        fresh.record(_req(i * 1.0, i * 1.0 + total / 2, i * 1.0 + total))
    assert cached == fresh.stats()
    assert cached.p50_ms == pytest.approx(
        float(np.percentile([v * 1e3 for v in vals], 50)))
