"""LM family tests: per-arch smoke (reduced config, one forward/train step,
shape + NaN asserts), decode==forward consistency, chunking equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models.api import build
from repro.models.params import init_params

LM_ARCHS = [a for a in list_archs() if not a.startswith("graphsage")]
RNG = np.random.default_rng(0)


def _batch_for(cfg, B, S):
    batch = {"tokens": jnp.asarray(RNG.integers(1, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    batch["targets"] = jnp.asarray(
        RNG.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.family == "encdec":
        batch["audio_embeds"] = jnp.asarray(
            RNG.normal(0, 1, (B, cfg.encoder_seq, cfg.d_model)), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            RNG.normal(0, 1, (B, 8, cfg.d_model)), jnp.bfloat16)
        batch["positions"] = jnp.tile(
            jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, 1))
    return batch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_arch_smoke_train_step(arch):
    """One reduced-config train step on CPU: finite loss, params update."""
    from repro.train.trainer import make_train_step
    from repro.train.optimizer import get_optimizer
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    opt = get_optimizer(cfg)
    step, _ = make_train_step(model, cfg, opt)
    params = init_params(model.decls, jax.random.PRNGKey(0))
    ostate = opt.init(params)
    batch = _batch_for(cfg, 2, 32)
    p2, o2, metrics = jax.jit(step)(params, ostate, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # at least one parameter changed
    changed = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda x, y: bool(jnp.any(x != y)), params, p2))
    assert changed
    # shapes preserved
    jax.tree.map(lambda x, y: None if x.shape == y.shape else
                 pytest.fail("shape changed"), params, p2)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_arch_smoke_decode(arch):
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    params = init_params(model.decls, jax.random.PRNGKey(0))
    B, T = 2, 16
    caches = init_params(model.cache_decls(B, T), jax.random.PRNGKey(1))
    batch = {"token": jnp.asarray([1, 2], jnp.int32),
             "pos": jnp.asarray([0, 0], jnp.int32)}
    if cfg.family == "vlm":
        batch["positions"] = jnp.zeros((3, B, 1), jnp.int32)
    logits, caches2 = jax.jit(model.decode)(params, caches, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mamba2-1.3b", "zamba2-7b",
                                  "whisper-medium"])
def test_prefill_then_decode_matches_forward(arch):
    """Greedy next token from (prefill prompt → decode one) must equal the
    argmax of teacher-forced forward logits at that position."""
    cfg = get_config(arch, smoke=True).replace(compute_dtype="float32")
    model = build(cfg)
    params = init_params(model.decls, jax.random.PRNGKey(0))
    B, S = 2, 12
    batch = _batch_for(cfg, B, S)
    pb = {k: v for k, v in batch.items() if k != "targets"}
    logits_prefill, caches = jax.jit(model.prefill)(params, pb)

    # teacher-forced forward over the same prompt: last-position logits
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models import transformer as T
        from repro.models import layers as L
        h, _ = T.forward(params, pb, cfg)
        W = L.unembed_matrix(params["embed"], cfg, h.dtype)
        ref = np.asarray(jnp.einsum("bd,dv->bv", h[:, -1], W))
    elif cfg.family == "ssm":
        from repro.models.api import _ssm_forward
        from repro.models import layers as L
        h, _ = _ssm_forward(params, pb, cfg)
        W = L.unembed_matrix(params["embed"], cfg, h.dtype)
        ref = np.asarray(jnp.einsum("bd,dv->bv", h[:, -1], W))
    elif cfg.family == "hybrid":
        from repro.models import hybrid as HY
        from repro.models import layers as L
        h, _ = HY.forward(params, pb, cfg)
        W = L.unembed_matrix(params["embed"], cfg, h.dtype)
        ref = np.asarray(jnp.einsum("bd,dv->bv", h[:, -1], W))
    else:  # encdec
        from repro.models import encdec as ED
        from repro.models import layers as L
        enc = ED.encode(params, pb["audio_embeds"], cfg)
        h = ED._decoder_fwd(params, pb["tokens"], enc, cfg)
        W = L.unembed_matrix(params["embed"], cfg, h.dtype)
        ref = np.asarray(jnp.einsum("bd,dv->bv", h[:, -1], W))

    np.testing.assert_allclose(np.asarray(logits_prefill), ref, atol=2e-3,
                               rtol=2e-3)


def test_decode_steps_match_prefill():
    """Decoding tokens one-by-one reproduces prefill's cache contents and
    next-token logits (dense family, f32)."""
    cfg = get_config("llama3.2-3b", smoke=True).replace(
        compute_dtype="float32")
    model = build(cfg)
    params = init_params(model.decls, jax.random.PRNGKey(0))
    B, S = 1, 8
    toks = jnp.asarray(RNG.integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    logits_pre, _ = jax.jit(model.prefill)(params, {"tokens": toks})

    T = 16
    caches = init_params(model.cache_decls(B, T), jax.random.PRNGKey(1))
    decode = jax.jit(model.decode)
    for i in range(S):
        logits_dec, caches = decode(params, caches,
                                    {"token": toks[:, i],
                                     "pos": jnp.full((B,), i, jnp.int32)})
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_pre), atol=2e-3, rtol=2e-3)


def test_chunked_attention_equals_plain():
    cfg0 = get_config("qwen3-4b", smoke=True).replace(compute_dtype="float32",
                                                      attn_chunk=0)
    cfg1 = cfg0.replace(attn_chunk=8)
    model0, model1 = build(cfg0), build(cfg1)
    params = init_params(model0.decls, jax.random.PRNGKey(0))
    batch = _batch_for(cfg0, 2, 32)
    l0, _ = model0.loss_fn(params, batch)
    l1, _ = model1.loss_fn(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)


def test_chunked_loss_equals_plain():
    cfg0 = get_config("glm4-9b", smoke=True).replace(compute_dtype="float32",
                                                     loss_chunk=0)
    cfg1 = cfg0.replace(loss_chunk=8)
    model0, model1 = build(cfg0), build(cfg1)
    params = init_params(model0.decls, jax.random.PRNGKey(0))
    batch = _batch_for(cfg0, 2, 32)
    l0, _ = model0.loss_fn(params, batch)
    l1, _ = model1.loss_fn(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)


def test_remat_does_not_change_loss():
    cfg0 = get_config("qwen3-4b", smoke=True).replace(compute_dtype="float32",
                                                      remat="none")
    params = init_params(build(cfg0).decls, jax.random.PRNGKey(0))
    batch = _batch_for(cfg0, 2, 16)
    losses = {}
    for remat in ("none", "dots", "full"):
        m = build(cfg0.replace(remat=remat))
        losses[remat] = float(m.loss_fn(params, batch)[0])
    assert np.allclose(list(losses.values()), losses["none"], rtol=1e-6)


def test_unroll_matches_scan():
    """force_unroll (dry-run cost probes) is numerically identical."""
    from repro.models.unroll import force_unroll
    cfg = get_config("qwen3-4b", smoke=True).replace(compute_dtype="float32")
    model = build(cfg)
    params = init_params(model.decls, jax.random.PRNGKey(0))
    batch = _batch_for(cfg, 2, 16)
    l0, _ = model.loss_fn(params, batch)
    with force_unroll(True):
        l1, _ = jax.jit(model.loss_fn)(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)


def test_head_padding_exact_equivalence():
    """pad_head_groups: zero-padded wq/wo slices reproduce the unpadded
    model bit-for-bit (per-kv-group padding preserves head→kv mapping)."""
    cfg0 = get_config("llama3.2-3b", smoke=True).replace(
        compute_dtype="float32")
    cfg1 = cfg0.replace(pad_head_groups=True)
    from repro.models.layers import eff_heads
    H, Hkv, Dh = cfg0.num_heads, cfg0.num_kv_heads, cfg0.head_dim
    Hp = eff_heads(cfg1)
    assert Hp % 16 == 0 and Hp >= H
    G, Gp = H // Hkv, Hp // Hkv
    m0, m1 = build(cfg0), build(cfg1)
    p0 = init_params(m0.decls, jax.random.PRNGKey(0))

    def pad_wq(wq):
        L, D = wq.shape[0], wq.shape[1]
        out = np.zeros((L, D, Hp, Dh), np.float32)
        out.reshape(L, D, Hkv, Gp, Dh)[:, :, :, :G] = (
            np.asarray(wq).reshape(L, D, Hkv, G, Dh))
        return jnp.asarray(out)

    def pad_wo(wo):
        L, D = wo.shape[0], wo.shape[-1]
        out = np.zeros((L, Hp, Dh, D), np.float32)
        out.reshape(L, Hkv, Gp, Dh, D)[:, :, :G] = (
            np.asarray(wo).reshape(L, Hkv, G, Dh, D))
        return jnp.asarray(out)

    p1 = jax.tree.map(lambda a: a, p0)
    p1["layers"]["attn"]["wq"] = pad_wq(p0["layers"]["attn"]["wq"])
    p1["layers"]["attn"]["wo"] = pad_wo(p0["layers"]["attn"]["wo"])
    batch = _batch_for(cfg0, 2, 16)
    l0, _ = m0.loss_fn(p0, batch)
    l1, _ = m1.loss_fn(p1, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6)
