"""Parallelism scheduling: modes, fault injection, perf models."""
import numpy as np
import pytest

from repro.core.a3gnn import A3GNNTrainer
from repro.core.perf_model import (StageTimes, MemoryTerms, throughput_seq,
                                   throughput_mode1, throughput_mode2,
                                   memory_seq, memory_mode1, memory_mode2)


@pytest.fixture(scope="module")
def trainer(smoke_graph, smoke_gnn_cfg):
    return A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)


@pytest.mark.slow
def test_all_modes_complete_and_learn(smoke_graph, smoke_gnn_cfg):
    for mode in ("seq", "mode1", "mode2"):
        tr = A3GNNTrainer(smoke_graph,
                          smoke_gnn_cfg.replace(parallel_mode=mode, workers=2),
                          seed=0)
        res = tr.run_epochs(1, max_steps_per_epoch=12)
        assert res.stats.steps == 12
        assert np.isfinite(res.stats.losses).all()
        assert res.stats.losses[-1] < res.stats.losses[0]


def test_worker_failure_reissued(smoke_graph, smoke_gnn_cfg):
    """A dying sampler worker must not lose work items (node-failure path)."""
    cfg = smoke_gnn_cfg.replace(parallel_mode="mode1", workers=2)
    tr = A3GNNTrainer(smoke_graph, cfg, seed=0)
    res = tr.run_epochs(1, max_steps_per_epoch=10, fail_worker=0)
    assert res.stats.steps == 10            # all steps completed
    assert res.stats.reissued >= 1          # failed items re-issued


def test_memory_model_ordering():
    """Eq. (3)/(5): mode1 ≥ mode2 ≥ seq for n ≥ 1 workers."""
    mt = MemoryTerms(cache_bytes=40e6, batch_bytes=30e6, model_bytes=100e6,
                     runtime_bytes=64e6)
    for n in (1, 2, 4, 8):
        m1 = memory_mode1(mt, n)
        m2 = memory_mode2(mt, n)
        ms = memory_seq(mt)
        assert m1 >= m2 >= ms
    # memory grows with workers in both parallel modes
    assert memory_mode1(mt, 4) > memory_mode1(mt, 1)
    assert memory_mode2(mt, 4) > memory_mode2(mt, 1)


def test_throughput_model_amdahl():
    """Eq. (2)/(4): more workers help until the serial stage dominates."""
    st = StageTimes(t_sample=0.08, t_batch=0.02, t_train=0.05)
    seq = throughput_seq(st, 10)
    m1 = [throughput_mode1(st, n, 10) for n in (1, 2, 4, 16)]
    m2 = [throughput_mode2(st, n, 10) for n in (1, 2, 4, 16)]
    assert all(b >= a for a, b in zip(m1, m1[1:]))
    assert m1[-1] == throughput_mode1(st, 64, 10)   # saturated at t_train
    assert m1[-1] >= m2[-1] >= seq
    # mode1 saturation = 1/t_train
    assert np.isclose(m1[-1], 1.0 / (st.t_train * 10))


@pytest.mark.slow
def test_modeled_memory_matches_mode(smoke_graph, smoke_gnn_cfg):
    r = {}
    for mode in ("seq", "mode1", "mode2"):
        tr = A3GNNTrainer(smoke_graph,
                          smoke_gnn_cfg.replace(parallel_mode=mode, workers=3),
                          seed=0)
        res = tr.run_epochs(1, max_steps_per_epoch=4)
        r[mode] = res.memory_bytes
    assert r["mode1"] >= r["mode2"] >= r["seq"]
