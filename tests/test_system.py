"""End-to-end behaviour of the paper's system (replaces the scaffold stub).

Validates the paper's HEADLINE CLAIMS at smoke scale:
  1. locality-aware sampling raises cache hit rate (Fig. 2b / Fig. 7)
  2. the three parallelism modes trade memory for throughput (Fig. 8)
  3. T*/M* Pareto endpoints behave as in Tab. II (T* faster, M* smaller)
  4. dedup shrinks biased batches (memory mechanism of §III-A)
"""
import numpy as np
import pytest

pytestmark = pytest.mark.slow      # end-to-end system runs — full lane only

from repro.configs.gnn import gnn_config
from repro.core.a3gnn import A3GNNTrainer, run_config, apply_baseline
from repro.core.cache import FeatureCache
from repro.core.locality import bias_weight_fn
from repro.core.sampling import NeighborSampler
from repro.graph.synthetic import dataset_like


@pytest.fixture(scope="module")
def graph():
    return dataset_like(gnn_config("reddit", smoke=True), seed=1)


def test_bias_raises_hit_rate_end_to_end(graph):
    cfg = gnn_config("reddit", smoke=True).replace(cache_volume_mb=0.3)
    hits = {}
    for gamma in (1.0, 6.0):
        tr = A3GNNTrainer(graph, cfg.replace(bias_rate=gamma), seed=0)
        res = tr.run_epochs(1, max_steps_per_epoch=8)
        hits[gamma] = res.cache_hit_rate
    assert hits[6.0] > hits[1.0] + 0.02      # the paper's +30% at full scale


def test_bias_shrinks_input_nodes(graph):
    """Biasing concentrates picks → more dedup → smaller input set."""
    cache = FeatureCache(graph, volume_mb=0.3, policy="static")
    sizes = {}
    for gamma in (1.0, 8.0):
        wfn = bias_weight_fn(cache, gamma) if gamma > 1 else None
        s = NeighborSampler(graph, (10, 10), weight_fn=wfn, seed=0)
        n = [s.sample(np.arange(64) + 64 * i).num_input_nodes()
             for i in range(4)]
        sizes[gamma] = np.mean(n)
    assert sizes[8.0] < sizes[1.0]


def test_mode_tradeoffs(graph):
    cfg = gnn_config("reddit", smoke=True).replace(workers=2)
    res = {m: run_config(graph, cfg.replace(parallel_mode=m), max_steps=10)
           for m in ("seq", "mode1", "mode2")}
    # memory ordering (Eqs. 3/5)
    assert (res["mode1"].memory_bytes >= res["mode2"].memory_bytes
            >= res["seq"].memory_bytes)
    # all learn
    for r in res.values():
        assert r.stats.losses[-1] < r.stats.losses[0]


def test_tstar_mstar_endpoints(graph):
    """T* (thr-optimal) vs M* (mem-optimal) behave like Tab. II rows."""
    base = gnn_config("reddit", smoke=True)
    t_star = base.replace(parallel_mode="mode1", workers=3, bias_rate=4.0,
                          cache_volume_mb=0.5)
    m_star = base.replace(parallel_mode="seq", bias_rate=6.0,
                          cache_volume_mb=0.1)
    rt = run_config(graph, t_star, max_steps=12)
    rm = run_config(graph, m_star, max_steps=12)
    assert rm.memory_bytes < rt.memory_bytes
    assert rt.throughput_steps_s > 0 and rm.throughput_steps_s > 0


def test_baseline_adapters(graph):
    cfg = gnn_config("reddit", smoke=True)
    pyg = apply_baseline(cfg, "pyg_like")
    assert pyg.cache_volume_mb == 0 and pyg.parallel_mode == "seq"
    qvr = apply_baseline(cfg, "quiver_like")
    assert qvr.bias_rate == 1.0 and qvr.parallel_mode == "mode1"
    r = run_config(graph, cfg, baseline="pyg_like", max_steps=6)
    assert r.cache_hit_rate == 0.0           # no cache in PyG-like


def test_partitioned_training(graph):
    cfg = gnn_config("reddit", smoke=True).replace(partitions=2)
    tr = A3GNNTrainer(graph, cfg, seed=0)
    assert tr.eta < 0.75                     # partition is a strict subset
    res = tr.run_epochs(1, max_steps_per_epoch=6)
    assert res.stats.steps == 6
    assert tr.predicted_accuracy_drop() > 0  # Eq. (1) partition term
