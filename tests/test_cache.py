"""Feature cache: policies, device map consistency, hit accounting."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.cache import FeatureCache
from repro.core.locality import expected_hit_rate


def test_static_cache_holds_hottest(smoke_graph):
    c = FeatureCache(smoke_graph, volume_mb=0.02, policy="static")
    assert c.capacity > 0
    hot = smoke_graph.hotness_order()[:c.capacity]
    assert c.is_cached(hot).all()
    # cached rows store the right features
    ids = hot[:10]
    np.testing.assert_allclose(c.fetch(ids), smoke_graph.features[ids])


def test_fetch_correct_for_hits_and_misses(smoke_graph):
    c = FeatureCache(smoke_graph, volume_mb=0.02, policy="static")
    rng = np.random.default_rng(0)
    ids = rng.integers(0, smoke_graph.num_nodes, 500)
    np.testing.assert_allclose(c.fetch(ids), smoke_graph.features[ids])
    st_ = c.stats
    assert st_.hits + st_.misses == 500
    assert st_.bytes_from_host == st_.misses * smoke_graph.feat_dim * 4


def test_fifo_inserts_and_evicts(smoke_graph):
    c = FeatureCache(smoke_graph, volume_mb=0.01, policy="fifo")
    rng = np.random.default_rng(0)
    ids = rng.integers(0, smoke_graph.num_nodes, 200)
    c.fetch(ids)
    recent = np.unique(ids)[-3:]
    # repeated fetch of recently-inserted ids must hit
    c.stats.reset()
    c.fetch(ids[-5:])
    assert c.stats.hits > 0
    # device map and slot owner stay consistent
    owners = c.slot_owner[c.slot_owner >= 0]
    for slot, owner in enumerate(c.slot_owner):
        if owner >= 0:
            assert c.device_map[owner] == slot


@given(vol=st.floats(0.001, 0.2), seed=st.integers(0, 100))
@settings(max_examples=15, deadline=None)
def test_device_map_invariant(smoke_graph, vol, seed):
    c = FeatureCache(smoke_graph, volume_mb=vol, policy="fifo")
    rng = np.random.default_rng(seed)
    c.fetch(rng.integers(0, smoke_graph.num_nodes, 300))
    cached = np.where(c.device_map >= 0)[0]
    assert len(cached) <= c.capacity
    # bijection between cached ids and owned slots
    slots = c.device_map[cached]
    assert len(np.unique(slots)) == len(slots)
    assert (c.slot_owner[slots] == cached).all()


def test_zero_volume_cache(smoke_graph):
    c = FeatureCache(smoke_graph, volume_mb=0.0, policy="static")
    assert c.capacity == 0
    ids = np.arange(10)
    np.testing.assert_allclose(c.fetch(ids), smoke_graph.features[ids])
    assert c.stats.hit_rate == 0.0


def test_hit_rate_model_monotone():
    """Analytic model: hit rate grows with γ and with cache fraction."""
    hr = [expected_hit_rate(0.05, g) for g in (1, 2, 4, 8)]
    assert all(b > a for a, b in zip(hr, hr[1:]))
    hr2 = [expected_hit_rate(f, 2.0) for f in (0.01, 0.05, 0.2)]
    assert all(b > a for a, b in zip(hr2, hr2[1:]))
