"""Optimizers, trainer, checkpointing, fault tolerance, compression, data."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.optimizer import (make_adamw, make_adafactor, make_sgd,
                                   make_lion, get_optimizer)
from repro.train.trainer import make_train_step, clip_by_global_norm
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import (TrainSupervisor, HeartbeatMonitor,
                                         StragglerMitigator)
from repro.train.compression import (quantize_int8, dequantize_int8,
                                     ef_compress_topk, ef_init, topk_sparsify,
                                     topk_densify)
from repro.train.data import SyntheticTokens, PrefetchLoader
from repro.models.params import decl, init_params, abstract_params

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make", [make_adamw, make_adafactor, make_sgd,
                                  make_lion])
def test_optimizer_minimizes_quadratic(make):
    opt = make()
    target = jnp.asarray(RNG.normal(0, 1, (4, 8)), jnp.float32)
    params = {"w": jnp.zeros((4, 8))}
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        grads = jax.grad(lambda p: jnp.mean((p["w"] - target) ** 2))(params)
        updates, state = opt.update(grads, state, params, 0.05)
        return jax.tree.map(lambda p, u: p + u, params, updates), state

    l0 = float(jnp.mean((params["w"] - target) ** 2))
    for _ in range(150):
        params, state = step(params, state)
    l1 = float(jnp.mean((params["w"] - target) ** 2))
    assert l1 < 0.1 * l0


@pytest.mark.parametrize("make", [make_adamw, make_adafactor, make_sgd])
def test_state_decls_match_init(make):
    opt = make()
    decls = {"a": decl((6, 4), (None, None)), "b": decl((3,), (None,))}
    params = init_params(decls, jax.random.PRNGKey(0))
    state = opt.init(params)
    adecl = abstract_params(opt.state_decls(decls))
    flat_s = jax.tree.leaves(state)
    flat_d = jax.tree.leaves(adecl)
    assert len(flat_s) == len(flat_d)
    for s, d in zip(flat_s, flat_d):
        assert s.shape == d.shape and s.dtype == d.dtype


def test_adafactor_memory_factored():
    opt = make_adafactor()
    decls = {"w": decl((512, 256), (None, None))}
    st = abstract_params(opt.state_decls(decls))
    n = sum(np.prod(l.shape) for l in jax.tree.leaves(st))
    assert n < 512 * 256 / 10           # way below a full second moment


def test_grad_clip():
    g = {"w": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert np.isclose(float(jnp.linalg.norm(clipped["w"])), 1.0, rtol=1e-4)
    g2 = {"w": jnp.full((10,), 1e-3)}
    clipped2, _ = clip_by_global_norm(g2, 1.0)
    np.testing.assert_allclose(np.asarray(clipped2["w"]),
                               np.asarray(g2["w"]))


def test_grad_accum_equivalence():
    """grad_accum=2 over a batch == accum=1 on the same batch (linear loss
    in batch dim ⇒ identical gradients)."""
    from repro.configs import get_config
    from repro.models.api import build
    cfg = get_config("llama3.2-3b", smoke=True).replace(
        compute_dtype="float32", optimizer="sgd")
    model = build(cfg)
    opt = get_optimizer(cfg)
    params = init_params(model.decls, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(RNG.integers(1, 250, (4, 16)), jnp.int32),
             "targets": jnp.asarray(RNG.integers(0, 250, (4, 16)), jnp.int32)}
    outs = {}
    for ga in (1, 2):
        step, _ = make_train_step(model, cfg, opt, grad_accum=ga)
        p2, _, m = jax.jit(step)(params, opt.init(params), batch)
        outs[ga] = (float(m["loss"]), p2)
    assert np.isclose(outs[1][0], outs[2][0], rtol=1e-5)
    flat1 = jax.tree.leaves(outs[1][1])
    flat2 = jax.tree.leaves(outs[2][1])
    for a, b in zip(flat1, flat2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                                   rtol=1e-4)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tiny_state():
    return {"params": {"w": jnp.asarray(RNG.normal(0, 1, (4, 4)),
                                        jnp.float32),
                       "b": jnp.arange(3, dtype=jnp.float32)},
            "opt_state": {"count": jnp.int32(7)}}


def test_checkpoint_roundtrip(tmp_path):
    cm = CheckpointManager(tmp_path, keep=2, async_save=False)
    state = _tiny_state()
    cm.save(10, state)
    restored, step = cm.restore(state)
    assert step == 10
    jax.tree.map(lambda a, b: np.testing.assert_allclose(np.asarray(a),
                                                         np.asarray(b)),
                 state, restored)


def test_checkpoint_keep_k_and_latest(tmp_path):
    cm = CheckpointManager(tmp_path, keep=2, async_save=False)
    state = _tiny_state()
    for s in (1, 2, 3, 4):
        cm.save(s, state)
    assert cm.all_steps() == [3, 4]
    assert cm.latest_step() == 4


def test_checkpoint_async(tmp_path):
    cm = CheckpointManager(tmp_path, keep=3, async_save=True)
    state = _tiny_state()
    cm.save(5, state)
    cm.wait()
    assert cm.latest_step() == 5


def test_checkpoint_ignores_uncommitted(tmp_path):
    cm = CheckpointManager(tmp_path, keep=3, async_save=False)
    state = _tiny_state()
    cm.save(1, state)
    # fake a torn write
    bad = tmp_path / "step_000000099"
    bad.mkdir()
    (bad / "shard_0.npz").write_bytes(b"garbage")
    assert cm.latest_step() == 1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    cm = CheckpointManager(tmp_path, keep=3, async_save=False)
    cm.save(1, _tiny_state())
    bad = {"params": {"w": jnp.zeros((5, 5)), "b": jnp.zeros(3)},
           "opt_state": {"count": jnp.int32(0)}}
    with pytest.raises(ValueError):
        cm.restore(bad)


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_supervisor_restarts_from_checkpoint(tmp_path):
    cm = CheckpointManager(tmp_path, keep=3, async_save=False)
    fail_at = {12}

    def step_fn(state, step):
        if step in fail_at:
            fail_at.clear()                 # fail exactly once
            raise RuntimeError("simulated node failure")
        return {"params": {"w": state["params"]["w"] + 1.0}}

    state = {"params": {"w": jnp.zeros(())}}
    sup = TrainSupervisor(cm, ckpt_every=5, max_restarts=2)
    final, rep = sup.run(state, step_fn, 20)
    assert rep.failures == 1 and rep.restores == 1
    assert rep.final_step == 20
    # w counts *effective* (non-lost) steps: restart replays 10..20
    assert float(final["params"]["w"]) == 20.0


def test_heartbeat_detects_dead():
    hb = HeartbeatMonitor(3, timeout=0.2)
    hb.beat(0)
    hb.beat(1)
    hb.mark_dead(2)
    assert 2 in hb.dead_workers()
    time.sleep(0.3)
    assert set(hb.dead_workers()) == {0, 1, 2}


def test_straggler_speculative_execution():
    sm = StragglerMitigator(factor=3.0, min_history=3)
    for _ in range(5):
        sm.record(0.01)
    calls = {"n": 0}

    def sometimes_slow():
        calls["n"] += 1
        if calls["n"] == 1:
            time.sleep(0.5)                 # straggling primary
        return 42

    v, winner = sm.run_speculative(sometimes_slow)
    assert v == 42
    assert winner == "backup"               # duplicate won


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_int8_quant_error_bound():
    x = jnp.asarray(RNG.normal(0, 1, (128, 64)), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6


def test_error_feedback_unbiased_over_time():
    """With EF, the *accumulated* compressed signal tracks the accumulated
    true gradient (residual stays bounded)."""
    rng = np.random.default_rng(123)
    g = {"w": jnp.asarray(rng.normal(0, 1, (64,)), jnp.float32)}
    res = ef_init(g)
    total_true = np.zeros(64)
    total_sent = np.zeros(64)
    for i in range(30):
        gi = {"w": g["w"] * (1 + 0.1 * i)}
        sent, res = ef_compress_topk(gi, res, frac=0.25)
        total_true += np.asarray(gi["w"])
        total_sent += np.asarray(sent["w"])
    resid = np.abs(np.asarray(res["w"]))
    drift = np.abs(total_true - total_sent)
    np.testing.assert_allclose(drift, resid, atol=1e-3)   # EF identity
    # residual bounded by ~the latest gradient's scale (EF does not diverge)
    last_scale = np.abs(np.asarray(g["w"])).max() * (1 + 0.1 * 29)
    assert resid.max() < 1.5 * last_scale


def test_topk_roundtrip():
    x = jnp.asarray(RNG.normal(0, 1, (32, 8)), jnp.float32)
    vals, idx = topk_sparsify(x, 0.5)
    dense = topk_densify(vals, idx, x.shape)
    kept = np.asarray(dense) != 0
    assert kept.sum() == int(0.5 * x.size)
    # kept entries match
    np.testing.assert_allclose(np.asarray(dense)[kept],
                               np.asarray(x)[kept])


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_prefetch_loader_preserves_order():
    ds = SyntheticTokens(100, 2, 8, seed=0, n_batches=12)
    sync = [b["tokens"] for b in PrefetchLoader(ds, workers=0)]
    par = [b["tokens"] for b in PrefetchLoader(ds, workers=3)]
    assert len(sync) == len(par) == 12
    for a, b in zip(sync, par):
        assert np.array_equal(a, b)
