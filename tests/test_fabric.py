"""Serving fabric: the ServingEngine contract, partition routing,
replica weight refresh, SLO admission, and graceful degradation under
saturation (the acceptance bar)."""
import time

import numpy as np
import pytest

from repro.core.a3gnn import A3GNNTrainer
from repro.graph.partition import plan_partitions
from repro.serve.common import (EngineBase, LatencyStats, LatencyWindow,
                                ServingEngine, SLOAdmission, latency_stats)
from repro.serve.engine import Engine, Request
from repro.serve.fabric import ServingFabric
from repro.serve.gnn_engine import GNNInferenceEngine, GNNRequest


def _fresh_graph(seed=0, **kw):
    from repro.configs.gnn import gnn_config
    from repro.graph.synthetic import dataset_like
    return dataset_like(gnn_config("products", smoke=True, **kw), seed=seed)


def _fabric(graph, cfg, params, parts=2, **kw):
    plan = plan_partitions(graph, parts, "locality", seed=0, halo_budget=32)
    return plan, ServingFabric.from_plan(graph, plan, cfg, params, **kw)


# ---------------------------------------------------------------------------
# the unified ServingEngine contract
# ---------------------------------------------------------------------------

def test_engines_and_fabric_conform_to_protocol(smoke_graph, smoke_gnn_cfg):
    from repro.configs import get_config
    tr = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
    gnn = GNNInferenceEngine.from_trainer(tr, batch=2, seed=0)
    lm = Engine(get_config("llama3.2-3b", smoke=True), batch=2, max_len=32,
                seed=0)
    _, fab = _fabric(smoke_graph, smoke_gnn_cfg, tr.params, batch=2)
    for eng in (gnn, lm, fab):
        assert isinstance(eng, ServingEngine)
        assert isinstance(eng, EngineBase)


def test_no_engine_local_contract_copies():
    """The concrete slot/drive machinery lives ONCE in EngineBase: an
    engine redefining it is how drive loops drift apart.  (The fabric
    legitimately overrides the slot views — they aggregate a fleet.)"""
    for cls in (Engine, GNNInferenceEngine):
        for name in ("free_slots", "utilization", "run_to_completion",
                     "stats", "has_work"):
            assert getattr(cls, name) is getattr(EngineBase, name), (
                f"{cls.__name__}.{name} shadows EngineBase.{name}")
        assert "drain" not in vars(cls)


def test_fabric_is_dropin_for_one_engine(smoke_graph, smoke_gnn_cfg):
    """A drive loop written against one engine runs the fleet unchanged."""
    tr = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
    _, fab = _fabric(smoke_graph, smoke_gnn_cfg, tr.params, batch=2)
    rng = np.random.default_rng(0)
    for rid, v in enumerate(rng.choice(smoke_graph.num_nodes, 9,
                                       replace=False)):
        fab.submit(GNNRequest(rid=rid, node=int(v)))
    stats = fab.run_to_completion()
    assert stats["completed"] == 9
    assert fab.utilization() == 0.0
    assert len(fab.free_slots()) == fab.batch
    assert isinstance(fab.stats(), LatencyStats)
    for req in fab.completed:
        assert req.status == "done"
        assert 0 <= req.pred < smoke_graph.num_classes


# ---------------------------------------------------------------------------
# partition routing
# ---------------------------------------------------------------------------

def test_routing_follows_ownership(smoke_graph, smoke_gnn_cfg):
    tr = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
    plan, fab = _fabric(smoke_graph, smoke_gnn_cfg, tr.params, parts=3,
                        batch=2)
    rng = np.random.default_rng(1)
    nodes = rng.choice(smoke_graph.num_nodes, 30, replace=False)
    for rid, v in enumerate(nodes):
        fab.submit(GNNRequest(rid=rid, node=int(v)))
    fab.run_to_completion()
    expect = np.bincount(plan.owner_of(nodes), minlength=3)
    assert fab.partition_completed() == list(expect)
    for req in fab.completed:
        assert req.partition == int(plan.owner_of([req.node])[0])


def test_routing_isolates_partition_caches(smoke_graph, smoke_gnn_cfg):
    """Queries for partition 0's nodes move ONLY partition 0's cache
    accounting — the observable proof requests run against the owner's
    plane, not the fleet's."""
    tr = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
    plan, fab = _fabric(smoke_graph, smoke_gnn_cfg, tr.params, batch=2)
    owned0 = np.where(plan.owner_of(np.arange(smoke_graph.num_nodes)) == 0)[0]
    marks = []
    for part in fab.engines:
        st = part[0].plane.stats
        marks.append(st.hits + st.misses)
    for rid, v in enumerate(owned0[:8]):
        fab.submit(GNNRequest(rid=rid, node=int(v)))
    fab.run_to_completion()
    st0 = fab.engines[0][0].plane.stats
    st1 = fab.engines[1][0].plane.stats
    assert st0.hits + st0.misses > marks[0]
    assert st1.hits + st1.misses == marks[1]


# ---------------------------------------------------------------------------
# replication + weight refresh
# ---------------------------------------------------------------------------

def test_replicas_share_load_and_plane(smoke_graph, smoke_gnn_cfg):
    tr = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
    _, fab = _fabric(smoke_graph, smoke_gnn_cfg, tr.params, batch=2,
                     replicas=2)
    assert len(fab.all_engines) == 4                     # 2 parts × 2 reps
    for part in fab.engines:
        assert part[0].plane is part[1].plane            # one warmed cache
    rng = np.random.default_rng(2)
    for rid, v in enumerate(rng.choice(smoke_graph.num_nodes, 16,
                                       replace=False)):
        fab.submit(GNNRequest(rid=rid, node=int(v)))
    stats = fab.run_to_completion()
    assert stats["completed"] == 16


def test_weight_refresh_is_bitexact_and_drops_nothing(smoke_graph):
    """Mid-serving refresh: logits after refresh_weights equal a fresh
    engine's with the same tree, bit for bit, and every request admitted
    before the refresh still retires done.  Full-neighborhood fanout
    makes sampling deterministic, so logits depend only on params."""
    from repro.configs.gnn import gnn_config
    cfg = gnn_config("products", smoke=True).replace(fanout=(64, 64))
    tr = A3GNNTrainer(smoke_graph, cfg, seed=0)
    plan, fab = _fabric(smoke_graph, cfg, tr.params, batch=2, replicas=2)
    probe = int(np.where(plan.owner_of(
        np.arange(smoke_graph.num_nodes)) == 0)[0][0])

    fab.submit(GNNRequest(rid=0, node=probe))
    fab.run_to_completion()
    before = fab.completed[-1].logits.copy()

    # queue a burst, make partial progress, then refresh mid-serving
    rng = np.random.default_rng(3)
    for rid, v in enumerate(rng.choice(smoke_graph.num_nodes, 10,
                                       replace=False)):
        fab.submit(GNNRequest(rid=100 + rid, node=int(v)))
    fab.step()
    tr.run_epochs(1, max_steps_per_epoch=2)
    fab.refresh_weights(tr.get_weights())
    fab.run_to_completion()
    assert fab.total_completed == 1 + 10               # none dropped
    assert all(r.status == "done" for r in fab.completed)

    fab.submit(GNNRequest(rid=1, node=probe))
    fab.run_to_completion()
    after = fab.completed[-1].logits

    # reference: a fresh engine over the SAME partition subgraph (the
    # halo budget truncates neighborhoods, so the full graph is not the
    # comparable baseline) with the refreshed tree
    ref = GNNInferenceEngine(plan.subgraphs[0], cfg,
                             tr.get_weights()["params"], batch=2, seed=99,
                             node_map=plan.node_maps()[0])
    ref.submit(GNNRequest(rid=2, node=probe))
    ref.run_to_completion()
    assert np.array_equal(after, ref.completed[-1].logits)     # bit-exact
    assert not np.array_equal(after, before)                   # and fresh


def test_from_trainer_refresh_pulls_source(smoke_graph, smoke_gnn_cfg):
    from repro.core.multipart import MultiPartitionTrainer
    mp = MultiPartitionTrainer(smoke_graph,
                               smoke_gnn_cfg.replace(partitions=2), seed=0)
    fab = ServingFabric.from_trainer(mp, batch=2, seed=0)
    mp.global_step()
    fab.refresh_weights()                   # no args: pulls from the trainer
    want = mp.get_weights()["params"]
    import jax
    for eng in fab.all_engines:
        same = jax.tree_util.tree_all(jax.tree_util.tree_map(
            lambda a, b: np.array_equal(np.asarray(a), np.asarray(b)),
            eng.params, want))
        assert same


# ---------------------------------------------------------------------------
# SLO admission + shedding
# ---------------------------------------------------------------------------

def _fake_req(submit, first, done):
    return GNNRequest(rid=-1, node=0, t_submit=submit, t_first=first,
                      t_done=done)


def test_slo_admission_verdicts():
    win = LatencyWindow(64)
    slo = SLOAdmission(10.0, win, slots=2)
    assert slo.on_offer(100) == "admit"                # cold window: learn
    for i in range(8):                                 # service ≈ 4 ms
        win.record(_fake_req(i * 0.01, i * 0.01 + 0.001, i * 0.01 + 0.005))
    assert slo.on_offer(0) == "admit"
    assert slo.on_offer(50) == "shed"                  # 50·4/2 ≫ 10 ms
    assert slo.on_dispatch(1.0, True) == "admit"
    assert slo.on_dispatch(1.0, False) == "defer"
    assert slo.on_dispatch(9.5, True) == "shed"        # age + service > slo
    assert slo.offered == 3 and slo.shed == 2
    assert slo.deferrals == 1
    disabled = SLOAdmission(0.0, win, slots=2)
    assert disabled.on_offer(10_000) == "admit"        # SLO off: defer-only


def test_fabric_shed_is_explicit(smoke_graph, smoke_gnn_cfg):
    """A shed request retires with status='shed' and the −1 pred
    sentinel — never a fabricated prediction."""
    tr = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
    _, fab = _fabric(smoke_graph, smoke_gnn_cfg, tr.params, batch=2,
                     slo_p99_ms=5.0)
    now = time.perf_counter()
    for i in range(16):                                # service ≈ 20 ms
        fab.window.record(_fake_req(now, now + 0.001, now + 0.021))
    rng = np.random.default_rng(4)
    for rid, v in enumerate(rng.choice(smoke_graph.num_nodes, 12,
                                       replace=False)):
        fab.submit(GNNRequest(rid=rid, node=int(v)))
    assert fab.slo.shed > 0
    for req in fab.shed_requests:
        assert req.status == "shed"
        assert req.pred == -1
        assert req.logits is None
    assert all(r.rid not in {s.rid for s in fab.shed_requests}
               for r in fab.completed)


@pytest.mark.slow
def test_saturation_degrades_gracefully(smoke_graph, smoke_gnn_cfg):
    """Past saturation: shed fraction rises monotonically with offered
    load while every ADMITTED request's queue age stays inside the SLO
    envelope (age + service ≤ target at dispatch — the bound the door
    enforces)."""
    slo_ms = 5.0
    tr = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
    _, fab = _fabric(smoke_graph, smoke_gnn_cfg, tr.params, batch=2,
                     slo_p99_ms=0.0)
    rng = np.random.default_rng(5)
    pool = rng.choice(smoke_graph.num_nodes, 160, replace=False)
    for w in range(3):                                 # warm: compile + regime
        for rid, v in enumerate(pool[:16]):
            fab.submit(GNNRequest(rid=-100 * w - rid, node=int(v)))
        fab.run_to_completion()

    fab.slo.slo_p99_ms = slo_ms
    fractions = []
    for burst in (4, 32, 128):                         # rising offered load
        mark_off, mark_shed = fab.slo.offered, fab.slo.shed
        for rid, v in enumerate(pool[:burst]):
            fab.submit(GNNRequest(rid=1000 * burst + rid, node=int(v)))
        fab.run_to_completion()
        off = fab.slo.offered - mark_off
        fractions.append((fab.slo.shed - mark_shed) / off)
    assert fractions == sorted(fractions)              # monotone degradation
    assert fractions[-1] > 0.0
    done = [r for r in fab.completed if r.rid >= 0]
    assert done
    for req in done:                                   # bounded queue age
        assert (req.t_first - req.t_submit) * 1e3 <= slo_ms + 500.0


# ---------------------------------------------------------------------------
# latency accounting
# ---------------------------------------------------------------------------

def test_latency_window_rolls_and_memoizes():
    win = LatencyWindow(4)
    for i in range(6):
        win.record(_fake_req(float(i), i + 0.010, i + 0.030))
    assert len(win) == 4                               # oldest evicted
    st = win.stats()
    assert st is win.stats()                           # memoized between records
    assert st.window == 4
    assert st.ttft_p50_ms == pytest.approx(10.0, rel=1e-6)
    assert st.p50_ms == pytest.approx(30.0, rel=1e-6)
    assert st.service_p50_ms == pytest.approx(20.0, rel=1e-6)
    win.record(_fake_req(9.0, 9.1, 9.2))
    assert win.stats() is not st                       # record invalidates
    win.reset()
    assert win.stats() == LatencyStats()


def test_latency_stats_typed_and_dict_shape():
    reqs = [_fake_req(0.0, 0.010, 0.020), _fake_req(0.0, 0.020, 0.100)]
    st = latency_stats(reqs)
    assert isinstance(st, LatencyStats)
    d = st.asdict()
    assert set(d) == {"p50_ms", "p99_ms", "ttft_p50_ms", "ttft_p99_ms",
                      "service_p50_ms", "qps", "window"}
    assert d["window"] == 2
    assert latency_stats([]) == LatencyStats()

    lm_req = Request(rid=0, prompt=np.array([1, 2], np.int32),
                     max_new_tokens=1)
    lm_req.t_submit, lm_req.t_first, lm_req.t_done = 0.0, 0.005, 0.015
    assert latency_stats([lm_req]).ttft_p50_ms == pytest.approx(5.0)
