"""Fused gather+aggregate kernel (kernels/fused_gather_agg) and its wiring:
oracle parity in interpret mode, plane-level host/device bit-exactness with
identical accounting, and end-to-end training parity with the fused flag on
and off — single- and multi-partition."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.a3gnn import A3GNNTrainer
from repro.core.cache import FeatureCache
from repro.core.feature_plane import DeviceFeaturePlane, HostFeaturePlane
from repro.kernels.fused_gather_agg.ops import gather_aggregate

RNG = np.random.default_rng(7)


def _case(Ns, Nd, fan, C, Na, F):
    cache = jnp.asarray(RNG.normal(0, 1, (C, F)), jnp.float32)
    aux = jnp.asarray(RNG.normal(0, 1, (Na, F)), jnp.float32)
    enc = np.where(RNG.random(Ns) < 0.6,
                   RNG.integers(0, C, Ns),
                   -RNG.integers(1, Na + 1, Ns)).astype(np.int32)
    idx = RNG.integers(-1, Ns, (Nd, fan)).astype(np.int32)
    return jnp.asarray(enc), jnp.asarray(idx), cache, aux


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Ns,Nd,fan,C,Na,F",
                         [(32, 16, 5, 24, 8, 256), (37, 11, 3, 16, 5, 128),
                          (9, 9, 4, 8, 3, 602), (64, 40, 7, 50, 20, 300)])
def test_fused_matches_ref(Ns, Nd, fan, C, Na, F):
    enc, idx, cache, aux = _case(Ns, Nd, fan, C, Na, F)
    h1, a1 = gather_aggregate(enc, idx, cache, aux, use_pallas=True,
                              interpret=True)
    h2, a2 = gather_aggregate(enc, idx, cache, aux, use_pallas=False)
    for h, a in ((h1, a1), (h2, a2)):
        assert h.shape == a.shape == (Nd, F)
        assert h.dtype == a.dtype == cache.dtype
    # the self rows are pure copies — bit-exact across backends
    assert np.array_equal(np.asarray(h1), np.asarray(h2))
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), atol=1e-5,
                               rtol=1e-5)


def test_fused_matches_unfused_composition():
    """The fused op == cache_gather∘neighbor_mean on the materialized
    resolved rows (the tensor the fusion avoids)."""
    enc, idx, cache, aux = _case(40, 24, 5, 32, 10, 256)
    h, a = gather_aggregate(enc, idx, cache, aux, use_pallas=False)
    enc_np = np.asarray(enc)
    rows = np.where(enc_np[:, None] >= 0,
                    np.asarray(cache)[np.maximum(enc_np, 0)],
                    np.asarray(aux)[np.maximum(-enc_np - 1, 0)])
    assert np.array_equal(np.asarray(h), rows[:24])
    mask = np.asarray(idx) >= 0
    ref = ((rows[np.maximum(np.asarray(idx), 0)] * mask[..., None]).sum(1)
           / np.maximum(mask.sum(1, keepdims=True), 1))
    np.testing.assert_allclose(np.asarray(a), ref, atol=1e-5, rtol=1e-5)


def test_fused_all_padded_neighbors():
    enc, _, cache, aux = _case(16, 1, 1, 8, 4, 128)
    idx = jnp.full((4, 5), -1, jnp.int32)
    for up in (True, False):
        h, a = gather_aggregate(enc, idx, cache, aux, use_pallas=up,
                                interpret=True)
        assert np.asarray(a).sum() == 0.0            # empty mean is zero
        assert np.asarray(h).shape == (4, 128)


# ---------------------------------------------------------------------------
# plane seam: host/device bit-exactness + identical accounting
# ---------------------------------------------------------------------------

def _stats_tuple(c):
    s = c.stats
    return (s.hits, s.misses, s.evictions, s.bytes_from_cache,
            s.bytes_from_host)


@pytest.mark.parametrize("policy", ["static", "fifo"])
def test_plane_gather_aggregate_parity(smoke_graph, policy):
    host = HostFeaturePlane(smoke_graph, FeatureCache(smoke_graph, 0.05,
                                                      policy))
    dev = DeviceFeaturePlane(smoke_graph, FeatureCache(smoke_graph, 0.05,
                                                       policy))
    rng = np.random.default_rng(0)
    for _ in range(4):
        ids = np.unique(rng.integers(0, smoke_graph.num_nodes, 96))
        n_dst = len(ids) // 2
        idx = rng.integers(-1, len(ids), (n_dst, 5)).astype(np.int32)
        hh, ha = host.gather_aggregate(ids, idx)
        dh, da = dev.gather_aggregate(ids, idx)
        assert np.array_equal(hh, dh)                 # bit-exact self rows
        assert np.array_equal(ha, da)                 # bit-exact aggregate
        np.testing.assert_array_equal(hh, smoke_graph.features[ids[:n_dst]])
    assert _stats_tuple(host.cache) == _stats_tuple(dev.cache)


def test_plane_gather_aggregate_accounting_matches_fetch(smoke_graph):
    """The fused read accounts exactly like the unfused fetch of the same
    ids — the stats stream (throughput model, bias feedback) must not
    notice the flag."""
    a = HostFeaturePlane(smoke_graph, FeatureCache(smoke_graph, 0.05, "fifo"))
    b = HostFeaturePlane(smoke_graph, FeatureCache(smoke_graph, 0.05, "fifo"))
    rng = np.random.default_rng(1)
    for _ in range(3):
        ids = np.unique(rng.integers(0, smoke_graph.num_nodes, 64))
        idx = rng.integers(-1, len(ids), (len(ids) // 2, 4)).astype(np.int32)
        a.fetch(ids)
        b.gather_aggregate(ids, idx)
        assert _stats_tuple(a.cache) == _stats_tuple(b.cache)


def test_plane_gather_aggregate_cacheless(smoke_graph):
    for plane in (HostFeaturePlane(smoke_graph, None),
                  DeviceFeaturePlane(smoke_graph, None)):
        ids = np.arange(24)
        idx = np.array([[0, 1, -1], [2, 2, 3]], np.int32)
        h, agg = plane.gather_aggregate(ids, idx)
        np.testing.assert_array_equal(h, smoke_graph.features[:2])
        want0 = smoke_graph.features[[0, 1]].mean(0)
        np.testing.assert_allclose(agg[0], want0, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# end-to-end: training with the flag on/off, cpu/device, 1 and 2 partitions
# ---------------------------------------------------------------------------

def _params_vec(params):
    import jax
    return np.concatenate([np.ravel(np.asarray(x))
                           for x in jax.tree_util.tree_leaves(params)])


@pytest.mark.parametrize("model", ["graphsage", "gcn", "gat", "gin"])
def test_training_bit_exact_cpu_device_fused_on_and_off(smoke_graph,
                                                        smoke_gnn_cfg,
                                                        model):
    """Acceptance: for EVERY model family, cpu/device training stays
    bit-exact on the same seed with the all-hop fused pipeline both on and
    off; fused vs unfused agree to numerical tolerance (different
    reduction order, same math)."""
    vecs = {}
    for fused in (False, True):
        for dev in ("cpu", "device"):
            cfg = smoke_gnn_cfg.replace(model=model, sampling_device=dev,
                                        fused_gather_agg=fused)
            tr = A3GNNTrainer(smoke_graph, cfg, seed=0)
            tr.run_epochs(1, max_steps_per_epoch=3)
            vecs[(fused, dev)] = _params_vec(tr.params)
    assert np.array_equal(vecs[(False, "cpu")], vecs[(False, "device")])
    assert np.array_equal(vecs[(True, "cpu")], vecs[(True, "device")])
    np.testing.assert_allclose(vecs[(False, "cpu")], vecs[(True, "cpu")],
                               atol=1e-4, rtol=1e-3)


@pytest.mark.parametrize("model", ["graphsage", "gat"])
def test_training_bit_exact_multipartition_fused(smoke_graph, smoke_gnn_cfg,
                                                 model):
    from repro.core.multipart import MultiPartitionTrainer
    cfg0 = smoke_gnn_cfg.replace(model=model, partitions=2, halo_budget=16,
                                 fused_gather_agg=True)
    vecs = {}
    for dev in ("cpu", "device"):
        tr = MultiPartitionTrainer(smoke_graph, cfg0.replace(
            sampling_device=dev), seed=0)
        try:
            for _ in range(2):
                tr.global_step()
            vecs[dev] = _params_vec(tr.params)
        finally:
            for s in tr.slots:
                s.pipe.shutdown()
    assert np.array_equal(vecs["cpu"], vecs["device"])


def test_allfused_single_jit_signature(smoke_graph, smoke_gnn_cfg):
    """Acceptance: ONE forward/backward trace per (model, level_caps) —
    the level-capped buffers keep every batch on one jit signature, so the
    step compiles exactly once no matter how many steps/epochs run."""
    cfg = smoke_gnn_cfg.replace(fused_gather_agg=True)
    tr = A3GNNTrainer(smoke_graph, cfg, seed=0)
    tr.run_epochs(2, max_steps_per_epoch=3)
    c = tr._step_allfused.counters
    assert c["calls"] >= 6
    assert c["traces"] == 1


def test_allfused_multipartition_single_signature(smoke_graph,
                                                  smoke_gnn_cfg):
    """Partition slots share one grad fn — level caps are derived from the
    GLOBAL batch/fanout, so two partition subgraphs still hit one trace."""
    from repro.core.multipart import MultiPartitionTrainer
    cfg = smoke_gnn_cfg.replace(partitions=2, fused_gather_agg=True)
    tr = MultiPartitionTrainer(smoke_graph, cfg, seed=0)
    try:
        for _ in range(3):
            tr.global_step()
    finally:
        for s in tr.slots:
            s.pipe.shutdown()
    c = tr._grad_allfused.counters
    assert c["calls"] == 3 * 2                        # steps × partitions
    assert c["traces"] == 1


# ---------------------------------------------------------------------------
# mode sweeps: the GAT (attention-weighted sum) and GIN (sum) aggregations
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["mean", "sum"])
@pytest.mark.parametrize("Ns,Nd,fan,C,Na,F", [(32, 16, 5, 24, 8, 256),
                                              (9, 9, 4, 8, 3, 602)])
def test_fused_mode_matches_ref(mode, Ns, Nd, fan, C, Na, F):
    """Kernel vs oracle for every aggregation mode the model families use
    (mean: graphsage/gcn; sum: gin and the gat weighted form)."""
    from repro.kernels.fused_gather_agg.ref import gather_aggregate_ref
    enc, idx, cache, aux = _case(Ns, Nd, fan, C, Na, F)
    want_h, want_a = gather_aggregate_ref(enc, idx, cache, aux, mode=mode)
    for up in (True, False):
        h, a = gather_aggregate(enc, idx, cache, aux, mode=mode,
                                use_pallas=up, interpret=True)
        assert np.array_equal(np.asarray(h), np.asarray(want_h))
        np.testing.assert_allclose(np.asarray(a), np.asarray(want_a),
                                   atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("mode,weighted", [("mean", False), ("sum", False),
                                           ("sum", True)])
def test_neighbor_agg_modes_match_ref(mode, weighted):
    """segment_agg generalization: sum mode and per-edge weights (the GAT
    attention path) against the jnp oracle, Pallas and XLA backends."""
    from repro.kernels.segment_agg.ops import neighbor_agg
    from repro.kernels.segment_agg.ref import neighbor_agg_ref
    Nd, Ns, fan, F = 16, 32, 5, 256
    h = jnp.asarray(RNG.normal(0, 1, (Ns, F)), jnp.float32)
    idx = jnp.asarray(RNG.integers(-1, Ns, (Nd, fan)), jnp.int32)
    w = (jnp.asarray(RNG.random((Nd, fan)), jnp.float32)
         if weighted else None)
    want = neighbor_agg_ref(idx, h, mode=mode, weights=w)
    for up in (True, False):
        got = neighbor_agg(idx, h, mode=mode, weights=w, use_pallas=up,
                           interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)


def test_neighbor_agg_weighted_mean_rejected():
    """Attention weights already normalize — weighted mean would silently
    double-normalize on one backend and sum on the other, so BOTH reject."""
    from repro.kernels.segment_agg.ops import neighbor_agg
    from repro.kernels.segment_agg.ref import neighbor_agg_ref
    h = jnp.ones((8, 128), jnp.float32)
    idx = jnp.zeros((4, 2), jnp.int32)
    w = jnp.ones((4, 2), jnp.float32)
    with pytest.raises(ValueError, match="mode='sum'"):
        neighbor_agg(idx, h, mode="mean", weights=w, use_pallas=False)
    with pytest.raises(ValueError, match="mode='sum'"):
        neighbor_agg_ref(idx, h, mode="mean", weights=w)


def test_gat_gin_layers_fused_match_unfused(smoke_gnn_cfg):
    """Layer-level parity for the two newly-fused families: the fused
    branch (weighted neighbor_agg / sum aggregation over the previous
    layer's buffer) == the materialize-then-aggregate branch."""
    import jax
    from repro.models.gnn import decls_gnn, gat_layer, gin_layer
    from repro.models.params import init_params
    Ns, Nd, fan = 48, 24, 5
    h = jnp.asarray(RNG.normal(0, 1, (Ns, 32)), jnp.float32)
    idx = jnp.asarray(RNG.integers(-1, Ns, (Nd, fan)), jnp.int32)
    for model, layer in (("gat", gat_layer), ("gin", gin_layer)):
        cfg = smoke_gnn_cfg.replace(model=model, feat_dim=32)
        p = init_params(decls_gnn(cfg), jax.random.PRNGKey(3))["layers"][0]
        out_u = layer(p, h, idx, fused=False)
        out_f = layer(p, h, idx, fused=True)
        np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_u),
                                   atol=1e-5, rtol=1e-5)


def test_fused_batch_defers_feature_work(smoke_graph, smoke_gnn_cfg):
    """generate_batch(fused=True) touches NO features — the minibatch goes
    out with features=None and zero plane traffic; the train step resolves
    the input hop at step time through FeaturePlane.fused_inputs against
    the level-capped aux sideband."""
    from repro.core.sampling import NeighborSampler
    from repro.graph.batch import (batch_device_arrays, compute_level_caps,
                                   generate_batch)
    from repro.kernels.fused_gather_agg.ref import resolve_rows_ref
    plane = HostFeaturePlane(smoke_graph, FeatureCache(smoke_graph, 0.05))
    sampler = NeighborSampler(smoke_graph, smoke_gnn_cfg.fanout, seed=0)
    seeds = np.arange(32)
    mb = generate_batch(sampler.sample(seeds), plane, smoke_graph,
                        fused=True)
    assert mb.features is None
    # deferral means NO feature traffic at batch-generation time
    assert plane.gather_dispatches == 0 and plane.gather_rows == 0
    assert _stats_tuple(plane.cache) == (0, 0, 0, 0, 0)
    caps = compute_level_caps(len(seeds), smoke_gnn_cfg.fanout,
                              smoke_graph.num_nodes)
    arrays = batch_device_arrays(mb, level_caps=caps)
    assert "features" not in arrays
    assert arrays["pads"] == caps                      # input hop first
    assert len(mb.input_ids) <= caps[0]
    # step-time resolution: encoded slots + sideband == the raw feature rows
    enc, aux, table = plane.fused_inputs(mb.input_ids, caps[0])
    assert plane.gather_dispatches == 1
    assert plane.gather_rows == len(mb.input_ids)
    rows = np.asarray(resolve_rows_ref(enc, table, aux))
    np.testing.assert_array_equal(rows[:len(mb.input_ids)],
                                  smoke_graph.features[mb.input_ids])
    # and the accounting matches an unfused fetch of the same ids
    twin = HostFeaturePlane(smoke_graph, FeatureCache(smoke_graph, 0.05))
    twin.fetch(mb.input_ids)
    assert _stats_tuple(plane.cache) == _stats_tuple(twin.cache)


def test_compute_level_caps_shared_with_serving(smoke_graph, smoke_gnn_cfg):
    """Train and serve derive their pad caps from ONE function — the jit
    signature (model, level_caps) is shared by construction."""
    from repro.graph.batch import compute_level_caps
    from repro.serve.gnn_engine import GNNInferenceEngine
    from repro.models.gnn import decls_gnn
    from repro.models.params import init_params
    caps = compute_level_caps(8, smoke_gnn_cfg.fanout, smoke_graph.num_nodes)
    assert caps == sorted(caps, reverse=True)          # input hop is widest
    assert caps[-1] == 8                               # seed level last
    import jax
    params = init_params(decls_gnn(smoke_gnn_cfg), jax.random.PRNGKey(0))
    eng = GNNInferenceEngine(smoke_graph, smoke_gnn_cfg, params, batch=8)
    assert eng._level_caps == caps


# ---------------------------------------------------------------------------
# pad-plan memoization + plane traffic counters + small-batch perf guard
# ---------------------------------------------------------------------------

def test_pad_plan_memoized_across_dispatches():
    """The (rows, feat, bucket) padding arithmetic is computed once per
    distinct shape and served from the plan table afterwards — repeated
    dispatches at one batch geometry must be pure hits."""
    from repro.kernels import pad_plan as pp
    pp.reset_plan_stats(clear_plans=True)
    # direct: one compute per key, hits afterwards
    assert pp.row_plan(13) == 16
    assert pp.plan_stats() == {"hits": 0, "misses": 1, "entries": 1}
    assert pp.row_plan(13) == 16
    assert pp.plan_stats() == {"hits": 1, "misses": 1, "entries": 1}
    assert pp.feat_plan(602)[1] >= 602                 # padded width
    assert pp.plan_stats()["misses"] == 2
    # through the jitted op: plans are built at TRACE time, so a fresh
    # geometry misses once and a retrace-free second call adds nothing
    # (distinctive shapes — any earlier trace of them would skip planning)
    enc, idx, cache, aux = _case(52, 20, 9, 24, 6, 320)
    gather_aggregate(enc, idx, cache, aux, use_pallas=False)
    first = pp.plan_stats()
    assert first["misses"] > 2                         # this geometry's plans
    gather_aggregate(enc, idx, cache, aux, use_pallas=False)
    assert pp.plan_stats()["misses"] == first["misses"]  # no recomputation


def test_plane_gather_traffic_counters(smoke_graph):
    """gather_dispatches/gather_rows (twin of the sync_* counters) tick on
    every feature read regardless of path — fetch, fused read, or
    step-time fused_inputs — on both planes."""
    for cls in (HostFeaturePlane, DeviceFeaturePlane):
        plane = cls(smoke_graph, FeatureCache(smoke_graph, 0.05))
        assert plane.gather_dispatches == 0 and plane.gather_rows == 0
        ids = np.arange(32)
        plane.fetch(ids)
        assert plane.gather_dispatches == 1 and plane.gather_rows == 32
        idx = np.zeros((4, 2), np.int32)
        plane.gather_aggregate(ids, idx)
        assert plane.gather_dispatches == 2 and plane.gather_rows == 64
        plane.fused_inputs(np.arange(24), 32)
        assert plane.gather_dispatches == 3 and plane.gather_rows == 88


def test_small_batch_fused_inputs_us_per_row(smoke_graph):
    """Small-batch regression guard (kernels CI lane): the step-time fused
    read at n=256 must stay in per-row territory that beats the old
    whole-row device fetch (PR6 measured 2.318 µs/row at n=256 on the
    full-size twin; the fused path measures ~0.4 µs/row here).  The bound
    is deliberately lenient to absorb CI host jitter while still catching
    a return to O(cap) per-batch feature traffic."""
    import time
    plane = DeviceFeaturePlane(smoke_graph, FeatureCache(smoke_graph, 1.0))
    ids = np.arange(256)
    plane.fused_inputs(ids, 256)                       # jit + upload warmup
    plane.fused_inputs(ids, 256)
    best = np.inf
    for _ in range(3):                                 # min-of-3: de-jitter
        t0 = time.perf_counter()
        for _ in range(20):
            plane.fused_inputs(ids, 256)
        best = min(best, (time.perf_counter() - t0) / 20)
    us_per_row = best / 256 * 1e6
    assert us_per_row < 2.3, f"fused small-batch read {us_per_row:.2f} us/row"
