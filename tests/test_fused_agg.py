"""Fused gather+aggregate kernel (kernels/fused_gather_agg) and its wiring:
oracle parity in interpret mode, plane-level host/device bit-exactness with
identical accounting, and end-to-end training parity with the fused flag on
and off — single- and multi-partition."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.a3gnn import A3GNNTrainer
from repro.core.cache import FeatureCache
from repro.core.feature_plane import DeviceFeaturePlane, HostFeaturePlane
from repro.kernels.fused_gather_agg.ops import gather_aggregate

RNG = np.random.default_rng(7)


def _case(Ns, Nd, fan, C, Na, F):
    cache = jnp.asarray(RNG.normal(0, 1, (C, F)), jnp.float32)
    aux = jnp.asarray(RNG.normal(0, 1, (Na, F)), jnp.float32)
    enc = np.where(RNG.random(Ns) < 0.6,
                   RNG.integers(0, C, Ns),
                   -RNG.integers(1, Na + 1, Ns)).astype(np.int32)
    idx = RNG.integers(-1, Ns, (Nd, fan)).astype(np.int32)
    return jnp.asarray(enc), jnp.asarray(idx), cache, aux


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Ns,Nd,fan,C,Na,F",
                         [(32, 16, 5, 24, 8, 256), (37, 11, 3, 16, 5, 128),
                          (9, 9, 4, 8, 3, 602), (64, 40, 7, 50, 20, 300)])
def test_fused_matches_ref(Ns, Nd, fan, C, Na, F):
    enc, idx, cache, aux = _case(Ns, Nd, fan, C, Na, F)
    h1, a1 = gather_aggregate(enc, idx, cache, aux, use_pallas=True,
                              interpret=True)
    h2, a2 = gather_aggregate(enc, idx, cache, aux, use_pallas=False)
    for h, a in ((h1, a1), (h2, a2)):
        assert h.shape == a.shape == (Nd, F)
        assert h.dtype == a.dtype == cache.dtype
    # the self rows are pure copies — bit-exact across backends
    assert np.array_equal(np.asarray(h1), np.asarray(h2))
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), atol=1e-5,
                               rtol=1e-5)


def test_fused_matches_unfused_composition():
    """The fused op == cache_gather∘neighbor_mean on the materialized
    resolved rows (the tensor the fusion avoids)."""
    enc, idx, cache, aux = _case(40, 24, 5, 32, 10, 256)
    h, a = gather_aggregate(enc, idx, cache, aux, use_pallas=False)
    enc_np = np.asarray(enc)
    rows = np.where(enc_np[:, None] >= 0,
                    np.asarray(cache)[np.maximum(enc_np, 0)],
                    np.asarray(aux)[np.maximum(-enc_np - 1, 0)])
    assert np.array_equal(np.asarray(h), rows[:24])
    mask = np.asarray(idx) >= 0
    ref = ((rows[np.maximum(np.asarray(idx), 0)] * mask[..., None]).sum(1)
           / np.maximum(mask.sum(1, keepdims=True), 1))
    np.testing.assert_allclose(np.asarray(a), ref, atol=1e-5, rtol=1e-5)


def test_fused_all_padded_neighbors():
    enc, _, cache, aux = _case(16, 1, 1, 8, 4, 128)
    idx = jnp.full((4, 5), -1, jnp.int32)
    for up in (True, False):
        h, a = gather_aggregate(enc, idx, cache, aux, use_pallas=up,
                                interpret=True)
        assert np.asarray(a).sum() == 0.0            # empty mean is zero
        assert np.asarray(h).shape == (4, 128)


# ---------------------------------------------------------------------------
# plane seam: host/device bit-exactness + identical accounting
# ---------------------------------------------------------------------------

def _stats_tuple(c):
    s = c.stats
    return (s.hits, s.misses, s.evictions, s.bytes_from_cache,
            s.bytes_from_host)


@pytest.mark.parametrize("policy", ["static", "fifo"])
def test_plane_gather_aggregate_parity(smoke_graph, policy):
    host = HostFeaturePlane(smoke_graph, FeatureCache(smoke_graph, 0.05,
                                                      policy))
    dev = DeviceFeaturePlane(smoke_graph, FeatureCache(smoke_graph, 0.05,
                                                       policy))
    rng = np.random.default_rng(0)
    for _ in range(4):
        ids = np.unique(rng.integers(0, smoke_graph.num_nodes, 96))
        n_dst = len(ids) // 2
        idx = rng.integers(-1, len(ids), (n_dst, 5)).astype(np.int32)
        hh, ha = host.gather_aggregate(ids, idx)
        dh, da = dev.gather_aggregate(ids, idx)
        assert np.array_equal(hh, dh)                 # bit-exact self rows
        assert np.array_equal(ha, da)                 # bit-exact aggregate
        np.testing.assert_array_equal(hh, smoke_graph.features[ids[:n_dst]])
    assert _stats_tuple(host.cache) == _stats_tuple(dev.cache)


def test_plane_gather_aggregate_accounting_matches_fetch(smoke_graph):
    """The fused read accounts exactly like the unfused fetch of the same
    ids — the stats stream (throughput model, bias feedback) must not
    notice the flag."""
    a = HostFeaturePlane(smoke_graph, FeatureCache(smoke_graph, 0.05, "fifo"))
    b = HostFeaturePlane(smoke_graph, FeatureCache(smoke_graph, 0.05, "fifo"))
    rng = np.random.default_rng(1)
    for _ in range(3):
        ids = np.unique(rng.integers(0, smoke_graph.num_nodes, 64))
        idx = rng.integers(-1, len(ids), (len(ids) // 2, 4)).astype(np.int32)
        a.fetch(ids)
        b.gather_aggregate(ids, idx)
        assert _stats_tuple(a.cache) == _stats_tuple(b.cache)


def test_plane_gather_aggregate_cacheless(smoke_graph):
    for plane in (HostFeaturePlane(smoke_graph, None),
                  DeviceFeaturePlane(smoke_graph, None)):
        ids = np.arange(24)
        idx = np.array([[0, 1, -1], [2, 2, 3]], np.int32)
        h, agg = plane.gather_aggregate(ids, idx)
        np.testing.assert_array_equal(h, smoke_graph.features[:2])
        want0 = smoke_graph.features[[0, 1]].mean(0)
        np.testing.assert_allclose(agg[0], want0, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# end-to-end: training with the flag on/off, cpu/device, 1 and 2 partitions
# ---------------------------------------------------------------------------

def _params_vec(params):
    import jax
    return np.concatenate([np.ravel(np.asarray(x))
                           for x in jax.tree_util.tree_leaves(params)])


def test_training_bit_exact_cpu_device_fused_on_and_off(smoke_graph,
                                                        smoke_gnn_cfg):
    """Acceptance: cpu/device training stays bit-exact on the same seed
    with the fused kernel both on and off; fused vs unfused agree to
    numerical tolerance (different reduction order, same math)."""
    vecs = {}
    for fused in (False, True):
        for dev in ("cpu", "device"):
            cfg = smoke_gnn_cfg.replace(sampling_device=dev,
                                        fused_gather_agg=fused)
            tr = A3GNNTrainer(smoke_graph, cfg, seed=0)
            tr.run_epochs(1, max_steps_per_epoch=3)
            vecs[(fused, dev)] = _params_vec(tr.params)
    assert np.array_equal(vecs[(False, "cpu")], vecs[(False, "device")])
    assert np.array_equal(vecs[(True, "cpu")], vecs[(True, "device")])
    np.testing.assert_allclose(vecs[(False, "cpu")], vecs[(True, "cpu")],
                               atol=1e-4, rtol=1e-3)


def test_training_bit_exact_multipartition_fused(smoke_graph, smoke_gnn_cfg):
    from repro.core.multipart import MultiPartitionTrainer
    cfg0 = smoke_gnn_cfg.replace(partitions=2, halo_budget=16,
                                 fused_gather_agg=True)
    vecs = {}
    for dev in ("cpu", "device"):
        tr = MultiPartitionTrainer(smoke_graph, cfg0.replace(
            sampling_device=dev), seed=0)
        try:
            for _ in range(2):
                tr.global_step()
            vecs[dev] = _params_vec(tr.params)
        finally:
            for s in tr.slots:
                s.pipe.shutdown()
    assert np.array_equal(vecs["cpu"], vecs["device"])


def test_fused_batch_carries_preaggregates(smoke_graph, smoke_gnn_cfg):
    """generate_batch(fused=True) emits (fused_h_dst, fused_agg) and no
    feature tensor; batch_device_arrays pads them to the dst level."""
    from repro.core.sampling import NeighborSampler
    from repro.graph.batch import batch_device_arrays, batch_bytes, \
        generate_batch
    plane = HostFeaturePlane(smoke_graph, FeatureCache(smoke_graph, 0.05))
    sampler = NeighborSampler(smoke_graph, smoke_gnn_cfg.fanout, seed=0)
    seeds = np.arange(32)
    mb = generate_batch(sampler.sample(seeds), plane, smoke_graph,
                        fused=True)
    assert mb.features is None
    n_dst0 = len(mb.blocks[0].dst_ids)
    assert mb.fused_h_dst.shape == mb.fused_agg.shape == \
        (n_dst0, smoke_graph.feat_dim)
    assert batch_bytes(mb) > 0
    arrays = batch_device_arrays(mb)
    assert "features" not in arrays
    assert arrays["h_dst0"].shape == arrays["agg0"].shape
    assert arrays["h_dst0"].shape[0] >= n_dst0        # pow2-padded dst level
    # chained-padding invariant: pre-aggregates live at hop 0's dst level,
    # i.e. the padded row count of hop 0's neighbor matrix
    assert arrays["h_dst0"].shape[0] == arrays["neigh_idxs"][0].shape[0]
    # the unfused twin of the same minibatch agrees with the pre-aggregates
    mb2 = generate_batch(dataclasses.replace(mb, fused_h_dst=None,
                                             fused_agg=None),
                         None, smoke_graph)
    np.testing.assert_array_equal(mb.fused_h_dst,
                                  mb2.features[:n_dst0])
