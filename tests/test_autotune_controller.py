"""Online auto-tuning controller: episode-boundary reconfiguration
(drain → reconfigure → resume), measured-Pareto properties, and the
closed-loop acceptance run (fit_autotuned beats the fixed seed config)."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.configs.gnn import AutotuneConfig
from repro.core.a3gnn import A3GNNTrainer
from repro.core.autotune.controller import (AutotuneController,
                                            AutotuneReport, Episode,
                                            episode_space)
from repro.core.cache import FeatureCache
from repro.core.pipeline import Pipeline
from repro.core.sampling import seed_loader


# ---------------------------------------------------------------------------
# episode-boundary reconfiguration
# ---------------------------------------------------------------------------

def test_cache_resize_preserves_hit_accounting(smoke_graph):
    c = FeatureCache(smoke_graph, volume_mb=0.05, policy="static")
    rng = np.random.default_rng(0)
    ids = rng.integers(0, smoke_graph.num_nodes, 400)
    c.fetch(ids)
    stats_before = (c.stats.hits, c.stats.misses, c.stats.bytes_from_cache,
                    c.stats.bytes_from_host)
    assert c.stats.hits + c.stats.misses == 400
    stats_obj = c.stats

    cap_before = c.capacity
    c.resize(0.1)                       # grow
    assert c.capacity > cap_before
    assert c.stats is stats_obj         # same accounting object
    assert (c.stats.hits, c.stats.misses, c.stats.bytes_from_cache,
            c.stats.bytes_from_host) == stats_before
    # still serves correct features, and accounting keeps accruing
    np.testing.assert_allclose(c.fetch(ids[:50]),
                               smoke_graph.features[ids[:50]])
    assert c.stats.hits + c.stats.misses == 450

    c.resize(0.02)                      # shrink below the original
    assert 0 < c.capacity < cap_before
    # device_map and slot_owner stay mutually consistent after resize
    cached = np.where(c.device_map >= 0)[0]
    assert len(cached) == c.capacity
    assert (c.slot_owner[c.device_map[cached]] == cached).all()


def test_fifo_resize_keeps_newest_residents(smoke_graph):
    c = FeatureCache(smoke_graph, volume_mb=0.05, policy="fifo")
    c.fetch(np.arange(c.capacity * 2))          # fill + wrap
    newest = c.slot_owner[c.slot_owner >= 0]
    c.resize(0.02)
    survivors = c.slot_owner[c.slot_owner >= 0]
    assert len(survivors) == c.capacity
    assert set(survivors) <= set(newest)        # no resurrected evictees
    np.testing.assert_allclose(c.fetch(survivors),
                               smoke_graph.features[survivors])


def test_gamma_swap_changes_reservoir_weights(smoke_graph, smoke_gnn_cfg):
    tr = A3GNNTrainer(smoke_graph, smoke_gnn_cfg.replace(bias_rate=2.0),
                      seed=0)
    cached = np.where(tr.cache.device_map >= 0)[0][:16]
    uncached = np.where(tr.cache.device_map < 0)[0][:16]
    np.testing.assert_allclose(tr.weight_fn(cached), 2.0)
    np.testing.assert_allclose(tr.weight_fn(uncached), 1.0)

    tr.apply_live_config({"bias_rate": 8.0})
    assert tr.cfg.bias_rate == 8.0
    np.testing.assert_allclose(tr.weight_fn(cached), 8.0)
    np.testing.assert_allclose(tr.weight_fn(uncached), 1.0)

    tr.apply_live_config({"bias_rate": 1.0})    # γ=1 → uniform sampling
    assert tr.weight_fn is None


def test_mode_switch_drains_queue_without_dropping(smoke_graph,
                                                   smoke_gnn_cfg):
    cfg = smoke_gnn_cfg.replace(parallel_mode="mode1", workers=2)
    tr = A3GNNTrainer(smoke_graph, cfg, seed=0)
    pipe = Pipeline(smoke_graph, cfg, tr._train_fn, cache=tr.cache,
                    weight_fn=tr.weight_fn, seed=0)
    try:
        batches = list(seed_loader(smoke_graph, cfg.batch_size, 0))[:8]
        pipe.begin_stats()
        pipe.submit(batches)
        # consume a few, then switch modes with work still in flight
        for _ in range(3):
            assert pipe.step()
        assert pipe.inflight == 5
        pipe.reconfigure(mode="mode2")          # drain → swap → resume
        assert pipe.inflight == 0
        assert pipe.stats.steps == 8            # nothing dropped
        assert pipe.mode == "mode2"
        # resumed execution under the new mode still works
        pipe.submit(batches[:2])
        pipe.drain()
        assert pipe.stats.steps == 10
    finally:
        pipe.shutdown()


def test_reconfigure_swaps_gamma_and_cache_live(smoke_graph, smoke_gnn_cfg):
    tr = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
    pipe = Pipeline(smoke_graph, tr.cfg, tr._train_fn, cache=tr.cache,
                    weight_fn=tr.weight_fn, seed=0)
    try:
        old_cache = tr.cache
        tr.apply_live_config({"bias_rate": 8.0, "cache_volume_mb": 0.5,
                              "parallel_mode": "mode2", "workers": 3}, pipe)
        assert tr.cache is old_cache            # resized, not rebuilt
        assert pipe.cache is tr.cache
        assert pipe.weight_fn is tr.weight_fn
        assert pipe.mode == "mode2" and pipe.workers_n == 3
        stats = pipe.run(max_steps=3)
        assert stats.steps == 3
    finally:
        pipe.shutdown()


# ---------------------------------------------------------------------------
# Pareto-frontier property
# ---------------------------------------------------------------------------

@given(n=st.integers(3, 30), seed=st.integers(0, 999))
@settings(max_examples=25, deadline=None)
def test_pareto_frontier_points_dominate_no_other(n, seed):
    """Every point the report exposes as Pareto-optimal must not be
    dominated by ANY measured episode (not just frontier members)."""
    rng = np.random.default_rng(seed)
    report = AutotuneReport()
    for i in range(n):
        thr, mem, acc = rng.random(3)
        report.episodes.append(Episode(
            index=i, config={"bias_rate": 1.0 + i},
            metrics={"throughput": thr, "memory": mem, "accuracy": acc},
            reward=thr, cache_hit_rate=0.0, steps=1))
    front = report.pareto_points()
    assert front                                 # never empty for n ≥ 1
    all_pts = np.array([[e.metrics["throughput"], -e.metrics["memory"],
                         e.metrics["accuracy"]] for e in report.episodes])
    for ep in front:
        p = np.array([ep.metrics["throughput"], -ep.metrics["memory"],
                      ep.metrics["accuracy"]])
        dominated = (np.all(all_pts >= p, axis=1)
                     & np.any(all_pts > p, axis=1))
        assert not dominated.any()


def test_episode_space_decodes_live_knobs():
    acfg = AutotuneConfig()
    sp = episode_space(acfg)
    knob_names = {k.name for k in sp.knobs}
    # batch_size / sampling_device knobs stay out of the space until gated on
    assert "batch_size" not in knob_names
    assert "sampling_device" not in knob_names
    rng = np.random.default_rng(0)
    for u in sp.sample(rng, 32):
        cfg = sp.decode(u)
        assert 1.0 <= cfg["bias_rate"] <= acfg.max_bias_rate
        assert 0.0 < cfg["cache_volume_mb"] <= acfg.max_cache_mb
        assert cfg["parallel_mode"] in ("seq", "mode1", "mode2")
        assert 1 <= cfg["workers"] <= acfg.max_workers


def test_episode_space_gates_batch_size_and_sampling_device():
    acfg = AutotuneConfig(max_batch_size=256, tune_sampling_device=True)
    sp = episode_space(acfg)
    rng = np.random.default_rng(0)
    seen_dev = set()
    for u in sp.sample(rng, 64):
        cfg = sp.decode(u)
        assert 16 <= cfg["batch_size"] <= 256
        assert cfg["sampling_device"] in ("cpu", "device")
        seen_dev.add(cfg["sampling_device"])
    assert seen_dev == {"cpu", "device"}            # both backends reachable


def test_batch_size_applies_live(smoke_graph, smoke_gnn_cfg):
    """The batch_size knob rides Pipeline.reconfigure: applied live, the
    next run window samples seed batches of the new size."""
    tr = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
    pipe = tr.make_pipeline()
    try:
        pipe.run(max_steps=2)
        tr.apply_live_config({"batch_size": 32}, pipe)
        assert tr.cfg.batch_size == 32 and pipe.batch_size == 32
        stats = pipe.run(max_steps=2)
        assert stats.steps == 2
    finally:
        pipe.shutdown()


def test_throughput_source_auto_switch(monkeypatch):
    """MEASURE uses wall-clock throughput on multi-core hosts and the
    Eq. 2/4 model on 1-core hosts; explicit settings always win."""
    from repro.core.autotune import controller as C
    acfg = AutotuneConfig()                          # auto
    monkeypatch.setattr(C, "available_cpus", lambda: 1)
    assert C.resolve_throughput_source(acfg) == "modeled"
    monkeypatch.setattr(C, "available_cpus", lambda: 4)
    assert C.resolve_throughput_source(acfg) == "wallclock"
    # available_cpus respects the scheduler affinity mask (cgroup pinning)
    monkeypatch.undo()
    if hasattr(C.os, "sched_getaffinity"):
        monkeypatch.setattr(C.os, "sched_getaffinity", lambda pid: {0})
        assert C.available_cpus() == 1
        assert C.resolve_throughput_source(acfg) == "modeled"
    assert C.resolve_throughput_source(
        acfg.replace(throughput_source="modeled")) == "modeled"
    assert C.resolve_throughput_source(
        acfg.replace(throughput_source="wallclock")) == "wallclock"
    with pytest.raises(ValueError):
        C.resolve_throughput_source(acfg.replace(throughput_source="x"))


def test_measure_respects_throughput_source(smoke_graph, smoke_gnn_cfg):
    """Pinned "modeled" reproduces the Eq. 2/4 number; pinned "wallclock"
    reports steps/t_wall — both from the same measured episode."""
    from repro.core.perf_model import bottleneck_step_time
    for source in ("modeled", "wallclock"):
        tr = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
        pipe = tr.make_pipeline()
        acfg = AutotuneConfig(steps_per_episode=3, warmup_steps=0,
                              throughput_source=source, seed=0)
        ctrl = AutotuneController(tr, pipe, acfg)
        try:
            ep = ctrl.measure(0, ctrl._current_config())
        finally:
            pipe.shutdown()
        if source == "modeled":
            want = 1.0 / max(bottleneck_step_time(
                pipe.mode, pipe.stats.stage_times(), pipe.workers_n), 1e-9)
        else:
            want = pipe.stats.throughput_steps_per_s()
        assert ep.metrics["throughput"] == pytest.approx(want)


# ---------------------------------------------------------------------------
# closed-loop acceptance: fit_autotuned on a synthetic graph
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def autotune_report(smoke_graph, smoke_gnn_cfg):
    tr = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
    acfg = AutotuneConfig(episodes=4, steps_per_episode=5, warmup_steps=2,
                          presample=48, surrogate_trees=16, ppo_updates=2,
                          ppo_horizon=6, max_workers=3,
                          w_throughput=1.0, w_memory=0.0, w_accuracy=0.0,
                          seed=0)
    return tr.fit_autotuned(acfg), tr


def test_fit_autotuned_completes_episodes(autotune_report):
    rep, _ = autotune_report
    assert len(rep.episodes) >= 3                  # ≥3 autotune episodes
    assert all(ep.steps == 5 for ep in rep.episodes)
    for ep in rep.episodes:
        for m in ("throughput", "memory", "accuracy"):
            assert np.isfinite(ep.metrics[m])


def test_fit_autotuned_changes_a_knob(autotune_report):
    rep, _ = autotune_report
    changed = rep.changed_knobs()
    assert {"bias_rate", "cache_volume_mb", "parallel_mode"} & set(changed), \
        f"no tuned knob changed across episodes: {changed}"


def test_fit_autotuned_beats_fixed_baseline(autotune_report):
    """Final measured throughput ≥ the fixed seed-config baseline, measured
    in the SAME run (episode 0 is the seed configuration)."""
    rep, tr = autotune_report
    assert rep.baseline.index == 0
    assert (rep.final_metrics["throughput"]
            >= rep.baseline_metrics["throughput"])
    # the trainer is left running the recommended configuration
    best = rep.best.config
    assert tr.cfg.parallel_mode == best["parallel_mode"]
    assert np.isclose(tr.cfg.bias_rate, best["bias_rate"])


def test_fit_autotuned_from_cacheless_config(smoke_graph, smoke_gnn_cfg):
    """A cache-less seed config (Θ=0, e.g. the pyg_like shape) must be
    recorded truthfully in the baseline episode and the controller must be
    able to bootstrap a cache live."""
    cfg = smoke_gnn_cfg.replace(cache_volume_mb=0.0, bias_rate=1.0)
    tr = A3GNNTrainer(smoke_graph, cfg, seed=0)
    assert tr.cache is None
    acfg = AutotuneConfig(episodes=3, steps_per_episode=4, warmup_steps=0,
                          presample=24, surrogate_trees=8, ppo_updates=1,
                          ppo_horizon=4, seed=0)
    rep = tr.fit_autotuned(acfg)
    assert rep.baseline.config["cache_volume_mb"] == 0.0
    assert rep.baseline.cache_hit_rate == 0.0
    # later episodes created a real cache live
    assert any(ep.config["cache_volume_mb"] > 0 for ep in rep.episodes[1:])
    # the trainer ends on the recommendation: cache state matches its Θ
    best_vol = rep.best.config["cache_volume_mb"]
    assert (tr.cache is None) == (best_vol <= 0)


def test_fit_autotuned_all_infeasible_flags_report(smoke_graph,
                                                   smoke_gnn_cfg):
    """An impossible memory budget must be reported, not silently ignored:
    best falls back to the least-memory measured point, flagged."""
    tr = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
    acfg = AutotuneConfig(episodes=2, steps_per_episode=3, warmup_steps=0,
                          presample=24, surrogate_trees=8, ppo_updates=1,
                          ppo_horizon=4, memory_limit_bytes=1.0, seed=0)
    rep = tr.fit_autotuned(acfg)
    assert rep.best_feasible is False
    assert rep.best.metrics["memory"] == min(
        ep.metrics["memory"] for ep in rep.episodes)


def test_shutdown_discards_backlog_without_training(smoke_graph,
                                                    smoke_gnn_cfg):
    """shutdown() runs in `finally` during exception unwind — it must NOT
    re-enter train_fn on the pending backlog (that would mask the error)."""
    calls = {"n": 0}

    def counting_train_fn(mb):
        calls["n"] += 1
        return 0.0, 0.0

    pipe = Pipeline(smoke_graph, smoke_gnn_cfg, counting_train_fn, seed=0)
    batches = list(seed_loader(smoke_graph, smoke_gnn_cfg.batch_size, 0))[:6]
    pipe.submit(batches)
    pipe.step()
    assert calls["n"] == 1 and pipe.inflight == 5
    pipe.shutdown()
    assert calls["n"] == 1                      # backlog discarded untrained
    assert pipe.inflight == 0


def test_fit_autotuned_feedback_reaches_surrogate(smoke_graph,
                                                  smoke_gnn_cfg):
    """Measured points must land in the surrogate training set (FEEDBACK)."""
    tr = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
    pipe = Pipeline(smoke_graph, tr.cfg, tr._train_fn, cache=tr.cache,
                    weight_fn=tr.weight_fn, seed=0)
    acfg = AutotuneConfig(episodes=2, steps_per_episode=3, warmup_steps=0,
                          presample=24, surrogate_trees=8, ppo_updates=1,
                          ppo_horizon=4, seed=0)
    ctrl = AutotuneController(tr, pipe, acfg)
    try:
        rep = ctrl.run()
    finally:
        pipe.shutdown()
    # presample analytic points + one per measured episode
    assert len(ctrl._X) == acfg.presample + len(rep.episodes)
    assert len(ctrl._measured_keys) == len(
        {tuple(sorted(e.config.items())) for e in rep.episodes})
