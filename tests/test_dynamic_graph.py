"""Dynamic-graph substrate differential harness (graph/storage.py delta-CSR
overlay → compaction → ``topology_version``; graph/partition.py incremental
re-balancing; serve/fabric.py topology-consistent serving).

Three differential anchors, each comparing the production path against an
independent model of the same semantics:

  (a) sampling over base+overlay is BIT-EXACT with sampling over the
      compacted CSR at the same seed and ``topology_version`` (the merged
      view and the folded base are the same arrays — verified against a
      dict-of-lists reference model so the check isn't circular);
  (b) budget-0 subgraphs after an incremental re-balance equal those from
      a fresh finalize over the mutated graph (nothing in the plan is
      stale), with the acceptance envelope: < 25% of nodes moved, cut
      fraction within 10% of a from-scratch partition;
  (c) mid-serving edge inserts never change predictions for queries
      admitted before the version bump (replicas sample frozen subgraph
      copies; the mutation reaches serving only through
      ``ServingFabric.refresh_topology``).

Property sweeps run through tests/_hypothesis_compat.py: real hypothesis
search when the extra is installed, a deterministic seeded fixed-case
sweep otherwise (the CI fast lane covers the shim path).
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs.gnn import gnn_config
from repro.core.feature_plane import DeviceFeaturePlane, HostFeaturePlane
from repro.core.sampling import NeighborSampler
from repro.graph.batch import batch_device_arrays, generate_batch
from repro.graph.partition import (assignment_cut_fraction, _finalize_plan,
                                   incremental_rebalance, plan_partitions)
from repro.graph.storage import Graph
from repro.graph.synthetic import dataset_like
from repro.serve.fabric import ServingFabric
from repro.serve.gnn_engine import GNNRequest


def _fresh_graph(seed=0):
    """Dynamic-graph tests mutate topology — never the session fixture."""
    return dataset_like(gnn_config("products", smoke=True), seed=seed)


def _tiny_graph(n=40, deg=4, seed=0):
    """Small graph for reference-model sweeps (O(N·E) model is fine)."""
    rng = np.random.default_rng(seed)
    src = np.repeat(np.arange(n), deg)
    dst = rng.integers(0, n, n * deg)
    order = np.argsort(src, kind="stable")
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(np.bincount(src, minlength=n), out=indptr[1:])
    return Graph(indptr=indptr, indices=dst[order].astype(np.int32),
                 features=rng.standard_normal((n, 4)).astype(np.float32),
                 labels=rng.integers(0, 3, n).astype(np.int32),
                 train_mask=np.ones(n, bool), val_mask=np.zeros(n, bool),
                 test_mask=np.zeros(n, bool), name=f"tiny{n}")


class RefAdjacency:
    """Independent dict-of-lists model of the delta-CSR semantics: per-row
    neighbor order is kept-base-order then insertion-order, insert is a
    set no-op on live pairs, remove deletes every live copy."""

    def __init__(self, g: Graph):
        self.rows = [[int(x) for x in g.indices[g.indptr[v]:g.indptr[v + 1]]]
                     for v in range(g.num_nodes)]

    def add(self, u, v):
        if v in self.rows[u]:
            return 0
        self.rows[u].append(v)
        return 1

    def remove(self, u, v):
        had = v in self.rows[u]
        self.rows[u] = [x for x in self.rows[u] if x != v]
        return int(had)

    def assert_equal(self, g: Graph):
        indptr, indices = g.adj()
        for v, row in enumerate(self.rows):
            got = indices[indptr[v]:indptr[v + 1]].tolist()
            assert got == row, f"row {v}: {got} != {row}"
        assert g.num_edges == sum(len(r) for r in self.rows)


# ---------------------------------------------------------------------------
# (sweep) insert/delete/compact interleavings vs. the reference model
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000),
       ops=st.lists(st.sampled_from(["add", "remove", "compact"]),
                    min_size=1, max_size=24))
def test_mutation_interleavings_match_reference_model(seed, ops):
    g = _tiny_graph(seed=7)
    ref = RefAdjacency(g)
    rng = np.random.default_rng(seed)
    version = g.topology_version
    for op in ops:
        if op == "compact":
            g.compact()
            assert g.topology_version == version    # layout, not topology
            continue
        u = rng.integers(0, g.num_nodes, 3)
        v = rng.integers(0, g.num_nodes, 3)
        if op == "add":
            want = sum(ref.add(int(a), int(b)) for a, b in zip(u, v))
            got = g.add_edges(u, v)
        else:
            want = sum(ref.remove(int(a), int(b)) for a, b in zip(u, v))
            got = g.remove_edges(u, v)
        assert got == want
        assert g.topology_version == version + (1 if want else 0)
        version = g.topology_version
        ref.assert_equal(g)
    g.compact()
    ref.assert_equal(g)                             # fold preserves order
    assert not g.has_overlay


def test_duplicate_insert_is_noop_and_double_delete_idempotent():
    g = _tiny_graph(seed=1)
    u, v = 0, int(g.neighbors(0)[0])                # a live base edge
    assert g.add_edges([u], [v]) == 0               # already present
    assert g.topology_version == 0
    assert g.add_edges([u], [g.num_nodes - 1]) <= 1
    tv = g.topology_version
    assert g.add_edges([u], [g.num_nodes - 1]) == 0  # duplicate overlay add
    assert g.topology_version == tv
    assert g.remove_edges([u], [v]) == 1
    assert g.remove_edges([u], [v]) == 0            # idempotent
    assert v not in g.neighbors(u)
    tv = g.topology_version
    assert g.remove_edges([u], [v]) == 0
    assert g.topology_version == tv


def test_remove_deletes_every_parallel_base_copy():
    # synthetic base CSRs can hold parallel edges; set-remove kills all
    g = _tiny_graph(seed=2)
    row0 = g.neighbors(0).copy()
    dup = int(row0[0])
    copies = int(np.sum(row0 == dup))
    before = g.num_edges
    assert g.remove_edges([0], [dup]) == 1          # one PAIR removed...
    assert dup not in g.neighbors(0)
    assert g.num_edges == before - copies           # ...but every copy died


def test_endpoint_validation():
    g = _tiny_graph()
    with pytest.raises(ValueError):
        g.add_edges([0], [g.num_nodes])
    with pytest.raises(ValueError):
        g.remove_edges([-1], [0])
    with pytest.raises(ValueError):
        g.add_edges([0, 1], [0])


def test_frozen_graph_adj_is_the_base_arrays():
    """No-overlay adj() must return the base arrays UNTOUCHED (identity,
    not a copy) — the zero-cost regression anchor for every existing
    frozen-graph consumer."""
    g = _fresh_graph()
    indptr, indices = g.adj()
    assert indptr is g.indptr and indices is g.indices
    g.add_edges([0], [1]) or g.remove_edges([0], [1])
    g.compact()
    indptr, indices = g.adj()
    assert indptr is g.indptr and indices is g.indices


# ---------------------------------------------------------------------------
# (a) overlay sampling ≡ compacted sampling, bit-exact, both backends
# ---------------------------------------------------------------------------

def _mutate(g: Graph, seed=11, n_add=400, n_del=150):
    rng = np.random.default_rng(seed)
    g.add_edges(rng.integers(0, g.num_nodes, n_add),
                rng.integers(0, g.num_nodes, n_add))
    del_src = rng.integers(0, g.num_nodes, n_del)
    del_dst = [int(g.neighbors(int(v))[0]) if len(g.neighbors(int(v)))
               else 0 for v in del_src]
    g.remove_edges(del_src, del_dst)
    return g


def test_overlay_vs_compacted_sampling_bitexact():
    g_over = _mutate(_fresh_graph(seed=5))
    g_comp = _mutate(_fresh_graph(seed=5))
    assert g_comp.compact() > 0
    assert g_over.topology_version == g_comp.topology_version
    assert g_over.num_edges == g_comp.num_edges
    seeds = np.unique(np.random.default_rng(3).integers(
        0, g_over.num_nodes, 64))[:32].astype(np.int64)
    for use_ref in (False, True):                   # ES fast path + oracle
        mb_o = NeighborSampler(g_over, (5, 5), seed=42,
                               use_reference=use_ref).sample(seeds)
        mb_c = NeighborSampler(g_comp, (5, 5), seed=42,
                               use_reference=use_ref).sample(seeds)
        assert mb_o.topology_version == mb_c.topology_version
        for bo, bc in zip(mb_o.blocks, mb_c.blocks):
            np.testing.assert_array_equal(bo.src_ids, bc.src_ids)
            np.testing.assert_array_equal(bo.dst_ids, bc.dst_ids)
            np.testing.assert_array_equal(bo.neigh_idx, bc.neigh_idx)


@pytest.mark.parametrize("plane_cls", [HostFeaturePlane, DeviceFeaturePlane])
def test_batch_generation_bitexact_across_compaction(plane_cls):
    """The full batch path (sample → plane gather → device arrays) is
    bit-exact across a compaction on BOTH feature-plane backends, and the
    arrays carry the sampled-at topology version."""
    from repro.core.cache import FeatureCache
    g_over = _mutate(_fresh_graph(seed=9))
    g_comp = _mutate(_fresh_graph(seed=9))
    g_comp.compact()
    seeds = np.arange(16, dtype=np.int64) * 7
    out = []
    for g in (g_over, g_comp):
        plane = plane_cls(g, FeatureCache(g, 0.05, "static"))
        mb = NeighborSampler(g, (3, 3), seed=8).sample(seeds)
        mb = generate_batch(mb, plane, g)
        out.append(batch_device_arrays(mb))
    np.testing.assert_array_equal(out[0]["features"], out[1]["features"])
    for a, b in zip(out[0]["neigh_idxs"], out[1]["neigh_idxs"]):
        np.testing.assert_array_equal(a, b)
    assert (out[0]["topology_version"] == out[1]["topology_version"]
            == g_comp.topology_version)


@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000),
       n_ops=st.integers(2, 6))
def test_long_interleaving_sampling_parity_sweep(seed, n_ops):
    """Longer randomized interleavings: after EVERY mutation batch, the
    overlay graph and an eagerly-compacted twin sample identically."""
    g_lazy = _tiny_graph(n=120, deg=5, seed=4)
    g_eager = _tiny_graph(n=120, deg=5, seed=4)
    rng = np.random.default_rng(seed)
    for i in range(n_ops):
        u = rng.integers(0, 120, 12)
        v = rng.integers(0, 120, 12)
        if rng.integers(2):
            g_lazy.add_edges(u, v)
            g_eager.add_edges(u, v)
        else:
            g_lazy.remove_edges(u, v)
            g_eager.remove_edges(u, v)
        g_eager.compact()
        assert g_lazy.topology_version == g_eager.topology_version
        seeds = np.unique(rng.integers(0, 120, 16)).astype(np.int64)
        mb_l = NeighborSampler(g_lazy, (4,), seed=seed + i).sample(seeds)
        mb_e = NeighborSampler(g_eager, (4,), seed=seed + i).sample(seeds)
        np.testing.assert_array_equal(mb_l.blocks[0].neigh_idx,
                                      mb_e.blocks[0].neigh_idx)


# ---------------------------------------------------------------------------
# (b) incremental re-balance: nothing stale, acceptance envelope holds
# ---------------------------------------------------------------------------

def test_rebalanced_plan_equals_fresh_finalize_of_mutated_graph():
    """Budget-0 subgraphs (and every stat) of the re-balanced plan equal a
    from-scratch finalize of the SAME assignment over the mutated graph —
    i.e. the re-balance recomputed everything against the new topology."""
    g = _mutate(_fresh_graph(seed=13), n_add=2000, n_del=0)
    plan = plan_partitions(_fresh_graph(seed=13), 3, "locality", seed=0)
    res = incremental_rebalance(g, plan)
    g.compact()                                     # fold; version unchanged
    fresh = _finalize_plan(g, res.plan.node_sets, res.plan.owner,
                           res.plan.method, 0)
    assert res.plan.topology_version == g.topology_version
    assert res.plan.cut_edges == fresh.cut_edges
    assert res.plan.kept_information(g) == fresh.kept_information(g)
    for a, b in zip(res.plan.subgraphs, fresh.subgraphs):
        np.testing.assert_array_equal(a.indptr, b.indptr)
        np.testing.assert_array_equal(a.indices, b.indices)
        np.testing.assert_array_equal(a.features, b.features)


def test_incremental_rebalance_meets_acceptance_envelope():
    g = _fresh_graph(seed=3)
    plan = plan_partitions(g, 4, "locality", seed=0)
    rng = np.random.default_rng(1)
    g.add_edges(rng.integers(0, g.num_nodes, 4000),
                rng.integers(0, g.num_nodes, 4000))
    res = incremental_rebalance(g, plan)
    fresh_cut = assignment_cut_fraction(
        g, plan_partitions(g, 4, "locality", seed=0).owner)
    assert res.moved_frac < 0.25                    # boundary nodes only
    assert res.cut_after <= res.cut_before
    assert res.cut_after <= fresh_cut * 1.10        # within 10% of fresh
    # ownership stays a total disjoint cover with bounded imbalance
    allv = np.concatenate(res.plan.node_sets)
    assert len(allv) == g.num_nodes
    assert len(np.unique(allv)) == g.num_nodes
    sizes = np.array([len(s) for s in res.plan.node_sets])
    assert sizes.min() >= int(np.floor(g.num_nodes / 4 * 0.9))


def test_rebalance_respects_move_budget():
    g = _mutate(_fresh_graph(seed=21), n_add=5000, n_del=0)
    plan = plan_partitions(_fresh_graph(seed=21), 4, "locality", seed=0)
    res = incremental_rebalance(g, plan, max_move_frac=0.01)
    assert res.moved_nodes <= int(0.01 * g.num_nodes)


# ---------------------------------------------------------------------------
# (c) serving: admitted queries are immune to mid-serving edge inserts
# ---------------------------------------------------------------------------

def _serving_pair(seed=17, parts=2):
    """Two identically-built (graph, plan, fabric) rigs — mutate one
    mid-serving, leave its twin frozen, and compare."""
    from repro.models.gnn import decls_gnn
    from repro.models.params import init_params
    import jax
    rigs = []
    cfg = gnn_config("products", smoke=True)
    params = None
    for _ in range(2):
        g = dataset_like(cfg, seed=seed)
        plan = plan_partitions(g, parts, "locality", seed=0, halo_budget=0)
        if params is None:
            params = init_params(decls_gnn(cfg), jax.random.PRNGKey(0))
        fab = ServingFabric.from_plan(g, plan, cfg, params, batch=2, seed=0)
        rigs.append((g, plan, fab))
    return rigs


def test_midserving_inserts_do_not_change_admitted_predictions():
    (g_mut, _, fab_mut), (_, _, fab_frozen) = _serving_pair()
    nodes = [3, 41, 77, 200, 515, 999]
    v0 = fab_mut.topology_version
    for i, n in enumerate(nodes):
        fab_mut.submit(GNNRequest(rid=i, node=n))
        fab_frozen.submit(GNNRequest(rid=i, node=n))
    # mutate AFTER admission, BEFORE any serving step ran
    rng = np.random.default_rng(2)
    assert g_mut.add_edges(rng.integers(0, g_mut.num_nodes, 500),
                           rng.integers(0, g_mut.num_nodes, 500)) > 0
    assert g_mut.topology_version > v0
    fab_mut.run_to_completion()
    fab_frozen.run_to_completion()
    assert fab_mut.topology_version == v0           # not yet refreshed
    by_rid = lambda fab: {r.rid: r for r in fab.completed}
    a, b = by_rid(fab_mut), by_rid(fab_frozen)
    assert set(a) == set(b) == set(range(len(nodes)))
    for rid in a:
        assert a[rid].topology_version == v0        # pre-bump stamp
        assert a[rid].pred == b[rid].pred
        np.testing.assert_array_equal(a[rid].logits, b[rid].logits)


def test_refresh_topology_adopts_new_plan_and_restamps():
    (g, _, fab), _ = _serving_pair()
    rng = np.random.default_rng(5)
    g.add_edges(rng.integers(0, g.num_nodes, 300),
                rng.integers(0, g.num_nodes, 300))
    new_plan = plan_partitions(g, 2, "locality", seed=0, halo_budget=0)
    assert new_plan.topology_version == g.topology_version
    fab.submit(GNNRequest(rid=0, node=7))           # queued pre-refresh
    old_v = fab.topology_version
    fab.refresh_topology(plan=new_plan)
    assert fab.topology_version == g.topology_version > old_v
    # queued-but-undispatched requests were re-routed and re-stamped
    assert fab.pending[0].topology_version == fab.topology_version
    assert fab.pending[0].partition == int(new_plan.owner_of([7])[0])
    fab.run_to_completion()
    assert fab.completed[-1].status == "done"
    # and a post-refresh submit serves the new topology's stamp
    fab.submit(GNNRequest(rid=1, node=11))
    assert fab.pending[0].topology_version == fab.topology_version
    fab.run_to_completion()


def test_refresh_topology_rejects_partition_count_change():
    (g, _, fab), _ = _serving_pair()
    plan3 = plan_partitions(g, 3, "locality", seed=0)
    with pytest.raises(ValueError, match="partition count"):
        fab.refresh_topology(plan=plan3)
