"""Multi-partition data-parallel GNN training (core/multipart.py).

Covers: locality-aware partition assignment, the partition mesh +
grad_allreduce collective (host-sim and real single-device mesh),
gradient parity of the 2-partition synced step vs the single-partition
step, checkpoint → rebuild → restore round-trips (incl. cache
hit-accounting and the partition-count guard), fault-tolerance
integration, and the autotune `partitions` knob's restart path."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.gnn import AutotuneConfig
from repro.core.a3gnn import A3GNNTrainer, make_trainer
from repro.core.autotune.controller import AutotuneController, episode_space
from repro.core.locality import edge_locality_score
from repro.core.multipart import MultiPartitionTrainer, MultiPipeline
from repro.core.sampling import NeighborSampler, seed_loader
from repro.distributed.collectives import grad_allreduce
from repro.graph.batch import generate_batch, batch_device_arrays
from repro.graph.partition import (bfs_partition, hash_partition,
                                   locality_partition, plan_partitions)
from repro.launch.mesh import HostSimMesh, make_partition_mesh
from repro.train.checkpoint import CheckpointManager


# ---------------------------------------------------------------------------
# locality-aware partitioning
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("parts", [1, 2, 3, 4])
def test_locality_partition_is_a_balanced_cover(smoke_graph, parts):
    sets = locality_partition(smoke_graph, parts, seed=0)
    assert len(sets) == parts
    allv = np.concatenate(sets)
    assert len(allv) == smoke_graph.num_nodes          # disjoint cover
    assert len(np.unique(allv)) == smoke_graph.num_nodes
    sizes = np.array([len(s) for s in sets])
    assert sizes.min() >= 0.5 * smoke_graph.num_nodes / parts  # balanced-ish


def test_locality_partition_beats_hash_and_bfs_on_cut(smoke_graph):
    """The locality objective: keep more edges internal than either
    baseline assigner (fewer halo fetches, larger effective η)."""
    def score(sets):
        owner = -np.ones(smoke_graph.num_nodes, np.int32)
        for p, ns in enumerate(sets):
            owner[ns] = p
        return edge_locality_score(smoke_graph, owner)

    loc = score(locality_partition(smoke_graph, 4, seed=0))
    assert loc > score(hash_partition(smoke_graph, 4, seed=0))
    assert loc > score(bfs_partition(smoke_graph, 4, seed=0))


def test_partition_plan_stats(smoke_graph):
    plan = plan_partitions(smoke_graph, 3, "locality", seed=0)
    assert plan.parts == 3
    assert len(plan.subgraphs) == 3
    assert abs(sum(plan.etas(smoke_graph)) - 1.0) < 1e-9
    assert 0.0 <= plan.edge_locality(smoke_graph) <= 1.0
    assert all(h >= 0 for h in plan.halo_counts)
    # owner array consistent with node sets
    for p, ns in enumerate(plan.node_sets):
        assert (plan.owner[ns] == p).all()
    with pytest.raises(ValueError, match="unknown partition method"):
        plan_partitions(smoke_graph, 2, "metis")


# ---------------------------------------------------------------------------
# partition mesh + gradient collective
# ---------------------------------------------------------------------------

def test_partition_mesh_host_simulated_when_devices_scarce():
    n_dev = len(jax.devices())
    mesh = make_partition_mesh(n_dev + 1)
    assert isinstance(mesh, HostSimMesh)
    assert mesh.shape == {"part": n_dev + 1}
    assert mesh.axis_names == ("part",)
    real = make_partition_mesh(1)                   # always enough for 1
    assert not isinstance(real, HostSimMesh)


def _tree(scale):
    return {"w": np.full((3, 2), scale, np.float32),
            "b": {"v": np.full((4,), 2.0 * scale, np.float32)}}


def test_grad_allreduce_host_sim_means_trees():
    fn = grad_allreduce(HostSimMesh(2))
    mean = fn([_tree(1.0), _tree(3.0)])
    np.testing.assert_allclose(mean["w"], 2.0)
    np.testing.assert_allclose(mean["b"]["v"], 4.0)


def test_grad_allreduce_real_mesh_single_device():
    """The shard_map psum path on a real 1-device mesh must agree with the
    host-sim arithmetic (same collective, different substrate)."""
    mesh = make_partition_mesh(1)
    out = grad_allreduce(mesh)([_tree(5.0)])
    np.testing.assert_allclose(np.asarray(out["w"]), 5.0)
    np.testing.assert_allclose(np.asarray(out["b"]["v"]), 10.0)
    with pytest.raises(ValueError, match="gradient trees"):
        grad_allreduce(mesh)([_tree(1.0), _tree(2.0)])


# ---------------------------------------------------------------------------
# gradient parity: 2-partition synced step == single-partition step
# ---------------------------------------------------------------------------

def test_two_partition_step_matches_single_partition(smoke_graph,
                                                     smoke_gnn_cfg):
    """Acceptance: on the same synthetic graph and the same mini-batch, the
    2-partition synchronized update (grad → all-reduce → shared apply)
    matches the single-partition fused train step to ≤ 1e-5."""
    single = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
    multi = make_trainer(smoke_graph, smoke_gnn_cfg.replace(partitions=2),
                         seed=0)
    assert isinstance(multi, MultiPartitionTrainer)
    multi.load_state_dict(single.state_dict())      # identical start point

    sampler = NeighborSampler(smoke_graph, smoke_gnn_cfg.fanout, seed=7)
    seeds = next(seed_loader(smoke_graph, smoke_gnn_cfg.batch_size, 7))
    mb = generate_batch(sampler.sample(seeds), None, smoke_graph)
    arrays = batch_device_arrays(mb)

    p1, _, _, _ = single._step(single.params, single.opt_state,
                               arrays["features"], arrays["neigh_idxs"],
                               arrays["labels"])
    multi.synced_update([arrays, arrays])           # both partitions: same mb
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(multi.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    # and the mean over DIFFERENT batches is the true gradient mean
    seeds2 = next(seed_loader(smoke_graph, smoke_gnn_cfg.batch_size, 8))
    mb2 = generate_batch(sampler.sample(seeds2), None, smoke_graph)
    arrays2 = batch_device_arrays(mb2)
    g1, _, _ = multi._grad(multi.params, arrays["features"],
                           arrays["neigh_idxs"], arrays["labels"])
    g2, _, _ = multi._grad(multi.params, arrays2["features"],
                           arrays2["neigh_idxs"], arrays2["labels"])
    mean = multi._allreduce([g1, g2])
    for m, a, b in zip(jax.tree.leaves(mean), jax.tree.leaves(g1),
                       jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(m),
                                   (np.asarray(a) + np.asarray(b)) / 2.0,
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# end-to-end multi-partition training
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mp_trainer(smoke_graph, smoke_gnn_cfg):
    return make_trainer(smoke_graph, smoke_gnn_cfg.replace(partitions=2),
                        seed=0)


def test_multipartition_smoke_training(mp_trainer):
    tr = mp_trainer
    assert len(tr.slots) == 2
    assert all(s.cache is not None for s in tr.slots)   # per-partition cache
    res = tr.run_epochs(1, max_steps_per_epoch=3)
    assert res.stats.steps == 6                  # 3 global × 2 partitions
    assert np.isfinite(res.stats.losses).all()
    assert res.modeled_steps_s > 0 and res.memory_bytes > 0
    assert 0.0 <= res.cache_hit_rate <= 1.0
    # every partition produced batches through its own cache
    assert all(s.cache.stats.hits + s.cache.stats.misses > 0
               for s in tr.slots)


def test_multipipeline_reconfigures_all_partitions(mp_trainer):
    tr = mp_trainer
    pipe = tr.make_pipeline()
    try:
        tr.apply_live_config({"parallel_mode": "mode2", "workers": 2,
                              "bias_rate": 4.0}, pipe)
        assert all(p.mode == "mode2" and p.workers_n == 2
                   for p in pipe.pipes)
        assert all(s.pipe.weight_fn is s.weight_fn for s in tr.slots)
        stats = pipe.run(max_steps=2)
        assert stats.steps == 4
    finally:
        pipe.shutdown()
        tr.apply_live_config({"parallel_mode": "seq", "bias_rate": 2.0})


def test_multipartition_worker_failure_reissued(smoke_graph, smoke_gnn_cfg):
    # workers=1 so the injected worker deterministically receives every
    # item and fails from its 3rd onward (fail_after=2); with 2 racing
    # workers the failing one may never get a 3rd item
    tr = make_trainer(smoke_graph,
                      smoke_gnn_cfg.replace(partitions=2,
                                            parallel_mode="mode1",
                                            workers=1), seed=0)
    res = tr.run_epochs(1, max_steps_per_epoch=5, fail_worker=0)
    assert res.stats.steps == 10                 # nothing dropped
    assert res.stats.reissued >= 3               # spare sampler took over


# ---------------------------------------------------------------------------
# checkpoint → rebuild → restore round-trip
# ---------------------------------------------------------------------------

def test_checkpoint_rebuild_restore_roundtrip(smoke_graph, smoke_gnn_cfg,
                                              tmp_path):
    cfg = smoke_gnn_cfg.replace(partitions=2)
    tr = make_trainer(smoke_graph, cfg, seed=0)
    rep = tr.fit_supervised(4, tmp_path / "ckpt", ckpt_every=2)
    assert rep.steps_run == 4 and rep.checkpoints >= 1
    hit_stats = [dataclasses.asdict(s.cache.stats) for s in tr.slots]
    assert any(st["hits"] + st["misses"] > 0 for st in hit_stats)

    # rebuild from scratch (the restart path) and restore
    tr2 = make_trainer(smoke_graph, cfg, seed=1)     # different init seed
    mgr = CheckpointManager(tmp_path / "ckpt", async_save=False)
    step = tr2.restore(mgr)
    assert step == 4 and tr2.global_steps == 4
    for a, b in zip(jax.tree.leaves(tr.params), jax.tree.leaves(tr2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(tr.opt_state),
                    jax.tree.leaves(tr2.opt_state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    # cache hit-accounting survives the rebuild
    assert [dataclasses.asdict(s.cache.stats) for s in tr2.slots] == hit_stats
    # and training resumes
    tr2.global_step()
    assert tr2.global_steps == 5


def test_restore_rejects_partition_count_change(smoke_graph, smoke_gnn_cfg,
                                                tmp_path):
    tr = make_trainer(smoke_graph, smoke_gnn_cfg.replace(partitions=2),
                      seed=0)
    mgr = CheckpointManager(tmp_path / "ckpt", async_save=False)
    tr.save(mgr, step=1)
    tr3 = make_trainer(smoke_graph, smoke_gnn_cfg.replace(partitions=3),
                       seed=0)
    with pytest.raises(ValueError, match="partitions=2"):
        tr3.restore(mgr)
    single = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
    with pytest.raises(ValueError, match="partitions=2"):
        single.restore(mgr)
    # explicit migration acknowledgement goes through (the restart path)
    step = tr3.restore(mgr, expect_partitions=2)
    assert step == 1
    for a, b in zip(jax.tree.leaves(tr.params), jax.tree.leaves(tr3.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_supervisor_restores_multipartition_on_failure(smoke_graph,
                                                       smoke_gnn_cfg,
                                                       tmp_path):
    tr = make_trainer(smoke_graph, smoke_gnn_cfg.replace(partitions=2),
                      seed=0)
    rep = tr.fit_supervised(5, tmp_path / "ckpt", ckpt_every=2,
                            fail_at_step=3)
    assert rep.failures == 1 and rep.restores == 1
    assert rep.final_step == 5                   # resumed to completion


# ---------------------------------------------------------------------------
# autotune: the `partitions` knob through the restart path
# ---------------------------------------------------------------------------

def test_episode_space_gains_partitions_knob():
    assert "partitions" not in {k.name for k in
                                episode_space(AutotuneConfig()).knobs}
    sp = episode_space(AutotuneConfig(max_partitions=4))
    assert "partitions" in {k.name for k in sp.knobs}
    rng = np.random.default_rng(0)
    decoded = [sp.decode(u)["partitions"] for u in sp.sample(rng, 64)]
    assert min(decoded) >= 1 and max(decoded) <= 4 and len(set(decoded)) > 1


def test_controller_restart_path_preserves_training_state(smoke_graph,
                                                          smoke_gnn_cfg,
                                                          tmp_path):
    """checkpoint → rebuild (new partition count) → restore: params carry
    over bit-exactly and the controller ends up driving the new fleet."""
    acfg = AutotuneConfig(episodes=2, steps_per_episode=2, warmup_steps=0,
                          presample=16, surrogate_trees=8, ppo_updates=1,
                          ppo_horizon=4, max_partitions=3,
                          restart_dir=str(tmp_path / "restart"), seed=0)
    tr = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
    ctrl = AutotuneController(tr, tr.make_pipeline(), acfg)
    try:
        before = [np.asarray(x).copy() for x in jax.tree.leaves(tr.params)]
        ctrl._restart(2)
        assert isinstance(ctrl.tr, MultiPartitionTrainer)
        assert isinstance(ctrl.pipe, MultiPipeline)
        assert ctrl.tr.cfg.partitions == 2 and ctrl.restarts == 1
        for a, b in zip(before, jax.tree.leaves(ctrl.tr.params)):
            np.testing.assert_allclose(a, np.asarray(b))
        # restart back down to a single partition
        ctrl._restart(1)
        assert isinstance(ctrl.tr, A3GNNTrainer) and ctrl.restarts == 2
        for a, b in zip(before, jax.tree.leaves(ctrl.tr.params)):
            np.testing.assert_allclose(a, np.asarray(b))
    finally:
        ctrl.pipe.shutdown()


@pytest.mark.slow
def test_fit_autotuned_with_partitions_knob(smoke_graph, smoke_gnn_cfg):
    """Full closed loop with the partitions knob enabled: every episode
    measures successfully whatever partition count the proposal picks."""
    tr = A3GNNTrainer(smoke_graph, smoke_gnn_cfg, seed=0)
    acfg = AutotuneConfig(episodes=3, steps_per_episode=3, warmup_steps=0,
                          presample=24, surrogate_trees=8, ppo_updates=1,
                          ppo_horizon=4, max_workers=2, max_partitions=2,
                          seed=0)
    rep = tr.fit_autotuned(acfg)
    assert len(rep.episodes) == 3
    assert all("partitions" in ep.config for ep in rep.episodes)
    for ep in rep.episodes:
        assert np.isfinite(list(ep.metrics.values())).all()
        # an episode at p partitions measured p mini-batches per global step
        assert ep.steps == acfg.steps_per_episode * int(
            ep.config["partitions"])


# ---------------------------------------------------------------------------
# dynamic topology: drift tracking + incremental re-balance (trainer path)
# ---------------------------------------------------------------------------

def _mutable_graph(seed=0):
    """Rebalance tests mutate topology — never the session fixture."""
    from repro.configs.gnn import gnn_config
    from repro.graph.synthetic import dataset_like
    return dataset_like(gnn_config("products", smoke=True), seed=seed)


def test_owner_of_total_and_disjoint_after_rebalance(smoke_gnn_cfg):
    """Post-migration, `owner_of` still answers every node with exactly
    one partition, consistent with the node sets, and halo sets never
    contain owned nodes."""
    from repro.graph.partition import incremental_rebalance
    g = _mutable_graph(seed=6)
    plan = plan_partitions(g, 3, "locality", seed=0, halo_budget=16)
    rng = np.random.default_rng(0)
    g.add_edges(rng.integers(0, g.num_nodes, 3000),
                rng.integers(0, g.num_nodes, 3000))
    new = incremental_rebalance(g, plan).plan
    assert new.halo_budget == plan.halo_budget      # budget carries over
    owners = new.owner_of(np.arange(g.num_nodes))
    assert (owners >= 0).all() and (owners < 3).all()
    for p, ns in enumerate(new.node_sets):
        assert (owners[ns] == p).all()
        assert not np.isin(new.halo_sets[p], ns).any()   # halo ∩ owned = ∅
        assert (owners[new.halo_sets[p]] != p).all()
    # the shared local-id map matches per-set positions (routing contract)
    local = new.local_ids()
    for ns in new.node_sets:
        np.testing.assert_array_equal(local[ns],
                                      np.arange(len(ns), dtype=np.int32))


def test_trainer_rebalance_updates_plan_and_accounting(smoke_gnn_cfg):
    cfg = smoke_gnn_cfg.replace(partitions=2)
    g = _mutable_graph(seed=8)
    tr = MultiPartitionTrainer(g, cfg, seed=0)
    try:
        assert tr.cut_drift() == 0.0                # version-matched: free
        rng = np.random.default_rng(4)
        g.add_edges(rng.integers(0, g.num_nodes, 3000),
                    rng.integers(0, g.num_nodes, 3000))
        drift = tr.cut_drift()
        assert drift > 0.0
        res = tr.rebalance_partitions()
        assert tr.rebalances == 1 and tr.last_rebalance is res
        assert res.moved_frac < cfg.rebalance_max_move + 1e-9
        assert tr.plan.topology_version == g.topology_version
        assert tr.cut_drift() == 0.0                # re-baselined
        # the new plan is live: slots rebuilt over the new subgraphs, and
        # training continues through them
        assert [s.graph.num_nodes for s in tr.slots] == \
            [len(ns) for ns in tr.plan.node_sets]
        params_before = jax.tree.leaves(tr.params)
        tr.global_step()
        assert any(not np.array_equal(a, np.asarray(b)) for a, b in
                   zip(params_before, jax.tree.leaves(tr.params)))
        extra = tr.checkpoint_extra()
        assert extra["topology_version"] == g.topology_version
        assert extra["rebalances"] == 1
    finally:
        for s in tr.slots:
            s.pipe.shutdown()


def test_drift_trigger_rebalances_between_global_steps(smoke_gnn_cfg):
    """`rebalance_drift` arms the trigger: a big enough cut-fraction
    degradation rebalances at the NEXT global step, exactly once."""
    cfg = smoke_gnn_cfg.replace(partitions=2, rebalance_drift=0.01)
    g = _mutable_graph(seed=12)
    tr = MultiPartitionTrainer(g, cfg, seed=0)
    try:
        tr.global_step()
        assert tr.rebalances == 0                   # no drift yet
        rng = np.random.default_rng(9)
        g.add_edges(rng.integers(0, g.num_nodes, 4000),
                    rng.integers(0, g.num_nodes, 4000))
        tr.global_step()
        assert tr.rebalances == 1
        tr.global_step()                            # re-baselined: no loop
        assert tr.rebalances == 1
    finally:
        for s in tr.slots:
            s.pipe.shutdown()
