# Tier-1 verification + fast lane.
#
# CI: .github/workflows/ci.yml runs scripts/ci.sh on every push/PR —
# three jobs (lint / fast / full) mirroring the lanes below; JUnit XML +
# per-lane timing land in artifacts/ and are uploaded per run.
# Badge: https://github.com/<org>/<repo>/actions/workflows/ci.yml/badge.svg
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast lint docs-check ci autotune-demo bench-quick \
        bench-gather fused-demo scaleout-demo halo-demo serve-gnn-demo

test:            ## full tier-1 suite (the ROADMAP bar)
	$(PY) -m pytest -x -q

test-fast:       ## fast lane: skips the slow pipeline/system tests
	$(PY) -m pytest -x -q -m "not slow"

lint:            ## ruff (or the offline fallback) over src/tests/benchmarks
	bash scripts/ci.sh lint

docs-check:      ## docs/*.md + README code anchors must resolve
	bash scripts/ci.sh docs

ci:              ## everything CI runs: lint + docs + fast + full, with artifacts
	bash scripts/ci.sh all

autotune-demo:   ## online auto-tuning on a smoke graph (paper §III-C)
	$(PY) -m repro.launch.train --arch graphsage-products --smoke \
	    --autotune --steps 6 --episodes-autotune 4

scaleout-demo:   ## 2-partition data-parallel smoke run + restore proof
	$(PY) -m repro.launch.train --arch graphsage-products --smoke \
	    --partitions 2 --steps 4

halo-demo:       ## scale-out with a bounded halo exchange (kept-info report)
	$(PY) -m repro.launch.train --arch graphsage-products --smoke \
	    --partitions 2 --halo-budget 32 --steps 4

serve-gnn-demo:  ## online GNN inference through the trainer's FeaturePlane
	$(PY) -m repro.launch.serve --gnn --arch graphsage-products --smoke \
	    --queries 16 --batch 4 --train-steps 4

fused-demo:      ## all-hop fused device pipeline on a smoke graph
	$(PY) -m repro.launch.train --arch graphsage-products --smoke \
	    --fused-gather-agg --steps 6

# perf targets run under the tuned host runtime (scripts/env_tuned.sh:
# tcmalloc preload when installed + pinned XLA host flags) so wall-clock
# numbers are taken the way a tuned training box would take them
bench-quick:     ## reduced benchmark sweep (tuned runtime)
	bash scripts/env_tuned.sh $(PY) -m benchmarks.run --quick

bench-gather:    ## feature-plane gather sweep: fused/unfused × host/device
	bash scripts/env_tuned.sh $(PY) -m benchmarks.run --only gather
