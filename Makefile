# Tier-1 verification + fast lane.  See scripts/ci.sh for the CI entry.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast autotune-demo bench-quick

test:            ## full tier-1 suite (the ROADMAP bar)
	$(PY) -m pytest -x -q

test-fast:       ## fast lane: skips the slow pipeline/system tests
	$(PY) -m pytest -x -q -m "not slow"

autotune-demo:   ## online auto-tuning on a smoke graph (paper §III-C)
	$(PY) -m repro.launch.train --arch graphsage-products --smoke \
	    --autotune --steps 6 --episodes-autotune 4

bench-quick:     ## reduced benchmark sweep
	$(PY) -m benchmarks.run --quick
