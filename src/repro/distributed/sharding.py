"""Logical→physical sharding resolution.

The model zoo declares shardings with *logical* axis names (params.py).
This module resolves them against a concrete mesh, per architecture:

  * ``fsdp``   → the ``data`` mesh axis (ZeRO-3 parameter sharding).  On a
    multi-pod mesh parameters stay sharded *within* a pod and replicated
    across pods (cross-pod is pure DP over the slower DCN links — gradient
    all-reduce only, optionally compressed; see train/compression.py).
  * ``tp``     → the ``model`` mesh axis.
  * ``tp_kv``  → ``model`` iff num_kv_heads divides the model-axis size,
    else replicated (Megatron-style KV replication for GQA).
  * ``expert`` → the ``model`` mesh axis (EP).  Requires padded expert
    count divisible by the axis (configs pad, e.g. 60→64).
  * ``dp``     → ``("pod","data")`` on multi-pod meshes else ``data``.
  * ``kvseq``  → ``model`` when the config selects sequence-sharded KV
    (kv_shard=="sequence" or auto with kv heads indivisible), else None.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.params import ParamDecl


def make_rules(cfg, mesh: Mesh) -> Dict[str, Any]:
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_size = axis_sizes.get("model", 1)
    multi_pod = "pod" in axis_sizes

    kv_heads = getattr(cfg, "num_kv_heads", 0) or 0
    q_heads = getattr(cfg, "num_heads", 0) or 0
    if getattr(cfg, "pad_head_groups", False) and kv_heads:
        from repro.models.layers import padded_heads
        q_heads = padded_heads(cfg, model_size)
    kv_div = kv_heads > 0 and kv_heads % model_size == 0
    q_div = q_heads > 0 and q_heads % model_size == 0
    kv_shard = getattr(cfg, "kv_shard", "auto")
    if kv_shard == "auto":
        kv_shard = "heads" if kv_div else "sequence"
    if kv_shard == "replicated":
        kv_shard = "none"

    rules: Dict[str, Any] = {
        "dp": ("pod", "data") if multi_pod else "data",
        "fsdp": "data" if getattr(cfg, "fsdp_params", True) else None,
        "tp": "model",
        "tp_kv": "model" if kv_div else None,
        "qheads": "model" if q_div else None,
        "expert": "model",
        "kvseq": "model" if kv_shard == "sequence" else None,
        # kv-head axis of the decode cache: shardable only in heads mode
        "kvheads": "model" if (kv_shard == "heads" and kv_div) else None,
        # decode: repeated-KV layout — shard time XOR heads, never both
        "dkr_t": "model" if kv_shard == "sequence" else None,
        "dkr_h": "model" if (kv_shard != "sequence" and q_div) else None,
        "seq": None,            # training activations: sequence replicated
        "vocab": ("model"
                  if getattr(cfg, "vocab_size", 0) % model_size == 0 else None),
    }
    return rules


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(axes, (tuple, list)):
        n = 1
        for a in axes:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(axes, 1)


def enforce_divisible(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on any dim the mesh axis doesn't divide evenly (pjit
    argument shardings require exact divisibility, unlike constraints)."""
    out = []
    for i, dim in enumerate(shape):
        ax = spec[i] if i < len(spec) else None
        if ax is not None and dim % _axis_size(mesh, ax) != 0:
            ax = None
        out.append(ax)
    return P(*out)


def resolve_spec(logical: P, rules: Dict[str, Any]) -> P:
    out = []
    for ax in logical:
        if ax is None:
            out.append(None)
        elif isinstance(ax, (tuple, list)):
            phys = []
            for a in ax:
                r = rules.get(a, None)
                if r is None:
                    continue
                phys.extend(r if isinstance(r, tuple) else (r,))
            out.append(tuple(phys) if phys else None)
        else:
            out.append(rules.get(ax, None))
    # PartitionSpec drops trailing Nones automatically
    return P(*out)


def physical_specs(decls_or_logical, cfg, mesh: Mesh):
    """Resolve a pytree of ParamDecl (or logical PartitionSpec) to physical
    specs, dropping any sharding that does not divide the dim evenly."""
    rules = make_rules(cfg, mesh)

    def one(x):
        if isinstance(x, ParamDecl):
            return enforce_divisible(resolve_spec(P(*x.axes), rules),
                                     x.shape, mesh)
        return resolve_spec(x, rules)

    return jax.tree.map(one, decls_or_logical,
                        is_leaf=lambda x: isinstance(x, (ParamDecl, P)))


def shardings_of(specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(cfg, mesh: Mesh) -> P:
    rules = make_rules(cfg, mesh)
    return resolve_spec(P("dp", None), rules)


def dp_size(mesh: Mesh) -> int:
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return axis_sizes.get("data", 1) * axis_sizes.get("pod", 1)


# ---------------------------------------------------------------------------
# Sharding context — lets model code state *logical* activation constraints
# without threading the mesh through every call.  Unset (CPU unit tests) it is
# a no-op; the launcher installs it around tracing/lowering.
# ---------------------------------------------------------------------------

class _ShardCtx:
    mesh: Optional[Mesh] = None
    rules: Optional[Dict[str, Any]] = None


_CTX = _ShardCtx()


class shard_ctx:
    """Context manager installing (mesh, rules) for `constrain`/`ctx_dp_size`."""

    def __init__(self, cfg, mesh: Mesh):
        self.mesh = mesh
        self.rules = make_rules(cfg, mesh)

    def __enter__(self):
        self._saved = (_CTX.mesh, _CTX.rules)
        _CTX.mesh, _CTX.rules = self.mesh, self.rules
        return self

    def __exit__(self, *exc):
        _CTX.mesh, _CTX.rules = self._saved
        return False


def constrain(x, *logical_axes):
    """with_sharding_constraint against the installed context (no-op if unset)."""
    if _CTX.mesh is None:
        return x
    spec = resolve_spec(P(*logical_axes), _CTX.rules)
    spec = enforce_divisible(spec, x.shape, _CTX.mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_CTX.mesh, spec))


def ctx_dp_size() -> int:
    if _CTX.mesh is None:
        return 1
    return dp_size(_CTX.mesh)


def ctx_axis_size(axis: str) -> int:
    if _CTX.mesh is None:
        return 1
    sizes = dict(zip(_CTX.mesh.axis_names, _CTX.mesh.devices.shape))
    return sizes.get(axis, 1)
