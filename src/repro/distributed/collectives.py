"""Explicit collectives built on shard_map: flash-decoding attention combine
and quantized reductions (compression lives in train/compression.py).

These are the hand-written alternatives to GSPMD's automatic choices —
used when the automatic partitioner picks a bad schedule (e.g. gathering a
sequence-sharded KV cache instead of combining partial softmaxes).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def _partial_attend(q, k, v, mask):
    """Local attention over this shard's time slice.

    q (B,H,Dh); k/v (B,Tl,H,Dh); mask (B,Tl) True=valid.
    Returns (o (B,H,Dh) UNNORMALIZED numerator at local max, m (B,H) local
    max, denom (B,H) local sum of exp)."""
    scores = jnp.einsum("bhe,bthe->bht", q, k).astype(jnp.float32)
    scores = jnp.where(mask[:, None, :], scores, -1e30)
    m = jnp.max(scores, axis=-1)                        # (B,H)
    p = jnp.exp(scores - m[..., None])
    p = jnp.where(mask[:, None, :], p, 0.0)
    denom = jnp.sum(p, axis=-1)                         # (B,H)
    o = jnp.einsum("bht,bthe->bhe", p.astype(v.dtype), v)
    return o.astype(jnp.float32), m, denom


def flash_decode_attention(mesh: Mesh, axis: str = "model"):
    """Sequence-sharded single-token attention with psum softmax combine.

    Inputs (global): q (B,H,Dh) replicated over ``axis``; cache_k/v
    (B,T,H,Dh) sharded on T over ``axis``; pos (B,) replicated.
    Output: (B,H,Dh) replicated — each shard attends over its T-slice and
    the partial (o·softmax-weight, lse) pairs combine with one psum instead
    of all-gathering the cache (bytes: B·H·Dh vs B·T·H·Dh/axis).
    """
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]

    def local(q, k, v, pos):
        Tl = k.shape[1]
        shard = jax.lax.axis_index(axis)
        base = shard * Tl
        mask = (base + jnp.arange(Tl))[None, :] <= pos[:, None]
        o, m, denom = _partial_attend(q, k, v, mask)
        g_max = jax.lax.pmax(m, axis)                   # (B,H) global max
        w = jnp.exp(m - g_max)                          # rescale to global max
        num = jax.lax.psum(o * w[..., None], axis)
        den = jax.lax.psum(denom * w, axis)
        return (num / jnp.maximum(den[..., None], 1e-30)).astype(v.dtype)

    in_specs = (P(), P(None, axis, None, None), P(None, axis, None, None), P())
    return shard_map(local, mesh=mesh, in_specs=in_specs, out_specs=P(),
                     check_rep=False)


def quantized_allreduce_bytes(shape, n_devices: int, bits: int = 8) -> float:
    """Analytic DCN volume of a compressed ring all-reduce (roofline helper)."""
    import numpy as np
    elems = float(np.prod(shape))
    payload = elems * bits / 8
    return 2.0 * payload * (n_devices - 1) / n_devices
