"""Explicit collectives built on shard_map: flash-decoding attention combine
and quantized reductions (compression lives in train/compression.py).

These are the hand-written alternatives to GSPMD's automatic choices —
used when the automatic partitioner picks a bad schedule (e.g. gathering a
sequence-sharded KV cache instead of combining partial softmaxes).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def _partial_attend(q, k, v, mask):
    """Local attention over this shard's time slice.

    q (B,H,Dh); k/v (B,Tl,H,Dh); mask (B,Tl) True=valid.
    Returns (o (B,H,Dh) UNNORMALIZED numerator at local max, m (B,H) local
    max, denom (B,H) local sum of exp)."""
    scores = jnp.einsum("bhe,bthe->bht", q, k).astype(jnp.float32)
    scores = jnp.where(mask[:, None, :], scores, -1e30)
    m = jnp.max(scores, axis=-1)                        # (B,H)
    p = jnp.exp(scores - m[..., None])
    p = jnp.where(mask[:, None, :], p, 0.0)
    denom = jnp.sum(p, axis=-1)                         # (B,H)
    o = jnp.einsum("bht,bthe->bhe", p.astype(v.dtype), v)
    return o.astype(jnp.float32), m, denom


def flash_decode_attention(mesh: Mesh, axis: str = "model"):
    """Sequence-sharded single-token attention with psum softmax combine.

    Inputs (global): q (B,H,Dh) replicated over ``axis``; cache_k/v
    (B,T,H,Dh) sharded on T over ``axis``; pos (B,) replicated.
    Output: (B,H,Dh) replicated — each shard attends over its T-slice and
    the partial (o·softmax-weight, lse) pairs combine with one psum instead
    of all-gathering the cache (bytes: B·H·Dh vs B·T·H·Dh/axis).
    """
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]

    def local(q, k, v, pos):
        Tl = k.shape[1]
        shard = jax.lax.axis_index(axis)
        base = shard * Tl
        mask = (base + jnp.arange(Tl))[None, :] <= pos[:, None]
        o, m, denom = _partial_attend(q, k, v, mask)
        g_max = jax.lax.pmax(m, axis)                   # (B,H) global max
        w = jnp.exp(m - g_max)                          # rescale to global max
        num = jax.lax.psum(o * w[..., None], axis)
        den = jax.lax.psum(denom * w, axis)
        return (num / jnp.maximum(den[..., None], 1e-30)).astype(v.dtype)

    in_specs = (P(), P(None, axis, None, None), P(None, axis, None, None), P())
    return shard_map(local, mesh=mesh, in_specs=in_specs, out_specs=P(),
                     check_rep=False)


def grad_allreduce(mesh, axis: str = "part"):
    """Mean-all-reduce over per-partition gradient pytrees (data-parallel
    GNN scale-out, core/multipart.py).

    Returns ``fn(trees) -> tree`` averaging a list of identically-structured
    gradient pytrees, one per partition.  On a real ``Mesh`` each leaf is
    stacked over ``axis`` and reduced with a shard_map psum (the collective
    that runs on hardware); on a ``HostSimMesh`` (CI: fewer devices than
    partitions) the same reduction happens as host-side tree arithmetic —
    bitwise the same mean, no device topology required.
    """
    from repro.launch.mesh import HostSimMesh

    if isinstance(mesh, HostSimMesh) or mesh is None:
        def host_mean(trees):
            n = float(len(trees))
            if len(trees) == 1:
                return trees[0]
            return jax.tree.map(lambda *xs: sum(xs) / n, *trees)
        return host_mean

    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]

    def local(x):
        return jax.lax.psum(x, axis) / axis_size

    # built ONCE per grad_allreduce call; jit caches per gradient-tree
    # structure, so the per-step cost is a single dispatch, not a retrace
    reduce_leaf = shard_map(local, mesh=mesh, in_specs=P(axis),
                            out_specs=P(axis), check_rep=False)

    @jax.jit
    def tree_mean(stacked):
        # every shard holds the mean after the psum; take shard 0's copy
        return jax.tree.map(lambda s: reduce_leaf(s)[0], stacked)

    def mesh_mean(trees):
        if len(trees) != axis_size:
            raise ValueError(f"got {len(trees)} gradient trees for a "
                             f"{axis_size}-way '{axis}' mesh axis")
        return tree_mean(jax.tree.map(lambda *xs: jnp.stack(xs), *trees))

    return mesh_mean


def halo_all_to_all(mesh, axis: str = "part"):
    """Bounded halo-feature exchange over the partition mesh.

    Returns ``fn(plan, part_feats) -> (halo_feats, volume_bytes)`` where
    ``part_feats[p]`` are partition p's OWNED feature rows in local order
    and ``halo_feats[p]`` are the rows for ``plan.halo_sets[p]`` in halo
    order — every row is owned by another partition, so all of them cross
    a boundary (``volume_bytes`` counts exactly that traffic, the HitGNN
    inter-device term the ``halo_budget`` knob caps).

    On a real ``Mesh`` (one device per partition) the rows move through a
    shard_map ``jax.lax.all_to_all`` over per-pair send buffers padded to
    the largest pair; on a ``HostSimMesh`` (CI: fewer devices than
    partitions) the same routing runs as host-side gathers — bitwise the
    same rows, no device topology required.
    """
    import numpy as np

    from repro.launch.mesh import HostSimMesh

    def _routing(plan):
        """Global→local index map plus, per (src q → dst p) pair, the rows
        q sends (q-local ids) and where p scatters them (halo positions)."""
        parts = plan.parts
        loc = np.zeros(len(plan.owner), np.int64)
        for ns in plan.node_sets:
            loc[ns] = np.arange(len(ns))
        send = [[None] * parts for _ in range(parts)]   # send[q][p]
        put = [[None] * parts for _ in range(parts)]    # put[p][q]
        for p, hs in enumerate(plan.halo_sets):
            owners = plan.owner[hs] if len(hs) else np.zeros(0, np.int32)
            for q in range(parts):
                pos = np.where(owners == q)[0]
                send[q][p] = loc[hs[pos]]
                put[p][q] = pos
        return send, put

    def _volume(plan, feat_dim: int) -> int:
        return plan.halo_rows * feat_dim * 4

    if isinstance(mesh, HostSimMesh) or mesh is None:
        def host_exchange(plan, part_feats):
            send, put = _routing(plan)
            halo_feats = []
            for p, hs in enumerate(plan.halo_sets):
                rows = np.zeros((len(hs), part_feats[p].shape[1]), np.float32)
                for q in range(plan.parts):
                    if len(put[p][q]):
                        rows[put[p][q]] = part_feats[q][send[q][p]]
                halo_feats.append(rows)
            return halo_feats, _volume(plan, part_feats[0].shape[1])
        return host_exchange

    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]

    def mesh_exchange(plan, part_feats):
        if plan.parts != axis_size:
            raise ValueError(f"plan has {plan.parts} partitions for a "
                             f"{axis_size}-way '{axis}' mesh axis")
        send, put = _routing(plan)
        feat_dim = part_feats[0].shape[1]
        pad = max((len(send[q][p]) for q in range(plan.parts)
                   for p in range(plan.parts)), default=0)
        if pad == 0:
            return ([np.zeros((0, feat_dim), np.float32)
                     for _ in range(plan.parts)], 0)
        # send_buf[q] : (parts, pad, F) — block p = rows q ships to p
        bufs = []
        for q in range(plan.parts):
            buf = np.zeros((plan.parts, pad, feat_dim), np.float32)
            for p in range(plan.parts):
                rows = send[q][p]
                buf[p, :len(rows)] = part_feats[q][rows]
            bufs.append(buf)
        stacked = jnp.stack(bufs)                     # (parts, parts, pad, F)

        def local(x):                                 # x: (1, parts, pad, F)
            return jax.lax.all_to_all(x, axis, split_axis=1, concat_axis=0)

        recv = shard_map(local, mesh=mesh, in_specs=P(axis),
                         out_specs=P(axis), check_rep=False)(stacked)
        # shard p's local (parts, 1, pad, F) blocks concatenate on axis 0
        recv = np.asarray(recv).reshape(plan.parts, plan.parts, pad,
                                        feat_dim)   # recv[p][q] = send[q][p]
        halo_feats = []
        for p, hs in enumerate(plan.halo_sets):
            rows = np.zeros((len(hs), feat_dim), np.float32)
            for q in range(plan.parts):
                if len(put[p][q]):
                    rows[put[p][q]] = recv[p, q, :len(put[p][q])]
            halo_feats.append(rows)
        return halo_feats, _volume(plan, feat_dim)

    return mesh_exchange


def quantized_allreduce_bytes(shape, n_devices: int, bits: int = 8) -> float:
    """Analytic DCN volume of a compressed ring all-reduce (roofline helper)."""
    import numpy as np
    elems = float(np.prod(shape))
    payload = elems * bits / 8
    return 2.0 * payload * (n_devices - 1) / n_devices
