"""GPipe-style pipeline parallelism via shard_map + collective_permute.

Provided as a first-class feature for depth-dominated deployments (the
default assigned meshes are covered by FSDP×TP, so PP is opt-in): the layer
stack is split into S stages over a ``stage`` mesh axis; microbatches flow
through the classic GPipe schedule (S + M - 1 ticks), activations hop
between stages with ppermute.  Differentiable — jax.grad through the
shard_map gives the usual 1F1B-equivalent memory behaviour under remat.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def make_pipeline_fn(layer_fn: Callable, n_stages: int, n_micro: int,
                     mesh: Mesh, stage_axis: str = "stage"):
    """Builds pipelined_apply(stacked_params, x_microbatches).

    ``layer_fn(params_stage, x) -> x`` is one stage's computation;
    ``stacked_params`` leading dim = n_stages (sharded over the stage axis);
    ``x_microbatches`` (n_micro, mb, ...) replicated.

    Returns outputs (n_micro, mb, ...) — the last stage's results,
    broadcast to all stages (psum over one-hot so the caller can compute a
    loss anywhere).
    """

    def pipelined(params, xs):
        # inside shard_map: params leaves have leading dim 1 (this stage)
        sid = jax.lax.axis_index(stage_axis)
        p_local = jax.tree.map(lambda a: a[0], params)
        mb_shape = xs.shape[1:]
        state = jnp.zeros(mb_shape, xs.dtype)          # current in-flight mb
        outs = jnp.zeros_like(xs)
        n_ticks = n_stages + n_micro - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(carry, t):
            state, outs = carry
            # stage 0 ingests microbatch t (when valid)
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            injected = jnp.where((sid == 0) & (t < n_micro),
                                 xs[mb_idx], state)
            y = layer_fn(p_local, injected)
            # last stage emits microbatch t-(S-1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            emit = (sid == n_stages - 1) & (t >= n_stages - 1)
            outs = jnp.where(
                (jnp.arange(n_micro) == out_idx)[:, None, None] & emit,
                y[None], outs)
            # rotate activations to the next stage
            state = jax.lax.ppermute(y, stage_axis, perm)
            return (state, outs), None

        (state, outs), _ = jax.lax.scan(tick, (state, outs),
                                        jnp.arange(n_ticks))
        # broadcast last stage's outputs everywhere (replicated out_spec)
        last = jax.lax.psum(
            jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)),
            stage_axis)
        return last

    return shard_map(pipelined, mesh=mesh,
                     in_specs=(P(stage_axis), P()),
                     out_specs=P(), check_rep=False)


def split_microbatches(x: jnp.ndarray, n_micro: int) -> jnp.ndarray:
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    return x.reshape(n_micro, B // n_micro, *x.shape[1:])
