"""jit wrapper: pad n to the id-block, dispatch kernel/ref."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gather.kernel import cache_gather_pallas
from repro.kernels.gather.ref import cache_gather_ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def cache_gather(slots, cache, use_pallas: bool = True,
                 interpret: bool = True):
    """slots (n,) int32 (−1 miss) → (features (n,F), miss (n,) int32)."""
    n = slots.shape[0]
    np_ = -(-n // 8) * 8
    slots_p = jnp.pad(slots.astype(jnp.int32), (0, np_ - n),
                      constant_values=-1)
    if use_pallas:
        out, miss = cache_gather_pallas(slots_p, cache, interpret=interpret)
    else:
        out, miss = cache_gather_ref(slots_p, cache)
    return out[:n], miss[:n]
