"""jit wrapper: pad n to the id-block (and F to the feature block),
dispatch kernel/ref.

Contract (shared with ref.py, regression-tested in tests/test_kernels.py):
``slots (n,) int → (features (n, F) of ``cache.dtype``, miss (n,) int32)``
for ANY n ≥ 1 and ANY feature width F — including widths that are not a
multiple of the kernel's feature block (e.g. the reddit twin's F=602).
Padded id rows are synthesized as misses and sliced away; padded feature
columns are zero and sliced away.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gather.kernel import cache_gather_pallas
from repro.kernels.gather.ref import cache_gather_ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def cache_gather(slots, cache, use_pallas: bool = True,
                 interpret: bool = True):
    """slots (n,) int32 (−1 miss) → (features (n,F), miss (n,) int32)."""
    n = slots.shape[0]
    C, F = cache.shape
    np_ = -(-n // 8) * 8
    slots_p = jnp.pad(slots.astype(jnp.int32), (0, np_ - n),
                      constant_values=-1)
    if use_pallas:
        # feature blocking: full-width when one block suffices, else a
        # lane-aligned block size that divides the (padded) width
        if F <= 512:
            block_f, fp = F, F
        else:
            block_f = 512 if F % 512 == 0 else 128
            fp = -(-F // block_f) * block_f
        cache_p = cache if fp == F else jnp.pad(cache, ((0, 0), (0, fp - F)))
        out, miss = cache_gather_pallas(slots_p, cache_p, block_f=block_f,
                                        interpret=interpret)
        out = out[:, :F]
    else:
        out, miss = cache_gather_ref(slots_p, cache)
    return out[:n].astype(cache.dtype), miss[:n].astype(jnp.int32)
