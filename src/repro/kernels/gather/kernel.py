"""Pallas kernel: device-map feature-cache gather.

Batch-generation hot loop on the device side: for each requested node id,
look up its cache slot (device map, scalar-prefetched into SMEM) and copy
the feature row from the HBM-resident cache into the output batch buffer.
Misses (slot < 0) emit zero rows + a miss flag; the host fills them from the
DRAM feature store (the paper's PCIe path, overlapped by pipeline mode 1/2).

Grid: (id_blocks, feature_blocks); ids are scalar-prefetched so the row DMA
address is known before the block body runs (the Pallas analogue of the
paper's "device map for efficient lookup").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _gather_kernel(slots_ref, cache_ref, out_ref, miss_ref, *,
                   ids_per_block: int, block_f: int):
    fi = pl.program_id(1)
    base = pl.program_id(0) * ids_per_block         # slots_ref is unblocked
    for r in range(ids_per_block):                  # static unroll (8 rows)
        slot = slots_ref[base + r]
        hit = slot >= 0
        safe = jnp.maximum(slot, 0)
        row = pl.load(cache_ref, (pl.dslice(safe, 1), slice(None)))  # (1,Bf)
        row = jnp.where(hit, row, jnp.zeros_like(row))
        pl.store(out_ref, (pl.dslice(r, 1), slice(None)), row)
        @pl.when(fi == 0)
        def _():
            miss_ref[r] = jnp.where(hit, 0, 1).astype(jnp.int32)


def cache_gather_pallas(slots: jnp.ndarray, cache: jnp.ndarray,
                        ids_per_block: int = 8, block_f: int = 512,
                        interpret: bool = True):
    """slots (n,) int32 (−1 = miss); cache (C, F) f32 →
    (out (n, F) f32, miss (n,) int32)."""
    n = slots.shape[0]
    C, F = cache.shape
    block_f = min(block_f, F)
    assert n % ids_per_block == 0 and F % block_f == 0
    grid = (n // ids_per_block, F // block_f)
    kernel = functools.partial(_gather_kernel, ids_per_block=ids_per_block,
                               block_f=block_f)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec((C, block_f), lambda i, f, slots: (0, f))],
        out_specs=[pl.BlockSpec((ids_per_block, block_f),
                                lambda i, f, slots: (i, f)),
                   pl.BlockSpec((ids_per_block,), lambda i, f, slots: (i,))],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n, F), cache.dtype),
                   jax.ShapeDtypeStruct((n,), jnp.int32)],
        interpret=interpret,
    )(slots, cache)
