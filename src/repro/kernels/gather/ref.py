"""Pure-jnp oracle for the cache gather."""
from __future__ import annotations

import jax.numpy as jnp


def cache_gather_ref(slots, cache):
    hit = slots >= 0
    rows = cache[jnp.maximum(slots, 0)]
    out = jnp.where(hit[:, None], rows, 0.0)
    return out, (~hit).astype(jnp.int32)
