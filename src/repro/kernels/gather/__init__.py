from repro.kernels.gather.ops import cache_gather
