"""Pallas TPU kernels for the compute hot-spots A³GNN optimizes.

Each subpackage: ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd wrapper with interpret/XLA fallbacks), ``ref.py`` (pure-jnp oracle).

  reservoir/        vectorized weighted-reservoir top-m neighbor selection
  gather/           device-map feature-cache row gather
  segment_agg/      masked neighbor mean aggregation (GraphSAGE SpMM analogue)
  fused_gather_agg/ gather + layer-0 neighbor mean in one pass (no
                    materialized batch feature tensor on the hit path)
  flash_attention/  blockwise fused attention fwd (LM stack hot-spot)
"""
