from repro.kernels.fused_gather_agg.ops import gather_aggregate  # noqa: F401
