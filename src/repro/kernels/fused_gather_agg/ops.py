"""jit wrapper: pad ids/dst rows to the id block (and F to the feature
block) through the memoized pad plan, dispatch kernel/ref.

Contract (shared with ref.py, regression-tested in tests/test_fused_agg.py):
``enc (Ns,) int32`` encodes where each input id's feature row lives —
``enc[i] >= 0`` is a cache-table slot, ``enc[i] < 0`` is row ``-enc[i]-1``
of the ``aux`` sideband (host-gathered misses; must have ≥ 1 row).
``neigh_idx (Nd, fanout)`` indexes the input ids (−1 = pad), the dst ids
being the prefix of the input ids (``Nd ≤ Ns``).  Returns
``(h_dst (Nd, F), agg (Nd, F))`` — the self rows and the masked neighbor
aggregate (``mode``: ``mean`` for GraphSAGE/GCN layer 0, ``sum`` for GIN)
— without ever materializing the (Ns, F) batch tensor on the kernel
path.  Padded dst rows are sliced away; padded enc entries resolve to
``aux[0]`` and are never referenced by a real dst row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fused_gather_agg.kernel import gather_aggregate_pallas
from repro.kernels.fused_gather_agg.ref import gather_aggregate_ref
from repro.kernels.pad_plan import feat_plan, pad_plan, row_plan


def _id_plan(Nd: int, Ns: int):
    """(padded Nd, padded Ns): both block multiples, Ns ≥ Nd."""
    def compute():
        ndp = row_plan(Nd)
        return ndp, max(row_plan(Ns), ndp)
    return pad_plan("fused_ids", (Nd, Ns), compute)


@functools.partial(jax.jit,
                   static_argnames=("mode", "use_pallas", "interpret"))
def gather_aggregate(enc, neigh_idx, cache, aux, mode: str = "mean",
                     use_pallas: bool = True, interpret: bool = True):
    Nd, fanout = neigh_idx.shape
    Ns = enc.shape[0]
    C, F = cache.shape
    ndp, nsp = _id_plan(Nd, Ns)
    enc_p = jnp.pad(enc.astype(jnp.int32), (0, nsp - Ns),
                    constant_values=-1)
    idx_p = jnp.pad(neigh_idx.astype(jnp.int32), ((0, ndp - Nd), (0, 0)),
                    constant_values=-1)
    if use_pallas:
        block_f, fp = feat_plan(F)
        cache_p = cache if fp == F else jnp.pad(cache, ((0, 0), (0, fp - F)))
        aux_p = aux if fp == F else jnp.pad(aux, ((0, 0), (0, fp - F)))
        h, a = gather_aggregate_pallas(enc_p, idx_p, cache_p, aux_p,
                                       mode=mode, block_f=block_f,
                                       interpret=interpret)
        h, a = h[:, :F], a[:, :F]
    else:
        h, a = gather_aggregate_ref(enc_p, idx_p, cache, aux, mode=mode)
    return h[:Nd].astype(cache.dtype), a[:Nd].astype(cache.dtype)
