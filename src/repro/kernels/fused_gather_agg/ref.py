"""Pure-jnp oracle for the fused gather + aggregate (and the XLA fast
path on CPU hosts): resolve encoded slots against (cache, aux), take the
dst prefix, and reuse the segment-agg oracle for the masked mean."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.segment_agg.ref import neighbor_mean_ref


def gather_aggregate_ref(enc, neigh_idx, cache, aux):
    hit = enc >= 0
    rows = jnp.where(hit[:, None],
                     cache[jnp.maximum(enc, 0)],
                     aux[jnp.maximum(-enc - 1, 0)])
    h_dst = rows[:neigh_idx.shape[0]]
    return h_dst, neighbor_mean_ref(neigh_idx, rows)
