"""Pure-jnp oracle for the fused gather + aggregate (and the XLA fast
path on CPU hosts): resolve encoded slots against (cache, aux), take the
dst prefix, and reuse the segment-agg oracle for the masked aggregation
(``mean`` — GraphSAGE/GCN layer 0; ``sum`` — GIN layer 0)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.segment_agg.ref import neighbor_agg_ref


def resolve_rows_ref(enc, cache, aux):
    """Encoded-slot resolve: ``enc[i] >= 0`` → cache slot, ``enc[i] < 0``
    → row ``-enc[i]-1`` of the ``aux`` sideband."""
    hit = enc >= 0
    return jnp.where(hit[:, None],
                     cache[jnp.maximum(enc, 0)],
                     aux[jnp.maximum(-enc - 1, 0)])


def gather_aggregate_ref(enc, neigh_idx, cache, aux, mode: str = "mean"):
    rows = resolve_rows_ref(enc, cache, aux)
    h_dst = rows[:neigh_idx.shape[0]]
    return h_dst, neighbor_agg_ref(neigh_idx, rows, mode=mode)
