"""Pallas kernel: fused cache gather + neighbor-mean aggregation.

The unfused hot path materializes the full sampled-feature batch tensor
(kernels/gather) and immediately reduces it (kernels/segment_agg) — for a
fanout-k layer that round-trips k× the aggregated volume through HBM.
This kernel chains the two: neighbor rows are resolved straight out of the
HBM-resident cache table (or the host-filled miss sideband) and folded
into the per-dst mean accumulator, so sampled neighbor features never
exist as a separate batch tensor.

Row addressing uses an *encoded slot* per input id:

  ``enc[i] >= 0`` → the row lives in the cache table at slot ``enc[i]``
  ``enc[i] <  0`` → the row is ``aux[-enc[i] - 1]`` (host-gathered miss)

Grid: (dst_blocks, feature_blocks); ``enc`` and ``neigh_idx`` are
scalar-prefetched so row DMA addresses are known before the block body
runs.  Outputs both the dst-prefix rows (``h_dst``, the self term of the
SAGE layer) and the neighbor mean (``agg``) in one pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _resolve(enc, cache_ref, aux_ref):
    """Load one feature row through the encoded slot (see module doc)."""
    hit = enc >= 0
    cs = jnp.maximum(enc, 0)
    ax = jnp.maximum(-enc - 1, 0)
    crow = pl.load(cache_ref, (pl.dslice(cs, 1), slice(None)))
    arow = pl.load(aux_ref, (pl.dslice(ax, 1), slice(None)))
    return jnp.where(hit, crow, arow).astype(jnp.float32)


def _fused_kernel(enc_ref, idx_ref, cache_ref, aux_ref, hdst_ref, agg_ref, *,
                  rows_per_block: int, fanout: int, mode: str):
    base = pl.program_id(0) * rows_per_block        # enc/idx are unblocked
    for r in range(rows_per_block):                 # static row unroll
        # self term: the dst ids are the prefix of the input ids
        row = _resolve(enc_ref[base + r], cache_ref, aux_ref)
        pl.store(hdst_ref, (pl.dslice(r, 1), slice(None)),
                 row.astype(hdst_ref.dtype))
        acc = jnp.zeros((1, agg_ref.shape[-1]), jnp.float32)
        cnt = jnp.zeros((), jnp.float32)
        for f in range(fanout):                     # static fanout unroll
            idx = idx_ref[base + r, f]
            valid = idx >= 0
            safe = jnp.maximum(idx, 0)
            nrow = _resolve(enc_ref[safe], cache_ref, aux_ref)
            acc = acc + jnp.where(valid, nrow, 0.0)
            cnt = cnt + jnp.where(valid, 1.0, 0.0)
        agg = acc / jnp.maximum(cnt, 1.0) if mode == "mean" else acc
        pl.store(agg_ref, (pl.dslice(r, 1), slice(None)),
                 agg.astype(agg_ref.dtype))


def gather_aggregate_pallas(enc: jnp.ndarray, neigh_idx: jnp.ndarray,
                            cache: jnp.ndarray, aux: jnp.ndarray,
                            mode: str = "mean",
                            rows_per_block: int = 8, block_f: int = 512,
                            interpret: bool = True):
    """enc (Ns,) int32; neigh_idx (Nd, fanout) int32 (−1 pad, values in
    [0, Ns)); cache (C, F); aux (Na, F) → (h_dst (Nd, F), agg (Nd, F));
    ``mode`` is ``mean`` (GraphSAGE/GCN) or ``sum`` (GIN)."""
    Ns = enc.shape[0]
    Nd, fanout = neigh_idx.shape
    C, F = cache.shape
    block_f = min(block_f, F)
    assert Nd % rows_per_block == 0 and F % block_f == 0 and Ns >= Nd
    grid = (Nd // rows_per_block, F // block_f)
    kernel = functools.partial(_fused_kernel, rows_per_block=rows_per_block,
                               fanout=fanout, mode=mode)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[pl.BlockSpec((C, block_f), lambda i, f, enc, idx: (0, f)),
                  pl.BlockSpec((aux.shape[0], block_f),
                               lambda i, f, enc, idx: (0, f))],
        out_specs=[pl.BlockSpec((rows_per_block, block_f),
                                lambda i, f, enc, idx: (i, f)),
                   pl.BlockSpec((rows_per_block, block_f),
                                lambda i, f, enc, idx: (i, f))],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((Nd, F), cache.dtype),
                   jax.ShapeDtypeStruct((Nd, F), cache.dtype)],
        interpret=interpret,
    )(enc, neigh_idx, cache, aux)
