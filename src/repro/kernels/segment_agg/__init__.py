from repro.kernels.segment_agg.ops import neighbor_mean
