"""jit wrapper: pad dst rows, dispatch kernel/ref."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.segment_agg.kernel import neighbor_mean_pallas
from repro.kernels.segment_agg.ref import neighbor_mean_ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def neighbor_mean(neigh_idx, h_src, use_pallas: bool = True,
                  interpret: bool = True):
    Nd, fanout = neigh_idx.shape
    ndp = -(-Nd // 8) * 8
    idx_p = jnp.pad(neigh_idx.astype(jnp.int32), ((0, ndp - Nd), (0, 0)),
                    constant_values=-1)
    if use_pallas:
        out = neighbor_mean_pallas(idx_p, h_src, interpret=interpret)
    else:
        out = neighbor_mean_ref(idx_p, h_src)
    return out[:Nd]
