"""jit wrapper: pad dst rows (memoized pad plan), dispatch kernel/ref.

``neighbor_agg`` is the per-hop fused aggregation entry the GNN layers
call (models/gnn.py, ``fused=True``): the previous layer's output buffer
is consumed in place — the (Nd, fanout, D) gathered-neighbor tensor of
the unfused path never materializes on the kernel path.  ``mode`` picks
the aggregation family (``mean`` — GraphSAGE/GCN; ``sum`` — GIN);
``weights`` (GAT attention, (Nd, fanout)) rides along for the weighted
sum.  With ``use_pallas=False`` the jitted pure-jnp oracle IS the
production path on CPU hosts (and it is differentiable, which the train
step requires — the Pallas path is forward-only today).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.pad_plan import row_plan
from repro.kernels.segment_agg.kernel import neighbor_agg_pallas
from repro.kernels.segment_agg.ref import neighbor_agg_ref


@functools.partial(jax.jit,
                   static_argnames=("mode", "use_pallas", "interpret"))
def neighbor_agg(neigh_idx, h_src, mode: str = "mean", weights=None,
                 use_pallas: bool = True, interpret: bool = True):
    if weights is not None and mode != "sum":
        # one contract across backends: attention weights are already
        # normalized, so the weighted family is the SUM (see ref.py)
        raise ValueError("per-edge weights imply mode='sum'")
    Nd, fanout = neigh_idx.shape
    ndp = row_plan(Nd)
    idx_p = jnp.pad(neigh_idx.astype(jnp.int32), ((0, ndp - Nd), (0, 0)),
                    constant_values=-1)
    w_p = (None if weights is None
           else jnp.pad(weights, ((0, ndp - Nd), (0, 0))))
    if use_pallas:
        out = neighbor_agg_pallas(idx_p, h_src, mode=mode, weights=w_p,
                                  interpret=interpret)
    else:
        out = neighbor_agg_ref(idx_p, h_src, mode=mode, weights=w_p)
    return out[:Nd]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def neighbor_mean(neigh_idx, h_src, use_pallas: bool = True,
                  interpret: bool = True):
    return neighbor_agg(neigh_idx, h_src, mode="mean",
                        use_pallas=use_pallas, interpret=interpret)
