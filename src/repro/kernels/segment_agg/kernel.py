"""Pallas kernel: masked neighbor-mean aggregation (GraphSAGE hot-spot).

TPU adaptation of the CSR SpMM the GPU frameworks use: the sampler's
fixed-fanout padded blocks turn aggregation into a dense masked gather-mean —
grid (dst_blocks, feature_blocks), neighbor indices scalar-prefetched, one
VMEM accumulator per dst row.  -1 indices are padding (masked out).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _agg_kernel(idx_ref, h_ref, out_ref, *, rows_per_block: int, fanout: int):
    base = pl.program_id(0) * rows_per_block        # idx_ref is unblocked
    for r in range(rows_per_block):                 # static row unroll
        acc = jnp.zeros((1, out_ref.shape[-1]), jnp.float32)
        cnt = jnp.zeros((), jnp.float32)
        for f in range(fanout):                     # static fanout unroll
            idx = idx_ref[base + r, f]
            valid = idx >= 0
            safe = jnp.maximum(idx, 0)
            row = pl.load(h_ref, (pl.dslice(safe, 1), slice(None)))
            acc = acc + jnp.where(valid, row.astype(jnp.float32), 0.0)
            cnt = cnt + jnp.where(valid, 1.0, 0.0)
        mean = acc / jnp.maximum(cnt, 1.0)
        pl.store(out_ref, (pl.dslice(r, 1), slice(None)),
                 mean.astype(out_ref.dtype))


def neighbor_mean_pallas(neigh_idx: jnp.ndarray, h_src: jnp.ndarray,
                         rows_per_block: int = 8, block_f: int = 256,
                         interpret: bool = True):
    """neigh_idx (Nd, fanout) int32 (−1 pad); h_src (Ns, F) → (Nd, F)."""
    Nd, fanout = neigh_idx.shape
    Ns, F = h_src.shape
    block_f = min(block_f, F)
    assert Nd % rows_per_block == 0 and F % block_f == 0
    grid = (Nd // rows_per_block, F // block_f)
    kernel = functools.partial(_agg_kernel, rows_per_block=rows_per_block,
                               fanout=fanout)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec((Ns, block_f), lambda i, f, idx: (0, f))],
        out_specs=pl.BlockSpec((rows_per_block, block_f),
                               lambda i, f, idx: (i, f)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Nd, F), h_src.dtype),
        interpret=interpret,
    )(neigh_idx, h_src)
