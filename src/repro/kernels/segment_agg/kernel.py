"""Pallas kernel: masked neighbor aggregation (the per-hop GNN hot-spot).

TPU adaptation of the CSR SpMM the GPU frameworks use: the sampler's
fixed-fanout padded blocks turn aggregation into a dense masked gather —
grid (dst_blocks, feature_blocks), neighbor indices scalar-prefetched, one
VMEM accumulator per dst row.  -1 indices are padding (masked out).

Three aggregation families behind one kernel (models/gnn.py's fused
per-hop path): ``mean`` (GraphSAGE/GCN), ``sum`` (GIN) and weighted sum
(GAT — per-edge attention weights ride along as a VMEM input).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _agg_kernel(idx_ref, h_ref, out_ref, *, rows_per_block: int, fanout: int,
                mode: str):
    base = pl.program_id(0) * rows_per_block        # idx_ref is unblocked
    for r in range(rows_per_block):                 # static row unroll
        acc = jnp.zeros((1, out_ref.shape[-1]), jnp.float32)
        cnt = jnp.zeros((), jnp.float32)
        for f in range(fanout):                     # static fanout unroll
            idx = idx_ref[base + r, f]
            valid = idx >= 0
            safe = jnp.maximum(idx, 0)
            row = pl.load(h_ref, (pl.dslice(safe, 1), slice(None)))
            acc = acc + jnp.where(valid, row.astype(jnp.float32), 0.0)
            cnt = cnt + jnp.where(valid, 1.0, 0.0)
        agg = acc / jnp.maximum(cnt, 1.0) if mode == "mean" else acc
        pl.store(out_ref, (pl.dslice(r, 1), slice(None)),
                 agg.astype(out_ref.dtype))


def _agg_kernel_weighted(idx_ref, h_ref, w_ref, out_ref, *,
                         rows_per_block: int, fanout: int):
    base = pl.program_id(0) * rows_per_block
    for r in range(rows_per_block):
        acc = jnp.zeros((1, out_ref.shape[-1]), jnp.float32)
        for f in range(fanout):
            idx = idx_ref[base + r, f]
            valid = idx >= 0
            safe = jnp.maximum(idx, 0)
            row = pl.load(h_ref, (pl.dslice(safe, 1), slice(None)))
            w = w_ref[r, f].astype(jnp.float32)
            acc = acc + jnp.where(valid, w * row.astype(jnp.float32), 0.0)
        pl.store(out_ref, (pl.dslice(r, 1), slice(None)),
                 acc.astype(out_ref.dtype))


def neighbor_agg_pallas(neigh_idx: jnp.ndarray, h_src: jnp.ndarray,
                        mode: str = "mean", weights=None,
                        rows_per_block: int = 8, block_f: int = 256,
                        interpret: bool = True):
    """neigh_idx (Nd, fanout) int32 (−1 pad); h_src (Ns, F);
    weights (Nd, fanout) float or None → (Nd, F)."""
    Nd, fanout = neigh_idx.shape
    Ns, F = h_src.shape
    block_f = min(block_f, F)
    assert Nd % rows_per_block == 0 and F % block_f == 0
    grid = (Nd // rows_per_block, F // block_f)
    if weights is not None:
        kernel = functools.partial(_agg_kernel_weighted,
                                   rows_per_block=rows_per_block,
                                   fanout=fanout)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((Ns, block_f), lambda i, f, idx: (0, f)),
                      pl.BlockSpec((rows_per_block, fanout),
                                   lambda i, f, idx: (i, 0))],
            out_specs=pl.BlockSpec((rows_per_block, block_f),
                                   lambda i, f, idx: (i, f)),
        )
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((Nd, F), h_src.dtype),
            interpret=interpret,
        )(neigh_idx, h_src, weights.astype(h_src.dtype))
    kernel = functools.partial(_agg_kernel, rows_per_block=rows_per_block,
                               fanout=fanout, mode=mode)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec((Ns, block_f), lambda i, f, idx: (0, f))],
        out_specs=pl.BlockSpec((rows_per_block, block_f),
                               lambda i, f, idx: (i, f)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Nd, F), h_src.dtype),
        interpret=interpret,
    )(neigh_idx, h_src)


def neighbor_mean_pallas(neigh_idx: jnp.ndarray, h_src: jnp.ndarray,
                         rows_per_block: int = 8, block_f: int = 256,
                         interpret: bool = True):
    """neigh_idx (Nd, fanout) int32 (−1 pad); h_src (Ns, F) → (Nd, F)."""
    return neighbor_agg_pallas(neigh_idx, h_src, mode="mean",
                               rows_per_block=rows_per_block,
                               block_f=block_f, interpret=interpret)
