"""Pure-jnp oracles: masked neighbor aggregation over fixed-fanout blocks.

``neighbor_mean_ref`` matches models/gnn._mean_agg bitwise (the original
GraphSAGE regression anchor).  ``neighbor_agg_ref`` generalizes the same
expressions to the three aggregation families the fused pipeline serves:

  * ``mean``      — GraphSAGE / GCN (masked mean, empty rows → 0)
  * ``sum``       — GIN (masked sum)
  * ``weights``   — GAT (per-edge attention weights, applied to the
    masked gathered rows before the sum; pass ``mode="sum"``)
"""
from __future__ import annotations

import jax.numpy as jnp


def neighbor_agg_ref(neigh_idx, h_src, mode: str = "mean", weights=None):
    """neigh_idx (Nd, fanout) int32 (−1 pad); h_src (Ns, F);
    weights (Nd, fanout) or None → (Nd, F)."""
    mask = neigh_idx >= 0
    nb = h_src[jnp.maximum(neigh_idx, 0)]
    nb = nb * mask[..., None].astype(h_src.dtype)
    if weights is not None:
        if mode != "sum":
            # attention weights already normalize (softmax over the edge
            # set) — a second /count would double-normalize, and the
            # Pallas kernel only implements the weighted SUM
            raise ValueError("per-edge weights imply mode='sum'")
        nb = nb * weights[..., None].astype(h_src.dtype)
    if mode == "sum":
        return nb.sum(1)
    if mode == "mean":
        cnt = jnp.maximum(mask.sum(-1, keepdims=True), 1).astype(h_src.dtype)
        return nb.sum(1) / cnt
    raise ValueError(f"unknown aggregation mode: {mode!r}")


def neighbor_mean_ref(neigh_idx, h_src):
    return neighbor_agg_ref(neigh_idx, h_src, mode="mean")
