"""Pure-jnp oracle: masked neighbor mean (matches models/gnn._mean_agg)."""
from __future__ import annotations

import jax.numpy as jnp


def neighbor_mean_ref(neigh_idx, h_src):
    mask = neigh_idx >= 0
    nb = h_src[jnp.maximum(neigh_idx, 0)]
    nb = nb * mask[..., None].astype(h_src.dtype)
    cnt = jnp.maximum(mask.sum(-1, keepdims=True), 1).astype(h_src.dtype)
    return nb.sum(1) / cnt
