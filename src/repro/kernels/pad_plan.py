"""Memoized pad plans for the aggregation kernels.

Every fused/segment aggregation call pads its row counts to block
multiples (and the feature width to a lane-aligned block) before
dispatch.  The shape arithmetic is pure Python and identical for every
same-shape batch — the training loop presents the SAME (n, F, fanout)
tuple thousands of times — so the plans are memoized here, per key,
with hit/miss counters that make the reuse testable
(tests/test_fused_agg.py) and visible in benchmarks.

Both kernel wrappers (kernels/segment_agg/ops.py and
kernels/fused_gather_agg/ops.py) and the host-side bucketing in
core/feature_plane.py route their shape math through ``pad_plan``.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

_PLANS: Dict[tuple, tuple] = {}
_STATS = {"hits": 0, "misses": 0}


def pad_plan(kind: str, key: tuple, compute: Callable[[], tuple]) -> tuple:
    """Return the cached plan for (kind, key), computing it on first use."""
    k = (kind, key)
    plan = _PLANS.get(k)
    if plan is not None:
        _STATS["hits"] += 1
        return plan
    _STATS["misses"] += 1
    plan = _PLANS[k] = compute()
    return plan


def plan_stats() -> Dict[str, int]:
    return {**_STATS, "entries": len(_PLANS)}


def reset_plan_stats(clear_plans: bool = False) -> None:
    _STATS["hits"] = _STATS["misses"] = 0
    if clear_plans:
        _PLANS.clear()


# -- shared plan shapes ------------------------------------------------------

def round_up(n: int, block: int) -> int:
    return -(-n // block) * block


def row_plan(n: int, block: int = 8) -> int:
    """Padded row count: ``n`` rounded up to a multiple of ``block``."""
    (p,) = pad_plan("rows", (n, block), lambda: (round_up(n, block),))
    return p


def feat_plan(F: int) -> Tuple[int, int]:
    """Feature blocking: full-width when one block suffices, else a
    lane-aligned block size that divides the (padded) width.  Returns
    ``(block_f, padded_F)``."""
    def compute():
        if F <= 512:
            return F, F
        block_f = 512 if F % 512 == 0 else 128
        return block_f, round_up(F, block_f)
    return pad_plan("feat", (F,), compute)


def bucket_plan(n: int, min_rows: int = 8) -> int:
    """Pow2 bucket (≥ ``min_rows``) — the host-side padding discipline of
    core/feature_plane.py, memoized with the same counters."""
    def compute():
        return (max(1 << (max(n, 1) - 1).bit_length(), min_rows),)
    (p,) = pad_plan("bucket", (n, min_rows), compute)
    return p
