"""Pure-jnp oracle: exact softmax attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    BH, S, Dh = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (Dh ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
