"""Pallas kernel: blockwise fused (flash) attention forward, causal/full.

Grid (batch·heads, q_blocks); the kernel streams KV blocks through VMEM with
an online-softmax running (max, sum, acc) state.  Block shapes are
MXU-aligned: q/kv blocks multiples of 128 lanes on Dh, sublane-tiled on the
sequence dims.  Causal masking prunes fully-masked KV blocks via the loop
bound (no wasted MXU work above the diagonal).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int, block_k: int,
                  seq_len: int, causal: bool, sm_scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * sm_scale          # (Bq, Dh)
    m_i = jnp.full((block_q,), NEG, jnp.float32)
    l_i = jnp.zeros((block_q,), jnp.float32)
    acc = jnp.zeros((block_q, q.shape[-1]), jnp.float32)

    n_kv = seq_len // block_k
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)

    def body(kv_i, carry):
        m_i, l_i, acc = carry
        # direct ref indexing (pl.load rejects plain-int axes on some
        # jax versions; ref.__getitem__ normalizes them)
        k = k_ref[0, pl.dslice(kv_i * block_k, block_k),
                  slice(None)].astype(jnp.float32)
        v = v_ref[0, pl.dslice(kv_i * block_k, block_k),
                  slice(None)].astype(jnp.float32)
        s = q @ k.T                                      # (Bq, Bk)
        if causal:
            kv_pos = kv_i * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= kv_pos, s, NEG)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc

    if causal:
        upper = (qi * block_q) // block_k + pl.cdiv(block_q, block_k)
    else:
        upper = n_kv
    m_i, l_i, acc = jax.lax.fori_loop(0, upper, body, (m_i, l_i, acc))
    o_ref[0] = (acc / jnp.maximum(l_i, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """q/k/v (BH, S, Dh) → (BH, S, Dh).  S must divide by the blocks."""
    BH, S, Dh = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    sm_scale = Dh ** -0.5
    grid = (BH, S // block_q)
    return pl.pallas_call(
        functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                          seq_len=S, causal=causal, sm_scale=sm_scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, Dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, S, Dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, S, Dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, Dh), q.dtype),
        interpret=interpret,
    )(q, k, v)
