"""jit wrapper for flash attention: (B,S,H,Dh) layout + fallback dispatch."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit,
                   static_argnames=("causal", "use_pallas", "interpret",
                                    "block_q", "block_k"))
def flash_attention(q, k, v, causal: bool = True, use_pallas: bool = True,
                    interpret: bool = True, block_q: int = 128,
                    block_k: int = 128):
    """q/k/v (B, S, H, Dh) — same-head-count (repeat GQA beforehand)."""
    B, S, H, Dh = q.shape
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, S, Dh)
    unfold = lambda x: x.reshape(B, H, S, Dh).transpose(0, 2, 1, 3)
    if use_pallas:
        o = flash_attention_pallas(fold(q), fold(k), fold(v), causal=causal,
                                   block_q=block_q, block_k=block_k,
                                   interpret=interpret)
    else:
        o = attention_ref(fold(q), fold(k), fold(v), causal=causal)
    return unfold(o)
