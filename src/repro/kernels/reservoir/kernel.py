"""Pallas kernel: vectorized weighted-reservoir (Efraimidis–Spirakis) top-m.

TPU-native reformulation of the paper's sequential Algo. 2: instead of a
per-neighbor heap loop (CPU-idiomatic, O(deg) serial), compute all keys
``log(u)/w`` for a padded neighbor row at once on the VPU and take the top-m
by m rounds of (max, mask) — identical sampling distribution, fully
data-parallel over rows and lanes.

Layout: rows = dst vertices (8/block, sublane-aligned), lanes = padded
neighbor slots (multiple of 128).  m is small (fanout ≤ 32) so the m-round
selection stays in VMEM registers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -3.0e38


def _topm_kernel(w_ref, u_ref, mask_ref, idx_ref, key_ref, *, m: int):
    w = w_ref[...]                                   # (Rb, Npad) f32
    u = u_ref[...]
    valid = mask_ref[...] != 0
    # ES keys in log space: log(u)/w  (monotone in u^{1/w})
    keys = jnp.log(jnp.maximum(u, 1e-30)) / jnp.maximum(w, 1e-9)
    keys = jnp.where(valid, keys, NEG)
    npad = keys.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, keys.shape, 1)
    for j in range(m):                               # static fanout rounds
        mx = jnp.max(keys, axis=1, keepdims=True)    # (Rb,1)
        is_max = (keys == mx) & (mx > NEG / 2)
        # first index attaining the max (lane-order tie-break)
        idx = jnp.min(jnp.where(is_max, iota, npad), axis=1)  # (Rb,)
        idx_ref[:, j] = idx.astype(jnp.int32)
        key_ref[:, j] = mx[:, 0]
        # mask the chosen lane
        chosen = iota == idx[:, None]
        keys = jnp.where(chosen, NEG, keys)


def reservoir_topm_pallas(weights: jnp.ndarray, u: jnp.ndarray,
                          mask: jnp.ndarray, m: int,
                          block_rows: int = 8,
                          interpret: bool = True):
    """weights/u (R, Npad) f32, mask (R, Npad) int32 → (idx (R,m) i32,
    keys (R,m) f32).  idx = Npad marks an exhausted row (fewer than m valid)."""
    R, npad = weights.shape
    assert R % block_rows == 0, (R, block_rows)
    grid = (R // block_rows,)
    bs_in = pl.BlockSpec((block_rows, npad), lambda r: (r, 0))
    bs_out = pl.BlockSpec((block_rows, m), lambda r: (r, 0))
    return pl.pallas_call(
        functools.partial(_topm_kernel, m=m),
        grid=grid,
        in_specs=[bs_in, bs_in, bs_in],
        out_specs=[bs_out, bs_out],
        out_shape=[jax.ShapeDtypeStruct((R, m), jnp.int32),
                   jax.ShapeDtypeStruct((R, m), jnp.float32)],
        interpret=interpret,
    )(weights, u, mask.astype(jnp.int32))
