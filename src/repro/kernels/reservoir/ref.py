"""Pure-jnp oracle for the reservoir top-m kernel."""
from __future__ import annotations

import jax.numpy as jnp

NEG = -3.0e38


def reservoir_topm_ref(weights, u, mask, m: int):
    keys = jnp.log(jnp.maximum(u, 1e-30)) / jnp.maximum(weights, 1e-9)
    keys = jnp.where(mask != 0, keys, NEG)
    R, npad = keys.shape
    iota = jnp.broadcast_to(jnp.arange(npad, dtype=jnp.int32), keys.shape)
    idxs, kouts = [], []
    for _ in range(m):
        mx = jnp.max(keys, axis=1, keepdims=True)
        is_max = (keys == mx) & (mx > NEG / 2)
        idx = jnp.min(jnp.where(is_max, iota, npad), axis=1)
        idxs.append(idx.astype(jnp.int32))
        kouts.append(mx[:, 0])
        keys = jnp.where(iota == idx[:, None], NEG, keys)
    return jnp.stack(idxs, 1), jnp.stack(kouts, 1)
