"""jit wrapper: pads rows/lanes to hardware tiles, dispatches kernel/ref."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.reservoir.kernel import reservoir_topm_pallas
from repro.kernels.reservoir.ref import reservoir_topm_ref


def _pad_to(x, rows, cols, value):
    R, C = x.shape
    return jnp.pad(x, ((0, rows - R), (0, cols - C)), constant_values=value)


@functools.partial(jax.jit, static_argnames=("m", "use_pallas", "interpret"))
def reservoir_topm(weights, u, mask, m: int, use_pallas: bool = True,
                   interpret: bool = True):
    """Top-m ES selection over padded neighbor rows.

    weights (R,N) f32; u (R,N) uniforms; mask (R,N) bool/int.
    Returns (idx (R,m) int32 — N_padded marks exhausted, keys (R,m))."""
    R, N = weights.shape
    Rp = -(-R // 8) * 8
    Np = max(-(-N // 128) * 128, 128)
    wp = _pad_to(weights.astype(jnp.float32), Rp, Np, 1.0)
    up = _pad_to(u.astype(jnp.float32), Rp, Np, 0.0)
    mp = _pad_to(mask.astype(jnp.int32), Rp, Np, 0)
    fn = (functools.partial(reservoir_topm_pallas, interpret=interpret)
          if use_pallas else reservoir_topm_ref)
    idx, keys = fn(wp, up, mp, m)
    idx = jnp.where(idx >= Np, N, idx)     # normalize exhausted marker
    return idx[:R], keys[:R]
