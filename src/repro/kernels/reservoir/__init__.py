from repro.kernels.reservoir.ops import reservoir_topm
