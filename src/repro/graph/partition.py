"""Graph partitioning (Algo. 1 line 2) — hash and BFS-grown partitions.

Each GPU/TPU worker trains on its own partition (the paper's no-NVLink
setting: no remote feature access, accepted accuracy cost modeled by the
η term of Eq. (1))."""
from __future__ import annotations

from typing import List

import numpy as np

from repro.graph.storage import Graph


def hash_partition(g: Graph, parts: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    assign = rng.integers(0, parts, size=g.num_nodes)
    return [np.where(assign == p)[0].astype(np.int32) for p in range(parts)]


def bfs_partition(g: Graph, parts: int, seed: int = 0) -> List[np.ndarray]:
    """Grow partitions from random seeds by BFS — better edge locality than
    hashing (fewer cut edges → higher η overlap per partition)."""
    rng = np.random.default_rng(seed)
    n = g.num_nodes
    owner = -np.ones(n, np.int32)
    target = n // parts + 1
    sizes = np.zeros(parts, np.int64)
    frontiers = [list(rng.choice(n, size=1)) for _ in range(parts)]
    for p in range(parts):
        owner[frontiers[p][0]] = p
        sizes[p] = 1
    active = True
    while active:
        active = False
        for p in range(parts):
            if sizes[p] >= target or not frontiers[p]:
                continue
            nxt = []
            for v in frontiers[p]:
                for u in g.neighbors(v):
                    if owner[u] < 0 and sizes[p] < target:
                        owner[u] = p
                        sizes[p] += 1
                        nxt.append(int(u))
            frontiers[p] = nxt
            active = active or bool(nxt)
    # orphans (disconnected) → smallest partition
    for v in np.where(owner < 0)[0]:
        p = int(np.argmin(sizes))
        owner[v] = p
        sizes[p] += 1
    return [np.where(owner == p)[0].astype(np.int32) for p in range(parts)]


def partition(g: Graph, parts: int, method: str = "bfs",
              seed: int = 0) -> List[Graph]:
    if parts <= 1:
        return [g]
    node_sets = (bfs_partition if method == "bfs" else hash_partition)(g, parts, seed)
    return [g.subgraph(ns) for ns in node_sets]


def overlap_ratio(part: Graph, full: Graph) -> float:
    """η = |Vs_i| / |V| of Eq. (1)."""
    return part.num_nodes / max(full.num_nodes, 1)
