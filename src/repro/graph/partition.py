"""Graph partitioning (Algo. 1 line 2) — hash, BFS-grown and locality-aware.

Each GPU/TPU worker trains on its own partition (the paper's no-NVLink
setting: no remote feature access, accepted accuracy cost modeled by the
η term of Eq. (1)).  The scale-out path (core/multipart.py) consumes a
``PartitionPlan`` — the assignment plus the cut/halo statistics that the
locality objective minimizes: a *halo node* of partition p is a node
owned elsewhere but adjacent to p, i.e. exactly the features p would
have to fetch remotely (HitGNN's inter-device traffic term).

BOUNDED HALO EXCHANGE: with ``halo_budget > 0`` each partition keeps the
top-k halo candidates by *affinity* — the number of owned→candidate cut
edges, i.e. exactly the edges the out-edge-following sampler can
traverse (remote→owned edges are invisible to it on these directed
graphs, so they earn no rank), ties broken by node id so larger budgets
are strict prefix-supersets of smaller ones.  The budgeted halo nodes
are appended to the partition's subgraph as feature-only leaves — owned
nodes keep their out-edges into them, so a sampled batch reaches ONE
hop across the cut — and their feature rows are moved through
``distributed/collectives.halo_all_to_all`` (never read locally).  With
``halo_budget=0`` the plan is bit-identical to the drop-cut-edges
setting (the regression anchor)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.core.locality import edge_locality_score
from repro.graph.storage import Graph


def hash_partition(g: Graph, parts: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    assign = rng.integers(0, parts, size=g.num_nodes)
    return [np.where(assign == p)[0].astype(np.int32) for p in range(parts)]


def bfs_partition(g: Graph, parts: int, seed: int = 0) -> List[np.ndarray]:
    """Grow partitions from random seeds by BFS — better edge locality than
    hashing (fewer cut edges → higher η overlap per partition)."""
    rng = np.random.default_rng(seed)
    n = g.num_nodes
    owner = -np.ones(n, np.int32)
    target = n // parts + 1
    sizes = np.zeros(parts, np.int64)
    frontiers = [list(rng.choice(n, size=1)) for _ in range(parts)]
    for p in range(parts):
        owner[frontiers[p][0]] = p
        sizes[p] = 1
    active = True
    while active:
        active = False
        for p in range(parts):
            if sizes[p] >= target or not frontiers[p]:
                continue
            nxt = []
            for v in frontiers[p]:
                for u in g.neighbors(v):
                    if owner[u] < 0 and sizes[p] < target:
                        owner[u] = p
                        sizes[p] += 1
                        nxt.append(int(u))
            frontiers[p] = nxt
            active = active or bool(nxt)
    # orphans (disconnected) → smallest partition
    for v in np.where(owner < 0)[0]:
        p = int(np.argmin(sizes))
        owner[v] = p
        sizes[p] += 1
    return [np.where(owner == p)[0].astype(np.int32) for p in range(parts)]


def locality_partition(g: Graph, parts: int, seed: int = 0) -> List[np.ndarray]:
    """Affinity-ordered growth: admit the frontier node with the most
    neighbors already inside the partition (maximum internal affinity ⇒
    minimum new halo).  Seeds are the hottest nodes (degree order), so each
    partition starts from a hub of its own community — the same hotness
    signal the static cache policy uses (core/cache.py).

    Per-partition frontiers are max-heaps with lazy invalidation (stale
    entries are skipped when popped), so the whole growth is
    O(E log E) rather than a per-admission scan over all nodes."""
    import heapq
    n = g.num_nodes
    if parts <= 1:
        return [np.arange(n, dtype=np.int32)]
    owner = -np.ones(n, np.int32)
    target = n // parts + 1
    sizes = np.zeros(parts, np.int64)
    # affinity[p][v] = #neighbors of v already owned by p (current score);
    # heaps hold (-affinity_at_push, v) — stale when affinity moved on
    affinity = np.zeros((parts, n), np.int32)
    heaps: List[list] = [[] for _ in range(parts)]

    def absorb(p: int, v: int):
        owner[v] = p
        sizes[p] += 1
        for u in g.neighbors(v):
            if owner[u] < 0:
                affinity[p, u] += 1
                heapq.heappush(heaps[p], (-int(affinity[p, u]), int(u)))

    hot = g.hotness_order()
    rng = np.random.default_rng(seed)
    for p, v in enumerate(hot[:parts]):
        absorb(p, int(v))
    stalled = np.zeros(parts, bool)
    while not stalled.all():
        for p in range(parts):
            if stalled[p]:
                continue
            if sizes[p] >= target:
                stalled[p] = True
                continue
            v = -1
            while heaps[p]:
                neg_a, cand = heapq.heappop(heaps[p])
                if owner[cand] < 0 and -neg_a == affinity[p, cand]:
                    v = cand
                    break
            if v < 0:
                stalled[p] = True
                continue
            absorb(p, v)
    # leftovers (disconnected or capped out): hash onto the smallest parts
    for v in np.where(owner < 0)[0]:
        p = int(np.argmin(sizes + rng.random(parts)))   # random tie-break
        owner[v] = p
        sizes[p] += 1
    return [np.where(owner == p)[0].astype(np.int32) for p in range(parts)]


_METHODS = {"hash": hash_partition, "bfs": bfs_partition,
            "locality": locality_partition}


@dataclass
class PartitionPlan:
    """A partition assignment plus the statistics the scale-out path and
    the Eq. (1) accuracy model consume.

    ``halo_sets[p]`` holds the budgeted halo nodes of partition p as
    GLOBAL ids in affinity-rank order; the subgraph of partition p appends
    them after the owned nodes, so local ids ``>= len(node_sets[p])`` are
    halo rows (feature-only leaves whose rows arrive through
    ``halo_all_to_all``)."""
    node_sets: List[np.ndarray]
    owner: np.ndarray               # (N,) int32 node → partition
    method: str
    subgraphs: List[Graph] = field(default_factory=list)
    cut_edges: int = 0              # edges crossing a partition boundary
    halo_counts: List[int] = field(default_factory=list)   # candidate pool
    halo_budget: int = 0            # per-partition cap on kept halo nodes
    halo_sets: List[np.ndarray] = field(default_factory=list)
    recovered_edges: int = 0        # cut edges reachable again via the halo
    # full affinity ranking (ids + per-id recovered-edge counts), kept so
    # a live re-budget slices prefixes instead of rescanning the edges
    halo_ranked: List[np.ndarray] = field(default_factory=list, repr=False)
    halo_ranked_aff: List[np.ndarray] = field(default_factory=list,
                                              repr=False)
    # graph topology version the plan was built against (dynamic graphs:
    # drift tracking compares the live graph's version to this one)
    topology_version: int = 0
    # lazy (N,) owned-local index (ownership lookup API) — one shared map
    # next to ``owner``, not a per-partition N-map, so routing costs O(N)
    # memory once, not P×N
    _local_ids: Optional[np.ndarray] = field(default=None, repr=False)

    @property
    def parts(self) -> int:
        return len(self.node_sets)

    # ------------------------------------------------------------------
    # ownership lookup — the routing API the serving fabric and the
    # multi-partition streaming path share: global node → (owner, local)
    # ------------------------------------------------------------------
    def owner_of(self, nodes) -> np.ndarray:
        """Owning partition of each global node id (vectorized)."""
        return self.owner[np.asarray(nodes, dtype=np.int64)]

    def local_ids(self) -> np.ndarray:
        """(N,) local id of each node WITHIN its owning partition's
        subgraph (owned prefix — halo tails are borrowed features, not
        membership).  Computed once and cached on the plan."""
        if self._local_ids is None:
            m = np.zeros(len(self.owner), dtype=np.int32)
            for ns in self.node_sets:
                m[ns] = np.arange(len(ns), dtype=np.int32)
            self._local_ids = m
        return self._local_ids

    def node_maps(self) -> List[np.ndarray]:
        """Per-partition (N,) global → local translation: the owned
        prefix id for partition p's nodes, −1 everywhere else.  Halo ids
        are deliberately −1 — a query for a halo-resident node routes to
        its OWNER (where its out-edges live); the halo tail only serves
        borrowed feature rows to cross-cut neighborhoods."""
        local = self.local_ids()
        maps = []
        for p in range(self.parts):
            m = np.full(len(self.owner), -1, dtype=np.int32)
            mine = self.owner == p
            m[mine] = local[mine]
            maps.append(m)
        return maps

    @property
    def halo_rows(self) -> int:
        """Total budgeted halo feature rows across the fleet — the row
        count ``halo_all_to_all`` moves (all of them cross a boundary)."""
        return int(sum(len(hs) for hs in self.halo_sets))

    def etas(self, full: Graph) -> List[float]:
        """Per-partition η = |Vs_i| / |V| of Eq. (1)."""
        return [len(ns) / max(full.num_nodes, 1) for ns in self.node_sets]

    def edge_locality(self, full: Graph) -> float:
        """Fraction of edges kept inside a partition (1 − cut ratio)."""
        return 1.0 - self.cut_edges / max(full.num_edges, 1)

    def kept_information(self, full: Graph) -> float:
        """Fraction of full-graph edges some partition's sampler can still
        follow: internal edges plus the cut edges recovered through the
        budgeted halo.  Equals ``edge_locality`` at ``halo_budget=0`` and
        strictly exceeds it whenever the budget recovers a cut edge."""
        kept = full.num_edges - self.cut_edges + self.recovered_edges
        return kept / max(full.num_edges, 1)

    def exchange_volume_bytes(self, full: Graph) -> int:
        """Analytic boundary-feature traffic of one full halo refresh."""
        return self.halo_rows * full.feat_dim * 4

    def with_halo_budget(self, full: Graph, budget: int) -> "PartitionPlan":
        """Re-budget the SAME assignment (owner/node_sets untouched) —
        the live ``halo_budget`` swap path: the stored affinity ranking is
        sliced to the new prefix (no edge rescan, no re-partition); only
        the subgraphs are rebuilt for the new halo tails."""
        return _finalize_plan(full, self.node_sets, self.owner, self.method,
                              budget, ranking=(self.halo_ranked,
                                               self.halo_ranked_aff,
                                               self.halo_counts,
                                               self.cut_edges))


def _halo_candidates(g: Graph, owner: np.ndarray, parts: int):
    """Per-partition halo candidates ranked by affinity = the number of
    owned→candidate cut edges (the only direction the out-edge-following
    sampler can traverse — a remote→owned edge recovers nothing, so it
    earns no rank); ties broken by ascending node id so a larger budget
    keeps a strict prefix-superset of a smaller one.  ``halo_counts``
    stays the full either-direction candidate pool (the remote-fetch
    statistic the PR 2 plan reported)."""
    indptr, indices = g.adj()
    src = np.repeat(np.arange(g.num_nodes), np.diff(indptr))
    cross = owner[src] != owner[indices]
    ranked, affs, counts = [], [], []
    for p in range(parts):
        out_nb = indices[cross & (owner[src] == p)]       # owned → remote
        in_src = src[cross & (owner[indices] == p)]       # remote → owned
        ids, aff = np.unique(out_nb, return_counts=True)
        order = np.lexsort((ids, -aff))
        ranked.append(ids[order].astype(np.int64))
        affs.append(aff[order].astype(np.int64))
        counts.append(int(len(np.unique(np.concatenate([out_nb, in_src])))))
    return ranked, affs, counts, int(cross.sum())


def _finalize_plan(g: Graph, node_sets: List[np.ndarray], owner: np.ndarray,
                   method: str, halo_budget: int,
                   ranking=None) -> PartitionPlan:
    parts = len(node_sets)
    budget = max(int(halo_budget), 0)
    if ranking is None:
        ranked, affs, counts, cut = _halo_candidates(g, owner, parts)
    else:                              # live re-budget: reuse the ranking
        ranked, affs, counts, cut = ranking
    halo_sets = [r[:budget] for r in ranked]
    # affinity IS the owned→halo cut-edge count, so the recovered total is
    # just the kept prefix sum — no edge rescan needed
    recovered = int(sum(int(a[:budget].sum()) for a in affs))
    return PartitionPlan(
        node_sets=node_sets, owner=owner, method=method,
        subgraphs=[g.subgraph(ns, feature_leaves=hs)
                   for ns, hs in zip(node_sets, halo_sets)],
        cut_edges=cut, halo_counts=counts, halo_budget=budget,
        halo_sets=halo_sets, recovered_edges=recovered,
        halo_ranked=ranked, halo_ranked_aff=affs,
        topology_version=g.topology_version)


def plan_partitions(g: Graph, parts: int, method: str = "locality",
                    seed: int = 0, halo_budget: int = 0) -> PartitionPlan:
    """Build the full plan: assignment, induced subgraphs (halo-augmented
    when ``halo_budget > 0``), cut/halo stats."""
    if method not in _METHODS:
        raise ValueError(f"unknown partition method {method!r}; "
                         f"expected one of {sorted(_METHODS)}")
    node_sets = _METHODS[method](g, max(parts, 1), seed)
    owner = -np.ones(g.num_nodes, np.int32)
    for p, ns in enumerate(node_sets):
        owner[ns] = p
    return _finalize_plan(g, node_sets, owner, method, halo_budget)


def assignment_cut_fraction(g: Graph, owner: np.ndarray) -> float:
    """Fraction of CURRENT edges crossing a partition boundary under an
    ownership vector — the drift statistic: computed against ``g.adj()``
    so streamed edge inserts/deletes move it even while ``plan.cut_edges``
    (frozen at plan-build) does not."""
    indptr, indices = g.adj()
    src = np.repeat(np.arange(g.num_nodes), np.diff(indptr))
    return float((owner[src] != owner[indices]).sum() / max(len(indices), 1))


@dataclass
class RebalanceResult:
    """Outcome of one ``incremental_rebalance`` call."""
    plan: PartitionPlan
    moved_nodes: int                # boundary nodes migrated
    moved_frac: float               # moved_nodes / N
    cut_before: float               # cut fraction entering the rebalance
    cut_after: float                # cut fraction of the new assignment
    sweeps: int                     # refinement sweeps executed


def incremental_rebalance(g: Graph, plan: PartitionPlan,
                          halo_budget: Optional[int] = None,
                          max_move_frac: float = 0.25,
                          balance_slack: float = 0.10,
                          max_sweeps: int = 8) -> RebalanceResult:
    """Restore partition quality after topology drift by migrating ONLY
    boundary nodes — never a full repartition (HitGNN's CPU-side
    preprocessing is the scalability bottleneck; re-running it per drift
    event is exactly what this avoids).

    Greedy gain refinement over the CURRENT adjacency (``g.adj()``, so
    pending overlay edges count): per node, ``aff[v, p]`` = incident
    edges (either direction — cut edges hurt both endpoints' partitions)
    landing in partition p; a boundary node moves to its best partition
    when the gain ``aff[v, best] - aff[v, own]`` is positive and the
    size-balance slack allows it, and its neighbors' affinities update
    incrementally.  Total moves are capped at ``max_move_frac·N`` — the
    incremental-vs-full contract benchmarked in fig_dynamic.  The
    returned plan is rebuilt through ``_finalize_plan`` on the new node
    sets, so subgraphs, halo sets and ``kept_information`` are recomputed
    against the mutated graph, never carried stale."""
    n = g.num_nodes
    parts = plan.parts
    owner = plan.owner.copy()
    indptr, indices = g.adj()
    cut_before = assignment_cut_fraction(g, owner)
    budget = plan.halo_budget if halo_budget is None else int(halo_budget)

    src = np.repeat(np.arange(n), np.diff(indptr)).astype(np.int64)
    aff = np.zeros((n, parts), np.int64)
    np.add.at(aff, (src, owner[indices]), 1)          # out-edges of src
    np.add.at(aff, (indices, owner[src]), 1)          # in-edges of dst
    # reverse CSR: in-neighbors of v, for incremental aff updates on move
    rev_order = np.argsort(indices, kind="stable")
    rev_src = src[rev_order]
    rev_indptr = np.zeros(n + 1, np.int64)
    np.cumsum(np.bincount(indices, minlength=n), out=rev_indptr[1:])

    sizes = np.bincount(owner, minlength=parts).astype(np.int64)
    target = n / parts
    lo = int(np.floor(target * (1.0 - balance_slack)))
    hi = int(np.ceil(target * (1.0 + balance_slack)))
    move_budget = int(max_move_frac * n)
    moved_total = 0
    sweeps = 0
    while sweeps < max_sweeps and moved_total < move_budget:
        sweeps += 1
        best = np.argmax(aff, axis=1)
        own_aff = aff[np.arange(n), owner]
        gain = aff[np.arange(n), best] - own_aff
        cand = np.where((gain > 0) & (best != owner))[0]
        if not len(cand):
            break
        moved_this_sweep = 0
        # biggest gains first: the move budget goes to the worst offenders
        for v in cand[np.argsort(-gain[cand], kind="stable")]:
            if moved_total >= move_budget:
                break
            p_from, p_to = int(owner[v]), int(np.argmax(aff[v]))
            if p_to == p_from or aff[v, p_to] <= aff[v, p_from]:
                continue                      # stale after earlier moves
            if sizes[p_from] - 1 < lo or sizes[p_to] + 1 > hi:
                continue
            owner[v] = p_to
            sizes[p_from] -= 1
            sizes[p_to] += 1
            moved_total += 1
            moved_this_sweep += 1
            out_nb = indices[indptr[v]:indptr[v + 1]]
            in_nb = rev_src[rev_indptr[v]:rev_indptr[v + 1]]
            for nb in (out_nb, in_nb):
                if len(nb):
                    np.add.at(aff, (nb, p_from), -1)
                    np.add.at(aff, (nb, p_to), 1)
        if not moved_this_sweep:
            break
    node_sets = [np.where(owner == p)[0].astype(np.int32)
                 for p in range(parts)]
    new_plan = _finalize_plan(g, node_sets, owner, plan.method, budget)
    return RebalanceResult(plan=new_plan, moved_nodes=moved_total,
                           moved_frac=moved_total / max(n, 1),
                           cut_before=cut_before,
                           cut_after=assignment_cut_fraction(g, owner),
                           sweeps=sweeps)


def partition(g: Graph, parts: int, method: str = "bfs",
              seed: int = 0) -> List[Graph]:
    if parts <= 1:
        return [g]
    node_sets = _METHODS[method](g, parts, seed)
    return [g.subgraph(ns) for ns in node_sets]


def overlap_ratio(part: Graph, full: Graph) -> float:
    """η = |Vs_i| / |V| of Eq. (1)."""
    return part.num_nodes / max(full.num_nodes, 1)


__all__ = ["hash_partition", "bfs_partition", "locality_partition",
           "PartitionPlan", "plan_partitions", "partition", "overlap_ratio",
           "edge_locality_score", "assignment_cut_fraction",
           "incremental_rebalance", "RebalanceResult"]
