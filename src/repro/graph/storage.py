"""Graph storage: CSR adjacency + node feature store (host DRAM).

The host-resident graph mirrors the paper's CPU-side data: adjacency in CSR,
features in a dense row store, labels + split masks for node classification.
Degree ("hotness") statistics drive the static cache policy (PaGraph-style).
``FeatureStore`` is the streaming write path over that row store: versioned
row updates fanned out to every derived copy (caches, device mirrors, halo
rows) so trainers and the serving engine observe feature drift coherently.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Graph:
    indptr: np.ndarray          # (N+1,) int64
    indices: np.ndarray         # (E,) int32 — neighbor lists, CSR
    features: np.ndarray        # (N, F) float32
    labels: np.ndarray          # (N,) int32
    train_mask: np.ndarray      # (N,) bool
    val_mask: np.ndarray
    test_mask: np.ndarray
    name: str = "graph"

    @property
    def num_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def num_edges(self) -> int:
        return len(self.indices)

    @property
    def feat_dim(self) -> int:
        return self.features.shape[1]

    @property
    def num_classes(self) -> int:
        return int(self.labels.max()) + 1

    def density(self) -> float:
        n = self.num_nodes
        return self.num_edges / max(n * (n - 1), 1)

    def avg_degree(self) -> float:
        return self.num_edges / max(self.num_nodes, 1)

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def hotness_order(self) -> np.ndarray:
        """Node ids sorted by descending out-degree (PaGraph hotness)."""
        return np.argsort(-self.degrees(), kind="stable").astype(np.int32)

    def subgraph(self, nodes: np.ndarray,
                 feature_leaves: np.ndarray = None) -> "Graph":
        """Induced subgraph with LOCAL ids 0..len(nodes)-1 (partitioning).

        ``feature_leaves``: optional extra nodes appended AFTER ``nodes``
        as feature-only leaves — reachable through ``nodes``' out-edges
        but with empty local adjacency, zeroed feature rows (their
        features are owned elsewhere; graph/partition.py fills them
        through the halo exchange) and all-False split masks.  With no
        leaves the result is bit-identical to the plain induced subgraph."""
        nodes = np.asarray(nodes, dtype=np.int32)
        leaves = (np.asarray(feature_leaves, dtype=np.int32)
                  if feature_leaves is not None else np.zeros(0, np.int32))
        aug = np.concatenate([nodes, leaves]) if len(leaves) else nodes
        remap = -np.ones(self.num_nodes, dtype=np.int32)
        remap[aug] = np.arange(len(aug), dtype=np.int32)
        if len(leaves) and (remap[nodes] != np.arange(len(nodes))).any():
            # a leaf id that is also owned would hijack the owned node's
            # local id, silently rerouting its edges to an empty leaf row
            raise ValueError("feature_leaves must be disjoint from nodes")
        indptr = [0]
        idx_out = []
        for v in nodes:
            nb = remap[self.neighbors(v)]
            nb = nb[nb >= 0]
            idx_out.append(nb)
            indptr.append(indptr[-1] + len(nb))
        if len(leaves):
            indptr.extend([indptr[-1]] * len(leaves))
            features = np.zeros((len(aug), self.feat_dim), np.float32)
            features[:len(nodes)] = self.features[nodes]
            off = np.zeros(len(leaves), bool)
            masks = [np.concatenate([m[nodes], off]) for m in
                     (self.train_mask, self.val_mask, self.test_mask)]
            name = f"{self.name}-sub{len(nodes)}+h{len(leaves)}"
        else:
            features = self.features[nodes]
            masks = [self.train_mask[nodes], self.val_mask[nodes],
                     self.test_mask[nodes]]
            name = f"{self.name}-sub{len(nodes)}"
        return Graph(
            indptr=np.asarray(indptr, np.int64),
            indices=(np.concatenate(idx_out) if idx_out else
                     np.zeros(0, np.int32)).astype(np.int32),
            features=features,
            labels=self.labels[aug],
            train_mask=masks[0],
            val_mask=masks[1],
            test_mask=masks[2],
            name=name,
        )

    def memory_bytes(self) -> int:
        return (self.indptr.nbytes + self.indices.nbytes
                + self.features.nbytes + self.labels.nbytes)


class FeatureStore:
    """Streaming mutation path for the host feature row store.

    ``Graph.features`` is the single source of truth for node features;
    every derived copy — cache-resident rows (``core/cache.py``), device
    mirrors (``core/feature_plane.py``), halo rows on other partitions
    (``core/multipart.py``) — must observe a row update or training and
    serving silently drift apart.  ``FeatureStore`` wraps one graph's
    store with a monotonic ``version`` and a subscriber fan-out so a
    single ``update_rows`` call reaches every consumer:

      * a ``FeaturePlane`` subscribes its ``fill_rows`` (via
        ``FeaturePlane.subscribe_to``) — cache-resident copies update and
        the device mirror invalidates through ``FeatureCache.version``;
      * ``MultiPartitionTrainer.attach_feature_store`` subscribes a
        global→local remap that routes owned rows into the owning
        partition's plane and marks stale halo copies for the bounded
        periodic re-fill.

    Subscribers receive ``(ids, rows)`` with GLOBAL node ids; the store
    writes ``graph.features`` first, so a subscriber may re-read the
    store instead of using ``rows``.
    """

    def __init__(self, graph: Graph):
        self.graph = graph
        self.version = 0                 # bumps once per update_rows call
        self.rows_updated = 0            # cumulative streamed row count
        self._subscribers = []

    def subscribe(self, fn):
        """Register ``fn(ids, rows)`` to run after every ``update_rows``."""
        self._subscribers.append(fn)
        return fn

    def unsubscribe(self, fn):
        """Drop a subscriber (no-op if absent) — consumers being replaced
        (a trainer rebuilt by the autotune ``partitions`` restart, a plane
        swapped by ``Pipeline.reconfigure``) MUST detach, or updates keep
        routing into the dead object while its replacement drifts."""
        if fn in self._subscribers:
            self._subscribers.remove(fn)

    def update_rows(self, ids: np.ndarray, rows: np.ndarray) -> int:
        """Overwrite feature rows ``ids`` (global) with ``rows`` and fan
        the update out to every subscriber.  Returns the new version."""
        ids = np.asarray(ids, dtype=np.int64)
        rows = np.asarray(rows, dtype=np.float32)
        if rows.shape != (len(ids), self.graph.feat_dim):
            raise ValueError(f"update_rows: rows shape {rows.shape} != "
                             f"({len(ids)}, {self.graph.feat_dim})")
        self.graph.features[ids] = rows
        self.version += 1
        self.rows_updated += len(ids)
        for fn in list(self._subscribers):
            fn(ids, rows)
        return self.version


class FeatureStreamConsumer:
    """Attach/detach scaffolding for trainers subscribing a
    ``_on_feature_update(ids, rows)`` callback to a ``FeatureStore``.

    Both trainer kinds (core/a3gnn.py, core/multipart.py) mix this in;
    the autotune ``partitions`` restart path migrates the subscription
    between them and relies on the two staying behaviorally identical,
    so the skeleton lives ONCE, here.  Subclasses implement
    ``_on_feature_update`` and may override ``_check_feature_store_target``
    to reject unroutable topologies."""

    feature_store: "FeatureStore" = None

    def _check_feature_store_target(self):
        pass

    def attach_feature_store(self, store: "FeatureStore" = None
                             ) -> "FeatureStore":
        """Subscribe this consumer to ``store`` (default: a fresh store
        over the trainer's full graph).  Any previous subscription is
        detached first — a consumer tracks at most one store, so a
        re-attach can never leak an unreachable subscription on the old
        one.  Returns the store."""
        self._check_feature_store_target()
        self.detach_feature_store()
        if store is None:
            store = FeatureStore(self.full_graph)
        store.subscribe(self._on_feature_update)
        self.feature_store = store
        return store

    def detach_feature_store(self):
        """Unsubscribe (a replaced trainer — e.g. the autotune
        ``partitions`` restart — must detach, or updates keep routing
        into the dead object); the store itself lives on."""
        if self.feature_store is not None:
            self.feature_store.unsubscribe(self._on_feature_update)
            self.feature_store = None


def from_edges(num_nodes: int, src: np.ndarray, dst: np.ndarray,
               features: np.ndarray, labels: np.ndarray,
               train_frac=0.66, val_frac=0.1, seed=0,
               name="graph") -> Graph:
    """Build CSR (out-edges src→dst) + random split masks."""
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    counts = np.bincount(src, minlength=num_nodes)
    indptr = np.zeros(num_nodes + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    rng = np.random.default_rng(seed)
    r = rng.random(num_nodes)
    train = r < train_frac
    val = (r >= train_frac) & (r < train_frac + val_frac)
    test = ~train & ~val
    return Graph(indptr, dst.astype(np.int32), features.astype(np.float32),
                 labels.astype(np.int32), train, val, test, name=name)
