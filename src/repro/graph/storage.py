"""Graph storage: CSR adjacency + node feature store (host DRAM).

The host-resident graph mirrors the paper's CPU-side data: adjacency in CSR,
features in a dense row store, labels + split masks for node classification.
Degree ("hotness") statistics drive the static cache policy (PaGraph-style).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Graph:
    indptr: np.ndarray          # (N+1,) int64
    indices: np.ndarray         # (E,) int32 — neighbor lists, CSR
    features: np.ndarray        # (N, F) float32
    labels: np.ndarray          # (N,) int32
    train_mask: np.ndarray      # (N,) bool
    val_mask: np.ndarray
    test_mask: np.ndarray
    name: str = "graph"

    @property
    def num_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def num_edges(self) -> int:
        return len(self.indices)

    @property
    def feat_dim(self) -> int:
        return self.features.shape[1]

    @property
    def num_classes(self) -> int:
        return int(self.labels.max()) + 1

    def density(self) -> float:
        n = self.num_nodes
        return self.num_edges / max(n * (n - 1), 1)

    def avg_degree(self) -> float:
        return self.num_edges / max(self.num_nodes, 1)

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def hotness_order(self) -> np.ndarray:
        """Node ids sorted by descending out-degree (PaGraph hotness)."""
        return np.argsort(-self.degrees(), kind="stable").astype(np.int32)

    def subgraph(self, nodes: np.ndarray) -> "Graph":
        """Induced subgraph with LOCAL ids 0..len(nodes)-1 (partitioning)."""
        nodes = np.asarray(nodes, dtype=np.int32)
        remap = -np.ones(self.num_nodes, dtype=np.int32)
        remap[nodes] = np.arange(len(nodes), dtype=np.int32)
        indptr = [0]
        idx_out = []
        for v in nodes:
            nb = remap[self.neighbors(v)]
            nb = nb[nb >= 0]
            idx_out.append(nb)
            indptr.append(indptr[-1] + len(nb))
        return Graph(
            indptr=np.asarray(indptr, np.int64),
            indices=(np.concatenate(idx_out) if idx_out else
                     np.zeros(0, np.int32)).astype(np.int32),
            features=self.features[nodes],
            labels=self.labels[nodes],
            train_mask=self.train_mask[nodes],
            val_mask=self.val_mask[nodes],
            test_mask=self.test_mask[nodes],
            name=f"{self.name}-sub{len(nodes)}",
        )

    def memory_bytes(self) -> int:
        return (self.indptr.nbytes + self.indices.nbytes
                + self.features.nbytes + self.labels.nbytes)


def from_edges(num_nodes: int, src: np.ndarray, dst: np.ndarray,
               features: np.ndarray, labels: np.ndarray,
               train_frac=0.66, val_frac=0.1, seed=0,
               name="graph") -> Graph:
    """Build CSR (out-edges src→dst) + random split masks."""
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    counts = np.bincount(src, minlength=num_nodes)
    indptr = np.zeros(num_nodes + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    rng = np.random.default_rng(seed)
    r = rng.random(num_nodes)
    train = r < train_frac
    val = (r >= train_frac) & (r < train_frac + val_frac)
    test = ~train & ~val
    return Graph(indptr, dst.astype(np.int32), features.astype(np.float32),
                 labels.astype(np.int32), train, val, test, name=name)
