"""Graph storage: CSR adjacency + node feature store (host DRAM).

The host-resident graph mirrors the paper's CPU-side data: adjacency in CSR,
features in a dense row store, labels + split masks for node classification.
Degree ("hotness") statistics drive the static cache policy (PaGraph-style).
``FeatureStore`` is the streaming write path over that row store: versioned
row updates fanned out to every derived copy (caches, device mirrors, halo
rows) so trainers and the serving engine observe feature drift coherently.

DYNAMIC TOPOLOGY (delta-CSR overlay): production graphs gain and lose
edges continuously, and the paper's CPU-side preprocessing is exactly the
path that must NOT be re-run per edge (HitGNN's scalability bottleneck).
``Graph.add_edges`` / ``Graph.remove_edges`` record mutations in a
``DeltaOverlay`` next to the frozen base CSR; every adjacency consumer
(``neighbors``, ``degrees``, ``subgraph``, the ``core/sampling.py``
samplers, the partitioner's cut scan) reads through ``Graph.adj()`` — the
merged base+overlay view, memoized per ``topology_version`` so the merge
costs one O(E) pass per mutation batch, not one per sample.  A periodic
``compact()`` folds the overlay into the base CSR WITHOUT changing
``topology_version`` — compaction is a layout change, not a topology
change, which is what makes "sampling over base+overlay is bit-exact with
sampling over the compacted CSR at the same seed and version" a testable
invariant (tests/test_dynamic_graph.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np


class DeltaOverlay:
    """Pending edge mutations over a frozen base CSR (delta-CSR).

    Semantics are SET-like per directed edge ``(src, dst)``: inserting an
    edge that is already live (in the kept base or the overlay) is a no-op
    (duplicate-edge insert), and removing one deletes every live copy —
    so a double-delete is idempotent.  Base-edge removals are a boolean
    ``kept`` mask over the base ``indices`` array; insertions append to a
    per-source list in arrival order.  The merged per-row neighbor order
    is therefore *kept base order, then insertion order* — the one
    ordering contract ``Graph.adj()``, ``Graph.compact()`` and the
    differential reference model in tests/test_dynamic_graph.py all
    share (neighbor order feeds the sampler's rng stream, so the order IS
    the bit-exactness contract)."""

    def __init__(self, num_base_edges: int):
        self.kept: Optional[np.ndarray] = None   # lazy (E_base,) bool
        self.added: dict = {}                    # src -> [dst, ...] arrival order
        self.added_set: set = set()              # {(src, dst)} live overlay edges
        self.n_removed_base = 0                  # base copies masked out
        self._num_base_edges = num_base_edges

    @property
    def n_added(self) -> int:
        return len(self.added_set)

    @property
    def empty(self) -> bool:
        return not self.added_set and self.n_removed_base == 0

    def ensure_kept(self) -> np.ndarray:
        if self.kept is None:
            self.kept = np.ones(self._num_base_edges, bool)
        return self.kept


@dataclass
class Graph:
    indptr: np.ndarray          # (N+1,) int64 — BASE CSR (frozen between compactions)
    indices: np.ndarray         # (E,) int32 — neighbor lists, CSR
    features: np.ndarray        # (N, F) float32
    labels: np.ndarray          # (N,) int32
    train_mask: np.ndarray      # (N,) bool
    val_mask: np.ndarray
    test_mask: np.ndarray
    name: str = "graph"
    # dynamic topology: monotone version (bumps once per mutating
    # add_edges/remove_edges call that changed the edge set; compact()
    # preserves it) + the pending delta overlay and the memoized merged view
    topology_version: int = 0
    _overlay: Optional[DeltaOverlay] = field(default=None, repr=False,
                                             compare=False)
    _adj_cache: Optional[Tuple[np.ndarray, np.ndarray]] = field(
        default=None, repr=False, compare=False)
    _adj_cache_version: int = field(default=-1, repr=False, compare=False)

    @property
    def num_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def num_edges(self) -> int:
        ov = self._overlay
        if ov is None or ov.empty:
            return len(self.indices)
        return len(self.indices) - ov.n_removed_base + ov.n_added

    @property
    def feat_dim(self) -> int:
        return self.features.shape[1]

    @property
    def num_classes(self) -> int:
        return int(self.labels.max()) + 1

    def density(self) -> float:
        n = self.num_nodes
        return self.num_edges / max(n * (n - 1), 1)

    def avg_degree(self) -> float:
        return self.num_edges / max(self.num_nodes, 1)

    def degrees(self) -> np.ndarray:
        return np.diff(self.adj()[0]).astype(np.int64)

    def neighbors(self, v: int) -> np.ndarray:
        indptr, indices = self.adj()
        return indices[indptr[v]:indptr[v + 1]]

    # ------------------------------------------------------------------
    # dynamic topology: delta-CSR overlay (add/remove/compact + merged view)
    # ------------------------------------------------------------------
    @property
    def has_overlay(self) -> bool:
        """True when uncompacted mutations are pending."""
        return self._overlay is not None and not self._overlay.empty

    def adj(self) -> Tuple[np.ndarray, np.ndarray]:
        """The CURRENT adjacency as ``(indptr, indices)`` — the base CSR
        when no mutations are pending, otherwise the merged base+overlay
        view.  This is THE read every adjacency consumer goes through
        (samplers, partitioner, ``subgraph``), so a mutation is visible to
        the very next sample.  The merge is memoized per
        ``topology_version``: one O(E) pass per mutation batch, amortized
        across every sample drawn at that version.  Callers must treat
        the returned arrays as read-only."""
        ov = self._overlay
        if ov is None or ov.empty:
            return self.indptr, self.indices
        if (self._adj_cache is not None
                and self._adj_cache_version == self.topology_version):
            return self._adj_cache
        self._adj_cache = self._merge_overlay(ov)
        self._adj_cache_version = self.topology_version
        return self._adj_cache

    def _merge_overlay(self, ov: DeltaOverlay):
        """Materialize the merged view: per row, kept base neighbors (in
        base order) followed by overlay insertions (in arrival order)."""
        n = self.num_nodes
        if ov.kept is not None and ov.n_removed_base:
            keep = ov.kept
            cum = np.zeros(len(self.indices) + 1, np.int64)
            np.cumsum(keep, out=cum[1:])
            kept_counts = cum[self.indptr[1:]] - cum[self.indptr[:-1]]
            kept_indices = self.indices[keep]
        else:
            kept_counts = np.diff(self.indptr)
            kept_indices = self.indices
        add_counts = np.zeros(n, np.int64)
        for u, lst in ov.added.items():
            add_counts[u] = len(lst)
        indptr = np.zeros(n + 1, np.int64)
        np.cumsum(kept_counts + add_counts, out=indptr[1:])
        indices = np.empty(int(indptr[-1]), np.int32)
        if len(kept_indices):
            # kept base edges land at the head of their merged row: global
            # row-major order is preserved, so destinations are one
            # vectorized scatter
            starts = np.cumsum(kept_counts) - kept_counts
            dest = (np.repeat(indptr[:-1] - starts, kept_counts)
                    + np.arange(len(kept_indices)))
            indices[dest] = kept_indices
        for u, lst in ov.added.items():
            at = indptr[u] + kept_counts[u]
            indices[at:at + len(lst)] = lst
        return indptr, indices

    def _check_endpoints(self, src: np.ndarray, dst: np.ndarray):
        if len(src) != len(dst):
            raise ValueError(f"src/dst length mismatch: {len(src)} vs "
                             f"{len(dst)}")
        for arr in (src, dst):
            if len(arr) and (arr.min() < 0 or arr.max() >= self.num_nodes):
                raise ValueError(f"edge endpoint outside [0, "
                                 f"{self.num_nodes})")

    def _base_live_positions(self, u: int, v: int) -> np.ndarray:
        """Base ``indices`` positions of live (kept) copies of u→v."""
        s, e = int(self.indptr[u]), int(self.indptr[u + 1])
        pos = s + np.where(self.indices[s:e] == v)[0]
        ov = self._overlay
        if ov is not None and ov.kept is not None and len(pos):
            pos = pos[ov.kept[pos]]
        return pos

    def add_edges(self, src, dst) -> int:
        """Insert directed edges ``src[i] → dst[i]`` into the overlay.
        Pairs already live (kept base copy or earlier insertion) are
        no-ops — duplicate-edge insert never creates a parallel edge.
        Returns the number actually added; bumps ``topology_version``
        once iff that number is > 0."""
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        self._check_endpoints(src, dst)
        if self._overlay is None:
            self._overlay = DeltaOverlay(len(self.indices))
        ov = self._overlay
        added = 0
        for u, v in zip(src.tolist(), dst.tolist()):
            if (u, v) in ov.added_set or len(self._base_live_positions(u, v)):
                continue
            ov.added.setdefault(u, []).append(v)
            ov.added_set.add((u, v))
            added += 1
        if added:
            self.topology_version += 1
        return added

    def remove_edges(self, src, dst) -> int:
        """Delete directed edges ``src[i] → dst[i]`` — every live copy
        (base AND overlay).  Absent pairs are no-ops, so a double-delete
        is idempotent.  Returns the number of pairs that had a live copy;
        bumps ``topology_version`` once iff that number is > 0."""
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        self._check_endpoints(src, dst)
        if self._overlay is None:
            self._overlay = DeltaOverlay(len(self.indices))
        ov = self._overlay
        removed = 0
        for u, v in zip(src.tolist(), dst.tolist()):
            hit = False
            if (u, v) in ov.added_set:
                ov.added_set.remove((u, v))
                ov.added[u].remove(v)
                if not ov.added[u]:
                    del ov.added[u]
                hit = True
            pos = self._base_live_positions(u, v)
            if len(pos):
                ov.ensure_kept()[pos] = False
                ov.n_removed_base += len(pos)
                hit = True
            removed += int(hit)
        if removed:
            self.topology_version += 1
        return removed

    def compact(self) -> int:
        """Fold the overlay into the base CSR.  The merged view BECOMES
        the base (same per-row neighbor order, so sampling at the same
        seed is bit-exact across the fold — the tested invariant), the
        overlay resets, and ``topology_version`` is UNCHANGED: compaction
        re-lays-out the same topology.  Returns the number of folded
        mutations (0 when nothing was pending)."""
        ov = self._overlay
        if ov is None or ov.empty:
            self._overlay = None
            return 0
        folded = ov.n_added + ov.n_removed_base
        indptr, indices = self.adj()
        self.indptr = np.ascontiguousarray(indptr, dtype=np.int64)
        self.indices = np.ascontiguousarray(indices, dtype=np.int32)
        self._overlay = None
        self._adj_cache = None
        self._adj_cache_version = -1
        return folded

    def hotness_order(self) -> np.ndarray:
        """Node ids sorted by descending out-degree (PaGraph hotness)."""
        return np.argsort(-self.degrees(), kind="stable").astype(np.int32)

    def subgraph(self, nodes: np.ndarray,
                 feature_leaves: np.ndarray = None) -> "Graph":
        """Induced subgraph with LOCAL ids 0..len(nodes)-1 (partitioning).

        ``feature_leaves``: optional extra nodes appended AFTER ``nodes``
        as feature-only leaves — reachable through ``nodes``' out-edges
        but with empty local adjacency, zeroed feature rows (their
        features are owned elsewhere; graph/partition.py fills them
        through the halo exchange) and all-False split masks.  With no
        leaves the result is bit-identical to the plain induced subgraph."""
        nodes = np.asarray(nodes, dtype=np.int32)
        leaves = (np.asarray(feature_leaves, dtype=np.int32)
                  if feature_leaves is not None else np.zeros(0, np.int32))
        aug = np.concatenate([nodes, leaves]) if len(leaves) else nodes
        remap = -np.ones(self.num_nodes, dtype=np.int32)
        remap[aug] = np.arange(len(aug), dtype=np.int32)
        if len(leaves) and (remap[nodes] != np.arange(len(nodes))).any():
            # a leaf id that is also owned would hijack the owned node's
            # local id, silently rerouting its edges to an empty leaf row
            raise ValueError("feature_leaves must be disjoint from nodes")
        indptr = [0]
        idx_out = []
        for v in nodes:
            nb = remap[self.neighbors(v)]
            nb = nb[nb >= 0]
            idx_out.append(nb)
            indptr.append(indptr[-1] + len(nb))
        if len(leaves):
            indptr.extend([indptr[-1]] * len(leaves))
            features = np.zeros((len(aug), self.feat_dim), np.float32)
            features[:len(nodes)] = self.features[nodes]
            off = np.zeros(len(leaves), bool)
            masks = [np.concatenate([m[nodes], off]) for m in
                     (self.train_mask, self.val_mask, self.test_mask)]
            name = f"{self.name}-sub{len(nodes)}+h{len(leaves)}"
        else:
            features = self.features[nodes]
            masks = [self.train_mask[nodes], self.val_mask[nodes],
                     self.test_mask[nodes]]
            name = f"{self.name}-sub{len(nodes)}"
        return Graph(
            indptr=np.asarray(indptr, np.int64),
            indices=(np.concatenate(idx_out) if idx_out else
                     np.zeros(0, np.int32)).astype(np.int32),
            features=features,
            labels=self.labels[aug],
            train_mask=masks[0],
            val_mask=masks[1],
            test_mask=masks[2],
            name=name,
        )

    def memory_bytes(self) -> int:
        return (self.indptr.nbytes + self.indices.nbytes
                + self.features.nbytes + self.labels.nbytes)


class FeatureStore:
    """Streaming mutation path for the host feature row store.

    ``Graph.features`` is the single source of truth for node features;
    every derived copy — cache-resident rows (``core/cache.py``), device
    mirrors (``core/feature_plane.py``), halo rows on other partitions
    (``core/multipart.py``) — must observe a row update or training and
    serving silently drift apart.  ``FeatureStore`` wraps one graph's
    store with a monotonic ``version`` and a subscriber fan-out so a
    single ``update_rows`` call reaches every consumer:

      * a ``FeaturePlane`` subscribes its ``fill_rows`` (via
        ``FeaturePlane.subscribe_to``) — cache-resident copies update and
        the device mirror invalidates through ``FeatureCache.version``;
      * ``MultiPartitionTrainer.attach_feature_store`` subscribes a
        global→local remap that routes owned rows into the owning
        partition's plane and marks stale halo copies for the bounded
        periodic re-fill.

    Subscribers receive ``(ids, rows)`` with GLOBAL node ids; the store
    writes ``graph.features`` first, so a subscriber may re-read the
    store instead of using ``rows``.
    """

    def __init__(self, graph: Graph):
        self.graph = graph
        self.version = 0                 # bumps once per update_rows call
        self.rows_updated = 0            # cumulative streamed row count
        self._subscribers = []

    def subscribe(self, fn):
        """Register ``fn(ids, rows)`` to run after every ``update_rows``."""
        self._subscribers.append(fn)
        return fn

    def unsubscribe(self, fn):
        """Drop a subscriber (no-op if absent) — consumers being replaced
        (a trainer rebuilt by the autotune ``partitions`` restart, a plane
        swapped by ``Pipeline.reconfigure``) MUST detach, or updates keep
        routing into the dead object while its replacement drifts."""
        if fn in self._subscribers:
            self._subscribers.remove(fn)

    def update_rows(self, ids: np.ndarray, rows: np.ndarray) -> int:
        """Overwrite feature rows ``ids`` (global) with ``rows`` and fan
        the update out to every subscriber.  Returns the new version."""
        ids = np.asarray(ids, dtype=np.int64)
        rows = np.asarray(rows, dtype=np.float32)
        if rows.shape != (len(ids), self.graph.feat_dim):
            raise ValueError(f"update_rows: rows shape {rows.shape} != "
                             f"({len(ids)}, {self.graph.feat_dim})")
        self.graph.features[ids] = rows
        self.version += 1
        self.rows_updated += len(ids)
        for fn in list(self._subscribers):
            # a subscriber may detach another (or itself) mid-fanout — e.g.
            # a plane being torn down by the trainer callback running just
            # before it; delivering to the detached one would write into a
            # dead object (tests/test_streaming.py covers this)
            if fn in self._subscribers:
                fn(ids, rows)
        return self.version


class FeatureStreamConsumer:
    """Attach/detach scaffolding for trainers subscribing a
    ``_on_feature_update(ids, rows)`` callback to a ``FeatureStore``.

    Both trainer kinds (core/a3gnn.py, core/multipart.py) mix this in;
    the autotune ``partitions`` restart path migrates the subscription
    between them and relies on the two staying behaviorally identical,
    so the skeleton lives ONCE, here.  Subclasses implement
    ``_on_feature_update`` and may override ``_check_feature_store_target``
    to reject unroutable topologies."""

    feature_store: "FeatureStore" = None

    def _check_feature_store_target(self):
        pass

    def attach_feature_store(self, store: "FeatureStore" = None
                             ) -> "FeatureStore":
        """Subscribe this consumer to ``store`` (default: a fresh store
        over the trainer's full graph).  Any previous subscription is
        detached first — a consumer tracks at most one store, so a
        re-attach can never leak an unreachable subscription on the old
        one.  Returns the store."""
        self._check_feature_store_target()
        self.detach_feature_store()
        if store is None:
            store = FeatureStore(self.full_graph)
        store.subscribe(self._on_feature_update)
        self.feature_store = store
        return store

    def detach_feature_store(self):
        """Unsubscribe (a replaced trainer — e.g. the autotune
        ``partitions`` restart — must detach, or updates keep routing
        into the dead object); the store itself lives on."""
        if self.feature_store is not None:
            self.feature_store.unsubscribe(self._on_feature_update)
            self.feature_store = None


def from_edges(num_nodes: int, src: np.ndarray, dst: np.ndarray,
               features: np.ndarray, labels: np.ndarray,
               train_frac=0.66, val_frac=0.1, seed=0,
               name="graph") -> Graph:
    """Build CSR (out-edges src→dst) + random split masks."""
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    counts = np.bincount(src, minlength=num_nodes)
    indptr = np.zeros(num_nodes + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    rng = np.random.default_rng(seed)
    r = rng.random(num_nodes)
    train = r < train_frac
    val = (r >= train_frac) & (r < train_frac + val_frac)
    test = ~train & ~val
    return Graph(indptr, dst.astype(np.int32), features.astype(np.float32),
                 labels.astype(np.int32), train, val, test, name=name)
