"""Graph storage: CSR adjacency + node feature store (host DRAM).

The host-resident graph mirrors the paper's CPU-side data: adjacency in CSR,
features in a dense row store, labels + split masks for node classification.
Degree ("hotness") statistics drive the static cache policy (PaGraph-style).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Graph:
    indptr: np.ndarray          # (N+1,) int64
    indices: np.ndarray         # (E,) int32 — neighbor lists, CSR
    features: np.ndarray        # (N, F) float32
    labels: np.ndarray          # (N,) int32
    train_mask: np.ndarray      # (N,) bool
    val_mask: np.ndarray
    test_mask: np.ndarray
    name: str = "graph"

    @property
    def num_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def num_edges(self) -> int:
        return len(self.indices)

    @property
    def feat_dim(self) -> int:
        return self.features.shape[1]

    @property
    def num_classes(self) -> int:
        return int(self.labels.max()) + 1

    def density(self) -> float:
        n = self.num_nodes
        return self.num_edges / max(n * (n - 1), 1)

    def avg_degree(self) -> float:
        return self.num_edges / max(self.num_nodes, 1)

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def hotness_order(self) -> np.ndarray:
        """Node ids sorted by descending out-degree (PaGraph hotness)."""
        return np.argsort(-self.degrees(), kind="stable").astype(np.int32)

    def subgraph(self, nodes: np.ndarray,
                 feature_leaves: np.ndarray = None) -> "Graph":
        """Induced subgraph with LOCAL ids 0..len(nodes)-1 (partitioning).

        ``feature_leaves``: optional extra nodes appended AFTER ``nodes``
        as feature-only leaves — reachable through ``nodes``' out-edges
        but with empty local adjacency, zeroed feature rows (their
        features are owned elsewhere; graph/partition.py fills them
        through the halo exchange) and all-False split masks.  With no
        leaves the result is bit-identical to the plain induced subgraph."""
        nodes = np.asarray(nodes, dtype=np.int32)
        leaves = (np.asarray(feature_leaves, dtype=np.int32)
                  if feature_leaves is not None else np.zeros(0, np.int32))
        aug = np.concatenate([nodes, leaves]) if len(leaves) else nodes
        remap = -np.ones(self.num_nodes, dtype=np.int32)
        remap[aug] = np.arange(len(aug), dtype=np.int32)
        if len(leaves) and (remap[nodes] != np.arange(len(nodes))).any():
            # a leaf id that is also owned would hijack the owned node's
            # local id, silently rerouting its edges to an empty leaf row
            raise ValueError("feature_leaves must be disjoint from nodes")
        indptr = [0]
        idx_out = []
        for v in nodes:
            nb = remap[self.neighbors(v)]
            nb = nb[nb >= 0]
            idx_out.append(nb)
            indptr.append(indptr[-1] + len(nb))
        if len(leaves):
            indptr.extend([indptr[-1]] * len(leaves))
            features = np.zeros((len(aug), self.feat_dim), np.float32)
            features[:len(nodes)] = self.features[nodes]
            off = np.zeros(len(leaves), bool)
            masks = [np.concatenate([m[nodes], off]) for m in
                     (self.train_mask, self.val_mask, self.test_mask)]
            name = f"{self.name}-sub{len(nodes)}+h{len(leaves)}"
        else:
            features = self.features[nodes]
            masks = [self.train_mask[nodes], self.val_mask[nodes],
                     self.test_mask[nodes]]
            name = f"{self.name}-sub{len(nodes)}"
        return Graph(
            indptr=np.asarray(indptr, np.int64),
            indices=(np.concatenate(idx_out) if idx_out else
                     np.zeros(0, np.int32)).astype(np.int32),
            features=features,
            labels=self.labels[aug],
            train_mask=masks[0],
            val_mask=masks[1],
            test_mask=masks[2],
            name=name,
        )

    def memory_bytes(self) -> int:
        return (self.indptr.nbytes + self.indices.nbytes
                + self.features.nbytes + self.labels.nbytes)


def from_edges(num_nodes: int, src: np.ndarray, dst: np.ndarray,
               features: np.ndarray, labels: np.ndarray,
               train_frac=0.66, val_frac=0.1, seed=0,
               name="graph") -> Graph:
    """Build CSR (out-edges src→dst) + random split masks."""
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    counts = np.bincount(src, minlength=num_nodes)
    indptr = np.zeros(num_nodes + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    rng = np.random.default_rng(seed)
    r = rng.random(num_nodes)
    train = r < train_frac
    val = (r >= train_frac) & (r < train_frac + val_frac)
    test = ~train & ~val
    return Graph(indptr, dst.astype(np.int32), features.astype(np.float32),
                 labels.astype(np.int32), train, val, test, name=name)
