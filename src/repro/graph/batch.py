"""Batch generation (Algo. 1 lines 9-10): dedup → reindex → feature retrieve.

Locality-aware sampling concentrates repeated node ids, so deduplication
shrinks the mini-batch substantially (the paper's memory win).  Features for
the input hop are fetched THROUGH the feature plane — the single
backend-pluggable seam (core/feature_plane.py) whose host backend wraps the
cache (hit/miss accounting feeds both throughput and the bias feedback
loop) and whose device backend runs the Pallas cache gather.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional, Sequence, Union

import numpy as np

from repro.core.cache import FeatureCache
from repro.core.sampling import MiniBatch

if TYPE_CHECKING:  # typing-only: the graph layer stays jax-free at runtime
    from repro.core.feature_plane import FeaturePlane


def generate_batch(mb: MiniBatch,
                   plane: Optional[Union["FeaturePlane", FeatureCache]],
                   graph, fused: bool = False) -> MiniBatch:
    """Fill ``mb.features`` for the input hop (dedup already done by the
    sampler's np.unique reindexing).  ``plane`` is a ``FeaturePlane`` (the
    hot path) or, for back-compat, a bare ``FeatureCache``; ``None`` reads
    the host store directly (evaluation paths).

    ``fused=True`` (``GNNConfig.fused_gather_agg``) DEFERS the feature
    work entirely: the batch is returned with ``features=None`` and the
    trainer resolves the input hop at step time through
    ``FeaturePlane.fused_inputs`` (encoded slots + miss sideband) — the
    (n_src0, F) input tensor never materializes, and encoding at step
    time means the slot references can never go stale between batch
    generation and the jitted step consuming them."""
    if fused and plane is not None and mb.blocks:
        return mb
    if plane is not None:
        feats = plane.fetch(mb.input_ids)
    else:
        feats = graph.features[mb.input_ids]
    return dataclasses.replace(mb, features=feats)


def compute_level_caps(batch: int, fanouts: Sequence[int],
                       num_nodes: int) -> list:
    """Fixed per-node-level caps (input-hop first, same order as
    ``batch_device_arrays`` ``sizes``): level i+1 can grow at most
    ``(1 + fanout)`` over level i (dst ∪ sampled, dedup only shrinks),
    and never beyond the graph.  One cap vector → ONE jit signature per
    (model, level_caps) across the whole batch-size schedule — the
    serving engines and the all-hop fused train step share this
    discipline (and therefore share compiled signatures)."""
    caps = [int(batch)]
    for f in fanouts:
        caps.append(min(caps[-1] * (1 + int(f)), int(num_nodes)))
    caps.reverse()                            # input-hop level first
    return caps


def _pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def batch_device_arrays(mb: MiniBatch, pad_seed_level: bool = False,
                        level_caps: Optional[Sequence[int]] = None):
    """Convert to jit-friendly arrays with CHAINED padding.

    Invariant required by models/gnn.py: the padded dst count of hop i equals
    the padded src count of hop i+1 (dst_ids ARE the prefix of the next hop's
    src_ids, so one pad size per node level).  Padded neighbor rows are -1
    (masked out); padded feature rows are zero.

    Three padding regimes per node level:

      * default — pow2 buckets, seeds exact (training: the seed count is
        the constant ``batch_size``, hop sizes drift within a few buckets
        and the retraces amortize over a long run);
      * ``pad_seed_level`` — seeds pow2-bucket too (a serving engine
        admits 1..batch seeds per step);
      * ``level_caps`` — every level pads to a FIXED cap (input-hop
        first, same order as ``sizes``): ONE jit signature ever, for
        latency-SLO serving where a single ~250 ms mid-sweep retrace
        stalls the fabric long enough to age out its whole queue.

    Padded rows are inert either way: they reference only masked −1
    neighbors, so real logits never see them."""
    n_levels = len(mb.blocks) + 1
    # level sizes: [n_src_hop0, n_dst_hop0 == n_src_hop1, ..., n_seeds]
    sizes = [len(mb.blocks[0].src_ids)] + [len(b.dst_ids) for b in mb.blocks]
    if level_caps is not None:
        if len(level_caps) != n_levels:
            raise ValueError(f"level_caps has {len(level_caps)} entries "
                             f"for {n_levels} node levels")
        pads = [max(int(c), s) for c, s in zip(level_caps, sizes)]
    else:
        pads = [_pow2(s) for s in sizes]
        if not pad_seed_level:
            pads[-1] = sizes[-1]                # seeds: exact batch size
    neigh_idxs = []
    for i, blk in enumerate(mb.blocks):
        pad_dst = pads[i + 1]
        m = -np.ones((pad_dst, blk.neigh_idx.shape[1]), np.int32)
        m[:blk.neigh_idx.shape[0]] = blk.neigh_idx
        neigh_idxs.append(m)
    out = {
        "neigh_idxs": neigh_idxs,
        "labels": mb.labels.astype(np.int32),
        "sizes": sizes,
        "pads": pads,
        # sampled-at topology version rides along (dynamic graphs:
        # consumers can audit which adjacency a batch was drawn from)
        "topology_version": mb.topology_version,
    }
    if mb.features is None:
        # deferred fused batch (generate_batch(fused=True)): the input
        # hop is resolved at step time via FeaturePlane.fused_inputs
        # against pads[0] — no feature tensor rides the batch
        return out
    feats = mb.features
    fpad = np.zeros((pads[0], feats.shape[1]), feats.dtype)
    fpad[:sizes[0]] = feats
    out["features"] = fpad
    return out


def inference_arrays(mb: MiniBatch,
                     level_caps: Optional[Sequence[int]] = None):
    """Forward-only view of ``batch_device_arrays`` for the serving path
    (serve/gnn_engine.py): same chained-padding invariant, no labels.
    With ``level_caps`` (the engines pass their precomputed per-level
    maxima) every step has ONE fixed shape — serving admits a varying
    seed count per step AND hop sizes vary with which seeds get
    co-batched, so shape-following pads retrace jit mid-serving (~250 ms
    each on this container, long enough that a latency-SLO fabric ages
    out its whole queue).  The engine reads only the real-seed prefix of
    the logits."""
    arrays = batch_device_arrays(mb, pad_seed_level=True,
                                 level_caps=level_caps)
    return {"features": arrays["features"],
            "neigh_idxs": arrays["neigh_idxs"],
            "sizes": arrays["sizes"]}


def batch_bytes(mb: MiniBatch) -> int:
    """B term of Eq. (3): bytes of the generated mini-batch."""
    total = mb.features.nbytes if mb.features is not None else 0
    for blk in mb.blocks:
        total += blk.neigh_idx.nbytes + blk.src_ids.nbytes + blk.dst_ids.nbytes
    return total + mb.labels.nbytes
