"""Batch generation (Algo. 1 lines 9-10): dedup → reindex → feature retrieve.

Locality-aware sampling concentrates repeated node ids, so deduplication
shrinks the mini-batch substantially (the paper's memory win).  Features for
the input hop are fetched THROUGH the feature plane — the single
backend-pluggable seam (core/feature_plane.py) whose host backend wraps the
cache (hit/miss accounting feeds both throughput and the bias feedback
loop) and whose device backend runs the Pallas cache gather.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional, Sequence, Union

import numpy as np

from repro.core.cache import FeatureCache
from repro.core.sampling import MiniBatch

if TYPE_CHECKING:  # typing-only: the graph layer stays jax-free at runtime
    from repro.core.feature_plane import FeaturePlane


def generate_batch(mb: MiniBatch,
                   plane: Optional[Union["FeaturePlane", FeatureCache]],
                   graph, fused: bool = False) -> MiniBatch:
    """Fill ``mb.features`` for the input hop (dedup already done by the
    sampler's np.unique reindexing).  ``plane`` is a ``FeaturePlane`` (the
    hot path) or, for back-compat, a bare ``FeatureCache``; ``None`` reads
    the host store directly (evaluation paths).

    ``fused=True`` (``GNNConfig.fused_gather_agg``, GraphSAGE layer 0)
    routes through ``FeaturePlane.gather_aggregate`` instead: the batch
    carries the layer-0 pre-aggregates (``fused_h_dst``, ``fused_agg``)
    and ``features`` stays ``None`` — the input-hop tensor never
    materializes."""
    if fused and plane is not None and mb.blocks:
        h_dst, agg = plane.gather_aggregate(mb.input_ids,
                                            mb.blocks[0].neigh_idx)
        return dataclasses.replace(mb, fused_h_dst=h_dst, fused_agg=agg)
    if plane is not None:
        feats = plane.fetch(mb.input_ids)
    else:
        feats = graph.features[mb.input_ids]
    return dataclasses.replace(mb, features=feats)


def _pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def batch_device_arrays(mb: MiniBatch, pad_seed_level: bool = False,
                        level_caps: Optional[Sequence[int]] = None):
    """Convert to jit-friendly arrays with CHAINED padding.

    Invariant required by models/gnn.py: the padded dst count of hop i equals
    the padded src count of hop i+1 (dst_ids ARE the prefix of the next hop's
    src_ids, so one pad size per node level).  Padded neighbor rows are -1
    (masked out); padded feature rows are zero.

    Three padding regimes per node level:

      * default — pow2 buckets, seeds exact (training: the seed count is
        the constant ``batch_size``, hop sizes drift within a few buckets
        and the retraces amortize over a long run);
      * ``pad_seed_level`` — seeds pow2-bucket too (a serving engine
        admits 1..batch seeds per step);
      * ``level_caps`` — every level pads to a FIXED cap (input-hop
        first, same order as ``sizes``): ONE jit signature ever, for
        latency-SLO serving where a single ~250 ms mid-sweep retrace
        stalls the fabric long enough to age out its whole queue.

    Padded rows are inert either way: they reference only masked −1
    neighbors, so real logits never see them."""
    n_levels = len(mb.blocks) + 1
    # level sizes: [n_src_hop0, n_dst_hop0 == n_src_hop1, ..., n_seeds]
    sizes = [len(mb.blocks[0].src_ids)] + [len(b.dst_ids) for b in mb.blocks]
    if level_caps is not None:
        if len(level_caps) != n_levels:
            raise ValueError(f"level_caps has {len(level_caps)} entries "
                             f"for {n_levels} node levels")
        pads = [max(int(c), s) for c, s in zip(level_caps, sizes)]
    else:
        pads = [_pow2(s) for s in sizes]
        if not pad_seed_level:
            pads[-1] = sizes[-1]                # seeds: exact batch size
    neigh_idxs = []
    for i, blk in enumerate(mb.blocks):
        pad_dst = pads[i + 1]
        m = -np.ones((pad_dst, blk.neigh_idx.shape[1]), np.int32)
        m[:blk.neigh_idx.shape[0]] = blk.neigh_idx
        neigh_idxs.append(m)
    out = {
        "neigh_idxs": neigh_idxs,
        "labels": mb.labels.astype(np.int32),
        "sizes": sizes,
        # sampled-at topology version rides along (dynamic graphs:
        # consumers can audit which adjacency a batch was drawn from)
        "topology_version": mb.topology_version,
    }
    if mb.fused_agg is not None:
        # fused batch generation: layer-0 pre-aggregates replace the
        # input-hop feature tensor; both pad to the DST level of hop 0
        # (zero rows — they never reach the loss, which slices to seeds)
        for key, arr in (("h_dst0", mb.fused_h_dst), ("agg0", mb.fused_agg)):
            pad = np.zeros((pads[1], arr.shape[1]), np.float32)
            pad[:sizes[1]] = arr
            out[key] = pad
        return out
    feats = mb.features
    fpad = np.zeros((pads[0], feats.shape[1]), feats.dtype)
    fpad[:sizes[0]] = feats
    out["features"] = fpad
    return out


def inference_arrays(mb: MiniBatch,
                     level_caps: Optional[Sequence[int]] = None):
    """Forward-only view of ``batch_device_arrays`` for the serving path
    (serve/gnn_engine.py): same chained-padding invariant, no labels.
    With ``level_caps`` (the engines pass their precomputed per-level
    maxima) every step has ONE fixed shape — serving admits a varying
    seed count per step AND hop sizes vary with which seeds get
    co-batched, so shape-following pads retrace jit mid-serving (~250 ms
    each on this container, long enough that a latency-SLO fabric ages
    out its whole queue).  The engine reads only the real-seed prefix of
    the logits."""
    arrays = batch_device_arrays(mb, pad_seed_level=True,
                                 level_caps=level_caps)
    return {"features": arrays["features"],
            "neigh_idxs": arrays["neigh_idxs"],
            "sizes": arrays["sizes"]}


def batch_bytes(mb: MiniBatch) -> int:
    """B term of Eq. (3): bytes of the generated mini-batch."""
    total = mb.features.nbytes if mb.features is not None else 0
    if mb.fused_agg is not None:
        total += mb.fused_agg.nbytes + mb.fused_h_dst.nbytes
    for blk in mb.blocks:
        total += blk.neigh_idx.nbytes + blk.src_ids.nbytes + blk.dst_ids.nbytes
    return total + mb.labels.nbytes
