"""Synthetic power-law graph generators (offline stand-ins for OGB).

The container has no dataset downloads, so every paper dataset gets a
synthetic twin with matched *statistics*: node/edge counts (scaled), a
power-law degree distribution with the dataset's exponent, homophilous
community structure (labels correlate with topology — so locality-biased
sampling has a real accuracy effect to measure), and features drawn from
class-conditional Gaussians (so a GNN actually learns).
"""
from __future__ import annotations


import numpy as np

from repro.graph.storage import Graph, from_edges


def powerlaw_graph(num_nodes: int, num_edges: int, power_exp: float = 2.1,
                   feat_dim: int = 100, num_classes: int = 16,
                   homophily: float = 0.7, seed: int = 0,
                   name: str = "synthetic") -> Graph:
    """Chung–Lu style power-law graph with community structure.

    Expected degree of node i ∝ i^{-1/(power_exp-1)}; edges are drawn with
    probability ∝ w_i·w_j, then rewired toward same-class targets with
    probability ``homophily``.
    """
    rng = np.random.default_rng(seed)
    n, m = num_nodes, num_edges

    # class assignment (balanced-ish communities)
    labels = rng.integers(0, num_classes, size=n).astype(np.int32)
    # order nodes by community so class-local edge sampling is cheap
    order = np.argsort(labels, kind="stable")
    labels = labels[order]
    class_start = np.searchsorted(labels, np.arange(num_classes))
    class_end = np.searchsorted(labels, np.arange(num_classes), side="right")

    # Chung–Lu weights (power-law ranks, shuffled so hot nodes span classes)
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks ** (-1.0 / (power_exp - 1.0))
    rng.shuffle(w)
    p = w / w.sum()

    src = rng.choice(n, size=m, p=p).astype(np.int32)
    dst_global = rng.choice(n, size=m, p=p).astype(np.int32)

    # homophilous rewiring: with prob `homophily`, redirect dst into src's class
    flip = rng.random(m) < homophily
    src_cls = labels[src]
    lo = class_start[src_cls]
    hi = class_end[src_cls]
    same_class_dst = (lo + (rng.random(m) * np.maximum(hi - lo, 1)).astype(np.int64))
    dst = np.where(flip, same_class_dst.astype(np.int32), dst_global)

    # self-loop removal (redirect to (v+1) mod n)
    self_loop = src == dst
    dst = np.where(self_loop, (dst + 1) % n, dst)

    # class-conditional Gaussian features
    centers = rng.normal(0, 1.0, size=(num_classes, feat_dim)).astype(np.float32)
    feats = centers[labels] + rng.normal(0, 2.0, size=(n, feat_dim)).astype(np.float32)

    return from_edges(n, src, dst, feats, labels, seed=seed, name=name)


def dataset_like(cfg, seed: int = 0) -> Graph:
    """Build the synthetic twin described by a GNNConfig."""
    return powerlaw_graph(
        num_nodes=cfg.num_nodes, num_edges=cfg.num_edges,
        power_exp=cfg.power_exp, feat_dim=cfg.feat_dim,
        num_classes=cfg.num_classes, seed=seed,
        name=cfg.name.replace("graphsage-", ""))
