"""Scan wrapper with a global force-unroll switch.

XLA's ``cost_analysis()`` counts a while-loop body ONCE, ignoring trip count,
which silently undercounts FLOPs/bytes/collectives of scanned layer stacks
(verified empirically — see EXPERIMENTS.md §Dry-run methodology).  The
dry-run therefore does cost measurement on reduced-depth configs compiled
with every scan fully unrolled (trip count 1 ⇒ exact counts), then
extrapolates linearly in depth.  Model code routes every lax.scan through
here so that a single switch flips the whole stack.
"""
from __future__ import annotations

import contextlib

import jax

_FORCE_UNROLL = False


def unroll_enabled() -> bool:
    return _FORCE_UNROLL


@contextlib.contextmanager
def force_unroll(enable: bool = True):
    global _FORCE_UNROLL
    prev = _FORCE_UNROLL
    _FORCE_UNROLL = enable
    try:
        yield
    finally:
        _FORCE_UNROLL = prev


def scan(f, init, xs, length=None, unroll=1):
    if _FORCE_UNROLL:
        if length is None:
            length = jax.tree.leaves(xs)[0].shape[0]
        unroll = max(int(length), 1)
    return jax.lax.scan(f, init, xs, length=length, unroll=unroll)
