"""Whisper-style encoder-decoder backbone.

The conv/mel audio frontend is a STUB per the assignment: ``input_specs``
supplies precomputed frame embeddings (B, encoder_seq, D).  Everything
downstream — bidirectional encoder, causal decoder with cross attention,
learned positional embeddings, pre-LN layernorm + GELU MLP — is real.
"""
from __future__ import annotations


from repro.models.unroll import scan as uscan
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.params import decl, ParamDecl
from repro.models.transformer import stack_decls, _remat, _cdt
from repro.distributed.sharding import constrain


def decls_encdec(cfg):
    enc_layer = {
        "ln1": L.decls_layernorm(cfg.d_model),
        "attn": L.decls_attention(cfg),
        "ln2": L.decls_layernorm(cfg.d_model),
        "mlp": L.decls_mlp(cfg),
    }
    dec_layer = {
        "ln1": L.decls_layernorm(cfg.d_model),
        "attn": L.decls_attention(cfg),
        "ln_x": L.decls_layernorm(cfg.d_model),
        "xattn": L.decls_attention(cfg),
        "ln2": L.decls_layernorm(cfg.d_model),
        "mlp": L.decls_mlp(cfg),
    }
    return {
        "embed": L.decls_embedding(cfg),
        "pos_enc": decl((cfg.encoder_seq, cfg.d_model), (None, "fsdp"),
                        init="normal", scale=0.02),
        "pos_dec": decl((cfg.max_seq, cfg.d_model), (None, "fsdp"),
                        init="normal", scale=0.02),
        "encoder": stack_decls(enc_layer, cfg.encoder_layers),
        "decoder": stack_decls(dec_layer, cfg.num_layers),
        "ln_enc": L.decls_layernorm(cfg.d_model),
        "ln_f": L.decls_layernorm(cfg.d_model),
    }


def encode(params, audio_embeds, cfg):
    """audio_embeds (B, S_enc, D) — precomputed frontend output (stub)."""
    h = audio_embeds.astype(_cdt(cfg))
    h = h + params["pos_enc"].astype(h.dtype)[None, :h.shape[1]]
    h = constrain(h, "dp", None, None)

    def body(h, lp):
        a = L.attention(lp["attn"], L.layernorm(lp["ln1"], h, cfg.norm_eps),
                        cfg, causal=False)
        h = h + a
        m = L.mlp(lp["mlp"], L.layernorm(lp["ln2"], h, cfg.norm_eps), cfg)
        return constrain(h + m, "dp", None, None), None

    body = _remat(body, cfg)
    h, _ = uscan(body, h, params["encoder"])
    return L.layernorm(params["ln_enc"], h, cfg.norm_eps)


def _decoder_fwd(params, tokens, enc_out, cfg):
    h = L.embed(params["embed"], tokens, cfg, _cdt(cfg))
    S = tokens.shape[1]
    h = h + params["pos_dec"].astype(h.dtype)[None, :S]
    h = constrain(h, "dp", None, None)

    def body(h, lp):
        a = L.attention(lp["attn"], L.layernorm(lp["ln1"], h, cfg.norm_eps),
                        cfg, causal=True)
        h = h + a
        kv = L.cross_kv(lp["xattn"], enc_out, cfg)
        x = L.attention_cross(lp["xattn"],
                              L.layernorm(lp["ln_x"], h, cfg.norm_eps), kv, cfg)
        h = h + x
        m = L.mlp(lp["mlp"], L.layernorm(lp["ln2"], h, cfg.norm_eps), cfg)
        return constrain(h + m, "dp", None, None), None

    body = _remat(body, cfg)
    h, _ = uscan(body, h, params["decoder"])
    return L.layernorm(params["ln_f"], h, cfg.norm_eps)


def loss_fn(params, batch, cfg):
    enc_out = encode(params, batch["audio_embeds"], cfg)
    h = _decoder_fwd(params, batch["tokens"], enc_out, cfg)
    loss = L.lm_loss(params["embed"], h, batch["targets"], cfg, batch.get("mask"))
    return loss, {"loss": loss, "aux": jnp.float32(0)}


# ---------------------------------------------------------------------------
# Decode: self-attn KV caches + precomputed cross-attn KV per layer
# ---------------------------------------------------------------------------

def cache_decls(cfg, batch: int, cache_len: int):
    Hkv, Dh, Lyr = cfg.num_kv_heads, cfg.head_dim, cfg.num_layers
    cdt = _cdt(cfg)
    self_axes = (None, "dp", "kvseq", "kvheads", None)
    cross_axes = (None, "dp", None, "kvheads", None)
    return {
        "k": ParamDecl((Lyr, batch, cache_len, Hkv, Dh), cdt, self_axes, "zeros"),
        "v": ParamDecl((Lyr, batch, cache_len, Hkv, Dh), cdt, self_axes, "zeros"),
        "xk": ParamDecl((Lyr, batch, cfg.encoder_seq, Hkv, Dh), cdt, cross_axes, "zeros"),
        "xv": ParamDecl((Lyr, batch, cfg.encoder_seq, Hkv, Dh), cdt, cross_axes, "zeros"),
    }


def prefill(params, batch, cfg):
    """Encode audio + run the decoder prompt, building all caches."""
    enc_out = encode(params, batch["audio_embeds"], cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = L.embed(params["embed"], tokens, cfg, _cdt(cfg))
    h = h + params["pos_dec"].astype(h.dtype)[None, :S]
    h = constrain(h, "dp", None, None)

    def body(h, lp):
        a, (k, v) = L.attention_prefill(
            lp["attn"], L.layernorm(lp["ln1"], h, cfg.norm_eps), cfg, causal=True)
        h = h + a
        xk, xv = L.cross_kv(lp["xattn"], enc_out, cfg)
        x = L.attention_cross(lp["xattn"],
                              L.layernorm(lp["ln_x"], h, cfg.norm_eps), (xk, xv), cfg)
        h = h + x
        m = L.mlp(lp["mlp"], L.layernorm(lp["ln2"], h, cfg.norm_eps), cfg)
        return constrain(h + m, "dp", None, None), (k, v, xk, xv)

    h, (ks, vs, xks, xvs) = uscan(body, h, params["decoder"])
    h = L.layernorm(params["ln_f"], h, cfg.norm_eps)
    W = L.unembed_matrix(params["embed"], cfg, h.dtype)
    logits = jnp.einsum("bd,dv->bv", h[:, -1], W).astype(jnp.float32)
    return logits, {"k": ks, "v": vs, "xk": xks, "xv": xvs}


def decode_step(params, caches, batch, cfg):
    B = batch["token"].shape[0]
    pos = batch["pos"]
    h = L.embed(params["embed"], batch["token"][:, None], cfg, _cdt(cfg))
    pe = params["pos_dec"].astype(h.dtype)[jnp.broadcast_to(pos, (B,))]
    h = h + pe[:, None, :]

    def body(h, xs):
        lp, ck, cv, xk, xv = xs
        a, ck, cv = L.attention_decode(
            lp["attn"], L.layernorm(lp["ln1"], h, cfg.norm_eps), cfg, ck, cv, pos)
        h = h + a
        x = L.attention_cross(lp["xattn"],
                              L.layernorm(lp["ln_x"], h, cfg.norm_eps), (xk, xv), cfg)
        h = h + x
        m = L.mlp(lp["mlp"], L.layernorm(lp["ln2"], h, cfg.norm_eps), cfg)
        return h + m, (ck, cv)

    h, (ks, vs) = uscan(body, h, (params["decoder"], caches["k"],
                                         caches["v"], caches["xk"], caches["xv"]))
    h = L.layernorm(params["ln_f"], h, cfg.norm_eps)
    W = L.unembed_matrix(params["embed"], cfg, h.dtype)
    logits = jnp.einsum("bd,dv->bv", h[:, 0], W).astype(jnp.float32)
    return logits, {"k": ks, "v": vs, "xk": caches["xk"], "xv": caches["xv"]}
