"""GNN models over sampled blocks: GraphSAGE (mean), GCN, GAT, GIN.

Blocks use fixed-fanout padded neighbor matrices (core/sampling.py) so every
hop is a dense masked gather + matmul — the TPU-native formulation of the
CSR SpMM the GPU frameworks use (kernels/segment_agg provides the Pallas
path).  Variable node counts are bucketed to powers of two (graph/batch.py)
so jit recompiles only a handful of times.

Every layer has two expressions of the same math:

- **unfused** (default): materialize the gathered-neighbor tensor
  (``_gather_neighbors``) and reduce it — simple, and the historical
  reference the fused path is tested against.
- **fused** (``fused=True``): the hop's aggregation runs through
  ``kernels/segment_agg.neighbor_agg`` consuming the previous layer's
  output buffer in place — the (Nd, fanout, D) tensor never
  materializes.  Layer 0 goes further: ``gnn_forward_allfused`` resolves
  input rows straight out of the feature-plane cache table via
  ``kernels/fused_gather_agg`` (encoded slots + miss sideband), so the
  (pad_src0, F) input-feature tensor never materializes either.

The train steps always run the fused kernels with ``use_pallas=False``:
the jitted pure-jnp oracle is the production path on CPU hosts and is
differentiable (the Pallas path is forward-only today).
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.fused_gather_agg.ops import gather_aggregate
from repro.kernels.fused_gather_agg.ref import resolve_rows_ref as resolve_rows
from repro.kernels.segment_agg.ops import neighbor_agg
from repro.models.params import decl


def layer_dims(cfg) -> List[Tuple[int, int]]:
    dims = [cfg.feat_dim] + [cfg.hidden] * (cfg.num_layers - 1) + [cfg.num_classes]
    return list(zip(dims[:-1], dims[1:]))


def decls_gnn(cfg):
    layers = []
    for (din, dout) in layer_dims(cfg):
        if cfg.model == "graphsage":
            layers.append({"w_self": decl((din, dout), (None, None)),
                           "w_neigh": decl((din, dout), (None, None)),
                           "b": decl((dout,), (None,), init="zeros")})
        elif cfg.model == "gcn":
            layers.append({"w": decl((din, dout), (None, None)),
                           "b": decl((dout,), (None,), init="zeros")})
        elif cfg.model == "gat":
            layers.append({"w": decl((din, dout), (None, None)),
                           "a_src": decl((dout,), (None,), scale=0.1, init="normal"),
                           "a_dst": decl((dout,), (None,), scale=0.1, init="normal"),
                           "b": decl((dout,), (None,), init="zeros")})
        elif cfg.model == "gin":
            layers.append({"eps": decl((1,), (None,), init="zeros"),
                           "w1": decl((din, dout), (None, None)),
                           "b1": decl((dout,), (None,), init="zeros"),
                           "w2": decl((dout, dout), (None, None)),
                           "b2": decl((dout,), (None,), init="zeros")})
        else:
            raise ValueError(cfg.model)
    return {"layers": layers}


def _gather_neighbors(h_src, neigh_idx):
    """h_src (Ns,D), neigh_idx (Nd,F) with -1 pad → (nb (Nd,F,D), mask)."""
    mask = (neigh_idx >= 0)
    idx = jnp.maximum(neigh_idx, 0)
    nb = h_src[idx]
    return nb * mask[..., None].astype(h_src.dtype), mask


def _mean_agg(h_src, neigh_idx):
    nb, mask = _gather_neighbors(h_src, neigh_idx)
    cnt = jnp.maximum(mask.sum(-1, keepdims=True), 1).astype(h_src.dtype)
    return nb.sum(1) / cnt


def _sum_agg(h_src, neigh_idx):
    nb, _ = _gather_neighbors(h_src, neigh_idx)
    return nb.sum(1)


# ---------------------------------------------------------------------------
# combine stages: what each model does AFTER the neighbor aggregation.
# Shared between the unfused layers, the fused layers, and the all-fused
# layer-0 entry (which gets (h_dst, agg) from kernels/fused_gather_agg).
# ---------------------------------------------------------------------------

def _sage_combine(p, h_dst, agg_mean, neigh_idx, act):
    out = h_dst @ p["w_self"] + agg_mean @ p["w_neigh"] + p["b"]
    return jax.nn.relu(out) if act else out


def _gcn_combine(p, h_dst, agg_mean, neigh_idx, act):
    # sampled-mean approximation of sym-normalized aggregation incl. self-loop
    mask = (neigh_idx >= 0)
    cnt = jnp.maximum(mask.sum(-1, keepdims=True), 1).astype(h_dst.dtype)
    z = (agg_mean * cnt + h_dst) / (cnt + 1.0)
    out = z @ p["w"] + p["b"]
    return jax.nn.relu(out) if act else out


def _gin_combine(p, h_dst, agg_sum, neigh_idx, act):
    z = (1.0 + p["eps"]) * h_dst + agg_sum
    out = jax.nn.relu(z @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]
    return jax.nn.relu(out) if act else out


# model → (combine fn, aggregation mode its layer consumes)
_COMBINE = {"graphsage": (_sage_combine, "mean"),
            "gcn": (_gcn_combine, "mean"),
            "gin": (_gin_combine, "sum")}


def _agg(h_src, neigh_idx, mode, *, fused, use_pallas, interpret):
    if fused:
        return neighbor_agg(neigh_idx, h_src, mode=mode,
                            use_pallas=use_pallas, interpret=interpret)
    return _mean_agg(h_src, neigh_idx) if mode == "mean" \
        else _sum_agg(h_src, neigh_idx)


def sage_layer(p, h_src, neigh_idx, *, act=True, fused=False,
               use_pallas=False, interpret=False):
    h_dst = h_src[:neigh_idx.shape[0]]
    agg = _agg(h_src, neigh_idx, "mean", fused=fused,
               use_pallas=use_pallas, interpret=interpret)
    return _sage_combine(p, h_dst, agg, neigh_idx, act)


def gcn_layer(p, h_src, neigh_idx, *, act=True, fused=False,
              use_pallas=False, interpret=False):
    h_dst = h_src[:neigh_idx.shape[0]]
    agg = _agg(h_src, neigh_idx, "mean", fused=fused,
               use_pallas=use_pallas, interpret=interpret)
    return _gcn_combine(p, h_dst, agg, neigh_idx, act)


def gin_layer(p, h_src, neigh_idx, *, act=True, fused=False,
              use_pallas=False, interpret=False):
    h_dst = h_src[:neigh_idx.shape[0]]
    agg = _agg(h_src, neigh_idx, "sum", fused=fused,
               use_pallas=use_pallas, interpret=interpret)
    return _gin_combine(p, h_dst, agg, neigh_idx, act)


def gat_layer(p, h_src, neigh_idx, *, act=True, fused=False,
              use_pallas=False, interpret=False):
    n_dst = neigh_idx.shape[0]
    z_src = h_src @ p["w"]                               # (Ns,D')
    z_dst = z_src[:n_dst]
    if fused:
        # attention scores need only the scalar projections z@a_src —
        # gather those (Nd, fanout) scalars, not (Nd, fanout, D') rows
        mask = (neigh_idx >= 0)
        s_src = z_src @ p["a_src"]                       # (Ns,)
        e = jax.nn.leaky_relu(
            jnp.where(mask, s_src[jnp.maximum(neigh_idx, 0)], 0.0)
            + (z_dst @ p["a_dst"])[:, None],
            negative_slope=0.2)
        e = jnp.where(mask, e, -1e30)
        e_self = jax.nn.leaky_relu(z_dst @ (p["a_src"] + p["a_dst"]))[:, None]
        alla = jax.nn.softmax(jnp.concatenate([e, e_self], axis=1), axis=1)
        agg = neighbor_agg(neigh_idx, z_src, mode="sum",
                           weights=alla[:, :-1], use_pallas=use_pallas,
                           interpret=interpret) + alla[:, -1:] * z_dst
    else:
        nb, mask = _gather_neighbors(z_src, neigh_idx)   # (Nd,F,D')
        e = jax.nn.leaky_relu(nb @ p["a_src"] + (z_dst @ p["a_dst"])[:, None],
                              negative_slope=0.2)
        e = jnp.where(mask, e, -1e30)
        # include self edge in the softmax
        e_self = jax.nn.leaky_relu(z_dst @ (p["a_src"] + p["a_dst"]))[:, None]
        alla = jax.nn.softmax(jnp.concatenate([e, e_self], axis=1), axis=1)
        agg = jnp.einsum("nf,nfd->nd", alla[:, :-1], nb) + alla[:, -1:] * z_dst
    out = agg + p["b"]
    return jax.nn.elu(out) if act else out


_LAYER_FNS = {"graphsage": sage_layer, "gcn": gcn_layer, "gat": gat_layer,
              "gin": gin_layer}


def gnn_forward(params, features, neigh_idxs: List[jnp.ndarray], cfg, *,
                fused=False, use_pallas=False, interpret=False):
    """features (pad_src0, F); neigh_idxs[i] (pad_dst_i, fanout_i) with the
    chained-padding invariant pad_dst_i == pad_src_{i+1}."""
    fn = _LAYER_FNS[cfg.model]
    h = features.astype(jnp.dtype(cfg.compute_dtype))
    n = len(params["layers"])
    for i, (p, idx) in enumerate(zip(params["layers"], neigh_idxs)):
        h = fn(p, h, idx, act=(i < n - 1), fused=fused,
               use_pallas=use_pallas, interpret=interpret)
    return h                                              # (pad_seeds, classes)


def gnn_forward_allfused(params, enc0, aux0, table, neigh_idxs, cfg, *,
                         use_pallas=False, interpret=False):
    """All-hop fused forward: layer-0 inputs are the encoded slots ``enc0``
    resolved against the feature-plane cache ``table`` and the host miss
    sideband ``aux0`` (kernels/fused_gather_agg) — the (pad_src0, F)
    input-feature tensor never materializes — and every hop ≥ 1 runs the
    fused per-hop aggregation over the previous layer's output buffer."""
    dt = jnp.dtype(cfg.compute_dtype)
    n = len(params["layers"])
    p0, idx0 = params["layers"][0], neigh_idxs[0]
    kw = dict(fused=True, use_pallas=use_pallas, interpret=interpret)
    if cfg.model == "gat":
        # attention needs the per-src projection: resolve the rows (still no
        # neighbor tensor) and run the fused GAT layer on them
        rows = resolve_rows(enc0, table, aux0).astype(dt)
        h = gat_layer(p0, rows, idx0, act=(n > 1), **kw)
    else:
        combine, mode = _COMBINE[cfg.model]
        h_dst, agg = gather_aggregate(enc0, idx0, table, aux0, mode=mode,
                                      use_pallas=use_pallas,
                                      interpret=interpret)
        h = combine(p0, h_dst.astype(dt), agg.astype(dt), idx0, act=(n > 1))
    fn = _LAYER_FNS[cfg.model]
    for i, (p, idx) in enumerate(zip(params["layers"][1:], neigh_idxs[1:]),
                                 start=1):
        h = fn(p, h, idx, act=(i < n - 1), **kw)
    return h


def _softmax_ce(logits, labels):
    logits = logits[:labels.shape[0]].astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(lse - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, acc


def gnn_loss(params, features, neigh_idxs, labels, cfg):
    logits = gnn_forward(params, features, neigh_idxs, cfg)
    return _softmax_ce(logits, labels)


def gnn_loss_allfused(params, enc0, aux0, table, neigh_idxs, labels, cfg):
    logits = gnn_forward_allfused(params, enc0, aux0, table, neigh_idxs, cfg)
    return _softmax_ce(logits, labels)


def make_train_step(cfg, opt):
    """jit-able (params, opt_state, features, neigh_idxs, labels) step."""

    @jax.jit
    def step(params, opt_state, features, neigh_idxs, labels):
        (loss, acc), grads = jax.value_and_grad(
            lambda p: gnn_loss(p, features, neigh_idxs, labels, cfg),
            has_aux=True)(params)
        updates, opt_state = opt.update(grads, opt_state, params, cfg.lr)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
        return params, opt_state, loss, acc

    return step


def make_train_step_allfused(cfg, opt):
    """All-hop fused twin of ``make_train_step``: consumes
    (enc0, aux0, table) from the feature plane instead of the materialized
    feature tensor.  With level-capped buffers (graph/batch.py
    ``compute_level_caps``) every batch hits ONE jit signature —
    ``step.counters['traces']`` counts retraces (incremented inside the jit
    body, so it bumps once per compilation) and ``['calls']`` counts
    invocations; tests assert traces == 1."""
    counters = {"traces": 0, "calls": 0}

    @jax.jit
    def _step(params, opt_state, enc0, aux0, table, neigh_idxs, labels):
        counters["traces"] += 1
        (loss, acc), grads = jax.value_and_grad(
            lambda p: gnn_loss_allfused(p, enc0, aux0, table, neigh_idxs,
                                        labels, cfg),
            has_aux=True)(params)
        updates, opt_state = opt.update(grads, opt_state, params, cfg.lr)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype), params,
                              updates)
        return params, opt_state, loss, acc

    def step(params, opt_state, enc0, aux0, table, neigh_idxs, labels):
        counters["calls"] += 1
        return _step(params, opt_state, enc0, aux0, table, neigh_idxs, labels)

    step.counters = counters
    return step


def make_grad_fn(cfg):
    """jit-able gradient step WITHOUT the optimizer update — the
    multi-partition path (core/multipart.py) averages gradients across
    partitions before applying a single shared update."""

    @jax.jit
    def gfn(params, features, neigh_idxs, labels):
        (loss, acc), grads = jax.value_and_grad(
            lambda p: gnn_loss(p, features, neigh_idxs, labels, cfg),
            has_aux=True)(params)
        return grads, loss, acc

    return gfn


def make_grad_fn_allfused(cfg):
    """All-hop fused twin of ``make_grad_fn`` (multi-partition path)."""
    counters = {"traces": 0, "calls": 0}

    @jax.jit
    def _gfn(params, enc0, aux0, table, neigh_idxs, labels):
        counters["traces"] += 1
        (loss, acc), grads = jax.value_and_grad(
            lambda p: gnn_loss_allfused(p, enc0, aux0, table, neigh_idxs,
                                        labels, cfg),
            has_aux=True)(params)
        return grads, loss, acc

    def gfn(params, enc0, aux0, table, neigh_idxs, labels):
        counters["calls"] += 1
        return _gfn(params, enc0, aux0, table, neigh_idxs, labels)

    gfn.counters = counters
    return gfn


def make_apply_fn(cfg, opt):
    """jit-able optimizer application for pre-averaged gradients."""

    @jax.jit
    def apply(params, opt_state, grads):
        updates, opt_state = opt.update(grads, opt_state, params, cfg.lr)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype), params,
                              updates)
        return params, opt_state

    return apply


def make_eval_fn(cfg):
    @jax.jit
    def ev(params, features, neigh_idxs, labels):
        logits = gnn_forward(params, features, neigh_idxs, cfg)
        logits = logits[:labels.shape[0]]
        return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return ev
