"""GNN models over sampled blocks: GraphSAGE (mean), GCN, GAT.

Blocks use fixed-fanout padded neighbor matrices (core/sampling.py) so every
hop is a dense masked gather + matmul — the TPU-native formulation of the
CSR SpMM the GPU frameworks use (kernels/segment_agg provides the Pallas
path).  Variable node counts are bucketed to powers of two (graph/batch.py)
so jit recompiles only a handful of times.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.models.params import decl


def layer_dims(cfg) -> List[Tuple[int, int]]:
    dims = [cfg.feat_dim] + [cfg.hidden] * (cfg.num_layers - 1) + [cfg.num_classes]
    return list(zip(dims[:-1], dims[1:]))


def decls_gnn(cfg):
    layers = []
    for (din, dout) in layer_dims(cfg):
        if cfg.model == "graphsage":
            layers.append({"w_self": decl((din, dout), (None, None)),
                           "w_neigh": decl((din, dout), (None, None)),
                           "b": decl((dout,), (None,), init="zeros")})
        elif cfg.model == "gcn":
            layers.append({"w": decl((din, dout), (None, None)),
                           "b": decl((dout,), (None,), init="zeros")})
        elif cfg.model == "gat":
            layers.append({"w": decl((din, dout), (None, None)),
                           "a_src": decl((dout,), (None,), scale=0.1, init="normal"),
                           "a_dst": decl((dout,), (None,), scale=0.1, init="normal"),
                           "b": decl((dout,), (None,), init="zeros")})
        else:
            raise ValueError(cfg.model)
    return {"layers": layers}


def _gather_neighbors(h_src, neigh_idx):
    """h_src (Ns,D), neigh_idx (Nd,F) with -1 pad → (nb (Nd,F,D), mask)."""
    mask = (neigh_idx >= 0)
    idx = jnp.maximum(neigh_idx, 0)
    nb = h_src[idx]
    return nb * mask[..., None].astype(h_src.dtype), mask


def _mean_agg(h_src, neigh_idx):
    nb, mask = _gather_neighbors(h_src, neigh_idx)
    cnt = jnp.maximum(mask.sum(-1, keepdims=True), 1).astype(h_src.dtype)
    return nb.sum(1) / cnt


def sage_layer(p, h_src, neigh_idx, *, act=True):
    n_dst = neigh_idx.shape[0]
    h_dst = h_src[:n_dst]
    agg = _mean_agg(h_src, neigh_idx)
    out = h_dst @ p["w_self"] + agg @ p["w_neigh"] + p["b"]
    return jax.nn.relu(out) if act else out


def gcn_layer(p, h_src, neigh_idx, *, act=True):
    n_dst = neigh_idx.shape[0]
    h_dst = h_src[:n_dst]
    # sampled-mean approximation of sym-normalized aggregation incl. self-loop
    mask = (neigh_idx >= 0)
    cnt = jnp.maximum(mask.sum(-1, keepdims=True), 1).astype(h_src.dtype)
    agg = (_mean_agg(h_src, neigh_idx) * cnt + h_dst) / (cnt + 1.0)
    out = agg @ p["w"] + p["b"]
    return jax.nn.relu(out) if act else out


def gat_layer(p, h_src, neigh_idx, *, act=True):
    n_dst = neigh_idx.shape[0]
    z_src = h_src @ p["w"]                               # (Ns,D')
    z_dst = z_src[:n_dst]
    nb, mask = _gather_neighbors(z_src, neigh_idx)       # (Nd,F,D')
    e = jax.nn.leaky_relu(nb @ p["a_src"] + (z_dst @ p["a_dst"])[:, None],
                          negative_slope=0.2)
    e = jnp.where(mask, e, -1e30)
    # include self edge in the softmax
    e_self = jax.nn.leaky_relu(z_dst @ (p["a_src"] + p["a_dst"]))[:, None]
    alla = jax.nn.softmax(jnp.concatenate([e, e_self], axis=1), axis=1)
    agg = jnp.einsum("nf,nfd->nd", alla[:, :-1], nb) + alla[:, -1:] * z_dst
    out = agg + p["b"]
    return jax.nn.elu(out) if act else out


_LAYER_FNS = {"graphsage": sage_layer, "gcn": gcn_layer, "gat": gat_layer}


def gnn_forward(params, features, neigh_idxs: List[jnp.ndarray], cfg):
    """features (pad_src0, F); neigh_idxs[i] (pad_dst_i, fanout_i) with the
    chained-padding invariant pad_dst_i == pad_src_{i+1}."""
    fn = _LAYER_FNS[cfg.model]
    h = features.astype(jnp.dtype(cfg.compute_dtype))
    n = len(params["layers"])
    for i, (p, idx) in enumerate(zip(params["layers"], neigh_idxs)):
        h = fn(p, h, idx, act=(i < n - 1))
    return h                                              # (pad_seeds, classes)


def gnn_forward_fused(params, h_dst0, agg0, neigh_idxs, cfg):
    """Forward pass whose layer-0 inputs were produced by the fused
    gather+aggregate kernel (kernels/fused_gather_agg): the batch-gen
    stage hands over ``h_dst0`` (the dst-prefix feature rows) and ``agg0``
    (the masked neighbor mean), both (pad_dst0, F) — the (pad_src0, F)
    input-feature tensor never materializes.  Only GraphSAGE layer 0 is
    expressible as (self, mean) pre-aggregates; layers 1+ run the normal
    per-hop path over ``neigh_idxs[1:]``."""
    assert cfg.model == "graphsage", "fused layer 0 is GraphSAGE-only"
    dt = jnp.dtype(cfg.compute_dtype)
    n = len(params["layers"])
    p0 = params["layers"][0]
    h = (h_dst0.astype(dt) @ p0["w_self"] + agg0.astype(dt) @ p0["w_neigh"]
         + p0["b"])
    h = jax.nn.relu(h) if n > 1 else h
    for i, (p, idx) in enumerate(zip(params["layers"][1:], neigh_idxs[1:]),
                                 start=1):
        h = sage_layer(p, h, idx, act=(i < n - 1))
    return h


def gnn_loss(params, features, neigh_idxs, labels, cfg):
    logits = gnn_forward(params, features, neigh_idxs, cfg)
    logits = logits[:labels.shape[0]].astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(lse - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, acc


def gnn_loss_fused(params, h_dst0, agg0, neigh_idxs, labels, cfg):
    logits = gnn_forward_fused(params, h_dst0, agg0, neigh_idxs, cfg)
    logits = logits[:labels.shape[0]].astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(lse - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, acc


def make_train_step(cfg, opt):
    """jit-able (params, opt_state, features, neigh_idxs, labels) step."""

    @jax.jit
    def step(params, opt_state, features, neigh_idxs, labels):
        (loss, acc), grads = jax.value_and_grad(
            lambda p: gnn_loss(p, features, neigh_idxs, labels, cfg),
            has_aux=True)(params)
        updates, opt_state = opt.update(grads, opt_state, params, cfg.lr)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
        return params, opt_state, loss, acc

    return step


def make_train_step_fused(cfg, opt):
    """Fused-layer-0 twin of ``make_train_step``: consumes the
    (h_dst0, agg0) pair from the fused gather+aggregate batch path."""

    @jax.jit
    def step(params, opt_state, h_dst0, agg0, neigh_idxs, labels):
        (loss, acc), grads = jax.value_and_grad(
            lambda p: gnn_loss_fused(p, h_dst0, agg0, neigh_idxs, labels,
                                     cfg),
            has_aux=True)(params)
        updates, opt_state = opt.update(grads, opt_state, params, cfg.lr)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype), params,
                              updates)
        return params, opt_state, loss, acc

    return step


def make_grad_fn(cfg):
    """jit-able gradient step WITHOUT the optimizer update — the
    multi-partition path (core/multipart.py) averages gradients across
    partitions before applying a single shared update."""

    @jax.jit
    def gfn(params, features, neigh_idxs, labels):
        (loss, acc), grads = jax.value_and_grad(
            lambda p: gnn_loss(p, features, neigh_idxs, labels, cfg),
            has_aux=True)(params)
        return grads, loss, acc

    return gfn


def make_grad_fn_fused(cfg):
    """Fused-layer-0 twin of ``make_grad_fn`` (multi-partition path)."""

    @jax.jit
    def gfn(params, h_dst0, agg0, neigh_idxs, labels):
        (loss, acc), grads = jax.value_and_grad(
            lambda p: gnn_loss_fused(p, h_dst0, agg0, neigh_idxs, labels,
                                     cfg),
            has_aux=True)(params)
        return grads, loss, acc

    return gfn


def make_apply_fn(cfg, opt):
    """jit-able optimizer application for pre-averaged gradients."""

    @jax.jit
    def apply(params, opt_state, grads):
        updates, opt_state = opt.update(grads, opt_state, params, cfg.lr)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype), params,
                              updates)
        return params, opt_state

    return apply


def make_eval_fn(cfg):
    @jax.jit
    def ev(params, features, neigh_idxs, labels):
        logits = gnn_forward(params, features, neigh_idxs, cfg)
        logits = logits[:labels.shape[0]]
        return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return ev
