"""Unified model API.

``build(cfg)`` returns a :class:`Model` whose members are pure functions
(params first) suitable for jit/pjit:

  * ``decls``                      parameter declarations (pytree of ParamDecl)
  * ``loss_fn(params, batch)``     → (loss, metrics)   [train shapes]
  * ``prefill(params, batch)``     → (logits, caches)  [prefill shapes]
  * ``decode(params, caches, batch)`` → (logits, caches) [decode shapes]
  * ``cache_decls(batch, len)``    abstract decode-cache declarations
  * ``input_specs(shape)``         ShapeDtypeStruct stand-ins for every input
                                   (+ logical PartitionSpecs) — the dry-run's
                                   no-allocation entry point
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax
from repro.models.unroll import scan as uscan
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.models import ssm as SSM
from repro.models import hybrid as HY
from repro.models import encdec as ED
from repro.models.params import ParamDecl, abstract_params
from repro.distributed.sharding import constrain

VISION_PREFIX = 1024  # stubbed patch-embedding prefix length (vlm prefill/train)


# ---------------------------------------------------------------------------
# Pure-SSM LM (Mamba2 stack)
# ---------------------------------------------------------------------------

def _ssm_decls(cfg):
    return {
        "embed": L.decls_embedding(cfg),
        "layers": T.stack_decls({"ln": L.decls_rmsnorm(cfg.d_model),
                                 "block": SSM.decls_mamba2(cfg)}, cfg.num_layers),
        "ln_f": L.decls_rmsnorm(cfg.d_model),
    }


def _ssm_forward(params, batch, cfg):
    h = L.embed(params["embed"], batch["tokens"], cfg, T._cdt(cfg))
    h = constrain(h, "dp", None, None)

    def body(h, lp):
        h = h + SSM.mamba2_block(lp["block"],
                                 L.rmsnorm(lp["ln"], h, cfg.norm_eps), cfg)
        return constrain(h, "dp", None, None), None

    body = T._remat(body, cfg)
    h, _ = uscan(body, h, params["layers"])
    return L.rmsnorm(params["ln_f"], h, cfg.norm_eps), jnp.float32(0)


def _ssm_loss(params, batch, cfg):
    h, aux = _ssm_forward(params, batch, cfg)
    loss = L.lm_loss(params["embed"], h, batch["targets"], cfg, batch.get("mask"))
    return loss, {"loss": loss, "aux": aux}


def _ssm_cache_decls(cfg, batch, cache_len):
    d_inner, nheads, N, conv_dim = SSM.ssm_dims(cfg)
    return {
        "ssm": ParamDecl((cfg.num_layers, batch, nheads, cfg.ssm_head_dim, N),
                         jnp.float32, (None, "dp", "tp", None, None), "zeros"),
        "conv": ParamDecl((cfg.num_layers, batch, cfg.ssm_conv_width - 1, conv_dim),
                          T._cdt(cfg), (None, "dp", None, "tp"), "zeros"),
    }


def _ssm_prefill(params, batch, cfg):
    """Prompt pass producing final SSM/conv states per layer."""
    h = L.embed(params["embed"], batch["tokens"], cfg, T._cdt(cfg))
    h = constrain(h, "dp", None, None)
    B, Ssz, _ = h.shape
    d_inner, nheads, N, conv_dim = SSM.ssm_dims(cfg)

    def body(h, lp):
        hn = L.rmsnorm(lp["ln"], h, cfg.norm_eps)
        p = lp["block"]
        zxbcdt = jnp.einsum("bsd,de->bse", hn, p["in_proj"].astype(h.dtype))
        z, xbc, dt = SSM._split_proj(cfg, zxbcdt)
        conv_tail = xbc[:, -(cfg.ssm_conv_width - 1):, :]
        xbc = SSM._causal_conv(xbc, p["conv_w"].astype(h.dtype),
                               p["conv_b"].astype(h.dtype))
        xin = xbc[..., :d_inner].reshape(B, Ssz, nheads, cfg.ssm_head_dim)
        Bm = xbc[..., d_inner:d_inner + N]
        Cm = xbc[..., d_inner + N:]
        dtv = jax.nn.softplus(dt.astype(jnp.float32)
                              + p["dt_bias"].astype(jnp.float32))
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        y, fstate = SSM.ssd_chunked(xin, dtv, A, Bm, Cm, min(cfg.ssm_chunk, Ssz))
        y = y + xin * p["D"].astype(y.dtype)[None, None, :, None]
        y = y.reshape(B, Ssz, d_inner) * jax.nn.silu(z)
        y = L.rmsnorm(p["norm"], y, cfg.norm_eps)
        h = h + jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(h.dtype))
        return constrain(h, "dp", None, None), (fstate, conv_tail)

    h, (fstates, tails) = uscan(body, h, params["layers"])
    h = L.rmsnorm(params["ln_f"], h, cfg.norm_eps)
    W = L.unembed_matrix(params["embed"], cfg, h.dtype)
    logits = jnp.einsum("bd,dv->bv", h[:, -1], W).astype(jnp.float32)
    return logits, {"ssm": fstates, "conv": tails}


def _ssm_decode(params, caches, batch, cfg):
    h = L.embed(params["embed"], batch["token"][:, None], cfg, T._cdt(cfg))

    def body(h, xs):
        lp, sc, cc = xs
        hn = L.rmsnorm(lp["ln"], h, cfg.norm_eps)
        y, nc = SSM.mamba2_decode(lp["block"], hn, cfg, {"ssm": sc, "conv": cc})
        return h + y, (nc["ssm"], nc["conv"])

    h, (ns, nc) = uscan(body, h, (params["layers"], caches["ssm"],
                                         caches["conv"]))
    h = L.rmsnorm(params["ln_f"], h, cfg.norm_eps)
    W = L.unembed_matrix(params["embed"], cfg, h.dtype)
    logits = jnp.einsum("bd,dv->bv", h[:, 0], W).astype(jnp.float32)
    return logits, {"ssm": ns, "conv": nc}


# ---------------------------------------------------------------------------
# Model wrapper
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    decls: Any
    loss_fn: Callable
    prefill: Callable
    decode: Callable
    cache_decls_fn: Callable            # (batch, cache_len) -> decls

    def cache_decls(self, batch: int, cache_len: int):
        return self.cache_decls_fn(batch, cache_len)

    # -- dry-run inputs ------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins + logical pspecs for one shape cell."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct
        cdt = jnp.dtype(cfg.compute_dtype)

        if shape.kind == "train":
            batch = {"tokens": sds((B, S), i32), "targets": sds((B, S), i32)}
            specs = {"tokens": P("dp", None), "targets": P("dp", None)}
            if cfg.family == "encdec":
                batch["audio_embeds"] = sds((B, cfg.encoder_seq, cfg.d_model), cdt)
                specs["audio_embeds"] = P("dp", None, None)
            if cfg.family == "vlm":
                vp = min(VISION_PREFIX, S // 4)
                batch["vision_embeds"] = sds((B, vp, cfg.d_model), cdt)
                specs["vision_embeds"] = P("dp", None, None)
                batch["positions"] = sds((3, B, S), i32)
                specs["positions"] = P(None, "dp", None)
            return {"kind": "train", "batch": batch, "batch_specs": specs}

        if shape.kind == "prefill":
            batch = {"tokens": sds((B, S), i32)}
            specs = {"tokens": P("dp", None)}
            if cfg.family == "encdec":
                batch["audio_embeds"] = sds((B, cfg.encoder_seq, cfg.d_model), cdt)
                specs["audio_embeds"] = P("dp", None, None)
            if cfg.family == "vlm":
                vp = min(VISION_PREFIX, S // 4)
                batch["vision_embeds"] = sds((B, vp, cfg.d_model), cdt)
                specs["vision_embeds"] = P("dp", None, None)
                batch["positions"] = sds((3, B, S), i32)
                specs["positions"] = P(None, "dp", None)
            return {"kind": "prefill", "batch": batch, "batch_specs": specs}

        # decode: one new token against a seq_len cache
        batch = {"token": sds((B,), i32), "pos": sds((B,), i32)}
        specs = {"token": P("dp"), "pos": P("dp")}
        if cfg.family == "vlm":
            batch["positions"] = sds((3, B, 1), i32)
            specs["positions"] = P(None, "dp", None)
        cdecls = self.cache_decls(B, S)
        caches = abstract_params(cdecls)
        return {"kind": "decode", "batch": batch, "batch_specs": specs,
                "caches": caches, "cache_decls": cdecls}


def build(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return Model(
            cfg=cfg,
            decls=T.decls_lm(cfg),
            loss_fn=lambda p, b: T.loss_fn(p, b, cfg),
            prefill=lambda p, b: T.prefill(p, b, cfg),
            decode=lambda p, c, b: T.decode_step(p, c, b, cfg),
            cache_decls_fn=lambda batch, n: T.cache_decls(cfg, batch, n),
        )
    if fam == "ssm":
        return Model(
            cfg=cfg,
            decls=_ssm_decls(cfg),
            loss_fn=lambda p, b: _ssm_loss(p, b, cfg),
            prefill=lambda p, b: _ssm_prefill(p, b, cfg),
            decode=lambda p, c, b: _ssm_decode(p, c, b, cfg),
            cache_decls_fn=lambda batch, n: _ssm_cache_decls(cfg, batch, n),
        )
    if fam == "hybrid":
        return Model(
            cfg=cfg,
            decls=HY.decls_hybrid(cfg),
            loss_fn=lambda p, b: HY.loss_fn(p, b, cfg),
            prefill=lambda p, b: HY.prefill(p, b, cfg),
            decode=lambda p, c, b: HY.decode_step(p, c, b, cfg),
            cache_decls_fn=lambda batch, n: HY.cache_decls(cfg, batch, n),
        )
    if fam == "encdec":
        return Model(
            cfg=cfg,
            decls=ED.decls_encdec(cfg),
            loss_fn=lambda p, b: ED.loss_fn(p, b, cfg),
            prefill=lambda p, b: ED.prefill(p, b, cfg),
            decode=lambda p, c, b: ED.decode_step(p, c, b, cfg),
            cache_decls_fn=lambda batch, n: ED.cache_decls(cfg, batch, n),
        )
    raise ValueError(f"unknown family {fam!r}")
