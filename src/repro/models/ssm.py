"""Mamba2 (SSD — state-space duality) block, pure JAX.

Training path: chunked SSD algorithm — intra-chunk quadratic (attention-like,
MXU-friendly) + inter-chunk linear recurrence carried by lax.scan, so memory
is O(S·L_chunk) not O(S²) and the 500k-token decode state is O(1).

Decode path: single-step recurrence over the (nheads, P, N) state plus a
rolling causal-conv buffer.

Simplifications vs. the reference CUDA implementation (documented in
DESIGN.md): ngroups=1 (B/C shared across heads), no variance-reduced init.
Heads are sharded over the ``tp`` axis; B/C (state projections) replicated.
"""
from __future__ import annotations


import jax
from repro.models.unroll import scan as uscan
import jax.numpy as jnp

from repro.models.params import decl
from repro.models.layers import decls_rmsnorm, rmsnorm
from repro.distributed.sharding import constrain


def ssm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_dim = d_inner + 2 * N            # conv over [x, B, C]
    return d_inner, nheads, N, conv_dim


def decls_mamba2(cfg):
    D = cfg.d_model
    d_inner, nheads, N, conv_dim = ssm_dims(cfg)
    # in_proj → [z (d_inner), x (d_inner), B (N), C (N), dt (nheads)]
    return {
        "in_proj": decl((D, 2 * d_inner + 2 * N + nheads), ("fsdp", "tp")),
        "conv_w": decl((cfg.ssm_conv_width, conv_dim), (None, "tp")),
        "conv_b": decl((conv_dim,), ("tp",), init="zeros"),
        "A_log": decl((nheads,), ("tp",), init="zeros"),
        "D": decl((nheads,), ("tp",), init="ones"),
        "dt_bias": decl((nheads,), ("tp",), init="zeros"),
        "norm": decls_rmsnorm(d_inner),
        "out_proj": decl((d_inner, D), ("tp", "fsdp")),
    }


def _split_proj(cfg, zxbcdt):
    d_inner, nheads, N, _ = ssm_dims(cfg)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + d_inner + 2 * N]
    dt = zxbcdt[..., -nheads:]
    return z, xbc, dt


def _segsum(a):
    """a (..., L) → (..., L, L) with out[i,j] = sum_{j<k<=i} a[k], -inf above diag."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    ss = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, ss, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """SSD forward.

    x (B,S,nh,P); dt (B,S,nh) post-softplus; A (nh,) negative;
    Bm/Cm (B,S,N) shared across heads.  Returns (y (B,S,nh,P),
    final_state (B,nh,P,N) f32).
    """
    Bsz, S, nh, P = x.shape
    N = Bm.shape[-1]
    nc = S // chunk
    assert S % chunk == 0, (S, chunk)

    xc = x.reshape(Bsz, nc, chunk, nh, P)
    dtc = dt.reshape(Bsz, nc, chunk, nh).astype(jnp.float32)
    bc = Bm.reshape(Bsz, nc, chunk, N)
    cc = Cm.reshape(Bsz, nc, chunk, N)

    dA = dtc * A[None, None, None, :]                     # (B,nc,L,nh) ≤ 0
    dA = jnp.moveaxis(dA, -1, 1)                          # (B,nh,nc,L)
    A_cum = jnp.cumsum(dA, axis=-1)                       # (B,nh,nc,L)

    # ---- intra-chunk (diagonal blocks) ----
    Lmat = jnp.exp(_segsum(dA))                           # (B,nh,nc,L,L)
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)        # (B,nc,L,L)
    xdt = xc.astype(jnp.float32) * dtc[..., None]         # x*dt (B,nc,L,nh,P)
    y_diag = jnp.einsum("bcij,bhcij,bcjhp->bcihp",
                        scores.astype(jnp.float32), Lmat, xdt)  # (B,nc,L,nh,P)

    # ---- chunk states ----
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)       # (B,nh,nc,L)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn",
                        bc.astype(jnp.float32), decay_states,
                        xdt.astype(jnp.float32))          # (B,nc,nh,P,N)

    # ---- inter-chunk recurrence (sequential scan over chunks) ----
    chunk_decay = jnp.exp(A_cum[..., -1])                 # (B,nh,nc)
    init = (jnp.zeros((Bsz, nh, P, N), jnp.float32)
            if init_state is None else init_state.astype(jnp.float32))

    def step(carry, inp):
        st, dec = inp                                     # (B,nh,P,N), (B,nh)
        new = carry * dec[..., None, None] + st
        return new, carry                                 # emit state *entering* chunk

    sts = jnp.moveaxis(states, 1, 0)                      # (nc,B,nh,P,N)
    decs = jnp.moveaxis(chunk_decay, -1, 0)               # (nc,B,nh)
    final_state, prev_states = uscan(step, init, (sts, decs))
    prev_states = jnp.moveaxis(prev_states, 0, 1)         # (B,nc,nh,P,N)

    # ---- state → output ----
    out_decay = jnp.exp(A_cum)                            # (B,nh,nc,L)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp",
                       cc.astype(jnp.float32), prev_states, out_decay)
    y = (y_diag + y_off).reshape(Bsz, S, nh, P).astype(x.dtype)
    return y, final_state


def _causal_conv(xbc, w, b):
    """Depthwise causal conv: xbc (B,S,Cd), w (K,Cd), b (Cd)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    return jax.nn.silu(out + b[None, None, :])


def mamba2_block(p, h, cfg):
    """Full-sequence forward: h (B,S,D) → (B,S,D)."""
    d_inner, nheads, N, conv_dim = ssm_dims(cfg)
    B, S, D = h.shape
    zxbcdt = jnp.einsum("bsd,de->bse", h, p["in_proj"].astype(h.dtype))
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(xbc, p["conv_w"].astype(h.dtype), p["conv_b"].astype(h.dtype))
    xin = xbc[..., :d_inner].reshape(B, S, nheads, cfg.ssm_head_dim)
    xin = constrain(xin, "dp", None, "tp", None)
    Bm = xbc[..., d_inner:d_inner + N]
    Cm = xbc[..., d_inner + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, _ = ssd_chunked(xin, dt, A, Bm, Cm, min(cfg.ssm_chunk, S))
    y = y + xin * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B, S, d_inner)
    y = y * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(h.dtype))


# ---------------------------------------------------------------------------
# Decode (single token, O(1) state)
# ---------------------------------------------------------------------------

def mamba2_cache_shape(cfg, batch: int):
    d_inner, nheads, N, conv_dim = ssm_dims(cfg)
    return {
        "ssm": (batch, nheads, cfg.ssm_head_dim, N),        # f32
        "conv": (batch, cfg.ssm_conv_width - 1, conv_dim),  # compute dtype
    }


def mamba2_decode(p, h, cfg, cache):
    """h (B,1,D); cache {"ssm": (B,nh,P,N) f32, "conv": (B,K-1,Cd)}."""
    d_inner, nheads, N, conv_dim = ssm_dims(cfg)
    B = h.shape[0]
    P = cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", h, p["in_proj"].astype(h.dtype))
    z, xbc, dt = _split_proj(cfg, zxbcdt)                   # xbc (B,1,Cd)
    # rolling conv buffer
    window = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B,K,Cd)
    new_conv = window[:, 1:, :]
    w = p["conv_w"].astype(h.dtype)
    conv_out = jnp.einsum("bkc,kc->bc", window, w) + p["conv_b"].astype(h.dtype)
    xbc1 = jax.nn.silu(conv_out)                            # (B,Cd)
    xin = xbc1[:, :d_inner].reshape(B, nheads, P)
    Bm = xbc1[:, d_inner:d_inner + N]                       # (B,N)
    Cm = xbc1[:, d_inner + N:]                              # (B,N)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))  # (B,nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # (nh,)
    dA = jnp.exp(dtv * A[None, :])                          # (B,nh)
    dBx = jnp.einsum("bh,bhp,bn->bhpn", dtv, xin.astype(jnp.float32),
                     Bm.astype(jnp.float32))
    new_state = cache["ssm"] * dA[..., None, None] + dBx    # (B,nh,P,N)
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm.astype(jnp.float32))
    y = y.astype(h.dtype) + xin * p["D"].astype(h.dtype)[None, :, None]
    y = y.reshape(B, 1, d_inner)
    y = y * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(h.dtype))
    return out, {"ssm": new_state, "conv": new_conv}
