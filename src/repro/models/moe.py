"""Mixture-of-Experts layer (GShard-style capacity, EP over the model axis).

TPU-native formulation ("gather-capacity MoE"): instead of the CUDA-idiomatic
token-permute + grouped-GEMM, each expert gathers its top-C tokens directly —

  1. router logits (T, E) → per-token top-k experts + weights
  2. per-expert scores (E, DS, T_l): routing weight where routed, -inf else,
     with the token axis pre-split into DS data shards so the per-expert
     top-C is computed *locally per data shard* (no cross-shard collective,
     identical semantics to all-to-all dispatch with per-shard capacity)
  3. per-expert top-C token indices → batched gather (E, DS, C, D) buffers
  4. dense batched expert matmuls   (E, DS, C, D) @ (E, D, F) — MXU-aligned
  5. scatter-add back with combine weights → (T, D); GSPMD reduces the
     expert-sharded partials with a single psum over the model axis

With E sharded over ``model`` this is expert parallelism whose only
collective is that psum — the same volume as a row-parallel TP matmul, with
no all-to-all over slow links.  Tokens beyond an expert's per-shard capacity
C = cf·k·T_l/E are dropped (GShard semantics); the residual carries them.
Router runs in f32.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.params import decl
from repro.distributed.sharding import constrain, ctx_dp_size


def decls_moe(cfg):
    D, F = cfg.d_model, cfg.d_ff
    E = cfg.num_experts_padded
    d = {
        "router": decl((D, E), ("fsdp", None), scale=1.0),
        "w_gate": decl((E, D, F), ("expert", "fsdp", None)),
        "w_up": decl((E, D, F), ("expert", "fsdp", None)),
        "w_down": decl((E, F, D), ("expert", None, "fsdp")),
    }
    if cfg.shared_expert_ff:
        d["shared"] = {
            "w_gate": decl((D, cfg.shared_expert_ff), ("fsdp", "tp")),
            "w_up": decl((D, cfg.shared_expert_ff), ("fsdp", "tp")),
            "w_down": decl((cfg.shared_expert_ff, D), ("tp", "fsdp")),
        }
    return d


def capacity(cfg, tokens_per_shard: int) -> int:
    E = cfg.num_experts_padded
    c = int(cfg.capacity_factor * cfg.moe_top_k * tokens_per_shard / E)
    # MXU alignment: round up to a multiple of 8 (sublane), min 8
    c = max(8, -(-c // 8) * 8)
    return min(c, tokens_per_shard)


def moe_mlp(p, x, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, D) → (y (B, S, D), aux_loss scalar)."""
    B, S, D = x.shape
    T = B * S
    E, K = cfg.num_experts_padded, cfg.moe_top_k
    DS = ctx_dp_size()
    if T % DS != 0:
        DS = 1
    Tl = T // DS
    C = capacity(cfg, Tl)

    xt = x.reshape(DS, Tl, D)
    xt = constrain(xt, "dp", None, None)
    logits = jnp.einsum("ntd,de->nte", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))            # (DS,Tl,E)
    if cfg.num_experts_padded > cfg.num_experts:
        pad_mask = jnp.arange(E) < cfg.num_experts
        logits = jnp.where(pad_mask[None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, K)                             # (DS,Tl,K)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)              # (DS,Tl,K,E)
    w_te = jnp.einsum("ntke,ntk->nte", onehot, topw)                 # (DS,Tl,E)
    scores = jnp.where(w_te > 0.0, w_te, -jnp.inf)
    scores = jnp.moveaxis(scores, -1, 0)                             # (E,DS,Tl)
    scores = constrain(scores, "expert", "dp", None)

    gathered_w, idx = jax.lax.top_k(scores, C)                       # (E,DS,C)
    valid = jnp.isfinite(gathered_w)
    gate_w = jnp.where(valid, gathered_w, 0.0)                       # (E,DS,C)

    # batched gather: per data shard, gather each expert's C tokens
    idx_flat = jnp.moveaxis(idx, 0, 1).reshape(DS, E * C)            # (DS,E*C)
    buf = jnp.take_along_axis(xt, idx_flat[..., None], axis=1)       # (DS,E*C,D)
    buf = jnp.moveaxis(buf.reshape(DS, E, C, D), 1, 0)               # (E,DS,C,D)
    buf = buf * valid[..., None].astype(buf.dtype)
    buf = constrain(buf, "expert", "dp", None, None)

    g = jnp.einsum("encd,edf->encf", buf, p["w_gate"].astype(buf.dtype))
    u = jnp.einsum("encd,edf->encf", buf, p["w_up"].astype(buf.dtype))
    h = jax.nn.silu(g) * u
    out = jnp.einsum("encf,efd->encd", h, p["w_down"].astype(buf.dtype))
    out = out * gate_w[..., None].astype(out.dtype)                  # (E,DS,C,D)

    # scatter-add back: (DS, Tl, D) ← sum over experts' contributions
    out_flat = jnp.moveaxis(out, 0, 1).reshape(DS, E * C, D)
    y = jnp.zeros((DS, Tl, D), out.dtype)
    y = y.at[jnp.arange(DS)[:, None], idx_flat].add(out_flat, mode="drop")
    y = constrain(y, "dp", None, None)
    y = y.reshape(B, S, D)

    if cfg.shared_expert_ff:
        sp = p["shared"]
        sg = jnp.einsum("bsd,df->bsf", x, sp["w_gate"].astype(x.dtype))
        su = jnp.einsum("bsd,df->bsf", x, sp["w_up"].astype(x.dtype))
        y = y + jnp.einsum("bsf,fd->bsd", jax.nn.silu(sg) * su,
                           sp["w_down"].astype(x.dtype))

    # load-balancing auxiliary loss (Switch): E * sum_e f_e * p_e
    me = probs.mean((0, 1))                                          # (E,)
    fe = onehot.sum(2).mean((0, 1))                                  # (E,)
    aux = cfg.num_experts * jnp.sum(me * fe) / max(K, 1)
    return y, aux.astype(jnp.float32)
