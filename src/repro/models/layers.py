"""Shared transformer building blocks (pure JAX, functional).

All functions take explicit parameter pytrees built from ParamDecl
declarations (see decls_* builders) so init / abstract-eval / sharding stay
in sync.  Attention supports:

  * GQA grouped layout (B, S, Hkv, G, Dh) — never materializes repeated KV
  * RoPE / M-RoPE (multimodal 3-section rope) / NoPE
  * optional qk-norm (Qwen3)
  * plain (seq<=attn_chunk or chunk=0) and q-chunked flash-style paths
  * single-token decode against a (B, T, Hkv, Dh) KV cache
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from repro.models.unroll import scan as uscan
import jax.numpy as jnp

from repro.models.params import decl
from repro.distributed.sharding import constrain

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def decls_rmsnorm(d):
    return {"scale": decl((d,), (None,), init="ones")}


def rmsnorm(p, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


def decls_layernorm(d):
    return {"scale": decl((d,), (None,), init="ones"),
            "bias": decl((d,), (None,), init="zeros")}


def layernorm(p, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (...,S,1,Dh/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                sections: Tuple[int, ...]) -> jnp.ndarray:
    """M-RoPE (Qwen2-VL): positions (3, ..., S) for (t, h, w) sections.

    ``sections`` are half-dim sizes summing to Dh/2; frequency slot f uses the
    (t|h|w) position stream its section assigns.
    """
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    sec_id = jnp.repeat(jnp.arange(len(sections)), jnp.array(sections),
                        total_repeat_length=dh // 2)    # (Dh/2,)
    pos = positions.astype(jnp.float32)                 # (3, ..., S)
    ang_all = pos[..., None] * freqs                    # (3, ..., S, Dh/2)
    sel = jax.nn.one_hot(sec_id, len(sections), dtype=jnp.float32)  # (Dh/2, 3)
    ang = jnp.einsum("k...f,fk->...f", ang_all, sel)    # (..., S, Dh/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def padded_heads(cfg, axis: int = 16) -> int:
    """Flat q-head count after per-kv-group zero padding: the smallest
    Hkv·Gp ≥ H with Hkv·Gp divisible by the TP axis.  Keeps each real head's
    kv assignment (head h uses kv h // Gp) while making the flat head dim
    TP-shardable — fixes the 16× attention-compute replication of archs with
    H % 16 != 0 (llama3.2 24H, qwen2-vl 12H).  Padded heads have zero
    wq/wo slices, so the function is exactly the unpadded model's."""
    H, Hkv = cfg.num_heads, cfg.num_kv_heads
    if not getattr(cfg, "pad_head_groups", False) or Hkv == 0 or H % axis == 0:
        return H
    gp = H // Hkv
    while (Hkv * gp) % axis != 0:
        gp += 1
    return Hkv * gp


def eff_heads(cfg) -> int:
    return padded_heads(cfg)


def decls_attention(cfg):
    """Flat-head layout.  KV heads are repeated to H at compute time
    (Megatron-style KV replication), so TP works whenever H divides the
    model axis even if Hkv does not; when neither divides, heads resolve to
    replicated and attention runs FSDP-style (batch-sharded activations) —
    unless cfg.pad_head_groups zero-pads the head dim (see padded_heads)."""
    D, Hkv, Dh = cfg.d_model, cfg.num_kv_heads, cfg.head_dim
    H = eff_heads(cfg)
    d = {
        "wq": decl((D, H, Dh), ("fsdp", "qheads", None)),
        "wk": decl((D, Hkv, Dh), ("fsdp", "tp_kv", None)),
        "wv": decl((D, Hkv, Dh), ("fsdp", "tp_kv", None)),
        "wo": decl((H, Dh, D), ("qheads", None, "fsdp")),
    }
    if cfg.qk_norm:
        d["q_norm"] = decls_rmsnorm(Dh)
        d["k_norm"] = decls_rmsnorm(Dh)
    return d


def _project_qkv(p, x, cfg, positions):
    """x (B,S,D) → q (B,S,H,Dh), k/v (B,S,Hkv,Dh), rope applied."""
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if cfg.use_rope:
        if cfg.mrope_sections:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "dp", None, "qheads", None)
    return q, k, v


def _repeat_kv(k, H):
    """(B,S,Hkv,Dh) → (B,S,H,Dh); head h uses kv head h // (H//Hkv)."""
    Hkv = k.shape[2]
    if Hkv == H:
        return k
    return jnp.repeat(k, H // Hkv, axis=2)


def _attend(q, k, v, mask_fn, scale):
    """q (B,Sq,H,Dh), k/v (B,Skv,H,Dh) → (B,Sq,H,Dh).

    mask_fn(q_idx (Sq,), k_idx (Skv,)) -> bool (Sq,Skv), True = attend.
    """
    scores = jnp.einsum("bqhe,bshe->bhqs", q, k) * scale
    scores = scores.astype(jnp.float32)
    Sq, Skv = q.shape[1], k.shape[1]
    if mask_fn is not None:
        m = mask_fn(jnp.arange(Sq), jnp.arange(Skv))
        scores = jnp.where(m[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqs,bshe->bqhe", probs, v)


def _attend_seq(q, k, v, cfg, causal):
    """Dispatch plain vs. q-chunked attention.  All flat-head."""
    B, S, H, Dh = q.shape
    scale = cfg.head_dim ** -0.5
    kr, vr = _repeat_kv(k, H), _repeat_kv(v, H)
    chunk = cfg.attn_chunk
    if chunk and S > chunk and S % chunk == 0:
        nchunks = S // chunk

        def body(c, _):
            qc = jax.lax.dynamic_slice_in_dim(q, c * chunk, chunk, axis=1)
            base = c * chunk

            def mask_fn(qi, ki):
                return (base + qi)[:, None] >= ki[None, :]
            o = _attend(qc, kr, vr, mask_fn if causal else None, scale)
            return c + 1, o

        _, out = uscan(body, 0, None, length=nchunks)
        out = jnp.moveaxis(out, 0, 1).reshape(B, S, H, Dh)
    else:
        mask_fn = (lambda qi, ki: qi[:, None] >= ki[None, :]) if causal else None
        out = _attend(q, kr, vr, mask_fn, scale)
    return out


def attention(p, x, cfg, positions=None, *, causal=True):
    """Full-sequence attention.  Chunked over queries when cfg.attn_chunk>0."""
    B, S, D = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    q, k, v = _project_qkv(p, x, cfg, positions)
    out = _attend_seq(q, k, v, cfg, causal)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype))


def attention_prefill(p, x, cfg, positions=None, *, causal=True):
    """Like attention() but also returns the (k, v) cache tensors."""
    B, S, D = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    q, k, v = _project_qkv(p, x, cfg, positions)
    out = _attend_seq(q, k, v, cfg, causal)
    y = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype))
    return y, (k, v)


def attention_decode(p, x, cfg, cache_k, cache_v, pos, positions=None):
    """Single-token decode.

    x (B,1,D); cache_k/v (B,T,Hkv,Dh) with valid entries < pos; pos (B,) or
    scalar; positions overrides the rope stream (M-RoPE: (3,B,1)).
    Returns (y (B,1,D), new_cache_k, new_cache_v).
    """
    B = x.shape[0]
    T = cache_k.shape[1]
    H = eff_heads(cfg)
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    if positions is None:
        positions = posb[:, None]
    q, k, v = _project_qkv(p, x, cfg, positions)
    # write new kv at pos — scatter touches only B rows; the one-hot-multiply
    # alternative also burns a full-cache-sized multiply-add per layer
    # (glm4 decode_32k: −14% HLO FLOPs, useful 0.30→0.35 — EXPERIMENTS §Perf)
    bidx = jnp.arange(B)
    cache_k = cache_k.at[bidx, posb].set(k[:, 0], mode="drop")
    cache_v = cache_v.at[bidx, posb].set(v[:, 0], mode="drop")
    cache_k = constrain(cache_k, "dp", "kvseq", "kvheads", None)
    cache_v = constrain(cache_v, "dp", "kvseq", "kvheads", None)
    kr, vr = _repeat_kv(cache_k, H), _repeat_kv(cache_v, H)
    # repeated layout: keep time XOR heads sharded (flash-decoding style —
    # GSPMD inserts the partial-softmax combine over the sharded axis)
    kr = constrain(kr, "dp", "dkr_t", "dkr_h", None)
    vr = constrain(vr, "dp", "dkr_t", "dkr_h", None)
    scale = cfg.head_dim ** -0.5
    scores = jnp.einsum("bqhe,bshe->bhqs", q, kr) * scale
    scores = scores.astype(jnp.float32)
    mask = jnp.arange(T)[None, :] <= posb[:, None]             # (B,T)
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqs,bshe->bqhe", probs, vr)
    y = jnp.einsum("bqhe,hed->bqd", out, p["wo"].astype(x.dtype))
    return y, cache_k, cache_v


def attention_cross(p, x, enc_kv, cfg):
    """Cross attention against precomputed encoder (k, v)."""
    k, v = enc_kv
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(x.dtype))
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
    scale = cfg.head_dim ** -0.5
    out = _attend(q, _repeat_kv(k, eff_heads(cfg)),
                  _repeat_kv(v, eff_heads(cfg)), None, scale)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype))


def cross_kv(p, enc_out, cfg):
    k = jnp.einsum("bsd,dkh->bskh", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dkh->bskh", enc_out, p["wv"].astype(enc_out.dtype))
    if cfg.qk_norm:
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    return k, v


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def decls_mlp(cfg, d_ff: Optional[int] = None):
    D, F = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp_type == "swiglu":
        return {"w_gate": decl((D, F), ("fsdp", "tp")),
                "w_up": decl((D, F), ("fsdp", "tp")),
                "w_down": decl((F, D), ("tp", "fsdp"))}
    return {"w_up": decl((D, F), ("fsdp", "tp")),
            "w_down": decl((F, D), ("tp", "fsdp"))}


def mlp(p, x, cfg):
    if "w_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
        h = jax.nn.silu(g) * u
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
        h = jax.nn.gelu(h) if cfg.mlp_type == "gelu" else jnp.square(jax.nn.relu(h))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Embedding / unembedding with chunked cross-entropy
# ---------------------------------------------------------------------------

def decls_embedding(cfg):
    V, D = cfg.vocab_size, cfg.d_model
    d = {"tok": decl((V, D), ("vocab", "fsdp"), scale=1.0, init="normal")}
    if not cfg.tie_embeddings:
        d["out"] = decl((D, V), ("fsdp", "vocab"))
    return d


def embed(p, tokens, cfg, compute_dtype):
    return p["tok"].astype(compute_dtype)[tokens]


def unembed_matrix(p, cfg, dtype):
    if cfg.tie_embeddings:
        return p["tok"].astype(dtype).T
    return p["out"].astype(dtype)


def softmax_xent(logits, targets, mask=None):
    """logits (..., V) f32; targets (...) i32; mean over valid tokens."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def lm_loss(p_emb, h, targets, cfg, mask=None):
    """Final-hidden → loss; chunked over sequence when cfg.loss_chunk>0.

    Chunking avoids materializing the (B,S,V) logits tensor — the backward
    pass recomputes per-chunk logits (jax.checkpoint), turning an O(B*S*V)
    memory term into O(B*loss_chunk*V).
    """
    W = unembed_matrix(p_emb, cfg, h.dtype)             # (D,V)
    B, S, D = h.shape
    chunk = cfg.loss_chunk
    if not chunk or S <= chunk or S % chunk != 0:
        logits = jnp.einsum("bsd,dv->bsv", h, W)
        return softmax_xent(logits, targets, mask)

    nch = S // chunk

    @jax.checkpoint
    def chunk_loss(hc, tc, mc):
        logits = jnp.einsum("bsd,dv->bsv", hc, W).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        nll = lse - gold
        if mc is None:
            return jnp.sum(nll), jnp.array(float(nll.size), jnp.float32)
        return jnp.sum(nll * mc), jnp.sum(mc)

    def body(carry, idx):
        tot, cnt = carry
        hc = jax.lax.dynamic_slice_in_dim(h, idx * chunk, chunk, 1)
        tc = jax.lax.dynamic_slice_in_dim(targets, idx * chunk, chunk, 1)
        mc = (jax.lax.dynamic_slice_in_dim(mask, idx * chunk, chunk, 1)
              if mask is not None else None)
        s, c = chunk_loss(hc, tc, mc)
        return (tot + s, cnt + c), None

    (tot, cnt), _ = uscan(body, (jnp.float32(0), jnp.float32(0)),
                                 jnp.arange(nch))
    return tot / jnp.maximum(cnt, 1.0)
