"""Decoder-only LM (dense & MoE) with a unified step API.

Parameters are declared per-layer then *stacked* with a leading layer axis so
the layer stack runs under lax.scan (one compiled layer body regardless of
depth — essential for the 61-layer MoE dry-runs).  Remat policy is applied to
the scan body.  The same module backs the VLM config (M-RoPE + stubbed patch
embeddings injected over a fixed prefix).

Step functions (built by api.py into jit-able closures):
  loss(params, batch)                      batch: tokens/targets/(mask/positions/vision_embeds)
  prefill(params, batch) -> (logits, caches)
  decode(params, caches, batch) -> (logits, caches)
"""
from __future__ import annotations


import jax
from repro.models.unroll import scan as uscan
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.params import decl, ParamDecl, tree_map_decls
from repro.models.moe import decls_moe, moe_mlp
from repro.distributed.sharding import constrain


# ---------------------------------------------------------------------------
# Declarations
# ---------------------------------------------------------------------------

def stack_decls(decls, n: int):
    """Add a leading layer axis (replicated) to every decl in the subtree."""
    def one(d: ParamDecl):
        return ParamDecl((n,) + d.shape, d.dtype, (None,) + d.axes, d.init, d.scale)
    return tree_map_decls(one, decls)


def decls_layer(cfg):
    d = {
        "ln1": L.decls_rmsnorm(cfg.d_model),
        "attn": L.decls_attention(cfg),
        "ln2": L.decls_rmsnorm(cfg.d_model),
    }
    if cfg.is_moe:
        d["moe"] = decls_moe(cfg)
    else:
        d["mlp"] = L.decls_mlp(cfg)
    return d


def decls_lm(cfg):
    d = {
        "embed": L.decls_embedding(cfg),
        "layers": stack_decls(decls_layer(cfg), cfg.num_layers),
        "ln_f": L.decls_rmsnorm(cfg.d_model),
    }
    if not cfg.use_rope:
        d["pos_emb"] = decl((cfg.max_seq, cfg.d_model), (None, "fsdp"),
                            init="normal", scale=0.02)
    return d


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _remat(fn, cfg):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def _layer_fwd(lp, h, cfg, positions):
    a = L.attention(lp["attn"], L.rmsnorm(lp["ln1"], h, cfg.norm_eps), cfg,
                    positions)
    h = h + a
    hn = L.rmsnorm(lp["ln2"], h, cfg.norm_eps)
    if cfg.is_moe:
        m, aux = moe_mlp(lp["moe"], hn, cfg)
    else:
        m, aux = L.mlp(lp["mlp"], hn, cfg), jnp.float32(0)
    h = h + m
    h = constrain(h, "dp", None, None)
    return h, aux


def _embed_input(params, batch, cfg):
    h = L.embed(params["embed"], batch["tokens"], cfg, _cdt(cfg))
    if "vision_embeds" in batch:
        ve = batch["vision_embeds"].astype(h.dtype)         # (B, VP, D)
        h = jax.lax.dynamic_update_slice(h, ve, (0, 0, 0))
    if "pos_emb" in params:
        S = h.shape[1]
        pos = batch.get("positions")
        if pos is not None and pos.ndim == 2:
            pe = params["pos_emb"].astype(h.dtype)[pos]     # (B,S,D)
        else:
            pe = params["pos_emb"].astype(h.dtype)[:S][None]
        h = h + pe
    return constrain(h, "dp", None, None)


def _cdt(cfg):
    return jnp.dtype(cfg.compute_dtype)


def _positions(batch, cfg, B, S):
    pos = batch.get("positions")
    if pos is None:
        return jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    return pos


def forward(params, batch, cfg):
    """tokens → final hidden states (B,S,D).  Scan over the layer stack."""
    h = _embed_input(params, batch, cfg)
    B, S, D = h.shape
    positions = _positions(batch, cfg, B, S)

    def body(carry, lp):
        h, aux = carry
        h, a = _layer_fwd(lp, h, cfg, positions)
        return (h, aux + a), None

    body = _remat(body, cfg)
    if cfg.scan_layers:
        (h, aux), _ = uscan(body, (h, jnp.float32(0)), params["layers"])
    else:
        aux = jnp.float32(0)
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            (h, aux), _ = body((h, aux), lp)
    h = L.rmsnorm(params["ln_f"], h, cfg.norm_eps)
    return h, aux


def loss_fn(params, batch, cfg):
    h, aux = forward(params, batch, cfg)
    loss = L.lm_loss(params["embed"], h, batch["targets"], cfg,
                     batch.get("mask"))
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux": aux}


# ---------------------------------------------------------------------------
# KV-cache decode / prefill
# ---------------------------------------------------------------------------

def cache_decls(cfg, batch: int, cache_len: int):
    """Abstract KV cache: dict of stacked (L,B,T,Hkv,Dh) ParamDecls."""
    Hkv, Dh, Lyr = cfg.num_kv_heads, cfg.head_dim, cfg.num_layers
    axes = (None, "dp", "kvseq", "kvheads", None)
    shape = (Lyr, batch, cache_len, Hkv, Dh)
    return {"k": ParamDecl(shape, _cdt(cfg), axes, "zeros"),
            "v": ParamDecl(shape, _cdt(cfg), axes, "zeros")}


def prefill(params, batch, cfg):
    """Forward over the prompt, returning last-token logits + KV caches."""
    h = _embed_input(params, batch, cfg)
    B, S, D = h.shape
    positions = _positions(batch, cfg, B, S)

    def body(h, lp):
        a, (k, v) = L.attention_prefill(
            lp["attn"], L.rmsnorm(lp["ln1"], h, cfg.norm_eps), cfg, positions)
        h = h + a
        hn = L.rmsnorm(lp["ln2"], h, cfg.norm_eps)
        m = (moe_mlp(lp["moe"], hn, cfg)[0] if cfg.is_moe
             else L.mlp(lp["mlp"], hn, cfg))
        h = constrain(h + m, "dp", None, None)
        return h, (k, v)

    body = _remat(body, cfg)
    h, (ks, vs) = uscan(body, h, params["layers"])
    h = L.rmsnorm(params["ln_f"], h, cfg.norm_eps)
    W = L.unembed_matrix(params["embed"], cfg, h.dtype)
    logits = jnp.einsum("bd,dv->bv", h[:, -1], W).astype(jnp.float32)
    return logits, {"k": ks, "v": vs}


def decode_step(params, caches, batch, cfg):
    """One decode step.  batch: {"token": (B,), "pos": (B,)}."""
    B = batch["token"].shape[0]
    tok = batch["token"][:, None]                            # (B,1)
    ebatch = {"tokens": tok}
    if "positions" in batch:
        ebatch["positions"] = batch["positions"]
    elif "pos_emb" in params:
        ebatch["positions"] = batch["pos"][:, None]
    h = _embed_input(params, ebatch, cfg)
    pos = batch["pos"]
    rope_positions = batch.get("positions") if cfg.mrope_sections else None

    def body(h, xs):
        lp, ck, cv = xs
        a, ck, cv = L.attention_decode(
            lp["attn"], L.rmsnorm(lp["ln1"], h, cfg.norm_eps), cfg, ck, cv, pos,
            positions=rope_positions)
        h = h + a
        hn = L.rmsnorm(lp["ln2"], h, cfg.norm_eps)
        m = (moe_mlp(lp["moe"], hn, cfg)[0] if cfg.is_moe
             else L.mlp(lp["mlp"], hn, cfg))
        return h + m, (ck, cv)

    h, (ks, vs) = uscan(body, h, (params["layers"], caches["k"],
                                         caches["v"]))
    h = L.rmsnorm(params["ln_f"], h, cfg.norm_eps)
    W = L.unembed_matrix(params["embed"], cfg, h.dtype)
    logits = jnp.einsum("bd,dv->bv", h[:, 0], W).astype(jnp.float32)
    return logits, {"k": ks, "v": vs}
