"""Zamba2-style hybrid: Mamba2 backbone + a single weight-SHARED attention
block applied after every ``shared_attn_every`` SSM layers.

Structure note (vs. the released Zamba2): we apply the shared block to the
running hidden state with pre-RMSNorm (the release concatenates the original
embedding and projects down; documented simplification in DESIGN.md).  The
layer stack is executed as python-level groups of ``every`` scanned Mamba
layers followed by one shared-attention application — this keeps HLO compact
(one scan body per group) while giving *exact* FLOP accounting (no lax.cond
double-counting in cost_analysis) and a statically-indexed KV cache per
application.
"""
from __future__ import annotations


import jax
from repro.models.unroll import scan as uscan
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.params import ParamDecl
from repro.models.transformer import stack_decls, _remat, _cdt
from repro.distributed.sharding import constrain


def n_attn_blocks(cfg) -> int:
    return cfg.num_layers // cfg.shared_attn_every


def _groups(cfg):
    """Static (start, size, has_attn) python-level grouping of the stack."""
    every, n = cfg.shared_attn_every, cfg.num_layers
    out = []
    start = 0
    while start < n:
        size = min(every, n - start)
        out.append((start, size, size == every))
        start += size
    return out


def decls_hybrid(cfg):
    return {
        "embed": L.decls_embedding(cfg),
        "mamba": stack_decls({"ln": L.decls_rmsnorm(cfg.d_model),
                              "block": S.decls_mamba2(cfg)}, cfg.num_layers),
        "shared": {
            "ln1": L.decls_rmsnorm(cfg.d_model),
            "attn": L.decls_attention(cfg),
            "ln2": L.decls_rmsnorm(cfg.d_model),
            "mlp": L.decls_mlp(cfg),
        },
        "ln_f": L.decls_rmsnorm(cfg.d_model),
    }


def _slice_group(stacked, start, size):
    return jax.tree.map(lambda a: jax.lax.slice_in_dim(a, start, start + size, axis=0),
                        stacked)


def _shared_fwd(sp, h, cfg, positions):
    a = L.attention(sp["attn"], L.rmsnorm(sp["ln1"], h, cfg.norm_eps), cfg,
                    positions)
    h = h + a
    m = L.mlp(sp["mlp"], L.rmsnorm(sp["ln2"], h, cfg.norm_eps), cfg)
    return constrain(h + m, "dp", None, None)


def forward(params, batch, cfg):
    h = L.embed(params["embed"], batch["tokens"], cfg, _cdt(cfg))
    h = constrain(h, "dp", None, None)
    B, Ssz, D = h.shape
    positions = jnp.arange(Ssz, dtype=jnp.int32)[None, :].repeat(B, 0)

    def body(h, lp):
        h = h + S.mamba2_block(lp["block"], L.rmsnorm(lp["ln"], h, cfg.norm_eps), cfg)
        return constrain(h, "dp", None, None), None

    body = _remat(body, cfg)
    for (start, size, has_attn) in _groups(cfg):
        gp = _slice_group(params["mamba"], start, size)
        h, _ = uscan(body, h, gp)
        if has_attn:
            h = _shared_fwd(params["shared"], h, cfg, positions)
    h = L.rmsnorm(params["ln_f"], h, cfg.norm_eps)
    return h, jnp.float32(0)


def loss_fn(params, batch, cfg):
    h, aux = forward(params, batch, cfg)
    loss = L.lm_loss(params["embed"], h, batch["targets"], cfg, batch.get("mask"))
    return loss, {"loss": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def cache_decls(cfg, batch: int, cache_len: int):
    d_inner, nheads, N, conv_dim = S.ssm_dims(cfg)
    n_attn = n_attn_blocks(cfg)
    Lyr = cfg.num_layers
    cdt = _cdt(cfg)
    return {
        "ssm": ParamDecl((Lyr, batch, nheads, cfg.ssm_head_dim, N),
                         jnp.float32, (None, "dp", "tp", None, None), "zeros"),
        "conv": ParamDecl((Lyr, batch, cfg.ssm_conv_width - 1, conv_dim),
                          cdt, (None, "dp", None, "tp"), "zeros"),
        "k": ParamDecl((n_attn, batch, cache_len, cfg.num_kv_heads, cfg.head_dim),
                       cdt, (None, "dp", "kvseq", "kvheads", None), "zeros"),
        "v": ParamDecl((n_attn, batch, cache_len, cfg.num_kv_heads, cfg.head_dim),
                       cdt, (None, "dp", "kvseq", "kvheads", None), "zeros"),
    }


def prefill(params, batch, cfg):
    """Prompt pass filling SSM states + shared-attn KV caches."""
    h = L.embed(params["embed"], batch["tokens"], cfg, _cdt(cfg))
    h = constrain(h, "dp", None, None)
    B, Ssz, D = h.shape
    positions = jnp.arange(Ssz, dtype=jnp.int32)[None, :].repeat(B, 0)
    d_inner, nheads, N, conv_dim = S.ssm_dims(cfg)

    def body(h, lp):
        hn = L.rmsnorm(lp["ln"], h, cfg.norm_eps)
        # full-seq mamba + final state extraction
        zxbcdt = jnp.einsum("bsd,de->bse", hn, lp["block"]["in_proj"].astype(h.dtype))
        z, xbc, dt = S._split_proj(cfg, zxbcdt)
        conv_tail = xbc[:, -(cfg.ssm_conv_width - 1):, :]
        xbc = S._causal_conv(xbc, lp["block"]["conv_w"].astype(h.dtype),
                             lp["block"]["conv_b"].astype(h.dtype))
        xin = xbc[..., :d_inner].reshape(B, Ssz, nheads, cfg.ssm_head_dim)
        Bm = xbc[..., d_inner:d_inner + N]
        Cm = xbc[..., d_inner + N:]
        dtv = jax.nn.softplus(dt.astype(jnp.float32)
                              + lp["block"]["dt_bias"].astype(jnp.float32))
        A = -jnp.exp(lp["block"]["A_log"].astype(jnp.float32))
        y, fstate = S.ssd_chunked(xin, dtv, A, Bm, Cm, min(cfg.ssm_chunk, Ssz))
        y = y + xin * lp["block"]["D"].astype(y.dtype)[None, None, :, None]
        y = y.reshape(B, Ssz, d_inner) * jax.nn.silu(z)
        y = L.rmsnorm(lp["block"]["norm"], y, cfg.norm_eps)
        h = h + jnp.einsum("bse,ed->bsd", y, lp["block"]["out_proj"].astype(h.dtype))
        return constrain(h, "dp", None, None), (fstate, conv_tail)

    ks, vs = [], []
    ssms, convs = [], []
    for (start, size, has_attn) in _groups(cfg):
        gp = _slice_group(params["mamba"], start, size)
        h, (fs, ct) = uscan(body, h, gp)
        ssms.append(fs)
        convs.append(ct)
        if has_attn:
            sp = params["shared"]
            a, (k, v) = L.attention_prefill(
                sp["attn"], L.rmsnorm(sp["ln1"], h, cfg.norm_eps), cfg, positions)
            h = h + a
            h = h + L.mlp(sp["mlp"], L.rmsnorm(sp["ln2"], h, cfg.norm_eps), cfg)
            h = constrain(h, "dp", None, None)
            ks.append(k)
            vs.append(v)
    h = L.rmsnorm(params["ln_f"], h, cfg.norm_eps)
    W = L.unembed_matrix(params["embed"], cfg, h.dtype)
    logits = jnp.einsum("bd,dv->bv", h[:, -1], W).astype(jnp.float32)
    caches = {
        "ssm": jnp.concatenate(ssms, 0).reshape(cfg.num_layers, B, nheads,
                                                cfg.ssm_head_dim, N),
        "conv": jnp.concatenate(convs, 0).reshape(cfg.num_layers, B,
                                                  cfg.ssm_conv_width - 1, conv_dim),
        "k": jnp.stack(ks, 0),
        "v": jnp.stack(vs, 0),
    }
    return logits, caches


def decode_step(params, caches, batch, cfg):
    B = batch["token"].shape[0]
    h = L.embed(params["embed"], batch["token"][:, None], cfg, _cdt(cfg))
    pos = batch["pos"]

    def body(h, xs):
        lp, ssm_c, conv_c = xs
        hn = L.rmsnorm(lp["ln"], h, cfg.norm_eps)
        y, new_cache = S.mamba2_decode(lp["block"], hn, cfg,
                                       {"ssm": ssm_c, "conv": conv_c})
        return h + y, (new_cache["ssm"], new_cache["conv"])

    new_ssm, new_conv, new_k, new_v = [], [], [], []
    gi = 0
    for (start, size, has_attn) in _groups(cfg):
        gp = _slice_group(params["mamba"], start, size)
        ssm_g = jax.lax.slice_in_dim(caches["ssm"], start, start + size, axis=0)
        conv_g = jax.lax.slice_in_dim(caches["conv"], start, start + size, axis=0)
        h, (s_new, c_new) = uscan(body, h, (gp, ssm_g, conv_g))
        new_ssm.append(s_new)
        new_conv.append(c_new)
        if has_attn:
            sp = params["shared"]
            a, ck, cv = L.attention_decode(
                sp["attn"], L.rmsnorm(sp["ln1"], h, cfg.norm_eps), cfg,
                caches["k"][gi], caches["v"][gi], pos)
            h = h + a
            h = h + L.mlp(sp["mlp"], L.rmsnorm(sp["ln2"], h, cfg.norm_eps), cfg)
            new_k.append(ck)
            new_v.append(cv)
            gi += 1
    h = L.rmsnorm(params["ln_f"], h, cfg.norm_eps)
    W = L.unembed_matrix(params["embed"], cfg, h.dtype)
    logits = jnp.einsum("bd,dv->bv", h[:, 0], W).astype(jnp.float32)
    caches = {
        "ssm": jnp.concatenate(new_ssm, 0),
        "conv": jnp.concatenate(new_conv, 0),
        "k": jnp.stack(new_k, 0),
        "v": jnp.stack(new_v, 0),
    }
    return logits, caches
