"""Parameter declaration system.

Every model declares its parameters ONCE as a pytree of :class:`ParamDecl`
(shape, dtype, logical sharding axes, initializer).  From that single
declaration we derive, guaranteed-consistent:

  * ``init_params``      — materialized arrays (CPU tests / real training)
  * ``abstract_params``  — ShapeDtypeStructs (AOT dry-run, no allocation)
  * ``logical_specs``    — pytree of logical PartitionSpecs
  * ``physical_specs``   — resolved against mesh rules (distributed/sharding.py)

Logical axis names used throughout the model zoo:

  ``fsdp``    parameter shard axis (ZeRO-3 over the data axis)
  ``tp``      tensor-parallel axis (model axis)
  ``tp_kv``   kv-head dims — resolves to ``tp`` only when divisible
  ``expert``  expert-parallel axis (model axis)
  ``dp``      batch axis for activations ((pod, data) on multi-pod meshes)
  ``kvseq``   KV-cache sequence axis for decode (resolves per config)
  ``None``    replicated
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Logical = Tuple[Any, ...]  # tuple of logical axis names (str or None)


@dataclass(frozen=True)
class ParamDecl:
    shape: Tuple[int, ...]
    dtype: Any
    axes: Logical                       # logical sharding, len == len(shape)
    init: str = "normal"                # normal | zeros | ones | scaled
    scale: float = 1.0                  # stddev multiplier (fan-in applied for 'scaled')

    def __post_init__(self):
        assert len(self.axes) == len(self.shape), (self.shape, self.axes)


def decl(shape, axes, init="scaled", scale=1.0, dtype=jnp.float32) -> ParamDecl:
    return ParamDecl(tuple(int(s) for s in shape), dtype, tuple(axes), init, scale)


# ---------------------------------------------------------------------------
# Derivations
# ---------------------------------------------------------------------------

def _is_decl(x) -> bool:
    return isinstance(x, ParamDecl)


def tree_map_decls(fn: Callable[[ParamDecl], Any], decls):
    return jax.tree.map(fn, decls, is_leaf=_is_decl)


def abstract_params(decls, dtype_override: Optional[Any] = None):
    def mk(d: ParamDecl):
        return jax.ShapeDtypeStruct(d.shape, dtype_override or d.dtype)
    return tree_map_decls(mk, decls)


def logical_specs(decls):
    from jax.sharding import PartitionSpec as P
    return tree_map_decls(lambda d: P(*d.axes), decls)


def init_params(decls, rng: jax.Array, dtype_override: Optional[Any] = None):
    """Materialize parameters.  Deterministic per-leaf folding of the key."""
    leaves, treedef = jax.tree.flatten(decls, is_leaf=_is_decl)
    keys = jax.random.split(rng, max(len(leaves), 1))
    out = []
    for i, d in enumerate(leaves):
        dt = dtype_override or d.dtype
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dt))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dt))
        elif d.init == "normal":
            out.append((jax.random.normal(keys[i], d.shape) * d.scale).astype(dt))
        elif d.init == "scaled":  # fan-in scaled (truncated-normal-ish)
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            std = d.scale / math.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(keys[i], d.shape) * std).astype(dt))
        else:
            raise ValueError(f"unknown init {d.init!r}")
    return jax.tree.unflatten(treedef, out)


def param_count(decls) -> int:
    leaves = jax.tree.leaves(decls, is_leaf=_is_decl)
    return sum(int(np.prod(d.shape)) for d in leaves)


def param_bytes(decls) -> int:
    leaves = jax.tree.leaves(decls, is_leaf=_is_decl)
    return sum(int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize for d in leaves)
