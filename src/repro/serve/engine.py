"""Batched decode engine (continuous batching) for the TOKEN-DECODE
families.

Drives the autoregressive model families from models/api.py (transformer /
ssm / hybrid / moe / encdec decoders): per-request prefill into a free
cache slot, then one jitted decode step per iteration for the whole batch;
finished requests free their slot and waiting prompts join.  Greedy or
temperature sampling.  Works on CPU for the serving example/tests and lowers
unchanged on the production mesh (the dry-run's decode cells are exactly
``engine.step``'s computation).

GNN node inference is NOT served here — that is serve/gnn_engine.py, which
batches single-shot node queries over the training-side FeaturePlane.  Both
engines are ``serve/common.py`` ``ServingEngine``s built on the shared
``EngineBase`` (slot accounting, admission, retirement bookkeeping, the
``run_to_completion`` drive loop), so continuous-batching policy changes
land once and apply to both.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import build
from repro.models.params import init_params
from repro.serve.common import EngineBase, admit_pending
from repro.serve.kv_cache import KVCacheManager


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_id: int = -1                   # -1: never stop early
    out_tokens: List[int] = field(default_factory=list)
    status: str = "pending"            # pending | done | shed
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class Engine(EngineBase):
    def __init__(self, cfg, params=None, batch: int = 8, max_len: int = 256,
                 temperature: float = 0.0, seed: int = 0,
                 keep_completed: int = 4096):
        self.cfg = cfg
        self.model = build(cfg)
        self.max_len = max_len
        self.temperature = temperature
        rng = jax.random.PRNGKey(seed)
        self.params = params if params is not None else init_params(
            self.model.decls, rng)
        caches = init_params(self.model.cache_decls(batch, max_len),
                             jax.random.PRNGKey(0))
        self.kv = KVCacheManager(caches, batch, max_len)
        self._decode = jax.jit(self.model.decode)
        self._rng = np.random.default_rng(seed)
        self._init_serving(batch, keep_completed)
        self.running: Dict[int, Request] = {}   # slot -> request
        self._tokens = np.zeros(batch, np.int32)

    # ------------------------------------------------------------------
    def _prefill_into_slot(self, req: Request, slot: int):
        """Sequential decode-based prefill: feeds prompt tokens one at a time
        through the decode path (single code path across all families —
        block prefill via model.prefill is used by the benchmarks)."""
        for i, tok in enumerate(req.prompt[:-1]):
            batch = self._make_batch(slot_tokens={slot: int(tok)},
                                     slot_pos={slot: i})
            _, self.kv.caches = self._decode(self.params, self.kv.caches, batch)
        self._tokens[slot] = int(req.prompt[-1])
        self.kv.slots[slot].length = len(req.prompt) - 1

    def _make_batch(self, slot_tokens: Dict[int, int],
                    slot_pos: Dict[int, int]):
        toks = self._tokens.copy()
        pos = self.kv.positions()
        for s, t in slot_tokens.items():
            toks[s] = t
        for s, p in slot_pos.items():
            pos[s] = p
        batch = {"token": jnp.asarray(toks), "pos": jnp.asarray(pos)}
        if self.cfg.mrope_sections:
            batch["positions"] = jnp.broadcast_to(
                jnp.asarray(pos)[None, :, None], (3, self.batch, 1))
        return batch

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine iteration: admit, decode, sample, retire."""
        # admit pending into free slots (the serve/common.py seam)
        admit_pending(self.pending, self.running,
                      lambda r: self.kv.allocate(r.rid, len(r.prompt)),
                      self._prefill_into_slot)
        if not self.running:
            return 0

        batch = self._make_batch({}, {})
        logits, self.kv.caches = self._decode(self.params, self.kv.caches,
                                              batch)
        logits = np.asarray(logits)
        n_emitted = 0
        for slot in list(self.running):
            req = self.running[slot]
            lg = logits[slot]
            if self.temperature > 0:
                p = np.exp((lg - lg.max()) / self.temperature)
                p /= p.sum()
                tok = int(self._rng.choice(len(p), p=p))
            else:
                tok = int(np.argmax(lg))
            if not req.out_tokens:
                req.t_first = time.perf_counter()
            req.out_tokens.append(tok)
            self._tokens[slot] = tok
            self.kv.advance(slot)
            n_emitted += 1
            done = (len(req.out_tokens) >= req.max_new_tokens
                    or tok == req.eos_id
                    or self.kv.slots[slot].length >= self.max_len - 1)
            if done:
                req.t_done = time.perf_counter()
                self.kv.release(slot)
                del self.running[slot]
                self._retire(req)
        return n_emitted

    # ------------------------------------------------------------------
    def _window_metrics(self, mark: Dict, emitted: int, done: int,
                        dt: float) -> Dict[str, float]:
        return {"tokens": emitted,
                "tokens_per_s": emitted / dt if dt else 0.0}
