"""ServingFabric — partition-routed, replicated, SLO-aware GNN serving.

One engine retires every admitted request on one partition; the layer
that faces MILLIONS of users is a fabric over a partition fleet (the
paper's scale-out claim, turned toward inference):

  * **partition routing** — each node query lands on the partition that
    OWNS the node (``PartitionPlan`` ownership, the same lookup the
    multi-partition trainer routes streamed updates through).  The
    owner's subgraph carries the node's out-edges plus its halo-budgeted
    boundary (feature-only leaves), so cross-cut neighborhoods are
    sampled and gathered entirely from the owner's FeaturePlane — no
    remote fetch on the query path, exactly the paper's no-remote-access
    training discipline.  Routing to a smaller, locality-grown subgraph
    is also the throughput win: the sampled frontier (and with it the
    gather) is a fraction of the full-graph one.
  * **replication behind one scheduler** — ``replicas`` engines per
    partition, all sharing the partition's plane (one warmed cache, one
    accounting stream), behind a single fabric-level admission queue.
    Dispatch is least-loaded-first among the owner's replicas.  Weight
    hand-off follows the trainer's get/set-weights discipline: a
    refresh swaps every replica's tree BETWEEN steps, so in-flight
    requests never see a half-updated model and none are dropped.
  * **SLO-aware admission** — a target p99 (``GNNConfig.slo_p99_ms``)
    drives ``serve/common.py`` ``SLOAdmission``: shed-or-defer decisions
    computed from the rolling ``LatencyWindow``, so past saturation the
    fabric sheds load (cheap, explicit, ``status == "shed"``) instead of
    letting queue wait blow up — p99 of what it DOES serve stays
    bounded.

The fabric itself conforms to the ``ServingEngine`` protocol — to a
drive loop, a benchmark or the launcher, a fleet is indistinguishable
from one engine.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.graph.partition import PartitionPlan
from repro.graph.storage import Graph
from repro.serve.common import EngineBase, SLOAdmission, drain
from repro.serve.gnn_engine import GNNInferenceEngine, GNNRequest


class ServingFabric(EngineBase):
    """Partition-routed fleet of ``GNNInferenceEngine`` replicas behind
    one SLO-aware admission scheduler.

    ``planes[p]`` serves every replica of partition p (the warmed cache
    and its accounting are per PARTITION, shared across replicas);
    ``params`` is shared fleet-wide and refreshed via
    ``refresh_weights``.  Requests use GLOBAL node ids throughout —
    translation to partition-local ids happens inside the replica at
    sampling time (``node_map``)."""

    def __init__(self, graph: Graph, plan: PartitionPlan, cfg, params,
                 planes: Optional[List] = None,
                 weight_fns: Optional[List[Optional[Callable]]] = None,
                 batch: int = 8, replicas: int = 1,
                 slo_p99_ms: Optional[float] = None, seed: int = 0,
                 keep_completed: int = 4096,
                 weight_source=None):
        if replicas < 1:
            raise ValueError(f"replicas must be ≥ 1, got {replicas}")
        self.graph = graph
        self.plan = plan
        self.cfg = cfg
        self.replicas = replicas
        self.engine_batch = batch
        self._weight_source = weight_source
        self._seed = seed
        # topology the fabric currently serves: each replica samples a
        # FROZEN subgraph copy built at plan time, so mutations to the
        # full graph are invisible until refresh_topology() adopts a new
        # plan — the version stamp makes that consistency auditable
        self.topology_version = plan.topology_version
        self._init_serving(batch * plan.parts * replicas, keep_completed,
                           window=max(256, 4 * batch * plan.parts))
        self.slo = SLOAdmission(
            cfg.slo_p99_ms if slo_p99_ms is None else slo_p99_ms,
            self.window, slots=self.batch)
        node_maps = plan.node_maps()
        planes = planes if planes is not None else [None] * plan.parts
        weight_fns = weight_fns if weight_fns is not None else (
            [None] * plan.parts)
        # engines[p][r]: replica r of partition p; replicas share the
        # partition plane, get distinct sampler seeds
        self.engines: List[List[GNNInferenceEngine]] = [
            [GNNInferenceEngine(plan.subgraphs[p], cfg, params,
                                plane=planes[p], batch=batch,
                                weight_fn=weight_fns[p],
                                seed=seed + 101 * p + r,
                                node_map=node_maps[p],
                                retire_hook=self._on_replica_retire,
                                keep_completed=max(batch, 16))
             for r in range(replicas)]
            for p in range(plan.parts)]
        self.steps = 0
        self.shed_requests: List[GNNRequest] = []

    # ------------------------------------------------------------------
    @classmethod
    def from_trainer(cls, trainer, batch: int = 8,
                     replicas: Optional[int] = None,
                     slo_p99_ms: Optional[float] = None,
                     seed: int = 0) -> "ServingFabric":
        """Serve over a ``MultiPartitionTrainer``'s own machinery: each
        partition's replicas share the slot's live feature plane (warmed
        cache + accounting), the γ bias is the slot's own ``weight_fn``,
        halo rows are the ones the trainer's exchange filled, and
        ``refresh_weights()`` pulls the trainer's exported tree."""
        replicas = (replicas if replicas is not None
                    else getattr(trainer.cfg, "serve_replicas", 1))
        return cls(trainer.full_graph, trainer.plan, trainer.cfg,
                   trainer.get_weights()["params"],
                   planes=[s.pipe.plane for s in trainer.slots],
                   weight_fns=[s.weight_fn for s in trainer.slots],
                   batch=batch, replicas=replicas, slo_p99_ms=slo_p99_ms,
                   seed=seed, weight_source=trainer)

    @classmethod
    def from_plan(cls, graph: Graph, plan: PartitionPlan, cfg, params,
                  batch: int = 8, replicas: int = 1,
                  slo_p99_ms: Optional[float] = None,
                  seed: int = 0) -> "ServingFabric":
        """Standalone fabric (no trainer): per-partition caches + planes
        over the plan's subgraphs, halo feature rows filled host-locally
        from the full graph (the one-host equivalent of the training
        path's ``halo_all_to_all`` result — same rows, same planes)."""
        from repro.core.cache import FeatureCache
        from repro.core.feature_plane import make_feature_plane
        from repro.core.locality import bias_weight_fn
        planes, weight_fns = [], []
        for p, sub in enumerate(plan.subgraphs):
            cache = (FeatureCache(sub, cfg.cache_volume_mb, cfg.cache_policy)
                     if cfg.cache_volume_mb > 0 else None)
            weight_fns.append(bias_weight_fn(cache, cfg.bias_rate)
                              if (cache is not None and cfg.bias_rate > 1.0)
                              else None)
            plane = make_feature_plane(sub, cache, cfg.sampling_device)
            halo = plan.halo_sets[p] if plan.halo_sets else []
            if len(halo):
                n_owned = len(plan.node_sets[p])
                local = np.arange(n_owned, n_owned + len(halo))
                plane.fill_rows(local, graph.features[halo])
            planes.append(plane)
        return cls(graph, plan, cfg, params, planes=planes,
                   weight_fns=weight_fns, batch=batch, replicas=replicas,
                   slo_p99_ms=slo_p99_ms, seed=seed)

    # ------------------------------------------------------------------
    # ServingEngine surface — aggregate views over the fleet
    # ------------------------------------------------------------------
    @property
    def all_engines(self) -> List[GNNInferenceEngine]:
        return [e for part in self.engines for e in part]

    @property
    def running(self) -> Dict:
        """Fleet-wide slot → request view, keyed (partition, replica,
        slot).  Built on access — the replicas own the live dicts."""
        return {(p, r, s): req
                for p, part in enumerate(self.engines)
                for r, eng in enumerate(part)
                for s, req in eng.running.items()}

    def free_slots(self) -> List:
        return [(p, r, s)
                for p, part in enumerate(self.engines)
                for r, eng in enumerate(part)
                for s in eng.free_slots()]

    def utilization(self) -> float:
        busy = sum(len(e.running) for e in self.all_engines)
        return busy / max(self.batch, 1)

    def _queued(self) -> int:
        """Backlog ahead of a new arrival: the fabric queue plus work
        already dispatched into the replicas."""
        return len(self.pending) + sum(len(e.pending) + len(e.running)
                                       for e in self.all_engines)

    def has_work(self) -> bool:
        """Fabric work covers its own queue AND the replicas' — the
        shared drain must not stop while a replica still holds queued
        work (e.g. a same-node twin waiting out one engine iteration)."""
        return bool(self.pending) or any(e.has_work()
                                         for e in self.all_engines)

    # ------------------------------------------------------------------
    def _validate(self, req: GNNRequest):
        if not (0 <= req.node < self.graph.num_nodes):
            raise ValueError(f"node {req.node} outside graph "
                             f"[0, {self.graph.num_nodes})")

    def submit(self, req: GNNRequest):
        """Offered load enters HERE: route (stamp the owner partition)
        and run the door half of SLO admission — a request whose
        estimated wait already busts the target is shed at the door,
        before it consumes queue space."""
        self._validate(req)
        req.partition = int(self.plan.owner_of([req.node])[0])
        req.topology_version = self.topology_version
        req.t_submit = time.perf_counter()
        if self.slo.on_offer(self._queued()) == "shed":
            self._shed(req)
            return
        self.pending.append(req)

    def _shed(self, req: GNNRequest):
        req.t_first = req.t_done = time.perf_counter()
        req.status = "shed"                     # pred stays the −1 sentinel
        self.shed_requests.append(req)
        if len(self.shed_requests) > self.keep_completed:
            del self.shed_requests[:len(self.shed_requests)
                                   - self.keep_completed]

    def _on_replica_retire(self, req: GNNRequest):
        """Replica retirement surfaces at the fabric: one fleet-wide
        history + rolling window (the SLO scheduler's input)."""
        self.completed.append(req)
        self.total_completed += 1
        self.window.record(req)
        from repro.serve.common import trim_completed
        trim_completed(self.completed, self.keep_completed)
        if self.retire_hook is not None:
            self.retire_hook(req)

    # ------------------------------------------------------------------
    def _dispatch_pass(self):
        """Drain the fabric queue toward the replicas: per request, the
        SLO decision (shed the hopeless, defer the currently-unplaceable)
        then least-loaded dispatch among the owner's replicas.  A
        deferred request keeps its place; requests for OTHER partitions
        behind it still dispatch (no cross-partition head-of-line
        blocking)."""
        now = time.perf_counter()
        keep: List[GNNRequest] = []
        while self.pending:
            req = self.pending.popleft()
            part = self.engines[req.partition]
            # capacity = a replica with a free slot not already serving
            # this node (the unique-seed invariant)
            candidates = [e for e in part
                          if len(e.running) + len(e.pending) < e.batch
                          and not any(r.node == req.node for r in
                                      list(e.running.values())
                                      + list(e.pending))]
            verdict = self.slo.on_dispatch((now - req.t_submit) * 1e3,
                                           bool(candidates))
            if verdict == "shed":
                self._shed(req)
            elif verdict == "defer":
                keep.append(req)
            else:
                target = min(candidates,
                             key=lambda e: len(e.running) + len(e.pending))
                target.submit(req)
        self.pending.extend(keep)

    def step(self) -> int:
        """One fabric tick: a dispatch pass, then one engine step on
        every replica with work in flight.  Returns fleet-wide
        retirements."""
        self._dispatch_pass()
        retired = 0
        for eng in self.all_engines:
            if eng.has_work():
                retired += eng.step()
        self.steps += 1
        return retired

    # ------------------------------------------------------------------
    # weight hand-off: trainer → every replica, between steps
    # ------------------------------------------------------------------
    def refresh_weights(self, weights: Optional[Dict] = None):
        """Swap every replica's params (the get/set-weights discipline).
        With no argument, pulls from the trainer this fabric was built
        from.  In-flight requests are NOT dropped: a single-shot query is
        computed wholly inside one engine step, so everything retired
        after this call used the refreshed tree."""
        if weights is None:
            if self._weight_source is None:
                raise ValueError("no weight source: pass weights= or build "
                                 "the fabric with from_trainer")
            weights = self._weight_source.get_weights()
        for eng in self.all_engines:
            eng.set_weights(weights)

    # ------------------------------------------------------------------
    # topology hand-off: a mutated graph reaches serving the same way
    # weights do — a whole-plan swap BETWEEN steps, never mid-flight
    # ------------------------------------------------------------------
    def refresh_topology(self, plan: Optional[PartitionPlan] = None,
                         planes: Optional[List] = None,
                         weight_fns: Optional[List] = None):
        """Adopt a new ``PartitionPlan`` (post edge stream / compaction /
        incremental re-balance).  The ``FeatureCache.version`` discipline
        generalized to topology: requests already dispatched finish
        against the subgraphs they were admitted under (each replica's
        graph is a frozen copy and a single-shot query retires inside one
        engine step), THEN the fleet is rebuilt over the new plan's
        subgraphs and every request admitted afterwards carries the new
        ``topology_version`` stamp.  Requests still in the fabric queue
        are re-routed (owner may have changed under a re-balance).  With
        no arguments, pulls plan/planes/weight_fns from the trainer this
        fabric was built from (``from_trainer``)."""
        if plan is None:
            if self._weight_source is None:
                raise ValueError("no topology source: pass plan= or build "
                                 "the fabric with from_trainer")
            src = self._weight_source
            plan = src.plan
            planes = [s.pipe.plane for s in src.slots]
            weight_fns = [s.weight_fn for s in src.slots]
        if plan.parts != self.plan.parts:
            raise ValueError(f"refresh_topology cannot change the partition "
                             f"count ({self.plan.parts} -> {plan.parts}); "
                             f"build a new fabric")
        # drain dispatched work: every replica finishes what it holds
        # against the OLD topology (bounded — single-shot queries retire
        # within one step each)
        for eng in self.all_engines:
            iters = 0
            while eng.has_work() and iters < 10_000:
                eng.step()
                iters += 1
        params = (self._weight_source.get_weights()["params"]
                  if self._weight_source is not None
                  else self.all_engines[0].params)
        node_maps = plan.node_maps()
        planes = planes if planes is not None else [None] * plan.parts
        weight_fns = (weight_fns if weight_fns is not None
                      else [None] * plan.parts)
        self.engines = [
            [GNNInferenceEngine(plan.subgraphs[p], self.cfg, params,
                                plane=planes[p], batch=self.engine_batch,
                                weight_fn=weight_fns[p],
                                seed=self._seed + 101 * p + r,
                                node_map=node_maps[p],
                                retire_hook=self._on_replica_retire,
                                keep_completed=max(self.engine_batch, 16))
             for r in range(self.replicas)]
            for p in range(plan.parts)]
        self.plan = plan
        self.topology_version = plan.topology_version
        # queued-but-undispatched requests route against the NEW owners
        # (and serve the new topology, so they get the new stamp)
        for req in self.pending:
            req.partition = int(plan.owner_of([req.node])[0])
            req.topology_version = self.topology_version

    # ------------------------------------------------------------------
    # metrics
    # ------------------------------------------------------------------
    @property
    def shed_fraction(self) -> float:
        return self.slo.shed_fraction

    def partition_completed(self) -> List[int]:
        """Fleet-wide retirements per partition (routing observability)."""
        return [sum(e.total_completed for e in part)
                for part in self.engines]

    def _begin_window(self) -> Dict:
        return {"steps": self.steps, "offered": self.slo.offered,
                "shed": self.slo.shed, "deferrals": self.slo.deferrals}

    def _window_metrics(self, mark: Dict, emitted: int, done: int,
                        dt: float) -> Dict[str, float]:
        offered = self.slo.offered - mark["offered"]
        shed = self.slo.shed - mark["shed"]
        return {"queries_per_s": done / dt if dt else 0.0,
                "fabric_steps": self.steps - mark["steps"],
                "offered": offered, "shed": shed,
                "deferrals": self.slo.deferrals - mark["deferrals"],
                "shed_fraction": shed / offered if offered else 0.0}

    def run_to_completion(self, max_iters: int = 10_000) -> Dict[str, float]:
        stats = super().run_to_completion(max_iters)
        caches = [e.plane.stats for e in
                  (part[0] for part in self.engines)]
        hits = sum(c.hits for c in caches if c is not None)
        total = hits + sum(c.misses for c in caches if c is not None)
        stats["cache_hit_rate"] = hits / total if total else 0.0
        return stats

    def drain(self, max_iters: int = 10_000):
        """Step until every queue (fabric + replicas) is empty."""
        return drain(self, max_iters)
