"""ServingFabric — partition-routed, replicated, SLO-aware GNN serving.

One engine retires every admitted request on one partition; the layer
that faces MILLIONS of users is a fabric over a partition fleet (the
paper's scale-out claim, turned toward inference):

  * **partition routing** — each node query lands on the partition that
    OWNS the node (``PartitionPlan`` ownership, the same lookup the
    multi-partition trainer routes streamed updates through).  The
    owner's subgraph carries the node's out-edges plus its halo-budgeted
    boundary (feature-only leaves), so cross-cut neighborhoods are
    sampled and gathered entirely from the owner's FeaturePlane — no
    remote fetch on the query path, exactly the paper's no-remote-access
    training discipline.  Routing to a smaller, locality-grown subgraph
    is also the throughput win: the sampled frontier (and with it the
    gather) is a fraction of the full-graph one.
  * **replication behind one scheduler, across a transport seam** —
    ``replicas`` engines per partition behind a single fabric-level
    admission queue.  Every replica sits behind a
    ``serve/transport.py`` ``ReplicaTransport`` — in-process
    ``LoopbackTransport`` by default (bit-exact with the pre-seam
    fabric), or a host-boundary ``SimHostTransport`` with injectable
    faults — and the fabric learns service time and health ONLY from
    when responses arrive, so the same dispatch works when a replica
    group is a real remote host.  Dispatch is least-loaded-first
    weighted by a per-replica response-time EWMA: a slow host's queue
    organically drains toward its faster peers.
  * **robustness** — a per-request timeout (``timeout_ms``) bounds how
    long the fabric waits on any one replica; a timed-out request is
    retried ONCE on another replica of its partition, then retired
    explicitly (``status == "timeout"``, never silently lost).
    Consecutive timeouts drive a replica's health through
    up → suspect → down; a down replica's in-flight work is re-routed
    to survivors immediately, its dispatch share goes to zero, and the
    SLO scheduler's capacity estimate shrinks so overload is shed at
    the edge BEFORE a query crosses the wire.  A recovered replica is
    probed after a cooldown and rejoins on its first success.
  * **SLO-aware admission** — a target p99 (``GNNConfig.slo_p99_ms``)
    drives ``serve/common.py`` ``SLOAdmission``: shed-or-defer decisions
    computed from the rolling ``LatencyWindow``, so past saturation the
    fabric sheds load (cheap, explicit, ``status == "shed"``) instead of
    letting queue wait blow up — p99 of what it DOES serve stays
    bounded.

Every retry, timeout, re-route and health transition is counted in
``FabricStats`` (per-replica EWMA snapshots included) — the chaos
harness in ``tests/test_transport_faults.py`` drives seeded fault
schedules against these counters and the conservation invariant: every
admitted query ends served, shed, or timed-out, explicitly.

The fabric itself conforms to the ``ServingEngine`` protocol — to a
drive loop, a benchmark or the launcher, a fleet is indistinguishable
from one engine.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.graph.partition import PartitionPlan
from repro.graph.storage import Graph
from repro.serve.common import EngineBase, SLOAdmission, drain, trim_completed
from repro.serve.gnn_engine import GNNInferenceEngine, GNNRequest
from repro.serve.transport import loopback_factory


@dataclass
class FabricStats:
    """Fleet-wide fault/robustness counters (the observability half of
    the transport seam).

    ``timeouts`` counts timer expiries (including ones recovered by a
    retry); ``retries`` re-dispatches onto another replica;
    ``reroutes`` in-flight requests pulled off a replica that went
    down; ``timed_out`` requests retired with ``status == "timeout"``
    (retry budget exhausted — the explicit terminal state, never a
    silent loss); ``late_responses`` responses that arrived after the
    fabric stopped waiting (post-timeout, or from a pre-retry attempt)
    and were discarded; ``health_transitions`` up/suspect/down edges.
    """
    timeouts: int = 0
    retries: int = 0
    reroutes: int = 0
    timed_out: int = 0
    late_responses: int = 0
    health_transitions: int = 0

    def asdict(self) -> Dict[str, int]:
        return {"timeouts": self.timeouts, "retries": self.retries,
                "reroutes": self.reroutes, "timed_out": self.timed_out,
                "late_responses": self.late_responses,
                "health_transitions": self.health_transitions}


@dataclass
class ReplicaState:
    """Per-replica health + dispatch statistics, inferred ONLY from
    response arrivals (the cross-host-honest view).

    The health machine: ``up`` → (any timeout) → ``suspect`` →
    (``down_after`` consecutive timeouts) → ``down`` → (cooldown
    ``down_retry_ms`` elapses) → probed with ONE request → ``up`` on
    success, back to ``down`` on another timeout.  Any success resets
    the machine to ``up``.
    """
    state: str = "up"                  # up | suspect | down
    consecutive_timeouts: int = 0
    down_since: float = 0.0
    ewma_ms: Optional[float] = None    # response-time EWMA (dispatch weight)
    sent: int = 0
    completed: int = 0
    timeouts: int = 0


@dataclass
class _Inflight:
    """One dispatched-but-unresolved request: the fabric's canonical
    request object, where it went, and when."""
    req: GNNRequest
    key: Tuple[int, int]               # (partition, replica)
    transport: object
    sent_at: float


class ServingFabric(EngineBase):
    """Partition-routed fleet of ``GNNInferenceEngine`` replicas behind
    one SLO-aware admission scheduler, across the replica transport seam.

    ``planes[p]`` serves every replica of partition p (the warmed cache
    and its accounting are per PARTITION, shared across replicas);
    ``params`` is shared fleet-wide and refreshed via
    ``refresh_weights``.  Requests use GLOBAL node ids throughout —
    translation to partition-local ids happens inside the replica at
    sampling time (``node_map``)."""

    # dispatch scoring: EWMA ratios inside the snap band count as equal
    # (a homogeneous in-process fleet must reduce to pure least-loaded —
    # the pre-seam dispatch, bit for bit); past it the ratio weights the
    # queue depth directly, capped so one compile spike cannot starve a
    # replica forever
    EWMA_SNAP = 2.0
    EWMA_CAP = 64.0
    EWMA_ALPHA = 0.3
    SUSPECT_PENALTY = 4.0

    def __init__(self, graph: Graph, plan: PartitionPlan, cfg, params,
                 planes: Optional[List] = None,
                 weight_fns: Optional[List[Optional[Callable]]] = None,
                 batch: int = 8, replicas: int = 1,
                 slo_p99_ms: Optional[float] = None, seed: int = 0,
                 keep_completed: int = 4096,
                 weight_source=None,
                 transport_factory: Optional[Callable] = None,
                 clock: Optional[Callable[[], float]] = None,
                 timeout_ms: Optional[float] = None,
                 retry_limit: int = 1, down_after: int = 2,
                 down_retry_ms: float = 50.0,
                 record_trace: bool = False):
        if replicas < 1:
            raise ValueError(f"replicas must be ≥ 1, got {replicas}")
        self.graph = graph
        self.plan = plan
        self.cfg = cfg
        self.replicas = replicas
        self.engine_batch = batch
        self._weight_source = weight_source
        self._seed = seed
        self.clock = clock if clock is not None else time.perf_counter
        self._transport_factory = (transport_factory
                                   if transport_factory is not None
                                   else loopback_factory)
        self.timeout_ms = float(timeout_ms if timeout_ms is not None
                                else getattr(cfg, "serve_timeout_ms", 0.0))
        self.retry_limit = int(retry_limit)
        self.down_after = max(int(down_after), 1)
        self.down_retry_ms = float(down_retry_ms)
        # topology the fabric currently serves: each replica samples a
        # FROZEN subgraph copy built at plan time, so mutations to the
        # full graph are invisible until refresh_topology() adopts a new
        # plan — the version stamp makes that consistency auditable
        self.topology_version = plan.topology_version
        self._init_serving(batch * plan.parts * replicas, keep_completed,
                           window=max(256, 4 * batch * plan.parts))
        self.slo = SLOAdmission(
            cfg.slo_p99_ms if slo_p99_ms is None else slo_p99_ms,
            self.window, slots=self.batch)
        self._build_fleet(plan, params, planes, weight_fns)
        self.steps = 0
        self.shed_requests: List[GNNRequest] = []
        self.timeout_requests: List[GNNRequest] = []
        self.fstats = FabricStats()
        # terminal-by-timeout rids, bounded: a response surfacing for one
        # of these is LATE (discard + count), not an external retirement
        self._failed_rids: Set[int] = set()
        self._failed_order: List[int] = []
        self.request_trace: Optional[List[Tuple]] = ([] if record_trace
                                                     else None)

    def _build_fleet(self, plan: PartitionPlan, params,
                     planes: Optional[List],
                     weight_fns: Optional[List]):
        """Engines + transports + per-replica dispatch state for one
        plan.  Replicas share the partition plane, get distinct sampler
        seeds; each sits behind its own transport (``retire_hook`` is
        the TRANSPORT's — responses reach the fabric only through
        ``_on_response``)."""
        node_maps = plan.node_maps()
        planes = planes if planes is not None else [None] * plan.parts
        weight_fns = weight_fns if weight_fns is not None else (
            [None] * plan.parts)
        # engines[p][r]: replica r of partition p
        self.engines: List[List[GNNInferenceEngine]] = [
            [GNNInferenceEngine(plan.subgraphs[p], self.cfg, params,
                                plane=planes[p], batch=self.engine_batch,
                                weight_fn=weight_fns[p],
                                seed=self._seed + 101 * p + r,
                                node_map=node_maps[p],
                                keep_completed=max(self.engine_batch, 16))
             for r in range(self.replicas)]
            for p in range(plan.parts)]
        self.transports: List[List] = []
        for p in range(plan.parts):
            row = []
            for r in range(self.replicas):
                t = self._transport_factory(self.engines[p][r], p, r,
                                            self.clock)
                t.bind(lambda resp, key=(p, r): self._on_response(key, resp))
                row.append(t)
            self.transports.append(row)
        self.inflight: Dict[int, _Inflight] = {}
        self.replica_state: Dict[Tuple[int, int], ReplicaState] = {
            (p, r): ReplicaState()
            for p in range(plan.parts) for r in range(self.replicas)}
        self._outstanding: Dict[Tuple[int, int], int] = {
            k: 0 for k in self.replica_state}
        self._inflight_nodes: Dict[Tuple[int, int], Set[int]] = {
            k: set() for k in self.replica_state}

    # ------------------------------------------------------------------
    @classmethod
    def from_trainer(cls, trainer, batch: int = 8,
                     replicas: Optional[int] = None,
                     slo_p99_ms: Optional[float] = None,
                     seed: int = 0, **fabric_kw) -> "ServingFabric":
        """Serve over a ``MultiPartitionTrainer``'s own machinery: each
        partition's replicas share the slot's live feature plane (warmed
        cache + accounting), the γ bias is the slot's own ``weight_fn``,
        halo rows are the ones the trainer's exchange filled, and
        ``refresh_weights()`` pulls the trainer's exported tree.
        ``fabric_kw`` passes the transport-seam knobs through
        (``transport_factory``, ``clock``, ``timeout_ms``, ...)."""
        replicas = (replicas if replicas is not None
                    else getattr(trainer.cfg, "serve_replicas", 1))
        return cls(trainer.full_graph, trainer.plan, trainer.cfg,
                   trainer.get_weights()["params"],
                   planes=[s.pipe.plane for s in trainer.slots],
                   weight_fns=[s.weight_fn for s in trainer.slots],
                   batch=batch, replicas=replicas, slo_p99_ms=slo_p99_ms,
                   seed=seed, weight_source=trainer, **fabric_kw)

    @classmethod
    def from_plan(cls, graph: Graph, plan: PartitionPlan, cfg, params,
                  batch: int = 8, replicas: int = 1,
                  slo_p99_ms: Optional[float] = None,
                  seed: int = 0, **fabric_kw) -> "ServingFabric":
        """Standalone fabric (no trainer): per-partition caches + planes
        over the plan's subgraphs, halo feature rows filled host-locally
        from the full graph (the one-host equivalent of the training
        path's ``halo_all_to_all`` result — same rows, same planes)."""
        from repro.core.cache import FeatureCache
        from repro.core.feature_plane import make_feature_plane
        from repro.core.locality import bias_weight_fn
        planes, weight_fns = [], []
        for p, sub in enumerate(plan.subgraphs):
            cache = (FeatureCache(sub, cfg.cache_volume_mb, cfg.cache_policy)
                     if cfg.cache_volume_mb > 0 else None)
            weight_fns.append(bias_weight_fn(cache, cfg.bias_rate)
                              if (cache is not None and cfg.bias_rate > 1.0)
                              else None)
            plane = make_feature_plane(sub, cache, cfg.sampling_device)
            halo = plan.halo_sets[p] if plan.halo_sets else []
            if len(halo):
                n_owned = len(plan.node_sets[p])
                local = np.arange(n_owned, n_owned + len(halo))
                plane.fill_rows(local, graph.features[halo])
            planes.append(plane)
        return cls(graph, plan, cfg, params, planes=planes,
                   weight_fns=weight_fns, batch=batch, replicas=replicas,
                   slo_p99_ms=slo_p99_ms, seed=seed, **fabric_kw)

    # ------------------------------------------------------------------
    # ServingEngine surface — aggregate views over the fleet
    # ------------------------------------------------------------------
    @property
    def all_engines(self) -> List[GNNInferenceEngine]:
        return [e for part in self.engines for e in part]

    @property
    def all_transports(self) -> List:
        return [t for part in self.transports for t in part]

    @property
    def running(self) -> Dict:
        """Fleet-wide dispatched-but-unresolved view, keyed (partition,
        replica, rid).  Built on access — ``inflight`` owns the records."""
        return {(rec.key[0], rec.key[1], rid): rec.req
                for rid, rec in self.inflight.items()}

    def free_slots(self) -> List:
        return [(p, r, s)
                for p in range(self.plan.parts)
                for r in range(self.replicas)
                for s in range(self.engine_batch
                               - self._outstanding[(p, r)])]

    def utilization(self) -> float:
        return sum(self._outstanding.values()) / max(self.batch, 1)

    def _queued(self) -> int:
        """Backlog ahead of a new arrival: the fabric queue plus work
        dispatched but not yet resolved."""
        return len(self.pending) + len(self.inflight)

    def has_work(self) -> bool:
        """Fabric work covers its own queue, everything dispatched and
        unresolved, and the transports' local queues (e.g. an engine
        driven directly for warmup) — the shared drain must not stop
        while any of them still holds work.  A disconnected transport's
        dead state is excluded (``busy`` is False); its in-flight
        requests keep the drain alive through ``inflight`` until the
        timeout reclaims them."""
        return (bool(self.pending) or bool(self.inflight)
                or any(t.busy() for t in self.all_transports))

    # ------------------------------------------------------------------
    def _validate(self, req: GNNRequest):
        if not (0 <= req.node < self.graph.num_nodes):
            raise ValueError(f"node {req.node} outside graph "
                             f"[0, {self.graph.num_nodes})")

    def submit(self, req: GNNRequest):
        """Offered load enters HERE: route (stamp the owner partition)
        and run the door half of SLO admission — a request whose
        estimated wait already busts the target is shed at the door,
        before it consumes queue space (and before it crosses any
        wire)."""
        self._validate(req)
        req.partition = int(self.plan.owner_of([req.node])[0])
        req.topology_version = self.topology_version
        req.t_submit = self.clock()
        if self.slo.on_offer(self._queued()) == "shed":
            self._shed(req)
            return
        self.pending.append(req)

    def _trace(self, req: GNNRequest, status: str):
        if self.request_trace is not None:
            self.request_trace.append((req.rid, req.partition, req.replica,
                                       status, req.pred))

    def _shed(self, req: GNNRequest):
        req.t_first = req.t_done = self.clock()
        req.status = "shed"                     # pred stays the −1 sentinel
        self.shed_requests.append(req)
        trim_completed(self.shed_requests, self.keep_completed)
        self._trace(req, "shed")

    def _account_retirement(self, req: GNNRequest):
        """One served retirement surfacing at the fabric: the fleet-wide
        history + rolling window (the SLO scheduler's input)."""
        self.completed.append(req)
        self.total_completed += 1
        self.window.record(req)
        trim_completed(self.completed, self.keep_completed)
        if self.retire_hook is not None:
            self.retire_hook(req)

    # ------------------------------------------------------------------
    # health + EWMA bookkeeping (inferred from response arrivals only)
    # ------------------------------------------------------------------
    def _update_slo_slots(self):
        """Live fleet capacity feeds the SLO wait estimate: a down
        replica's slots stop counting, so the door sheds the load the
        survivors cannot carry — before it queues, before any wire."""
        alive = sum(1 for st in self.replica_state.values()
                    if st.state != "down")
        self.slo.slots = max(1, self.engine_batch * alive)

    def _note_success(self, key: Tuple[int, int], sample_ms: float):
        st = self.replica_state[key]
        st.consecutive_timeouts = 0
        if st.state != "up":
            st.state = "up"
            self.fstats.health_transitions += 1
            self._update_slo_slots()
        st.completed += 1
        st.ewma_ms = (sample_ms if st.ewma_ms is None else
                      self.EWMA_ALPHA * sample_ms
                      + (1.0 - self.EWMA_ALPHA) * st.ewma_ms)

    def _note_timeout(self, key: Tuple[int, int], now: float):
        st = self.replica_state[key]
        st.consecutive_timeouts += 1
        st.timeouts += 1
        if st.state == "up":
            st.state = "suspect"
            self.fstats.health_transitions += 1
        if (st.state == "suspect"
                and st.consecutive_timeouts >= self.down_after):
            st.state = "down"
            st.down_since = now
            self.fstats.health_transitions += 1
            self._update_slo_slots()
            self._reroute_replica(key, now)
        elif st.state == "down":
            st.down_since = now          # failed probe: restart cooldown

    def _note_failed_rid(self, rid: int):
        self._failed_rids.add(rid)
        self._failed_order.append(rid)
        if len(self._failed_order) > 4096:
            drop = self._failed_order[:len(self._failed_order) - 4096]
            del self._failed_order[:len(self._failed_order) - 4096]
            self._failed_rids.difference_update(drop)

    # ------------------------------------------------------------------
    # dispatch: SLO verdict, then health/EWMA-weighted least-loaded
    # ------------------------------------------------------------------
    def _candidates(self, req: GNNRequest, now: float) -> List[int]:
        """Replica indices of the owner partition eligible for this
        request: not down (unless their probe cooldown elapsed), with a
        free slot (suspect/probed replicas carry at most ONE in-flight
        request), and not already holding this node (the unique-seed
        invariant — checked against the fabric's dispatch record AND the
        transport's local view, which also covers directly-driven
        warmup work)."""
        p = req.partition
        out = []
        for r in range(self.replicas):
            key = (p, r)
            st = self.replica_state[key]
            depth = self._outstanding[key]
            if st.state == "down":
                if now < st.down_since + self.down_retry_ms * 1e-3:
                    continue
                if depth >= 1:
                    continue             # one probe at a time
            elif st.state == "suspect" and depth >= 1:
                continue
            if depth >= self.engine_batch:
                continue
            if req.node in self._inflight_nodes[key]:
                continue
            if req.node in self.transports[p][r].in_flight_nodes():
                continue
            out.append(r)
        return out

    def _pick_replica(self, req: GNNRequest, candidates: List[int]) -> int:
        """Least-loaded weighted by the response-time EWMA.  Ratios
        inside ``EWMA_SNAP`` count as equal, so a homogeneous fleet
        reduces EXACTLY to the pre-seam queue-depth choice (first
        minimal index) — the loopback bit-exactness anchor — while a
        genuinely slow host (a 10× wire delay) takes proportionally
        fewer requests and organically drains.  Suspect replicas carry
        a fixed penalty: they are probed, not trusted."""
        p = req.partition
        prev = req.replica if req.retries > 0 else -1
        pool = [r for r in candidates if r != prev] or candidates
        sampled = [self.replica_state[(p, r)].ewma_ms for r in pool
                   if self.replica_state[(p, r)].ewma_ms is not None]
        ewma_min = min(sampled) if sampled else 0.0
        best_r, best_score = pool[0], float("inf")
        for r in pool:
            st = self.replica_state[(p, r)]
            rel = 1.0
            if st.ewma_ms is not None and ewma_min > 0:
                rel = st.ewma_ms / ewma_min
                rel = 1.0 if rel < self.EWMA_SNAP else min(rel,
                                                           self.EWMA_CAP)
            pen = 1.0 if st.state == "up" else self.SUSPECT_PENALTY
            score = (self._outstanding[(p, r)] + 1) * rel * pen
            if score < best_score:
                best_r, best_score = r, score
        return best_r

    def _send(self, req: GNNRequest, r: int, now: float):
        key = (req.partition, r)
        req.replica = r
        transport = self.transports[req.partition][r]
        self.inflight[req.rid] = _Inflight(req, key, transport, now)
        self._outstanding[key] += 1
        self._inflight_nodes[key].add(req.node)
        self.replica_state[key].sent += 1
        transport.send(req)

    def _dispatch_pass(self, now: float):
        """Drain the fabric queue toward the replicas: per request, the
        SLO decision (shed the hopeless, defer the currently-unplaceable)
        then the weighted least-loaded choice among the owner's eligible
        replicas.  A deferred request keeps its place; requests for
        OTHER partitions behind it still dispatch (no cross-partition
        head-of-line blocking)."""
        keep: List[GNNRequest] = []
        while self.pending:
            req = self.pending.popleft()
            candidates = self._candidates(req, now)
            verdict = self.slo.on_dispatch((now - req.t_submit) * 1e3,
                                           bool(candidates))
            if verdict == "shed":
                self._shed(req)
            elif verdict == "defer" or not candidates:
                keep.append(req)
            else:
                self._send(req, self._pick_replica(req, candidates), now)
        self.pending.extend(keep)

    # ------------------------------------------------------------------
    # responses, timeouts, retries, re-routes
    # ------------------------------------------------------------------
    def _resolve(self, rec: _Inflight):
        self.inflight.pop(rec.req.rid, None)
        self._outstanding[rec.key] -= 1
        self._inflight_nodes[rec.key].discard(rec.req.node)

    def _on_response(self, key: Tuple[int, int], resp: GNNRequest):
        """A transport delivered a response.  Three cases: the request
        is in flight on that replica (success — retire it); the fabric
        stopped waiting, or retried elsewhere (late — discard, count);
        or the fabric never dispatched it (an engine driven directly,
        e.g. jit warmup — account it the pre-seam way)."""
        now = self.clock()
        rec = self.inflight.get(resp.rid)
        if rec is None or rec.key != key:
            if rec is not None or resp.rid in self._failed_rids:
                self.fstats.late_responses += 1
                return
            self._account_retirement(resp)       # external retirement
            return
        req = rec.req
        self._resolve(rec)
        if resp is not req:
            # the response crossed a modeled wire: fold the remote copy's
            # results back into the canonical request, stamped on the
            # fabric clock (dispatch → delivery is the honest latency)
            req.pred = resp.pred
            req.logits = resp.logits
            req.status = resp.status
            req.t_first = rec.sent_at
            req.t_done = now
        self._note_success(key, (now - rec.sent_at) * 1e3)
        self._account_retirement(req)
        self._trace(req, "done")

    def _fail_attempt(self, rec: _Inflight, now: float, reroute: bool):
        """One dispatched attempt gave up (timer expiry, or its replica
        went down): reclaim it, then retry on another replica while the
        budget lasts — otherwise retire it EXPLICITLY as timed out.
        Every admitted request ends in exactly one terminal state; none
        vanish inside a dead host."""
        req = rec.req
        if req.rid not in self.inflight:
            # already reclaimed this step: a timeout that tips its replica
            # to down re-routes the SAME records the expiry snapshot holds
            return
        self._resolve(rec)
        rec.transport.cancel(req.rid)
        if reroute:
            self.fstats.reroutes += 1
        else:
            self.fstats.timeouts += 1
            self._note_timeout(rec.key, now)
        req.retries += 1
        if req.retries <= self.retry_limit:
            self.fstats.retries += 1
            self.pending.append(req)
            return
        req.status = "timeout"
        req.t_done = now
        self.timeout_requests.append(req)
        trim_completed(self.timeout_requests, self.keep_completed)
        self.fstats.timed_out += 1
        self._note_failed_rid(req.rid)
        self._trace(req, "timeout")

    def _reroute_replica(self, key: Tuple[int, int], now: float):
        """A replica went down: pull everything in flight on it back
        and re-route to survivors (or retire explicitly) NOW — waiting
        out each request's own timer would serialize the failures."""
        stuck = [rec for rec in self.inflight.values() if rec.key == key]
        for rec in stuck:
            self._fail_attempt(rec, now, reroute=True)

    def _service_timeouts(self, now: float):
        if self.timeout_ms <= 0 or not self.inflight:
            return
        expired = [rec for rec in self.inflight.values()
                   if (now - rec.sent_at) * 1e3 > self.timeout_ms]
        for rec in expired:
            self._fail_attempt(rec, now, reroute=False)

    # ------------------------------------------------------------------
    def _advance_clock(self) -> float:
        tick = getattr(self.clock, "tick", None)
        if tick is not None:
            tick()                       # VirtualClock: one tick per step
        return self.clock()

    def step(self) -> int:
        """One fabric tick: service timeouts, a dispatch pass, then one
        poll on every transport (which drives in-process engines one
        step and delivers whatever responses are due).  Returns
        fleet-wide resolutions (served + explicitly timed out)."""
        now = self._advance_clock()
        done0 = self.total_completed
        timed0 = self.fstats.timed_out
        self._service_timeouts(now)
        self._dispatch_pass(now)
        for part in self.transports:
            for t in part:
                t.poll(now)
        self.steps += 1
        return (self.total_completed - done0
                + self.fstats.timed_out - timed0)

    # ------------------------------------------------------------------
    # weight hand-off: trainer → every replica, between steps
    # ------------------------------------------------------------------
    def refresh_weights(self, weights: Optional[Dict] = None):
        """Swap every replica's params (the get/set-weights discipline).
        With no argument, pulls from the trainer this fabric was built
        from.  In-flight requests are NOT dropped: a single-shot query is
        computed wholly inside one engine step, so everything retired
        after this call used the refreshed tree."""
        if weights is None:
            if self._weight_source is None:
                raise ValueError("no weight source: pass weights= or build "
                                 "the fabric with from_trainer")
            weights = self._weight_source.get_weights()
        for eng in self.all_engines:
            eng.set_weights(weights)

    # ------------------------------------------------------------------
    # topology hand-off: a mutated graph reaches serving the same way
    # weights do — a whole-plan swap BETWEEN steps, never mid-flight
    # ------------------------------------------------------------------
    def refresh_topology(self, plan: Optional[PartitionPlan] = None,
                         planes: Optional[List] = None,
                         weight_fns: Optional[List] = None):
        """Adopt a new ``PartitionPlan`` (post edge stream / compaction /
        incremental re-balance).  The ``FeatureCache.version`` discipline
        generalized to topology: requests already dispatched finish
        against the subgraphs they were admitted under (each replica's
        graph is a frozen copy and a single-shot query retires inside one
        engine step), THEN the fleet is rebuilt over the new plan's
        subgraphs and every request admitted afterwards carries the new
        ``topology_version`` stamp.  Requests still queued — including
        retries reclaimed mid-rebuild, and anything a dead or
        unresponsive replica never answered — are RE-STAMPED against the
        new plan (owner may have changed under a re-balance) and
        re-dispatched onto the rebuilt fleet; none are dropped.  With
        no arguments, pulls plan/planes/weight_fns from the trainer this
        fabric was built from (``from_trainer``)."""
        if plan is None:
            if self._weight_source is None:
                raise ValueError("no topology source: pass plan= or build "
                                 "the fabric with from_trainer")
            src = self._weight_source
            plan = src.plan
            planes = [s.pipe.plane for s in src.slots]
            weight_fns = [s.weight_fn for s in src.slots]
        if plan.parts != self.plan.parts:
            raise ValueError(f"refresh_topology cannot change the partition "
                             f"count ({self.plan.parts} -> {plan.parts}); "
                             f"build a new fabric")
        # drain dispatched work against the OLD topology: poll transports
        # (responses in flight on a wire still count) and service
        # timeouts, bounded.  A timed-out request's retry lands in the
        # fabric queue — no dispatch pass runs here, so it waits for the
        # rebuilt fleet instead of a replica about to be torn down.
        iters = 0
        while ((self.inflight or any(t.busy() for t in self.all_transports))
               and iters < 10_000):
            now = self._advance_clock()
            self._service_timeouts(now)
            for t in self.all_transports:
                t.poll(now)
            iters += 1
            if (self.inflight and self.timeout_ms <= 0
                    and not any(t.busy() for t in self.all_transports)):
                break   # nothing will resolve these — pull them back below
        # anything STILL unresolved (a disconnected host, or timeouts
        # disabled) is pulled back and re-queued — the rebuild is not the
        # request's fault, so its retry budget is untouched
        if self.inflight:
            for rec in list(self.inflight.values()):
                self._resolve(rec)
                rec.transport.cancel(rec.req.rid)
                self.pending.append(rec.req)
        params = (self._weight_source.get_weights()["params"]
                  if self._weight_source is not None
                  else self.all_engines[0].params)
        self._build_fleet(plan, params, planes, weight_fns)
        self.plan = plan
        self.topology_version = plan.topology_version
        self._update_slo_slots()
        # queued-but-undispatched requests (reclaimed retries included)
        # route against the NEW owners and serve the new topology, so
        # they get the new stamp — re-stamped, never dropped
        for req in self.pending:
            req.partition = int(plan.owner_of([req.node])[0])
            req.topology_version = self.topology_version
            req.replica = -1
        self.steps += 1

    # ------------------------------------------------------------------
    # metrics
    # ------------------------------------------------------------------
    @property
    def shed_fraction(self) -> float:
        return self.slo.shed_fraction

    def partition_completed(self) -> List[int]:
        """Fleet-wide retirements per partition (routing observability).
        Engine-side counts: what each partition's replicas COMPUTED —
        under fault injection this can exceed what the fabric received
        (a dropped response was still computed)."""
        return [sum(e.total_completed for e in part)
                for part in self.engines]

    def fabric_stats(self) -> Dict:
        """One observability snapshot: the ``FabricStats`` counters plus
        per-replica health, response-time EWMA and transport-side fault
        counters — the numbers the chaos harness and
        ``benchmarks/fig_serve.py`` stamp into their artifacts."""
        out = self.fstats.asdict()
        out["slo_slots"] = self.slo.slots
        reps = {}
        for (p, r), st in sorted(self.replica_state.items()):
            t = self.transports[p][r]
            entry = {"health": st.state,
                     "ewma_ms": (round(st.ewma_ms, 4)
                                 if st.ewma_ms is not None else None),
                     "sent": st.sent, "completed": st.completed,
                     "timeouts": st.timeouts,
                     "outstanding": self._outstanding[(p, r)]}
            for counter in ("delivered", "dropped_responses",
                            "blackholed_sends", "lost_on_disconnect"):
                if hasattr(t, counter):
                    entry[counter] = getattr(t, counter)
            reps[f"{p}/{r}"] = entry
        out["replicas"] = reps
        return out

    def audit(self) -> Dict[str, int]:
        """Conservation ledger: every offered request is in exactly one
        bucket.  ``offered == done + shed + timed_out + pending +
        inflight`` is the chaos harness's no-silent-loss invariant
        (door-validated rejections raise before ``offered`` counts)."""
        return {"offered": self.slo.offered,
                "done": self.total_completed,
                "shed": self.slo.shed,
                "timed_out": self.fstats.timed_out,
                "pending": len(self.pending),
                "inflight": len(self.inflight)}

    def _begin_window(self) -> Dict:
        return {"steps": self.steps, "offered": self.slo.offered,
                "shed": self.slo.shed, "deferrals": self.slo.deferrals,
                "timeouts": self.fstats.timeouts,
                "retries": self.fstats.retries}

    def _window_metrics(self, mark: Dict, emitted: int, done: int,
                        dt: float) -> Dict[str, float]:
        offered = self.slo.offered - mark["offered"]
        shed = self.slo.shed - mark["shed"]
        return {"queries_per_s": done / dt if dt else 0.0,
                "fabric_steps": self.steps - mark["steps"],
                "offered": offered, "shed": shed,
                "deferrals": self.slo.deferrals - mark["deferrals"],
                "timeouts": self.fstats.timeouts - mark["timeouts"],
                "retries": self.fstats.retries - mark["retries"],
                "shed_fraction": shed / offered if offered else 0.0}

    def run_to_completion(self, max_iters: int = 10_000) -> Dict[str, float]:
        stats = super().run_to_completion(max_iters)
        caches = [e.plane.stats for e in
                  (part[0] for part in self.engines)]
        hits = sum(c.hits for c in caches if c is not None)
        total = hits + sum(c.misses for c in caches if c is not None)
        stats["cache_hit_rate"] = hits / total if total else 0.0
        return stats

    def drain(self, max_iters: int = 10_000):
        """Step until every queue (fabric + transports + replicas) is
        empty."""
        return drain(self, max_iters)
