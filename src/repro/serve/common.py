"""Shared serving machinery — the seam between the two engines.

``serve/engine.py`` (token decode) and ``serve/gnn_engine.py`` (online GNN
node inference) run the same continuous-batching skeleton: a FIFO of
pending requests, a fixed pool of batch slots, admit → execute → retire.
The admission logic and the latency accounting live HERE so the engines
cannot drift apart — an admission-policy change (priorities, backpressure,
fairness) lands in one place and both engines inherit it.
"""
from __future__ import annotations

import time
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np


def admit_pending(pending: Deque, running: Dict,
                  try_allocate: Callable[[object], Optional[int]],
                  on_admit: Optional[Callable[[object, int], None]] = None
                  ) -> int:
    """Admit queued requests into free slots, in FIFO order.

    ``pending`` is a ``collections.deque`` (both engines'), so the
    head-pop per admission is O(1) instead of the O(n) list shuffle.
    ``try_allocate(req)`` returns a slot index or ``None`` (no capacity —
    or a request the pool cannot ever hold, which then blocks the head of
    the line exactly like the pre-seam engines did).  ``on_admit(req,
    slot)`` runs per admission (the LM engine prefills the KV slot there);
    afterwards ``running[slot] = req``.  Returns the number admitted.
    """
    admitted = 0
    while pending:
        req = pending[0]
        slot = try_allocate(req)
        if slot is None:
            break
        pending.popleft()
        if on_admit is not None:
            on_admit(req, slot)
        running[slot] = req
        admitted += 1
    return admitted


def trim_completed(completed: List, keep: int):
    """Bound the retained result history in place (oldest dropped) —
    an online engine must not grow per-request state forever."""
    if len(completed) > keep:
        del completed[:len(completed) - keep]


def drain(engine, max_iters: int) -> Tuple[int, float]:
    """Step ``engine`` until its queues are empty (or ``max_iters``);
    returns ``(emitted, seconds)``.  The run_to_completion drive loop
    both engines share — like ``admit_pending``, it lives once so the
    drain policy cannot drift between them."""
    t0 = time.perf_counter()
    emitted = 0
    iters = 0
    while (engine.pending or engine.running) and iters < max_iters:
        emitted += engine.step()
        iters += 1
    return emitted, time.perf_counter() - t0


def latency_stats(completed: List) -> Dict[str, float]:
    """p50/p99 latency over completed requests, in milliseconds.

    Requests carry ``t_submit`` / ``t_first`` / ``t_done`` perf-counter
    stamps (both engines' request dataclasses); ``total`` is
    submit → done (queue wait included — the number a caller of the
    serving endpoint experiences), ``ttft`` is submit → first output.
    """
    if not completed:
        return {"p50_ms": 0.0, "p99_ms": 0.0,
                "ttft_p50_ms": 0.0, "ttft_p99_ms": 0.0}
    total = np.array([r.t_done - r.t_submit for r in completed])
    ttft = np.array([r.t_first - r.t_submit for r in completed])
    return {"p50_ms": float(np.percentile(total, 50) * 1e3),
            "p99_ms": float(np.percentile(total, 99) * 1e3),
            "ttft_p50_ms": float(np.percentile(ttft, 50) * 1e3),
            "ttft_p99_ms": float(np.percentile(ttft, 99) * 1e3)}
