"""Shared serving machinery — the seam between engines and the fabric.

``serve/engine.py`` (token decode), ``serve/gnn_engine.py`` (online GNN
node inference) and ``serve/fabric.py`` (the partition-routed fleet) all
face callers through ONE contract, the ``ServingEngine`` protocol:
``submit / step / pending / running / free_slots / utilization / stats``.
The concrete machinery behind it lives HERE so implementations cannot
drift apart:

  * ``EngineBase`` — slot accounting (``free_slots`` / ``utilization``),
    submit timestamping, retirement bookkeeping (bounded history + the
    rolling ``LatencyWindow`` + the ``retire_hook`` the fabric uses to
    observe its replicas), and the ``run_to_completion`` drive loop over
    the shared ``drain``;
  * ``admit_pending`` — FIFO slot admission;
  * ``LatencyStats`` / ``latency_stats`` / ``LatencyWindow`` — typed
    latency accounting, both whole-window and rolling;
  * ``SLOAdmission`` — the windowed shed-or-defer scheduler the fabric
    runs admission through.

An admission-policy change (priorities, backpressure, fairness, SLO
targets) lands in one place and every engine inherits it.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from dataclasses import dataclass
from typing import (Callable, Deque, Dict, List, Optional, Protocol, Tuple,
                    runtime_checkable)

import numpy as np


# ---------------------------------------------------------------------------
# latency accounting
# ---------------------------------------------------------------------------

@dataclass
class LatencyStats:
    """Typed latency summary (milliseconds) over a set of retired requests.

    ``p50_ms``/``p99_ms`` cover submit → done (queue wait included — the
    number a caller of the serving endpoint experiences); ``ttft_*``
    cover submit → first progress (first emitted token for the decode
    engine, slot admission for the single-shot GNN engine — i.e. queue
    wait).  ``qps`` is retirements over the window's wall-clock span and
    ``window`` is the sample count.  ``asdict()`` flattens into the
    benchmark-JSON dict shape the pre-typed ``latency_stats`` returned.
    """
    p50_ms: float = 0.0
    p99_ms: float = 0.0
    ttft_p50_ms: float = 0.0
    ttft_p99_ms: float = 0.0
    # first-progress → done: time IN a slot, queue wait excluded — the
    # congestion-free estimate SLO admission projects from (an end-to-end
    # estimate would feed queue wait back into itself: one backlog episode
    # would poison admission long after the queue drained)
    service_p50_ms: float = 0.0
    qps: float = 0.0
    window: int = 0

    def asdict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def _percentile_stats(total_s: np.ndarray, ttft_s: np.ndarray,
                      span_s: float) -> LatencyStats:
    n = len(total_s)
    return LatencyStats(
        p50_ms=float(np.percentile(total_s, 50) * 1e3),
        p99_ms=float(np.percentile(total_s, 99) * 1e3),
        ttft_p50_ms=float(np.percentile(ttft_s, 50) * 1e3),
        ttft_p99_ms=float(np.percentile(ttft_s, 99) * 1e3),
        service_p50_ms=float(np.percentile(total_s - ttft_s, 50) * 1e3),
        qps=(n / span_s if span_s > 0 else 0.0),
        window=n)


def latency_stats(completed: List) -> LatencyStats:
    """Latency percentiles over retired requests.

    Requests carry ``t_submit`` / ``t_first`` / ``t_done`` perf-counter
    stamps (every engine's request dataclass).  Returns a zeroed
    ``LatencyStats`` on an empty window.
    """
    if not completed:
        return LatencyStats()
    total = np.array([r.t_done - r.t_submit for r in completed])
    ttft = np.array([r.t_first - r.t_submit for r in completed])
    span = (max(r.t_done for r in completed)
            - min(r.t_submit for r in completed))
    return _percentile_stats(total, ttft, span)


class LatencyWindow:
    """Rolling window over the most recent retirements — the variant the
    SLO scheduler needs: admission decisions must track the CURRENT
    latency regime, not the lifetime average (a warm engine's history
    would mask a saturation onset forever)."""

    def __init__(self, maxlen: int = 256):
        self._samples: Deque[Tuple[float, float, float]] = deque(maxlen=maxlen)
        self._cache: Optional[LatencyStats] = None

    def __len__(self) -> int:
        return len(self._samples)

    def record(self, req):
        """Fold one retired request (its perf-counter stamps) in."""
        self._samples.append((req.t_done, req.t_done - req.t_submit,
                              req.t_first - req.t_submit))
        self._cache = None

    def reset(self):
        self._samples.clear()
        self._cache = None

    def stats(self) -> LatencyStats:
        # memoized until the next record(): SLO admission consults this
        # per offered request, and a percentile recompute per arrival
        # turns the scheduler itself into the bottleneck under load (the
        # stall then ages out the queue — a self-inflicted shed storm)
        if self._cache is None:
            if not self._samples:
                return LatencyStats()
            done = np.array([s[0] for s in self._samples])
            total = np.array([s[1] for s in self._samples])
            ttft = np.array([s[2] for s in self._samples])
            self._cache = _percentile_stats(total, ttft,
                                            float(done.max() - done.min()))
        return self._cache


# ---------------------------------------------------------------------------
# the unified engine contract
# ---------------------------------------------------------------------------

@runtime_checkable
class ServingEngine(Protocol):
    """What every serving surface looks like from the outside — a single
    engine, a replica, or the whole partition-routed fabric.  Callers
    (drive loops, benchmarks, launchers) program against THIS, so a
    fleet is a drop-in replacement for one engine."""
    batch: int
    pending: Deque
    running: Dict
    completed: List

    def submit(self, req) -> None: ...
    def step(self) -> int: ...
    def free_slots(self) -> List[int]: ...
    def utilization(self) -> float: ...
    def stats(self) -> LatencyStats: ...
    def run_to_completion(self, max_iters: int = 10_000) -> Dict[str, float]: ...


class EngineBase:
    """Concrete half of the ``ServingEngine`` contract.

    Engines call ``_init_serving`` and own exactly three things: their
    ``running`` store, a ``step`` body, and retirement timestamps.  Slot
    arithmetic, the bounded history, the rolling latency window, and the
    drive loop live here ONCE — the pre-seam engines each carried their
    own ``free_slots``/``utilization``/``run_to_completion`` copies,
    which is precisely how drive loops drift apart."""

    def _init_serving(self, batch: int, keep_completed: int = 4096,
                      retire_hook: Optional[Callable] = None,
                      window: int = 256):
        self.batch = batch
        self.pending: Deque = deque()
        self.completed: List = []
        self.total_completed = 0
        # retained result history is BOUNDED (an online engine must not
        # grow per-request state forever); oldest entries are dropped
        self.keep_completed = max(int(keep_completed), 1)
        self.window = LatencyWindow(window)
        self.retire_hook = retire_hook

    # -- slot accounting ------------------------------------------------
    def has_work(self) -> bool:
        """Anything queued or in flight?  The shared ``drain`` loop's
        termination test — the fabric overrides it to cover its replicas'
        queues too."""
        return bool(self.pending or self.running)

    def free_slots(self) -> List[int]:
        return [s for s in range(self.batch) if s not in self.running]

    def utilization(self) -> float:
        return len(self.running) / max(self.batch, 1)

    # -- submission -----------------------------------------------------
    def _validate(self, req):
        """Engine-specific submit check (raise to reject)."""

    def submit(self, req):
        self._validate(req)
        req.t_submit = time.perf_counter()
        self.pending.append(req)

    # -- retirement -----------------------------------------------------
    def _retire(self, req, status: str = "done"):
        """One retirement: status, bounded history, rolling window, and
        the observer hook (the fabric's view into its replicas)."""
        req.status = status
        self.completed.append(req)
        self.total_completed += 1
        self.window.record(req)
        trim_completed(self.completed, self.keep_completed)
        if self.retire_hook is not None:
            self.retire_hook(req)

    # -- stats + drive loop ---------------------------------------------
    def stats(self) -> LatencyStats:
        """Rolling-window latency view (the SLO scheduler's input)."""
        return self.window.stats()

    def _begin_window(self) -> Dict:
        """Marks captured before a drain, for ``_window_metrics``."""
        return {}

    def _window_metrics(self, mark: Dict, emitted: int, done: int,
                        dt: float) -> Dict[str, float]:
        """Engine-specific additions to the drain summary."""
        return {}

    def run_to_completion(self, max_iters: int = 10_000) -> Dict[str, float]:
        """Drain the queues; every metric covers THIS call's window (the
        requests completed here), so repeated calls — warmup, then a
        measured wave, then a streamed re-query — each get
        self-consistent numbers.  Latency percentiles cover the window's
        tail still inside the bounded ``keep_completed`` history."""
        mark = self._begin_window()
        done0 = self.total_completed
        emitted, dt = drain(self, max_iters)
        done = self.total_completed - done0
        win = self.completed[-done:] if done else []
        out = {"completed": done, "seconds": dt}
        out.update(latency_stats(win).asdict())
        out.update(self._window_metrics(mark, emitted, done, dt))
        return out


# ---------------------------------------------------------------------------
# admission + drive-loop helpers
# ---------------------------------------------------------------------------

def admit_pending(pending: Deque, running: Dict,
                  try_allocate: Callable[[object], Optional[int]],
                  on_admit: Optional[Callable[[object, int], None]] = None
                  ) -> int:
    """Admit queued requests into free slots, in FIFO order.

    ``pending`` is a ``collections.deque`` (every engine's), so the
    head-pop per admission is O(1) instead of the O(n) list shuffle.
    ``try_allocate(req)`` returns a slot index or ``None`` (no capacity —
    or a request the pool cannot ever hold, which then blocks the head of
    the line exactly like the pre-seam engines did).  ``on_admit(req,
    slot)`` runs per admission (the LM engine prefills the KV slot there);
    afterwards ``running[slot] = req``.  Returns the number admitted.
    """
    admitted = 0
    while pending:
        req = pending[0]
        slot = try_allocate(req)
        if slot is None:
            break
        pending.popleft()
        if on_admit is not None:
            on_admit(req, slot)
        running[slot] = req
        admitted += 1
    return admitted


def trim_completed(completed: List, keep: int):
    """Bound the retained result history in place (oldest dropped) —
    an online engine must not grow per-request state forever."""
    if len(completed) > keep:
        del completed[:len(completed) - keep]


def drain(engine, max_iters: int) -> Tuple[int, float]:
    """Step ``engine`` until its queues are empty (or ``max_iters``);
    returns ``(emitted, seconds)``.  The one drive loop every
    ``ServingEngine`` shares — it lives once so the drain policy cannot
    drift between implementations."""
    t0 = time.perf_counter()
    emitted = 0
    iters = 0
    has_work = getattr(engine, "has_work",
                       lambda: bool(engine.pending or engine.running))
    while has_work() and iters < max_iters:
        emitted += engine.step()
        iters += 1
    return emitted, time.perf_counter() - t0


# ---------------------------------------------------------------------------
# SLO-aware admission
# ---------------------------------------------------------------------------

class SLOAdmission:
    """Windowed shed-or-defer admission against a target p99 (ms).

    Two decision points, both computed from the rolling ``LatencyWindow``
    (never from lifetime averages — saturation must show up immediately):

      * ``on_offer`` at the door: with the backlog's estimated drain time
        (backlog / windowed qps) plus one windowed p50 service already
        past the target, admitting is a promise the fabric cannot keep —
        shed NOW, cheaply, instead of queueing a request that will time
        out after consuming queue space.
      * ``on_dispatch`` per queued request each scheduling tick: a
        request whose queue age plus estimated service has crossed the
        target is shed (completing it late would blow the p99 the SLO
        protects); one whose target is still reachable but whose owner
        replica has no capacity is DEFERRED — it stays queued, which is
        the graceful half of degradation.

    Both estimates are STRUCTURAL, never congestion-fed: the service
    estimate is the windowed p50 of time-IN-slot (``t_done − t_first``,
    queue wait excluded) and the drain rate is slots / service — using
    end-to-end latency or observed qps instead feeds the backlog back
    into its own admission decision, and one saturation episode poisons
    the window into shedding everything forever (the death-spiral this
    replaced).

    With ``slo_p99_ms <= 0`` admission is unconditional (defer-only) and
    the fabric behaves like the pre-SLO engines: queue wait grows
    without bound past saturation.
    """

    def __init__(self, slo_p99_ms: float, window: LatencyWindow,
                 slots: int = 1):
        self.slo_p99_ms = float(slo_p99_ms)
        self.window = window
        self.slots = max(int(slots), 1)   # fleet-wide concurrent capacity
        self.offered = 0
        self.admitted = 0
        self.shed = 0
        self.deferrals = 0

    @property
    def enabled(self) -> bool:
        return self.slo_p99_ms > 0

    def service_estimate_ms(self) -> float:
        """Windowed p50 time-in-slot (0 until history exists — a cold
        fabric admits everything and learns its regime)."""
        st = self.window.stats()
        return st.service_p50_ms if st.window else 0.0

    def wait_estimate_ms(self, backlog: int) -> float:
        """Estimated queue wait behind ``backlog`` requests: the fleet
        drains ``slots`` requests per service interval."""
        return backlog * self.service_estimate_ms() / self.slots

    def on_offer(self, backlog: int) -> str:
        """Door decision at submit time: ``admit`` (to the queue) or
        ``shed``."""
        self.offered += 1
        if (self.enabled and self.wait_estimate_ms(backlog)
                + self.service_estimate_ms() > self.slo_p99_ms):
            self.shed += 1
            return "shed"
        return "admit"

    def on_dispatch(self, age_ms: float, has_capacity: bool) -> str:
        """Scheduling decision for one queued request: ``admit`` /
        ``defer`` / ``shed``."""
        if (self.enabled
                and age_ms + self.service_estimate_ms() > self.slo_p99_ms):
            self.shed += 1
            return "shed"
        if not has_capacity:
            self.deferrals += 1
            return "defer"
        self.admitted += 1
        return "admit"

    @property
    def shed_fraction(self) -> float:
        return self.shed / self.offered if self.offered else 0.0
