"""The replica transport seam — how the fabric talks to a replica.

PR 7's fabric called its replicas directly: every partition's replica
group lived in one process, so "dispatch" was a method call and a
response could not be late, lost, or from a dead host.  Cross-host
serving changes none of the fabric's POLICY (routing, SLO admission,
least-loaded dispatch) but all of its FAILURE MODEL — a cheap fleet
exhibits slow hosts, dropped responses and dead replicas, and the
scheduler must survive them.  This module is the seam that separates
the two, mirroring the training side's ``HostSimMesh`` twin pattern
(``repro/launch/mesh.py``): one protocol, an in-process implementation
that is bit-exact with the pre-seam fabric, and a host-boundary twin
with injectable faults so the failure model is testable on one CI core.

  * ``ReplicaTransport`` — the protocol: ``send`` a request toward the
    replica, ``poll`` to advance it and deliver any responses due, plus
    the local bookkeeping views dispatch needs (``in_flight_nodes`` for
    the unique-seed guard, ``busy`` for drain termination).  Responses
    come back through a callback the fabric ``bind``s — never a return
    value — because on a real wire arrival time is the transport's
    decision, not the caller's.
  * ``LoopbackTransport`` — zero-overhead in-process delivery: ``send``
    is ``engine.submit``, a retirement is delivered synchronously from
    inside ``engine.step``, and the request object crosses untouched
    (no copy), so a loopback fabric is bit-exact with the pre-seam one.
  * ``SimHostTransport`` — a modeled host boundary: requests are COPIED
    across the "wire" (the remote host owns its copy — result fields
    travel back only when a response is delivered), responses are held
    for ``added_latency_ms`` plus seeded jitter, and the ``FaultSpec``
    knobs inject the cheap-fleet failure modes — dropped responses,
    a scheduled disconnect (host crash: queued state dies with it) and
    recovery.  Every random draw comes from one seeded generator, so a
    fault schedule is exactly reproducible.
  * ``VirtualClock`` — a ``perf_counter`` stand-in the fabric ticks
    once per step.  Chaos tests run on it so timeouts, latencies and
    health transitions are deterministic functions of the schedule,
    not of host speed.
"""
from __future__ import annotations

import copy
import heapq
from dataclasses import dataclass
from typing import Callable, List, Optional, Protocol, Set, runtime_checkable

import numpy as np


class VirtualClock:
    """Deterministic ``perf_counter`` stand-in (seconds).

    The fabric auto-advances it by ``tick_s`` once per ``step`` (it
    duck-types on ``tick``); tests may also ``advance`` it explicitly.
    All request timestamps, timeouts, EWMAs and fault schedules then
    move in lock-step with the step count — same seed + same schedule
    ⇒ the same trace, on any host.
    """

    def __init__(self, start: float = 0.0, tick_s: float = 1e-3):
        self.now = float(start)
        self.tick_s = float(tick_s)

    def __call__(self) -> float:
        return self.now

    def tick(self):
        self.now += self.tick_s

    def advance(self, dt_s: float):
        self.now += float(dt_s)


@runtime_checkable
class ReplicaTransport(Protocol):
    """What the fabric sees of one replica, wherever it lives.

    The fabric ``bind``s a delivery callback, ``send``s requests, and
    ``poll``s every step; everything else it knows about the replica —
    service time, health — it must infer from when (and whether)
    responses arrive.  That inference is the point of the seam: the
    dispatch/timeout/health machinery written against it works
    unchanged when the replica is a real remote host.
    """

    engine: object

    def bind(self, deliver: Callable) -> None: ...
    def send(self, req) -> None: ...
    def poll(self, now: float) -> int: ...
    def cancel(self, rid: int) -> bool: ...
    def in_flight_nodes(self) -> Set[int]: ...
    def busy(self) -> bool: ...
    def connected(self) -> bool: ...


class LoopbackTransport:
    """In-process transport — the pre-seam fabric, behind the seam.

    No copies, no queues of its own, no latency model: ``send`` feeds
    the engine directly and a retirement is delivered synchronously
    from inside ``engine.step`` (the engine's ``retire_hook``).  A
    fabric over loopback transports is bit-exact with the pre-transport
    ``ServingFabric`` — same dispatch order, same request objects, same
    timestamps — which is the regression anchor every fault-injection
    run is compared against.
    """

    def __init__(self, engine, clock=None, fault=None, seed: int = 0):
        self.engine = engine
        self._deliver: Optional[Callable] = None
        engine.retire_hook = self._on_retire

    def bind(self, deliver: Callable):
        self._deliver = deliver

    def _on_retire(self, req):
        if self._deliver is not None:
            self._deliver(req)

    def send(self, req):
        self.engine.submit(req)

    def poll(self, now: float) -> int:
        if self.engine.has_work():
            return self.engine.step()
        return 0

    def cancel(self, rid: int) -> bool:
        for i, req in enumerate(self.engine.pending):
            if req.rid == rid:
                del self.engine.pending[i]
                return True
        return False

    def in_flight_nodes(self) -> Set[int]:
        return ({r.node for r in self.engine.running.values()}
                | {r.node for r in self.engine.pending})

    def busy(self) -> bool:
        return self.engine.has_work()

    def connected(self) -> bool:
        return True


@dataclass
class FaultSpec:
    """Injectable faults for one ``SimHostTransport`` — all deterministic
    under the transport's seed.

    ``added_latency_ms`` is the fixed per-response wire+service cost a
    host boundary adds (set it 10× on one replica to model a slow
    host); ``jitter_ms`` adds a seeded uniform draw in [0, jitter_ms)
    per response; ``drop_rate`` silently drops that fraction of
    responses AFTER the remote computed them (the fabric sees only a
    timeout); ``down_at_ms``/``up_at_ms`` schedule a full disconnect
    and recovery on the transport clock (relative to construction);
    ``down_after_responses`` disconnects after the Nth delivered
    response (kill-mid-burst without knowing timestamps).
    """

    added_latency_ms: float = 0.0
    jitter_ms: float = 0.0
    drop_rate: float = 0.0
    down_at_ms: Optional[float] = None
    up_at_ms: Optional[float] = None
    down_after_responses: Optional[int] = None


class SimHostTransport:
    """A modeled host boundary around one in-process engine.

    The wrapped engine is the "remote host": ``send`` copies the
    request across the wire (the fabric's object and the host's are
    distinct — exactly the aliasing a real RPC forces), ``poll`` drives
    the host one engine step and schedules each computed response for
    delivery at ``now + added_latency + jitter``, and delivery copies
    the result fields back into the fabric's canonical request.  Faults
    (``FaultSpec``) intercept that flow: a dropped response is computed
    but never delivered; a disconnected host blackholes sends, loses
    its queued state (crash semantics) and delivers nothing until the
    scheduled recovery.  One seeded generator drives jitter, drops and
    nothing else — the whole failure schedule replays bit-identically.
    """

    def __init__(self, engine, clock=None, fault: Optional[FaultSpec] = None,
                 seed: int = 0):
        import time
        self.engine = engine
        self.clock = clock if clock is not None else time.perf_counter
        self.fault = fault if fault is not None else FaultSpec()
        self._rng = np.random.default_rng(seed)
        self._deliver: Optional[Callable] = None
        self._t0 = self.clock()
        self._connected = True
        self._auto_down_done = False
        self._auto_up_done = False
        # (due_time, seq, response copy): a heap so jittered responses
        # can overtake each other on the wire, deterministically
        self._wire: List = []
        self._seq = 0
        self._captured: List = []
        # transport-local counters (surfaced in FabricStats snapshots)
        self.sent = 0
        self.delivered = 0
        self.dropped_responses = 0
        self.blackholed_sends = 0
        self.lost_on_disconnect = 0
        engine.retire_hook = self._captured.append

    def bind(self, deliver: Callable):
        self._deliver = deliver

    # -- fault control (tests drive these directly or via the spec) ----
    def kill(self):
        """Full disconnect: the host crashes.  Everything it held —
        queued requests, computed-but-undelivered responses — dies with
        it; the fabric learns only through timeouts."""
        if not self._connected:
            return
        self._connected = False
        self.lost_on_disconnect += (len(self._wire) + len(self._captured)
                                    + len(self.engine.pending)
                                    + len(self.engine.running))
        self._wire.clear()
        self._captured.clear()
        self.engine.pending.clear()
        self.engine.running.clear()

    def revive(self):
        """Recovery: the host is back, empty-handed (restart, not
        resume) — it serves whatever the fabric sends next."""
        self._connected = True

    def connected(self) -> bool:
        return self._connected

    # ------------------------------------------------------------------
    def _apply_schedule(self, now: float):
        ms = (now - self._t0) * 1e3
        f = self.fault
        if (f.down_at_ms is not None and not self._auto_down_done
                and ms >= f.down_at_ms):
            self._auto_down_done = True
            self.kill()
        if (f.up_at_ms is not None and not self._auto_up_done
                and ms >= f.up_at_ms):
            self._auto_up_done = True
            self.revive()

    def send(self, req):
        self._apply_schedule(self.clock())
        if not self._connected:
            self.blackholed_sends += 1      # the fabric's timeout finds it
            return
        self.sent += 1
        self.engine.submit(copy.copy(req))

    def poll(self, now: float) -> int:
        self._apply_schedule(now)
        if self._connected and self.engine.has_work():
            self.engine.step()
        # computed responses board the wire with their delivery time
        for resp in self._captured:
            extra = (self._rng.uniform(0.0, self.fault.jitter_ms)
                     if self.fault.jitter_ms > 0 else 0.0)
            due = now + (self.fault.added_latency_ms + extra) * 1e-3
            heapq.heappush(self._wire, (due, self._seq, resp))
            self._seq += 1
        self._captured.clear()
        delivered = 0
        while self._wire and self._wire[0][0] <= now and self._connected:
            due, _, resp = heapq.heappop(self._wire)
            if (self.fault.drop_rate > 0
                    and self._rng.random() < self.fault.drop_rate):
                self.dropped_responses += 1
                continue
            resp.t_done = due if due > now - 1e-12 else now
            self.delivered += 1
            if self._deliver is not None:
                self._deliver(resp)
                delivered += 1
            if (self.fault.down_after_responses is not None
                    and self.delivered >= self.fault.down_after_responses):
                self.kill()
        return delivered

    def cancel(self, rid: int) -> bool:
        for i, req in enumerate(self.engine.pending):
            if req.rid == rid:
                del self.engine.pending[i]
                return True
        for i, (due, seq, resp) in enumerate(self._wire):
            if resp.rid == rid:
                del self._wire[i]
                heapq.heapify(self._wire)
                return True
        return False

    def in_flight_nodes(self) -> Set[int]:
        return ({r.node for r in self.engine.running.values()}
                | {r.node for r in self.engine.pending}
                | {resp.node for _, _, resp in self._wire}
                | {resp.node for resp in self._captured})

    def busy(self) -> bool:
        # a disconnected host's queues are DEAD state, not pending work:
        # nothing it holds will ever be delivered, so it must not keep a
        # drain loop alive (the fabric's timeout owns those requests)
        return self._connected and (bool(self._wire) or bool(self._captured)
                                    or self.engine.has_work())


def loopback_factory(engine, partition: int, replica: int, clock):
    """Default transport factory: the in-process fabric (bit-exact with
    the pre-seam one)."""
    return LoopbackTransport(engine, clock=clock)


def sim_host_factory(faults=None, base: Optional[FaultSpec] = None,
                     seed: int = 0):
    """Factory-maker for a fabric of ``SimHostTransport`` replicas.

    ``faults`` maps ``(partition, replica)`` → ``FaultSpec`` overrides;
    every other replica gets ``base`` (default: a clean ``FaultSpec()``
    — a host boundary with zero modeled cost).  Per-transport seeds are
    derived from ``seed`` and the replica coordinates, so two fabrics
    built with the same arguments replay identical fault schedules.
    """
    faults = dict(faults or {})

    def factory(engine, partition: int, replica: int, clock):
        spec = faults.get((partition, replica),
                          base if base is not None else FaultSpec())
        return SimHostTransport(engine, clock=clock, fault=spec,
                                seed=seed + 7919 * partition + 13 * replica)
    return factory
