"""KV-cache management for batched serving.

Contiguous per-request rows inside the stacked (L, B, T, Hkv, Dh) cache the
model families expose (models/*.cache_decls).  The manager tracks per-slot
lengths and free slots so the engine can run continuous batching: finished
requests release their row, new prompts prefill into it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import numpy as np


@dataclass
class SlotState:
    active: bool = False
    length: int = 0
    request_id: int = -1


class KVCacheManager:
    """Slot allocator over a fixed-batch cache pytree."""

    def __init__(self, caches, batch: int, max_len: int):
        self.caches = caches
        self.batch = batch
        self.max_len = max_len
        self.slots = [SlotState() for _ in range(batch)]

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.active]

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.active]

    def allocate(self, request_id: int, prompt_len: int) -> Optional[int]:
        free = self.free_slots()
        if not free or prompt_len >= self.max_len:
            return None
        slot = free[0]
        self.slots[slot] = SlotState(True, prompt_len, request_id)
        return slot

    def advance(self, slot: int):
        self.slots[slot].length += 1

    def release(self, slot: int) -> int:
        rid = self.slots[slot].request_id
        self.slots[slot] = SlotState()
        return rid

    def positions(self) -> np.ndarray:
        """Current write position per slot (0 for inactive — masked)."""
        return np.array([s.length if s.active else 0 for s in self.slots],
                        np.int32)

    def utilization(self) -> float:
        return sum(s.active for s in self.slots) / max(self.batch, 1)
