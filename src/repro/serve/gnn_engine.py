"""Online GNN inference serving over the training-side FeaturePlane.

Answers per-node prediction requests ("what class is node v, given the
LIVE graph and features?") with the same machinery that makes training
affordable on CPU-GPU platforms (paper §III):

  * **incremental sampling** — each engine step samples the admitted
    seeds' neighborhoods on demand with the locality-aware
    ``core/sampling.py`` ``NeighborSampler`` (bias γ toward cached ids,
    exactly like the training sampler, so serving latency benefits from
    the same cache the trainer warmed);
  * **the FeaturePlane seam** — features are fetched through the SAME
    ``core/feature_plane.py`` plane a trainer built (host numpy cache or
    device-resident Pallas ``cache_gather``), so the γ/Θ cache, its
    hit/miss accounting and the device-mirror versioning all carry over
    from training to serving;
  * **continuous batching** — a fixed pool of ``batch`` slots, FIFO
    admission through the serve/common.py ``EngineBase`` seam shared
    with the LM decode engine (the ``ServingEngine`` contract), one
    jitted forward-only step per iteration over the active slots (every
    node level padded to a fixed per-engine cap — ONE jit signature,
    and no phantom filler traffic through the shared plane), completed
    requests retire immediately and waiting queries join.

As a partition replica (serve/fabric.py): constructed with a
``node_map`` (global → local id, −1 for nodes owned elsewhere) the
engine serves GLOBAL node ids against its partition subgraph — queries
keep their fleet-wide identity, seeds are translated only at sampling
time, and the fabric's ``retire_hook`` observes every retirement.
Weight hand-off follows the get/set-weights discipline: a trainer's
exported tree swaps in BETWEEN steps, so in-flight requests (each
computed wholly inside one step) never see a half-updated model.

Streaming updates: subscribe the plane to a ``graph/storage.py``
``FeatureStore`` (``plane.subscribe_to(store)``) and a mid-serving
``update_rows`` is reflected in the very next prediction on BOTH
backends — the cache-resident copy updates in place and the device
mirror re-syncs off ``FeatureCache.version``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, Optional

import numpy as np

from repro.core.feature_plane import FeaturePlane, make_feature_plane
from repro.core.sampling import NeighborSampler
from repro.graph.batch import (generate_batch, inference_arrays,
                               compute_level_caps)
from repro.graph.storage import Graph
from repro.serve.common import EngineBase, admit_pending


@dataclass
class GNNRequest:
    """One node-prediction query (the GNN twin of engine.py's Request).

    ``status`` makes retirement explicit: ``done`` (``pred``/``logits``
    are real), ``shed`` (SLO admission dropped it — ``pred`` stays the
    −1 sentinel and must not be read as a class) or ``timeout`` (the
    fabric stopped waiting on every dispatched attempt — same sentinel
    rule).  ``partition`` is stamped by the fabric router; −1 means not
    fabric-routed.  ``replica``/``retries`` are the fabric's dispatch
    record: the last replica the request was sent to, and how many
    attempts gave up (timer expiry or a replica going down) before this
    one.  ``t_first`` is the slot-admission stamp (TTFT = queue wait
    for a single-shot query)."""
    rid: int
    node: int                          # node id to classify (GLOBAL under
    #                                    a fabric; engine-graph-local else)
    pred: int = -1                     # argmax class (valid iff status=="done")
    logits: Optional[np.ndarray] = None  # (num_classes,) float32
    status: str = "pending"            # pending | done | shed | timeout
    partition: int = -1                # owning partition (fabric-routed)
    replica: int = -1                  # last dispatch target (fabric-stamped)
    retries: int = 0                   # failed attempts before this one
    # graph topology version at admission (fabric-stamped; −1 = unrouted):
    # a query answers against the topology it was admitted under — edges
    # streamed after the stamp only affect later requests
    topology_version: int = -1
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class GNNInferenceEngine(EngineBase):
    """Continuous-batching node-prediction engine over a FeaturePlane.

    ``plane`` is intended to be the plane a trainer's pipeline built
    (``from_trainer`` wires that up) — sharing it means serving hits the
    warmed cache and its accounting proves the reuse.  A standalone
    engine (no trainer) gets a fresh plane over the bare host store.
    """

    def __init__(self, graph: Graph, cfg, params,
                 plane: Optional[FeaturePlane] = None, batch: int = 8,
                 weight_fn=None, seed: int = 0,
                 keep_completed: int = 4096,
                 node_map: Optional[np.ndarray] = None,
                 retire_hook: Optional[Callable] = None):
        import jax
        from repro.models.gnn import gnn_forward
        self.graph = graph
        self.cfg = cfg
        self.params = params
        # node_map: (N_global,) local id within `graph`, −1 if not owned
        # here — a fabric replica serves global ids over its subgraph
        self.node_map = (np.asarray(node_map, dtype=np.int32)
                         if node_map is not None else None)
        self._id_space = (len(self.node_map) if self.node_map is not None
                          else graph.num_nodes)
        owned = (int((self.node_map >= 0).sum())
                 if self.node_map is not None else graph.num_nodes)
        # seeds must be UNIQUE (the sampler's dedup/reindex invariant),
        # so in-flight queries are distinct nodes — a pool larger than
        # the servable node set could never fill
        if batch > owned:
            raise ValueError(f"batch {batch} exceeds the {owned}-node "
                             f"servable set (in-flight seeds must be "
                             f"distinct nodes)")
        self._init_serving(batch, keep_completed, retire_hook)
        self.running: Dict[int, GNNRequest] = {}   # slot -> request
        # fixed per-level pad caps → ONE jit signature for this engine's
        # forward, ever — the SAME cap discipline the all-hop fused train
        # step uses (graph/batch.py:compute_level_caps), so train and
        # serve share one signature shape per (model, level_caps)
        self._level_caps = compute_level_caps(batch, cfg.fanout,
                                              graph.num_nodes)
        self.plane = (plane if plane is not None else
                      make_feature_plane(graph, None, cfg.sampling_device))
        self.sampler = NeighborSampler(graph, cfg.fanout,
                                       weight_fn=weight_fn, seed=seed)
        self._fwd = jax.jit(
            lambda p, feats, idxs: gnn_forward(p, feats, idxs, cfg))
        self.steps = 0

    @classmethod
    def from_trainer(cls, trainer, batch: int = 8,
                     plane: Optional[FeaturePlane] = None,
                     seed: int = 0) -> "GNNInferenceEngine":
        """Serve with the trainer's feature machinery: pass the live
        pipeline's plane (``trainer.make_pipeline().plane``) to share the
        exact plane INSTANCE, or let this build one around the trainer's
        cache — either way hit/miss accounting is the trainer's own
        ``FeatureCache.stats`` and the γ bias is the trainer's
        ``weight_fn``."""
        if plane is None:
            plane = make_feature_plane(trainer.graph, trainer.cache,
                                       trainer.cfg.sampling_device)
        return cls(trainer.graph, trainer.cfg, trainer.params, plane=plane,
                   batch=batch, weight_fn=trainer.weight_fn, seed=seed)

    # ------------------------------------------------------------------
    # weight hand-off (trainer → replica, SNIPPETS §2 discipline): the
    # exported tree swaps in whole, between steps — single-shot requests
    # are computed inside one step, so none ever sees a partial refresh
    # ------------------------------------------------------------------
    def get_weights(self) -> Dict:
        return {"params": self.params}

    def set_weights(self, weights: Dict):
        self.params = weights["params"]

    # ------------------------------------------------------------------
    def _validate(self, req: GNNRequest):
        if not (0 <= req.node < self._id_space):
            raise ValueError(f"node {req.node} outside graph "
                             f"[0, {self._id_space})")
        if self.node_map is not None and self.node_map[req.node] < 0:
            raise ValueError(f"node {req.node} is not owned by this "
                             f"partition replica (route via the fabric)")

    def _try_allocate(self, req: GNNRequest) -> Optional[int]:
        free = self.free_slots()
        if not free:
            return None
        if any(r.node == req.node for r in self.running.values()):
            # a same-node query is already in flight: seeds must stay
            # unique, so the FIFO head waits one engine iteration (the
            # in-flight twin retires at the end of this step)
            return None
        return free[0]

    @staticmethod
    def _on_admit(req: GNNRequest, slot: int):
        req.t_first = time.perf_counter()      # TTFT = queue wait

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine iteration: admit, sample, gather (through the
        plane), forward, retire.  Returns completed-request count."""
        admit_pending(self.pending, self.running, self._try_allocate,
                      self._on_admit)
        if not self.running:
            return 0
        # one mini-batch over the ACTIVE seeds only — padding free slots
        # with real filler nodes would push phantom traffic through the
        # shared plane (polluting the trainer's CacheStats and, under
        # FIFO, evicting warmed rows).  inference_arrays pads every node
        # level to this engine's fixed caps (padded rows reference only
        # masked −1 neighbors), so the forward has ONE jit signature no
        # matter how many seeds are admitted or what they sample.
        active_slots = sorted(self.running)
        seeds = np.array([self.running[s].node for s in active_slots],
                         dtype=np.int64)
        if self.node_map is not None:
            seeds = self.node_map[seeds].astype(np.int64)
        mb = self.sampler.sample(seeds)
        mb = generate_batch(mb, self.plane, self.graph)
        arrays = inference_arrays(mb, level_caps=self._level_caps)
        logits = np.asarray(self._fwd(self.params, arrays["features"],
                                      arrays["neigh_idxs"]),
                            dtype=np.float32)
        now = time.perf_counter()
        retired = 0
        for i, slot in enumerate(active_slots):
            req = self.running.pop(slot)
            req.logits = logits[i].copy()
            req.pred = int(np.argmax(req.logits))
            req.t_done = now
            self._retire(req)
            retired += 1
        self.steps += 1
        return retired

    # ------------------------------------------------------------------
    def _begin_window(self) -> Dict:
        return {"steps": self.steps}

    def _window_metrics(self, mark: Dict, emitted: int, done: int,
                        dt: float) -> Dict[str, float]:
        out = {"queries_per_s": done / dt if dt else 0.0,
               "engine_steps": self.steps - mark["steps"]}
        if self.plane.stats is not None:
            out["cache_hit_rate"] = self.plane.stats.hit_rate
        return out
