"""Online GNN inference serving over the training-side FeaturePlane.

Answers per-node prediction requests ("what class is node v, given the
LIVE graph and features?") with the same machinery that makes training
affordable on CPU-GPU platforms (paper §III):

  * **incremental sampling** — each engine step samples the admitted
    seeds' neighborhoods on demand with the locality-aware
    ``core/sampling.py`` ``NeighborSampler`` (bias γ toward cached ids,
    exactly like the training sampler, so serving latency benefits from
    the same cache the trainer warmed);
  * **the FeaturePlane seam** — features are fetched through the SAME
    ``core/feature_plane.py`` plane a trainer built (host numpy cache or
    device-resident Pallas ``cache_gather``), so the γ/Θ cache, its
    hit/miss accounting and the device-mirror versioning all carry over
    from training to serving;
  * **continuous batching** — a fixed pool of ``batch`` slots, FIFO
    admission through the serve/common.py seam shared with the LM decode
    engine, one jitted forward-only step per iteration over the active
    slots (seed level exact, upper hops pow2-bucketed — at most
    ``batch`` jit signatures, and no phantom filler traffic through the
    shared plane), completed requests retire immediately and waiting
    queries join.

Streaming updates: subscribe the plane to a ``graph/storage.py``
``FeatureStore`` (``plane.subscribe_to(store)``) and a mid-serving
``update_rows`` is reflected in the very next prediction on BOTH
backends — the cache-resident copy updates in place and the device
mirror re-syncs off ``FeatureCache.version``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.core.feature_plane import FeaturePlane, make_feature_plane
from repro.core.sampling import NeighborSampler
from repro.graph.batch import generate_batch, inference_arrays
from repro.graph.storage import Graph
from repro.serve.common import (admit_pending, drain, latency_stats,
                                trim_completed)


@dataclass
class GNNRequest:
    """One node-prediction query (the GNN twin of engine.py's Request)."""
    rid: int
    node: int                          # global node id to classify
    pred: int = -1                     # argmax class (filled at retire)
    logits: Optional[np.ndarray] = None  # (num_classes,) float32
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class GNNInferenceEngine:
    """Continuous-batching node-prediction engine over a FeaturePlane.

    ``plane`` is intended to be the plane a trainer's pipeline built
    (``from_trainer`` wires that up) — sharing it means serving hits the
    warmed cache and its accounting proves the reuse.  A standalone
    engine (no trainer) gets a fresh plane over the bare host store.
    """

    def __init__(self, graph: Graph, cfg, params,
                 plane: Optional[FeaturePlane] = None, batch: int = 8,
                 weight_fn=None, seed: int = 0,
                 keep_completed: int = 4096):
        import jax
        from repro.models.gnn import gnn_forward
        self.graph = graph
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.plane = (plane if plane is not None else
                      make_feature_plane(graph, None, cfg.sampling_device))
        self.sampler = NeighborSampler(graph, cfg.fanout,
                                       weight_fn=weight_fn, seed=seed)
        self._fwd = jax.jit(
            lambda p, feats, idxs: gnn_forward(p, feats, idxs, cfg))
        self.pending: Deque[GNNRequest] = deque()
        self.running: Dict[int, GNNRequest] = {}   # slot -> request
        # retained result history is BOUNDED (an online engine must not
        # grow per-query state forever); oldest entries are dropped
        self.keep_completed = max(int(keep_completed), 1)
        self.completed: List[GNNRequest] = []
        self.total_completed = 0
        self._free = deque(range(batch))
        # seeds must be UNIQUE (the sampler's dedup/reindex invariant),
        # so in-flight queries are distinct nodes — a pool larger than
        # the graph could never fill
        if batch > graph.num_nodes:
            raise ValueError(f"batch {batch} exceeds the "
                             f"{graph.num_nodes}-node graph (in-flight "
                             f"seeds must be distinct nodes)")
        self.steps = 0

    @classmethod
    def from_trainer(cls, trainer, batch: int = 8,
                     plane: Optional[FeaturePlane] = None,
                     seed: int = 0) -> "GNNInferenceEngine":
        """Serve with the trainer's feature machinery: pass the live
        pipeline's plane (``trainer.make_pipeline().plane``) to share the
        exact plane INSTANCE, or let this build one around the trainer's
        cache — either way hit/miss accounting is the trainer's own
        ``FeatureCache.stats`` and the γ bias is the trainer's
        ``weight_fn``."""
        if plane is None:
            plane = make_feature_plane(trainer.graph, trainer.cache,
                                       trainer.cfg.sampling_device)
        return cls(trainer.graph, trainer.cfg, trainer.params, plane=plane,
                   batch=batch, weight_fn=trainer.weight_fn, seed=seed)

    # ------------------------------------------------------------------
    def submit(self, req: GNNRequest):
        if not (0 <= req.node < self.graph.num_nodes):
            raise ValueError(f"node {req.node} outside graph "
                             f"[0, {self.graph.num_nodes})")
        req.t_submit = time.perf_counter()
        self.pending.append(req)

    def _try_allocate(self, req: GNNRequest) -> Optional[int]:
        if not self._free:
            return None
        if any(r.node == req.node for r in self.running.values()):
            # a same-node query is already in flight: seeds must stay
            # unique, so the FIFO head waits one engine iteration (the
            # in-flight twin retires at the end of this step)
            return None
        return self._free.popleft()

    def free_slots(self) -> List[int]:
        return sorted(self._free)

    def utilization(self) -> float:
        return len(self.running) / max(self.batch, 1)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine iteration: admit, sample, gather (through the
        plane), forward, retire.  Returns completed-request count."""
        admit_pending(self.pending, self.running, self._try_allocate)
        if not self.running:
            return 0
        # one mini-batch over the ACTIVE seeds only — padding free slots
        # with real filler nodes would push phantom traffic through the
        # shared plane (polluting the trainer's CacheStats and, under
        # FIFO, evicting warmed rows).  The seed level is exact in
        # batch_device_arrays and upper hops are pow2-bucketed, so the
        # jit signature varies over at most ``batch`` sizes.
        active_slots = sorted(self.running)
        seeds = np.array([self.running[s].node for s in active_slots],
                         dtype=np.int64)
        mb = self.sampler.sample(seeds)
        mb = generate_batch(mb, self.plane, self.graph)
        arrays = inference_arrays(mb)
        logits = np.asarray(self._fwd(self.params, arrays["features"],
                                      arrays["neigh_idxs"]),
                            dtype=np.float32)
        now = time.perf_counter()
        retired = 0
        for i, slot in enumerate(active_slots):
            req = self.running.pop(slot)
            req.logits = logits[i].copy()
            req.pred = int(np.argmax(req.logits))
            req.t_first = req.t_done = now
            self.completed.append(req)
            self._free.append(slot)
            retired += 1
        self.total_completed += retired
        trim_completed(self.completed, self.keep_completed)
        self.steps += 1
        return retired

    # ------------------------------------------------------------------
    def run_to_completion(self, max_iters: int = 10_000) -> Dict[str, float]:
        """Drain the queue; every metric covers THIS call's window (the
        requests completed and steps taken here), so repeated calls —
        warmup, then a measured wave, then a streamed re-query — each get
        self-consistent numbers.  Latency percentiles cover the window's
        tail still inside the bounded ``keep_completed`` history."""
        steps0 = self.steps
        done, dt = drain(self, max_iters)
        window = self.completed[-done:] if done else []
        stats = {"completed": done, "seconds": dt,
                 "queries_per_s": done / dt if dt else 0.0,
                 "engine_steps": self.steps - steps0,
                 **latency_stats(window)}
        if self.plane.stats is not None:
            stats["cache_hit_rate"] = self.plane.stats.hit_rate
        return stats
