"""Version-compat helpers around XLA's AOT introspection APIs.

Side-effect free on import (unlike launch/dryrun.py, which forces 512 host
devices) — safe to import from tests and subprocesses that control their
own device count.
"""
from __future__ import annotations


def cost_analysis_dict(compiled) -> dict:
    """Normalized ``compiled.cost_analysis()``.

    jax ≤0.4.x returns a one-element list of per-program dicts; newer
    releases return the dict directly (and may return None when the backend
    provides no analysis).  Downstream cost code always wants a flat dict.
    """
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        return dict(ca[0]) if len(ca) else {}
    return dict(ca)
