import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod AOT dry-run.

For every (architecture × input shape × mesh) cell:

1. FULL-DEPTH compile (scan-over-layers): proves the sharding config is
   coherent at production scale; records ``memory_analysis()`` (per-device
   fit proof) and compile wall-time.
2. COST PROBES: two reduced-depth configs compiled with every scan fully
   unrolled.  XLA's ``cost_analysis()`` counts a while-loop body once,
   ignoring trip count (verified empirically), so scanned full-depth counts
   are wrong; per-layer cost is exactly linear in depth for our homogeneous
   stacks, so two unrolled probes give exact full-depth
   FLOPs / bytes / collective-traffic via linear extrapolation.

Results are cached as JSON under ``benchmarks/artifacts/dryrun/`` so the
sweep is resumable; ``benchmarks/roofline.py`` consumes them.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch minitron-8b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import re
import time
import traceback
from collections import defaultdict
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_archs, SHAPES_BY_NAME, applicable_shapes
from repro.models.api import build
from repro.models.params import abstract_params, param_count
from repro.models.unroll import force_unroll
from repro.distributed.sharding import (physical_specs, shardings_of, make_rules,
                                        resolve_spec, shard_ctx, enforce_divisible)
from repro.launch.mesh import make_production_mesh
from repro.launch.xla_compat import cost_analysis_dict
from repro.train.trainer import make_train_step
from repro.train.optimizer import get_optimizer

ART_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def parse_collectives(hlo_text: str):
    """Per-device collective traffic from post-SPMD HLO.

    Volume model (ring algorithms, (n-1)/n ≈ 1):
      all-gather / all-to-all / collective-permute : result bytes
      all-reduce / reduce-scatter                  : 2 × result bytes
    ``*-done`` ops are skipped (their ``*-start`` twin is counted).
    """
    per_op = defaultdict(lambda: {"count": 0, "bytes": 0.0})
    for m in _COLL_RE.finditer(hlo_text):
        dt, dims, op = m.groups()
        if m.group(0).rstrip().endswith("-done("):
            continue
        nbytes = _DTYPE_BYTES.get(dt, 4)
        if dims.strip():
            for d in dims.split(","):
                nbytes *= int(d)
        factor = 2.0 if op in ("all-reduce", "reduce-scatter") else 1.0
        per_op[op]["count"] += 1
        per_op[op]["bytes"] += nbytes * factor
    total = sum(v["bytes"] for v in per_op.values())
    return dict(per_op), total


# ---------------------------------------------------------------------------
# Depth scaling
# ---------------------------------------------------------------------------

def depth_probe_cfgs(cfg):
    """(cfg1, u1), (cfg2, u2), u_full — linear depth units per family."""
    if cfg.family == "hybrid":
        every, rem = cfg.shared_attn_every, cfg.num_layers % cfg.shared_attn_every
        l1, l2 = every + rem, 2 * every + rem
        return ((cfg.replace(num_layers=l1), 1),
                (cfg.replace(num_layers=l2), 2),
                cfg.num_layers // every)
    if cfg.family == "encdec":
        return ((cfg.replace(num_layers=2, encoder_layers=2), 2),
                (cfg.replace(num_layers=4, encoder_layers=4), 4),
                cfg.num_layers)
    return ((cfg.replace(num_layers=2), 2),
            (cfg.replace(num_layers=4), 4),
            cfg.num_layers)


def _extrapolate(c1, c2, u1, u2, uf):
    b = (c2 - c1) / max(u2 - u1, 1)
    return max(c1 + b * (uf - u1), 0.0)


# ---------------------------------------------------------------------------
# Lower + compile one step function
# ---------------------------------------------------------------------------

def _lower_cell(cfg, shape, mesh):
    """Returns (lowered, kind).  Must run inside shard_ctx."""
    model = build(cfg)
    rules = make_rules(cfg, mesh)
    spec = model.input_specs(shape)
    batch = spec["batch"]
    batch_sh = jax.tree.map(
        lambda s, b: NamedSharding(
            mesh, enforce_divisible(resolve_spec(s, rules), b.shape, mesh)),
        spec["batch_specs"], batch,
        is_leaf=lambda x: isinstance(x, P))
    pspecs = physical_specs(model.decls, cfg, mesh)
    param_sh = shardings_of(pspecs, mesh)
    aparams = abstract_params(model.decls,
                              dtype_override=jnp.dtype(cfg.param_dtype))
    repl = NamedSharding(mesh, P())

    if spec["kind"] == "train":
        opt = get_optimizer(cfg)
        step, _ = make_train_step(model, cfg, opt,
                                  grad_accum=getattr(cfg, "grad_accum", 1))
        odecls = opt.state_decls(model.decls)
        ostate = abstract_params(odecls)
        opt_sh = shardings_of(physical_specs(odecls, cfg, mesh), mesh)
        metric_sh = jax.tree.map(lambda _: repl,
                                 {"loss": 0, "grad_norm": 0, "aux": 0})
        jf = jax.jit(step, in_shardings=(param_sh, opt_sh, batch_sh),
                     out_shardings=(param_sh, opt_sh, metric_sh),
                     donate_argnums=(0, 1))
        return jf.lower(aparams, ostate, batch), spec
    logit_spec = enforce_divisible(
        resolve_spec(P("dp", None), rules),
        (shape.global_batch, cfg.vocab_size), mesh)
    if spec["kind"] == "prefill":
        cdecls = model.cache_decls(shape.global_batch, shape.seq_len)
        cache_sh = shardings_of(physical_specs(cdecls, cfg, mesh), mesh)
        logit_sh = NamedSharding(mesh, logit_spec)
        jf = jax.jit(model.prefill, in_shardings=(param_sh, batch_sh),
                     out_shardings=(logit_sh, cache_sh))
        return jf.lower(aparams, batch), spec
    # decode
    cdecls = spec["cache_decls"]
    cache_sh = shardings_of(physical_specs(cdecls, cfg, mesh), mesh)
    logit_sh = NamedSharding(mesh, logit_spec)
    jf = jax.jit(model.decode, in_shardings=(param_sh, cache_sh, batch_sh),
                 out_shardings=(logit_sh, cache_sh), donate_argnums=(1,))
    return jf.lower(aparams, spec["caches"], batch), spec


def _probe_costs(cfg, shape, mesh):
    """Reduced-depth fully-unrolled compile → exact per-device cost fields."""
    with shard_ctx(cfg, mesh), force_unroll(True):
        lowered, _ = _lower_cell(cfg, shape, mesh)
        compiled = lowered.compile()
    ca = cost_analysis_dict(compiled)
    per_op, coll_total = parse_collectives(compiled.as_text())
    out = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
        "coll_total": float(coll_total),
    }
    for op, v in per_op.items():
        out[f"coll_{op}"] = v["bytes"]
        out[f"collcnt_{op}"] = v["count"]
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             overrides: dict | None = None, tag: str = ""):
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES_BY_NAME[shape_name]
    if shape not in applicable_shapes(cfg):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "tag": tag, "skipped": True,
                "reason": "long_500k needs sub-quadratic attention"}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = int(np.prod(mesh.devices.shape))
    model = build(cfg)

    # ---- full-depth compile: sharding proof + memory analysis ----
    t0 = time.time()
    with shard_ctx(cfg, mesh):
        lowered, spec = _lower_cell(cfg, shape, mesh)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    ca_raw = cost_analysis_dict(compiled)

    # ---- cost probes: reduced depth, fully unrolled ----
    (cfg1, u1), (cfg2, u2), uf = depth_probe_cfgs(cfg)
    t0 = time.time()
    p1 = _probe_costs(cfg1, shape, mesh)
    p2 = _probe_costs(cfg2, shape, mesh)
    t_probe = time.time() - t0
    keys = sorted(set(p1) | set(p2))
    cost = {k: _extrapolate(p1.get(k, 0.0), p2.get(k, 0.0), u1, u2, uf)
            for k in keys}

    res = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag,
        "kind": spec["kind"], "skipped": False,
        "n_devices": n_dev,
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "t_probe_s": round(t_probe, 2),
        "params_total": param_count(model.decls),
        "params_active": cfg.active_param_count(),
        "param_bytes_dtype": jnp.dtype(cfg.param_dtype).itemsize,
        "tokens_per_step": shape.global_batch * (
            shape.seq_len if spec["kind"] in ("train", "prefill") else 1),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_device_bytes": (ma.argument_size_in_bytes
                                  + ma.temp_size_in_bytes),
        },
        "cost": {
            "flops_per_device": cost.get("flops", 0.0),
            "bytes_per_device": cost.get("bytes", 0.0),
            "transcendentals": cost.get("transcendentals", 0.0),
            "collective_bytes_per_device": cost.get("coll_total", 0.0),
            "per_op": {k[5:]: v for k, v in cost.items()
                       if k.startswith("coll_") and not k.startswith("collcnt")},
            "raw_full_flops_scanned": float(ca_raw.get("flops", 0.0)),
            "probe_depths": [u1, u2], "full_depth_units": uf,
        },
        "config": {
            "remat": cfg.remat, "attn_chunk": cfg.attn_chunk,
            "loss_chunk": cfg.loss_chunk, "param_dtype": cfg.param_dtype,
            "optimizer": cfg.optimizer, "kv_shard": cfg.kv_shard,
            **(overrides or {}),
        },
    }
    return res


def cell_path(arch, shape, mesh_kind, tag=""):
    sfx = f"__{tag}" if tag else ""
    return ART_DIR / mesh_kind / f"{arch}__{shape}{sfx}.json"


def parse_overrides(pairs):
    overrides = {}
    for kv in pairs:
        k, v = kv.split("=", 1)
        if v in ("True", "False"):
            overrides[k] = v == "True"
            continue
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        overrides[k] = v
    return overrides


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--set", action="append", default=[],
                    help="config override k=v (int/float/str/bool)")
    args = ap.parse_args()

    overrides = parse_overrides(args.set)
    lm_archs = [a for a in list_archs() if not a.startswith("graphsage")]
    archs = args.arch or (lm_archs if args.all else [])
    shapes = args.shape or list(SHAPES_BY_NAME)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if not archs:
        ap.error("pass --arch or --all")

    done, failed = 0, 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                out = cell_path(arch, shape, mesh_kind, args.tag)
                if out.exists() and not args.force:
                    print(f"[skip-cached] {mesh_kind}/{arch}/{shape}")
                    continue
                print(f"[run] {mesh_kind}/{arch}/{shape} ...", flush=True)
                try:
                    res = run_cell(arch, shape, mesh_kind,
                                   overrides or None, args.tag)
                except Exception as e:  # noqa: BLE001 — sweep must continue
                    res = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "tag": args.tag,
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    failed += 1
                    print(f"  FAILED: {type(e).__name__}: {e}", flush=True)
                out.parent.mkdir(parents=True, exist_ok=True)
                out.write_text(json.dumps(res, indent=1))
                if "error" not in res:
                    done += 1
                    if res.get("skipped"):
                        print("  skipped:", res["reason"], flush=True)
                    else:
                        c, m = res["cost"], res["memory"]
                        print(f"  ok: compile={res['t_compile_s']}s "
                              f"probe={res['t_probe_s']}s "
                              f"flops/dev={c['flops_per_device']:.3e} "
                              f"peak={m['peak_device_bytes']/2**30:.2f}GiB "
                              f"coll={c['collective_bytes_per_device']/2**20:.1f}MiB",
                              flush=True)
    print(f"done={done} failed={failed}")
    return 0 if failed == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
