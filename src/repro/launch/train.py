"""Training entry point.

Two workloads behind one CLI:

  * GNN (the paper):  --arch graphsage-products [--baseline pyg_like] ...
    runs A³GNN end-to-end on a synthetic twin dataset with the configured
    sampling/caching/parallelism strategy, reporting the paper's metrics.

  * LM (assigned archs): --arch minitron-8b --smoke ... runs the reduced
    config on the host mesh with the real train step, host data pipeline,
    checkpointing and fault-tolerance supervisor.  On a real TPU slice the
    same code path takes the production mesh (launch/mesh.py) — XLA flags
    for latency-hiding collectives are set below.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch graphsage-products --steps 30
  PYTHONPATH=src python -m repro.launch.train --arch graphsage-products \
      --smoke --autotune --episodes-autotune 4
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke --steps 10
"""
from __future__ import annotations

import argparse
import os
import time


def _tpu_xla_flags():
    """Latency-hiding scheduler + async collectives for real TPU runs."""
    flags = os.environ.get("LIBTPU_INIT_ARGS", "")
    os.environ["LIBTPU_INIT_ARGS"] = flags + (
        " --xla_tpu_enable_latency_hiding_scheduler=true"
        " --xla_tpu_enable_async_collective_fusion=true"
        " --xla_enable_async_all_gather=true")


def run_gnn_multipartition(args, cfg, graph):
    """Scale-out GNN path: locality-partitioned data parallelism under the
    fault-tolerance supervisor, with a restart-path restore proof."""
    from repro.core.a3gnn import make_trainer
    from repro.train.checkpoint import CheckpointManager

    tr = make_trainer(graph, cfg, seed=args.seed)
    plan = tr.plan
    print(f"[partition] {plan.parts} partitions ({plan.method}): "
          f"sizes={[len(ns) for ns in plan.node_sets]} "
          f"edge_locality={plan.edge_locality(graph):.3f} "
          f"halo={plan.halo_counts}")
    if plan.halo_budget > 0:
        print(f"[halo] budget={plan.halo_budget}/partition "
              f"kept={[len(hs) for hs in plan.halo_sets]} "
              f"kept_information={plan.kept_information(graph):.3f} "
              f"(vs {plan.edge_locality(graph):.3f} at budget=0) "
              f"exchange={tr.halo_exchange_bytes/2**10:.1f} KiB")
    # fresh dir per run unless the caller pins one — a reused dir would
    # let keep-k GC favor a previous (longer) run's higher step numbers
    # and the restore proof below would resurrect stale parameters
    import tempfile
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(
        prefix=f"ckpt_gnn_p{cfg.partitions}_")
    rep = tr.fit_supervised(args.steps, ckpt_dir,
                            ckpt_every=max(args.steps // 2, 1))
    acc = tr.evaluate()
    halo_note = (f" halo_hit={tr.halo_hit_rate:.3f}"
                 if plan.halo_budget > 0 else "")
    print(f"[result] {rep.steps_run} global steps "
          f"({rep.steps_run * plan.parts} partition mini-batches), "
          f"checkpoints={rep.checkpoints} acc={acc:.4f} "
          f"cache_hit={tr.cache_hit_rate:.3f}{halo_note}")
    # restart-path proof: rebuild a fresh trainer and restore the committed
    # checkpoint (the same machinery the autotune `partitions` knob uses)
    tr2 = make_trainer(graph, cfg, seed=args.seed)
    step = tr2.restore(CheckpointManager(ckpt_dir, async_save=False))
    print(f"[restore] fresh trainer restored from step {step} "
          f"(global_steps={tr2.global_steps}) acc={tr2.evaluate():.4f}")
    return 0


def run_gnn(args):
    from repro.configs import get_config
    from repro.graph.synthetic import dataset_like
    from repro.core.a3gnn import A3GNNTrainer, apply_baseline

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.mode:
        cfg = cfg.replace(parallel_mode=args.mode)
    if args.bias_rate is not None:
        cfg = cfg.replace(bias_rate=args.bias_rate)
    if args.partitions is not None:
        cfg = cfg.replace(partitions=args.partitions)
    if args.halo_budget is not None:
        cfg = cfg.replace(halo_budget=args.halo_budget)
    if args.halo_refresh_interval is not None:
        cfg = cfg.replace(halo_refresh_interval=args.halo_refresh_interval)
    if args.rebalance_drift is not None:
        cfg = cfg.replace(rebalance_drift=args.rebalance_drift)
    if args.sampling_device is not None:
        cfg = cfg.replace(sampling_device=args.sampling_device)
    if args.fused_gather_agg:
        cfg = cfg.replace(fused_gather_agg=True)
    cfg = apply_baseline(cfg, args.baseline)
    graph = dataset_like(cfg, seed=args.seed)
    print(f"[data] {graph.name}: {graph.num_nodes} nodes, "
          f"{graph.num_edges} edges")
    if cfg.partitions > 1:
        return run_gnn_multipartition(args, cfg, graph)
    tr = A3GNNTrainer(graph, cfg, seed=args.seed)
    if args.autotune:
        acfg = cfg.autotune.replace(episodes=args.episodes_autotune,
                                    steps_per_episode=args.steps,
                                    seed=args.seed)
        rep = tr.fit_autotuned(acfg)
        for ep in rep.episodes:
            c, m = ep.config, ep.metrics
            print(f"[episode {ep.index}] γ={c['bias_rate']:.2f} "
                  f"Θ={c['cache_volume_mb']:.2f}MB "
                  f"mode={c['parallel_mode']} workers={int(c['workers'])} | "
                  f"thr={m['throughput']:.2f} steps/s "
                  f"mem={m['memory']/2**20:.1f} MiB acc={m['accuracy']:.3f} "
                  f"hit={ep.cache_hit_rate:.2f}")
        b, m = rep.best, rep.best.metrics
        print(f"[autotune] best=episode {b.index} "
              f"thr={m['throughput']:.2f} steps/s "
              f"(baseline {rep.baseline_metrics['throughput']:.2f}) "
              f"changed={sorted(rep.changed_knobs())}")
        print(f"[pareto] {len(rep.pareto_points())} non-dominated "
              f"measured points")
        return 0
    res = tr.run_epochs(args.epochs, max_steps_per_epoch=args.steps)
    print(f"[result] thr={res.throughput_epochs_s:.4f} ep/s "
          f"({res.throughput_steps_s:.2f} steps/s) "
          f"mem={res.memory_bytes/2**20:.1f} MiB "
          f"acc={res.test_acc:.4f} hit_rate={res.cache_hit_rate:.3f}")
    st = res.stats.stage_times()
    print(f"[stages] sample={st.t_sample*1e3:.1f}ms "
          f"batch={st.t_batch*1e3:.1f}ms train={st.t_train*1e3:.1f}ms")
    return 0


def run_lm(args):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models.api import build
    from repro.models.params import init_params
    from repro.train.trainer import make_train_step
    from repro.train.optimizer import get_optimizer
    from repro.train.data import SyntheticTokens, PrefetchLoader
    from repro.train.checkpoint import CheckpointManager
    from repro.train.fault_tolerance import TrainSupervisor

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build(cfg)
    opt = get_optimizer(cfg)
    step_fn, _ = make_train_step(model, cfg, opt)
    jstep = jax.jit(step_fn, donate_argnums=(0, 1))
    rng = jax.random.PRNGKey(args.seed)
    params = init_params(model.decls, rng,
                         dtype_override=jnp.dtype(cfg.param_dtype))
    opt_state = opt.init(params)
    data = SyntheticTokens(cfg.vocab_size, args.batch, args.seq,
                           seed=args.seed, n_batches=args.steps)
    loader = PrefetchLoader(data, workers=args.workers)
    ckpt = CheckpointManager(args.ckpt_dir or f"/tmp/ckpt_{args.arch}",
                             keep=2, async_save=True)

    state = {"params": params, "opt_state": opt_state}
    it = iter(loader)

    def one_step(state, step):
        batch = next(it)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        p, o, metrics = jstep(state["params"], state["opt_state"], batch)
        if step % max(args.steps // 10, 1) == 0:
            print(f"  step {step}: loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f}", flush=True)
        return {"params": p, "opt_state": o}

    sup = TrainSupervisor(ckpt, ckpt_every=max(args.steps // 3, 1))
    t0 = time.time()
    state, rep = sup.run(state, one_step, args.steps)
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    print(f"[result] {args.steps} steps in {dt:.1f}s "
          f"({toks/dt:.0f} tok/s), checkpoints={rep.checkpoints}")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--epochs", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    # GNN knobs
    ap.add_argument("--baseline", default=None,
                    choices=[None, "a3gnn", "pyg_like", "quiver_like"])
    ap.add_argument("--mode", default=None,
                    choices=[None, "seq", "mode1", "mode2"])
    ap.add_argument("--bias-rate", type=float, default=None)
    ap.add_argument("--partitions", type=int, default=None,
                    help="data-parallel graph partitions (scale-out path; "
                         "host-simulated mesh when devices < partitions)")
    ap.add_argument("--halo-budget", type=int, default=None,
                    help="per-partition cap on boundary feature rows "
                         "exchanged through the mesh (0 = drop cut edges, "
                         "the paper's no-remote-access setting)")
    ap.add_argument("--halo-refresh-interval", type=int, default=None,
                    help="re-run the bounded halo exchange every N global "
                         "steps when streamed feature updates left halo "
                         "copies stale (0 = explicit refresh only)")
    ap.add_argument("--rebalance-drift", type=float, default=None,
                    help="cut-fraction drift past the plan baseline that "
                         "triggers an incremental partition re-balance "
                         "between global steps on a mutating graph "
                         "(boundary-node migration; <= 0 disables)")
    ap.add_argument("--sampling-device", default=None,
                    choices=[None, "cpu", "device", "auto"],
                    help="feature-plane backend for batch generation: "
                         "cpu (numpy cache), device (Pallas cache gather), "
                         "auto (probe jax.devices())")
    ap.add_argument("--fused-gather-agg", action="store_true",
                    help="all-hop fused device pipeline: batch generation "
                         "defers feature work to the train step, which "
                         "resolves the input hop from encoded cache slots "
                         "+ a miss sideband and aggregates every hop in "
                         "place (one jit signature per model/level_caps; "
                         "all model families, bit-exact with unfused)")
    ap.add_argument("--autotune", action="store_true",
                    help="run the online auto-tuning controller (§III-C)")
    ap.add_argument("--episodes-autotune", type=int, default=4)
    # LM knobs
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    if args.arch.startswith("graphsage"):
        return run_gnn(args)
    return run_lm(args)


if __name__ == "__main__":
    raise SystemExit(main())
