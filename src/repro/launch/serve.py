"""Serving entry point: batched decode with continuous batching.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
      --requests 16 --batch 4 --max-new 12
"""
from __future__ import annotations

import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.serve.engine import Engine, Request

    cfg = get_config(args.arch, smoke=args.smoke)
    eng = Engine(cfg, batch=args.batch, max_len=args.max_len,
                 temperature=args.temperature, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        plen = int(rng.integers(2, args.prompt_len + 1))
        prompt = rng.integers(1, cfg.vocab_size, plen).astype(np.int32)
        eng.submit(Request(rid=rid, prompt=prompt,
                           max_new_tokens=args.max_new))
    stats = eng.run_to_completion()
    lat = [r.t_first - r.t_submit for r in eng.completed]
    print(f"[result] {stats['completed']} requests, {stats['tokens']} tokens "
          f"in {stats['seconds']:.2f}s → {stats['tokens_per_s']:.1f} tok/s; "
          f"mean TTFT {np.mean(lat)*1e3:.1f} ms")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
