"""Serving entry point — two engines behind one CLI.

LM token decode (continuous batching over prompts):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
      --requests 16 --batch 4 --max-new 12

Online GNN node inference over the training-side FeaturePlane (trains
briefly to warm params + cache, then serves node queries and applies a
streaming feature update mid-serving):

  PYTHONPATH=src python -m repro.launch.serve --gnn \
      --arch graphsage-products --smoke --queries 16 --batch 4

Partition-routed serving fabric (``--partitions`` > 1): a multi-partition
trainer warms per-partition planes, then a ``ServingFabric`` routes node
queries to owner-partition replicas behind SLO-aware admission, with a
mid-serving trainer → replica weight refresh:

  PYTHONPATH=src python -m repro.launch.serve --gnn \
      --arch graphsage-products --smoke --queries 32 --batch 4 \
      --partitions 2 --replicas 2 --slo-p99-ms 50
"""
from __future__ import annotations

import argparse

import numpy as np


def run_lm_serve(args):
    from repro.configs import get_config
    from repro.serve.engine import Engine, Request

    cfg = get_config(args.arch, smoke=args.smoke)
    eng = Engine(cfg, batch=args.batch, max_len=args.max_len,
                 temperature=args.temperature, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        plen = int(rng.integers(2, args.prompt_len + 1))
        prompt = rng.integers(1, cfg.vocab_size, plen).astype(np.int32)
        eng.submit(Request(rid=rid, prompt=prompt,
                           max_new_tokens=args.max_new))
    stats = eng.run_to_completion()
    print(f"[result] {stats['completed']} requests, {stats['tokens']} tokens "
          f"in {stats['seconds']:.2f}s → {stats['tokens_per_s']:.1f} tok/s; "
          f"TTFT p50 {stats['ttft_p50_ms']:.1f} ms "
          f"p99 {stats['ttft_p99_ms']:.1f} ms")
    return 0


def run_fabric_serve(args, cfg, graph):
    """Partition-routed fleet: warm a multi-partition trainer, serve the
    query load through a ``ServingFabric`` (ownership routing + replicas
    + SLO admission), refresh weights from the live trainer mid-serving,
    then drive a saturating burst to show explicit shedding."""
    from repro.core.multipart import MultiPartitionTrainer
    from repro.serve.fabric import ServingFabric
    from repro.serve.gnn_engine import GNNRequest

    cfg = cfg.replace(partitions=args.partitions)
    tr = MultiPartitionTrainer(graph, cfg, seed=args.seed)
    tr.run_epochs(1, max_steps_per_epoch=args.train_steps)
    print(f"[train] {args.train_steps} steps over {args.partitions} "
          f"partitions warmed the planes: "
          f"cache_hit_rate={tr.cache_hit_rate:.3f}")

    fab = ServingFabric.from_trainer(tr, batch=args.batch,
                                     replicas=args.replicas,
                                     slo_p99_ms=args.slo_p99_ms,
                                     seed=args.seed,
                                     timeout_ms=args.serve_timeout_ms)
    # trigger each replica's one jit compile BEFORE timing anything: a
    # ~250 ms compile inside the first served queries would poison the
    # SLO scheduler's service estimate into shedding the real load
    for part in fab.engines:
        for eng in part:
            owned = np.flatnonzero(eng.node_map >= 0)
            for j, v in enumerate(owned[:eng.batch]):
                eng.submit(GNNRequest(rid=-1 - j, node=int(v)))
            eng.run_to_completion()
    fab.window.reset()
    warm_per_part = fab.partition_completed()

    rng = np.random.default_rng(args.seed)
    nodes = rng.choice(np.where(graph.test_mask)[0], size=args.queries,
                       replace=False)
    for rid, v in enumerate(nodes):
        fab.submit(GNNRequest(rid=rid, node=int(v)))
    stats = fab.run_to_completion()
    per_part = [a - b for a, b in zip(fab.partition_completed(),
                                      warm_per_part)]
    print(f"[fabric] {stats['completed']} queries in "
          f"{stats['seconds']:.2f}s → {stats['queries_per_s']:.1f} q/s "
          f"across {args.partitions}×{args.replicas} replicas "
          f"(per-partition {per_part}); latency p50 "
          f"{stats['p50_ms']:.1f} ms p99 {stats['p99_ms']:.1f} ms")

    # trainer → replica hand-off: swap every replica's tree between steps
    tr.global_step()
    fab.refresh_weights()
    fab.submit(GNNRequest(rid=args.queries, node=int(nodes[0])))
    fab.run_to_completion()
    print(f"[refresh] trainer step → refresh_weights() → re-query "
          f"pred={fab.completed[-1].pred} (served on the updated tree)")

    # saturating burst: the door sheds what it cannot serve inside the SLO
    burst = np.where(fab.plan.owner_of(
        np.arange(graph.num_nodes)) >= 0)[0][:args.queries * 8]
    mark = fab.slo.offered
    for rid, v in enumerate(burst):
        fab.submit(GNNRequest(rid=10_000 + rid, node=int(v)))
    fab.run_to_completion()
    offered = fab.slo.offered - mark
    print(f"[slo] burst of {offered} offered at target "
          f"{fab.slo.slo_p99_ms:.0f} ms: shed {fab.slo.shed} "
          f"(fraction {fab.shed_fraction:.2f}), deferrals "
          f"{fab.slo.deferrals} — degradation is explicit, not queued")
    return 0


def run_gnn_serve(args):
    """Online GNN inference: brief training warms the params AND the γ/Θ
    feature cache, then the SAME FeaturePlane instance serves node
    queries — shared hit/miss accounting proves the reuse — and a
    mid-serving ``FeatureStore.update_rows`` is reflected in the very
    next prediction."""
    from repro.configs import get_config
    from repro.core.a3gnn import A3GNNTrainer
    from repro.graph.storage import FeatureStore
    from repro.graph.synthetic import dataset_like
    from repro.serve.gnn_engine import GNNInferenceEngine, GNNRequest

    cfg = get_config(args.arch, smoke=args.smoke)
    if getattr(cfg, "family", None) != "gnn":
        raise SystemExit(f"--gnn serving needs a GNN arch "
                         f"(e.g. graphsage-products); {args.arch!r} is a "
                         f"{getattr(cfg, 'family', 'non-GNN')} config — "
                         f"drop --gnn for token-decode serving")
    if args.sampling_device:
        cfg = cfg.replace(sampling_device=args.sampling_device)
    graph = dataset_like(cfg, seed=args.seed)
    print(f"[data] {graph.name}: {graph.num_nodes} nodes, "
          f"{graph.num_edges} edges, {graph.num_classes} classes")

    if args.partitions > 1:
        return run_fabric_serve(args, cfg, graph)

    tr = A3GNNTrainer(graph, cfg, seed=args.seed)
    pipe = tr.make_pipeline()
    try:
        pipe.run(max_steps=args.train_steps)
    finally:
        pipe.shutdown()               # workers down; the plane stays live
    hits_trained = tr.cache.stats.hits if tr.cache else 0
    print(f"[train] {args.train_steps} steps warmed the cache: "
          f"{hits_trained} hits, "
          f"hit_rate={tr.cache_hit_rate:.3f}")

    eng = GNNInferenceEngine.from_trainer(tr, batch=args.batch,
                                          plane=pipe.plane, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    test_ids = np.where(graph.test_mask)[0]
    nodes = rng.choice(test_ids, size=args.queries, replace=True)
    for rid, v in enumerate(nodes):
        eng.submit(GNNRequest(rid=rid, node=int(v)))
    stats = eng.run_to_completion()
    print(f"[serve] {stats['completed']} queries in {stats['seconds']:.2f}s "
          f"→ {stats['queries_per_s']:.1f} q/s over "
          f"{stats['engine_steps']} engine steps "
          f"(batch={args.batch}, backend={eng.plane.backend}); "
          f"latency p50 {stats['p50_ms']:.1f} ms p99 {stats['p99_ms']:.1f} ms")
    if tr.cache is not None:
        print(f"[plane] shared with training: hits {hits_trained} → "
              f"{tr.cache.stats.hits} (serving added "
              f"{tr.cache.stats.hits - hits_trained}), "
              f"hit_rate={tr.cache.stats.hit_rate:.3f}")

    # streaming update mid-serving: the store fans the row out through the
    # plane (cache-resident copy + device-mirror invalidation), so the
    # re-query sees the drifted feature immediately
    store = FeatureStore(graph)
    eng.plane.subscribe_to(store)
    node = int(nodes[0])
    before = eng.completed[0].pred
    store.update_rows(np.array([node]),
                      np.full((1, graph.feat_dim), 1.0, np.float32))
    eng.submit(GNNRequest(rid=args.queries, node=node))
    eng.run_to_completion()
    after = eng.completed[-1].pred
    print(f"[stream] update_rows(node {node}) → store v{store.version}; "
          f"re-query pred {before} → {after} "
          f"(drift observed through the live plane)")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    # LM decode knobs
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    # GNN serving knobs
    ap.add_argument("--gnn", action="store_true",
                    help="serve online GNN node predictions through the "
                         "training-side FeaturePlane (serve/gnn_engine.py); "
                         "implied when --arch names a GNN config "
                         "(graphsage-*)")
    ap.add_argument("--queries", type=int, default=16,
                    help="node-prediction requests to serve (--gnn)")
    ap.add_argument("--train-steps", type=int, default=4,
                    help="brief training steps to warm params + cache "
                         "before serving (--gnn)")
    ap.add_argument("--sampling-device", default=None,
                    choices=[None, "cpu", "device", "auto"],
                    help="feature-plane backend for the serving gather")
    ap.add_argument("--partitions", type=int, default=1,
                    help="> 1 serves through the partition-routed "
                         "ServingFabric (serve/fabric.py) instead of one "
                         "engine (--gnn)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas per partition behind the "
                         "fabric's shared admission scheduler")
    ap.add_argument("--slo-p99-ms", type=float, default=50.0,
                    help="target p99 for SLO-aware admission (0 disables "
                         "shedding; fabric only)")
    ap.add_argument("--serve-timeout-ms", type=float, default=0.0,
                    help="per-request fabric timeout before retry-on-"
                         "another-replica (≤ 0 disables — the pre-seam "
                         "behavior; fabric only)")
    args = ap.parse_args()

    if args.gnn or args.arch.startswith("graphsage"):
        return run_gnn_serve(args)
    return run_lm_serve(args)


if __name__ == "__main__":
    raise SystemExit(main())
