"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Production topology: TPU v5e pods of 16×16=256
chips; multi-pod adds a leading ``pod`` axis (cross-pod traffic goes over
DCN — pure data parallelism with optional gradient compression).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke tests (axis names preserved)."""
    return jax.make_mesh((1, 1), ("data", "model"))


@dataclass(frozen=True)
class HostSimMesh:
    """Host-simulated device mesh for the multi-partition GNN path.

    When the process has fewer devices than partitions (the 1-CPU CI
    container), collectives cannot run as real shard_map programs; this
    stand-in carries the same (axis name, size) topology so the rest of the
    stack — distributed/collectives.grad_allreduce, core/multipart.py — is
    written against one mesh API and swaps in real devices transparently.
    """
    size: int
    axis: str = "part"

    @property
    def axis_names(self):
        return (self.axis,)

    @property
    def shape(self):
        return {self.axis: self.size}


def make_partition_mesh(num_partitions: int, axis: str = "part"):
    """1-D mesh over the data-parallel GNN partitions.

    Real ``Mesh`` over the first ``num_partitions`` devices when the host
    has enough of them; ``HostSimMesh`` otherwise (CI: 1 CPU device, any
    partition count)."""
    devices = jax.devices()
    if num_partitions <= len(devices):
        return Mesh(np.asarray(devices[:num_partitions]), (axis,))
    return HostSimMesh(num_partitions, axis)


# TPU v5e hardware constants (per chip) — used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link
    "hbm_bytes": 16 * 1024**3,   # 16 GiB
}
