"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Production topology: TPU v5e pods of 16×16=256
chips; multi-pod adds a leading ``pod`` axis (cross-pod traffic goes over
DCN — pure data parallelism with optional gradient compression).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke tests (axis names preserved)."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants (per chip) — used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link
    "hbm_bytes": 16 * 1024**3,   # 16 GiB
}
