"""Gradient compression for the slow cross-pod (DCN) data-parallel axis.

Two schemes, both with error feedback (residual carried to the next step so
compression error doesn't bias convergence):

  * int8 uniform quantization with per-tensor (or per-row) scales —
    4× volume reduction vs f32, 2× vs bf16
  * top-k sparsification — k·(4+4) bytes per tensor

``compressed_psum_int8`` is the shard_map building block: quantize locally,
all-reduce the int8 payload (as int32 accumulators to avoid overflow),
dequantize — this is what the multi-pod train step uses over the ``pod``
axis, cutting DCN bytes ~4× at the cost of one extra max-reduce for scales.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Quantization primitives
# ---------------------------------------------------------------------------

def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)).astype(jnp.float32) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def topk_sparsify(x: jnp.ndarray, frac: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Keep the top-``frac`` entries by magnitude; returns (values, flat idx)."""
    flat = x.reshape(-1)
    k = max(int(frac * flat.size), 1)
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def topk_densify(vals: jnp.ndarray, idx: jnp.ndarray, shape) -> jnp.ndarray:
    out = jnp.zeros(int(jnp.prod(jnp.array(shape))), vals.dtype)
    return out.at[idx].set(vals).reshape(shape)


# ---------------------------------------------------------------------------
# Error feedback
# ---------------------------------------------------------------------------

def ef_init(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress_int8(grads, residual):
    """(compressed-then-decompressed grads, new residual) with error feedback."""
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), corrected - deq
    pairs = jax.tree.map(one, grads, residual)
    return (jax.tree.map(lambda t: t[0], pairs,
                         is_leaf=lambda x: isinstance(x, tuple)),
            jax.tree.map(lambda t: t[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple)))


def ef_compress_topk(grads, residual, frac: float = 0.05):
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        vals, idx = topk_sparsify(corrected, frac)
        dense = topk_densify(vals, idx, corrected.shape)
        return dense.astype(g.dtype), corrected - dense
    pairs = jax.tree.map(one, grads, residual)
    return (jax.tree.map(lambda t: t[0], pairs,
                         is_leaf=lambda x: isinstance(x, tuple)),
            jax.tree.map(lambda t: t[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple)))


# ---------------------------------------------------------------------------
# shard_map collective: int8 all-reduce over a named axis
# ---------------------------------------------------------------------------

def compressed_psum_int8(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Mean-reduce ``x`` over ``axis_name`` with int8 payload.

    Wire format per tensor: int8 payload (psum'd as int32) + f32 scale
    (max-reduced).  ~4× fewer DCN bytes than f32 ring all-reduce.
    Call inside shard_map with ``axis_name`` bound (e.g. "pod").
    """
    n = jax.lax.psum(1, axis_name)
    scale = jnp.max(jnp.abs(x)).astype(jnp.float32) / 127.0 + 1e-12
    scale = jax.lax.pmax(scale, axis_name)            # shared scale
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return (acc.astype(jnp.float32) * scale / n).astype(x.dtype)


def make_crosspod_grad_transform(mesh, kind: str = "int8"):
    """grad_transform hook for make_train_step: reduce grads over the pod
    axis with compression (shard_map over 'pod'; other axes untouched)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    if "pod" not in mesh.axis_names:
        return None

    def transform(grads):
        def red(g):
            fn = shard_map(
                lambda t: compressed_psum_int8(t, "pod"),
                mesh=mesh,
                in_specs=P(*((None,) * g.ndim)),
                out_specs=P(*((None,) * g.ndim)),
                check_rep=False)
            return fn(g)
        return jax.tree.map(red, grads)

    return transform
