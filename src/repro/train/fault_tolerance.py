"""Fault tolerance: heartbeats, straggler mitigation, checkpoint/restart.

Three layers (all exercised by tests/test_fault_tolerance.py):

  * ``HeartbeatMonitor`` — workers stamp a shared table; the monitor flags
    silent workers after ``timeout`` (node-death detection at pipeline level;
    core/pipeline.py re-issues their work items to a spare sampler).
  * ``StragglerMitigator`` — tracks per-task latency; tasks exceeding
    k × running-median are speculatively duplicated, first finisher wins
    (classic backup-requests; applied to host-side sampling/batch-gen).
  * ``TrainSupervisor`` — wraps the device train loop: periodic checkpoints
    (train/checkpoint.py), on failure restores the latest committed step and
    resumes; supports elastic restart onto a smaller mesh (the checkpoint
    manager reshards on load).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.train.checkpoint import CheckpointManager


class HeartbeatMonitor:
    def __init__(self, n_workers: int, timeout: float = 5.0):
        self.table = {w: time.time() for w in range(n_workers)}
        self.timeout = timeout
        self._lock = threading.Lock()

    def beat(self, worker: int):
        with self._lock:
            self.table[worker] = time.time()

    def mark_dead(self, worker: int):
        with self._lock:
            self.table[worker] = -1.0

    def dead_workers(self) -> List[int]:
        now = time.time()
        with self._lock:
            return [w for w, t in self.table.items()
                    if t < 0 or now - t > self.timeout]

    def alive(self) -> List[int]:
        dead = set(self.dead_workers())
        return [w for w in self.table if w not in dead]


class StragglerMitigator:
    """Backup-request policy: duplicate tasks slower than k× median."""

    def __init__(self, factor: float = 3.0, min_history: int = 5):
        self.factor = factor
        self.min_history = min_history
        self.durations: List[float] = []
        self._lock = threading.Lock()

    def record(self, duration: float):
        with self._lock:
            self.durations.append(duration)

    def median(self) -> float:
        with self._lock:
            if not self.durations:
                return float("inf")
            return float(np.median(self.durations))

    def is_straggling(self, elapsed: float) -> bool:
        if len(self.durations) < self.min_history:
            return False
        return elapsed > self.factor * self.median()

    def run_speculative(self, fn: Callable[[], Any],
                        elapsed_provider: Optional[Callable[[], float]] = None):
        """Run fn; if it exceeds the straggler bound, race a duplicate.
        (Thread-based — fn must be re-executable / idempotent.)"""
        result: Dict[str, Any] = {}
        done = threading.Event()

        def runner(tag):
            t0 = time.perf_counter()
            try:
                r = fn()
            except Exception as e:  # noqa: BLE001
                r = e
            if not done.is_set():
                result.setdefault("value", r)
                result.setdefault("winner", tag)
                done.set()
            self.record(time.perf_counter() - t0)

        t1 = threading.Thread(target=runner, args=("primary",), daemon=True)
        t1.start()
        bound = self.factor * self.median() if len(self.durations) >= self.min_history else None
        if bound is not None and bound != float("inf"):
            if not done.wait(timeout=bound):
                t2 = threading.Thread(target=runner, args=("backup",), daemon=True)
                t2.start()
        done.wait()
        v = result["value"]
        if isinstance(v, Exception):
            raise v
        return v, result["winner"]


@dataclass
class SupervisorReport:
    steps_run: int = 0
    failures: int = 0
    restores: int = 0
    checkpoints: int = 0
    final_step: int = 0


class TrainSupervisor:
    """Checkpoint/restart driver around an arbitrary step function.

    ``step_fn(state, step) -> state`` may raise (simulated node failure /
    real OOM); the supervisor restores the latest committed checkpoint and
    resumes.  ``max_restarts`` bounds the retry loop.
    """

    def __init__(self, ckpt: CheckpointManager, ckpt_every: int = 10,
                 max_restarts: int = 3,
                 extra_fn: Optional[Callable[[], Dict]] = None):
        self.ckpt = ckpt
        self.every = ckpt_every
        self.max_restarts = max_restarts
        # attached to every checkpoint manifest (e.g. the multi-partition
        # trainer records its partition topology + cache hit accounting)
        self.extra_fn = extra_fn

    def run(self, state: Dict[str, Any], step_fn: Callable[[Dict, int], Dict],
            n_steps: int, start_step: int = 0,
            shardings: Optional[Dict] = None) -> tuple[Dict, SupervisorReport]:
        rep = SupervisorReport()
        step = start_step
        restarts = 0
        while step < n_steps:
            try:
                state = step_fn(state, step)
                rep.steps_run += 1
                step += 1
                if step % self.every == 0 or step == n_steps:
                    self.ckpt.save(step, state,
                                   extra=(self.extra_fn()
                                          if self.extra_fn else None))
                    rep.checkpoints += 1
            except Exception:  # noqa: BLE001 — node failure path
                rep.failures += 1
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:
                    step = start_step     # nothing committed yet: restart cold
                    continue
                state, step = self.ckpt.restore(state, latest,
                                                shardings=shardings)
                rep.restores += 1
        self.ckpt.wait()
        rep.final_step = step
        return state, rep
