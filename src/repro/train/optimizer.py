"""Optimizers in pure JAX, declared abstractly.

Each optimizer exposes:
  * ``state_decls(param_decls)`` — pytree of ParamDecl mirroring the params
    (so the AOT dry-run can shard & size optimizer memory without allocating)
  * ``init(params)``             — concrete state
  * ``update(grads, state, params, lr)`` — (updates, new_state)

Optimizer state inherits each parameter's sharding (ZeRO: fully sharded).
``adafactor`` factors the second moment over the last two axes — the only
option that fits a 1T-param model on 256 chips (see kimi-k2 config).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.params import ParamDecl, tree_map_decls


def _mirror(d: ParamDecl, dtype=jnp.float32) -> ParamDecl:
    return ParamDecl(d.shape, dtype, d.axes, "zeros")


def _is_decl(x):
    return isinstance(x, ParamDecl)


class Optimizer(NamedTuple):
    name: str
    state_decls: Callable[[Any], Any]
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, Any], Tuple[Any, Any]]


def _count_decl() -> ParamDecl:
    return ParamDecl((), jnp.int32, (), "zeros")


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def make_adamw(b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0) -> Optimizer:
    def state_decls(decls):
        return {"m": tree_map_decls(_mirror, decls),
                "v": tree_map_decls(_mirror, decls),
                "count": _count_decl()}

    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"m": z, "v": jax.tree.map(jnp.copy, z),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        c = state["count"] + 1
        cf = c.astype(jnp.float32)
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** cf
        bc2 = 1 - b2 ** cf

        def upd(m, v, p):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype)

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"m": m, "v": v, "count": c}

    return Optimizer("adamw", state_decls, init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; no first moment)
# ---------------------------------------------------------------------------

def _factored(d: ParamDecl) -> bool:
    return len(d.shape) >= 2 and d.shape[-1] > 1 and d.shape[-2] > 1


def make_adafactor(b2=0.99, eps=1e-30, clip_rms=1.0) -> Optimizer:
    def state_decls(decls):
        def one(d: ParamDecl):
            if _factored(d):
                return {"vr": ParamDecl(d.shape[:-1], jnp.float32,
                                        d.axes[:-1], "zeros"),
                        "vc": ParamDecl(d.shape[:-2] + d.shape[-1:], jnp.float32,
                                        d.axes[:-2] + d.axes[-1:], "zeros")}
            return {"v": _mirror(d)}
        return {"fac": tree_map_decls(one, decls), "count": _count_decl()}

    def init(params):
        def one(p):
            if p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"fac": jax.tree.map(one, params), "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        c = state["count"] + 1

        def upd(s, g, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if "vr" in s:
                vr = b2 * s["vr"] + (1 - b2) * g2.mean(-1)
                vc = b2 * s["vc"] + (1 - b2) * g2.mean(-2)
                rfac = vr / jnp.maximum(vr.mean(-1, keepdims=True), eps)
                denom = jnp.sqrt(rfac[..., None] * vc[..., None, :])
                u = g32 / jnp.maximum(denom, eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = b2 * s["v"] + (1 - b2) * g2
                u = g32 / (jnp.sqrt(v) + 1e-8)
                new_s = {"v": v}
            # update-RMS clipping (Adafactor's d=1.0 rule)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_rms)
            return (-lr * u).astype(p.dtype), new_s

        flat = jax.tree.map(upd, state["fac"], grads, params,
                            is_leaf=lambda x: isinstance(x, dict)
                            and ("vr" in x or "v" in x))
        updates = jax.tree.map(lambda t: t[0], flat,
                               is_leaf=lambda x: isinstance(x, tuple))
        new_fac = jax.tree.map(lambda t: t[1], flat,
                               is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"fac": new_fac, "count": c}

    return Optimizer("adafactor", state_decls, init, update)


# ---------------------------------------------------------------------------
# SGD(+momentum), Lion
# ---------------------------------------------------------------------------

def make_sgd(momentum=0.9) -> Optimizer:
    def state_decls(decls):
        return {"mu": tree_map_decls(_mirror, decls), "count": _count_decl()}

    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                   params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                          state["mu"], grads)
        updates = jax.tree.map(lambda m, p: (-lr * m).astype(p.dtype), mu, params)
        return updates, {"mu": mu, "count": state["count"] + 1}

    return Optimizer("sgd", state_decls, init, update)


def make_lion(b1=0.9, b2=0.99, weight_decay=0.0) -> Optimizer:
    def state_decls(decls):
        return {"m": tree_map_decls(_mirror, decls), "count": _count_decl()}

    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        def upd(m, g, p):
            g32 = g.astype(jnp.float32)
            u = jnp.sign(b1 * m + (1 - b1) * g32)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype)
        updates = jax.tree.map(upd, state["m"], grads, params)
        m = jax.tree.map(lambda m, g: b2 * m + (1 - b2) * g.astype(jnp.float32),
                         state["m"], grads)
        return updates, {"m": m, "count": state["count"] + 1}

    return Optimizer("lion", state_decls, init, update)


def get_optimizer(cfg) -> Optimizer:
    name = getattr(cfg, "optimizer", "adamw")
    wd = getattr(cfg, "weight_decay", 0.0)
    if name == "adamw":
        return make_adamw(weight_decay=wd)
    if name == "adafactor":
        return make_adafactor()
    if name == "sgd":
        return make_sgd()
    if name == "lion":
        return make_lion(weight_decay=wd)
    raise ValueError(f"unknown optimizer {name!r}")
