"""Training-step construction: grads → clip → optimizer → apply.

``make_train_step`` builds the jit-able (params, opt_state, batch) →
(params, opt_state, metrics) function used by the real training loop, the
multi-pod dry-run, and the benchmarks.  Gradient-accumulation and the
cross-pod gradient-compression hook live here too.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.unroll import scan as uscan
from repro.train.optimizer import Optimizer, get_optimizer


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gnorm


def make_train_step(model, cfg, opt: Optional[Optimizer] = None,
                    grad_accum: int = 1,
                    grad_transform: Optional[Callable] = None):
    """grad_transform: optional (grads -> grads) hook — e.g. cross-pod
    compressed all-reduce (train/compression.py)."""
    opt = opt or get_optimizer(cfg)

    def compute_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if grad_accum > 1:
            # split the batch leading dim into microbatches and lax.scan
            def micro(carry, mb):
                loss, metrics, grads = compute_grads(params, mb)
                acc = jax.tree.map(jnp.add, carry[0], grads)
                return (acc, carry[1] + loss), None

            def reshape_mb(x):
                return x.reshape(grad_accum, x.shape[0] // grad_accum,
                                 *x.shape[1:])

            mbs = jax.tree.map(reshape_mb, batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            (gsum, lsum), _ = uscan(micro, (zero, jnp.float32(0)), mbs)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            loss = lsum / grad_accum
            metrics = {"loss": loss, "aux": jnp.float32(0)}
        else:
            loss, metrics, grads = compute_grads(params, batch)

        if grad_transform is not None:
            grads = grad_transform(grads)
        if cfg.grad_clip:
            grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        else:
            gnorm = global_norm(grads)
        updates, opt_state = opt.update(grads, opt_state, params,
                                        cfg.learning_rate)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype), params,
                              updates)
        out_metrics = {"loss": loss, "grad_norm": gnorm, **metrics}
        return params, opt_state, out_metrics

    return train_step, opt
