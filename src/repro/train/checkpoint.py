"""Sharded, atomic, async checkpointing with elastic restore.

Layout per step::

    <dir>/step_000123/
        MANIFEST.json     tree structure, shapes, dtypes, mesh, spec per leaf
        shard_<host>.npz  this host's param/opt shards
        _COMMITTED        written last — restore ignores uncommitted dirs

Features required at 1000+-node scale:
  * atomic commit (tmp dir + rename + commit marker) — a preempted writer
    never corrupts the latest checkpoint
  * keep-k garbage collection
  * async save (background thread; the train loop donates nothing — arrays
    are snapshotted to host first)
  * ELASTIC restore: the target mesh/sharding may differ from the saved one;
    leaves are loaded full-size and resharded via make_array_from_callback,
    so restarting 512→256 chips (or CPU) after a pod loss "just works".
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_path(tree):
    # jax.tree.flatten_with_path only exists from jax 0.4.x+ (0.6 moved it
    # onto jax.tree); fall back to the stable tree_util spelling.
    fn = getattr(jax.tree, "flatten_with_path", None)
    if fn is None:
        fn = jax.tree_util.tree_flatten_with_path
    return fn(tree)


def _flatten_with_names(tree):
    flat, treedef = _flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out[name] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3,
                 async_save: bool = True, host_id: int = 0):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self.host_id = host_id
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any],
             extra: Optional[Dict] = None):
        """state: {"params": ..., "opt_state": ...} (any pytree dict)."""
        # snapshot to host (so donation/mutation cannot race the writer)
        host_state = jax.tree.map(lambda a: np.asarray(a), state)
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_state, extra), daemon=True)
            self._thread.start()
        else:
            # synchronous save: surface writer errors immediately instead of
            # parking them for a wait() that may never come
            self._write(step, host_state, extra)
            if self._error is not None:
                err, self._error = self._error, None
                raise err

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ------------------------------------------------------------------
    def _write(self, step: int, host_state, extra):
        try:
            final = self.dir / f"step_{step:09d}"
            tmp = self.dir / f".tmp_step_{step:09d}"
            # the target dir may not exist yet on first save (or may have
            # been removed between construction and save)
            self.dir.mkdir(parents=True, exist_ok=True)
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {"step": step, "extra": extra or {}, "leaves": {},
                        "time": time.time()}
            arrays = {}
            for group, tree in host_state.items():
                named, _ = _flatten_with_names(tree)
                for name, leaf in named.items():
                    key = f"{group}/{name}"
                    arrays[key.replace('/', '__')] = leaf
                    manifest["leaves"][key] = {"shape": list(np.shape(leaf)),
                                               "dtype": str(np.asarray(leaf).dtype)}
            np.savez(tmp / f"shard_{self.host_id}.npz", **arrays)
            (tmp / "MANIFEST.json").write_text(json.dumps(manifest))
            (tmp / "_COMMITTED").write_text("ok")
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "_COMMITTED").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def read_manifest(self, step: Optional[int] = None) -> Dict[str, Any]:
        """Manifest of a committed step (tree metadata + the ``extra`` dict
        the writer attached — e.g. partition topology and cache accounting
        for the multi-partition GNN restore path)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        return json.loads(
            (self.dir / f"step_{step:09d}" / "MANIFEST.json").read_text())

    # ------------------------------------------------------------------
    def restore(self, template: Dict[str, Any], step: Optional[int] = None,
                shardings: Optional[Dict[str, Any]] = None
                ) -> Tuple[Dict[str, Any], int]:
        """Restore into the structure of ``template`` (arrays or
        ShapeDtypeStructs).  ``shardings``: matching pytree of NamedSharding
        for elastic placement onto the CURRENT mesh (may differ from the
        mesh at save time)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        d = self.dir / f"step_{step:09d}"
        data = {}
        for shard in sorted(d.glob("shard_*.npz")):
            with np.load(shard) as z:
                for k in z.files:
                    data[k] = z[k]

        out = {}
        for group, tree in template.items():
            named, treedef = _flatten_with_names(tree)
            leaves = []
            for name, leaf in named.items():
                key = f"{group}/{name}".replace("/", "__")
                if key not in data:
                    raise KeyError(f"checkpoint missing leaf {group}/{name}")
                arr = data[key]
                want_shape = tuple(leaf.shape)
                if tuple(arr.shape) != want_shape:
                    raise ValueError(f"shape mismatch for {group}/{name}: "
                                     f"ckpt {arr.shape} vs target {want_shape}")
                if shardings is not None:
                    sh = _lookup_named(shardings[group], name)
                    arr = jax.make_array_from_callback(
                        want_shape, sh, lambda idx, a=arr: a[idx])
                else:
                    arr = jnp.asarray(arr)
                leaves.append(arr)
            out[group] = jax.tree.unflatten(treedef, leaves)
        return out, step


def _lookup_named(tree, name: str):
    node = tree
    for part in name.split("/"):
        if isinstance(node, (list, tuple)):
            node = node[int(part)]
        else:
            node = node[part]
    return node


class TrainerCheckpointMixin:
    """Shared checkpoint/restore contract for the GNN trainers (single- and
    multi-partition, core/a3gnn.py and core/multipart.py).

    Expects ``self.params``, ``self.opt_state`` and ``self.cfg.partitions``;
    subclasses extend ``checkpoint_extra`` (manifest payload) and
    ``_after_restore`` (e.g. cache hit-accounting).  A checkpoint written
    under a different partition count is REJECTED unless the caller
    explicitly acknowledges the migration (``expect_partitions`` = the
    saved count — the autotune restart path does exactly that after
    rebuilding the trainer)."""

    def state_dict(self) -> Dict[str, Any]:
        return {"params": self.params, "opt_state": self.opt_state}

    def load_state_dict(self, state: Dict[str, Any]):
        self.params = state["params"]
        self.opt_state = state["opt_state"]

    def checkpoint_extra(self) -> Dict[str, Any]:
        return {"partitions": int(self.cfg.partitions),
                "global_steps": int(getattr(self, "global_steps", 0))}

    def save(self, ckpt: "CheckpointManager", step: Optional[int] = None):
        ckpt.save(step if step is not None
                  else int(getattr(self, "global_steps", 0)),
                  self.state_dict(), extra=self.checkpoint_extra())

    def restore(self, ckpt: "CheckpointManager", step: Optional[int] = None,
                expect_partitions: Optional[int] = None) -> int:
        step = step if step is not None else ckpt.latest_step()
        extra = ckpt.read_manifest(step).get("extra") or {}
        saved_parts = extra.get("partitions")
        want = (expect_partitions if expect_partitions is not None
                else int(self.cfg.partitions))
        if saved_parts is not None and int(saved_parts) != int(want):
            raise ValueError(
                f"checkpoint step {step} was written with "
                f"partitions={saved_parts}, but this trainer runs "
                f"partitions={self.cfg.partitions}; rebuild the trainer "
                f"with partitions={saved_parts}, or pass "
                f"expect_partitions={saved_parts} to migrate through the "
                f"restart path (checkpoint → rebuild → restore)")
        state, step = ckpt.restore(self.state_dict(), step)
        self.load_state_dict(state)
        self._after_restore(extra, step)
        return step

    def _after_restore(self, extra: Dict[str, Any], step: int):
        pass
