"""Host-side LM data pipeline with the paper's pipeline modes applied.

The A³GNN insight that transfers to the LM stack (DESIGN.md
§Arch-applicability): the host data path (sample → batch-generate) and the
device step can be scheduled sequentially or overlapped with n workers —
same throughput/memory trade as §III-B.  ``PrefetchLoader`` implements
mode-1 style overlap (bounded queue = device double buffer); ``workers=0``
degrades to the sequential mode.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np


class SyntheticTokens:
    """Deterministic synthetic LM batches (zipfian token distribution)."""

    def __init__(self, vocab_size: int, batch: int, seq: int, seed: int = 0,
                 n_batches: int = 1_000_000):
        self.vocab, self.batch, self.seq = vocab_size, batch, seq
        self.seed = seed
        self.n_batches = n_batches
        ranks = np.arange(1, min(vocab_size, 65536) + 1, dtype=np.float64)
        p = 1.0 / ranks
        self.p = p / p.sum()
        self.support = len(ranks)

    def __len__(self):
        return self.n_batches

    def make(self, i: int) -> dict:
        rng = np.random.default_rng(self.seed * 100003 + i)
        toks = rng.choice(self.support, size=(self.batch, self.seq + 1),
                          p=self.p).astype(np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def __iter__(self):
        for i in range(self.n_batches):
            yield self.make(i)


class PrefetchLoader:
    """n-worker prefetch with a bounded queue (parallel mode 1 for tokens)."""

    def __init__(self, dataset, workers: int = 2, depth: int = 4):
        self.ds = dataset
        self.workers = workers
        self.depth = depth

    def __iter__(self) -> Iterator[dict]:
        if self.workers <= 0:
            yield from self.ds
            return
        q: queue.Queue = queue.Queue(maxsize=self.depth)
        n = len(self.ds)

        def worker(wid):
            for i in range(wid, n, self.workers):
                q.put((i, self.ds.make(i)))
            q.put((None, None))

        threads = [threading.Thread(target=worker, args=(w,), daemon=True)
                   for w in range(self.workers)]
        for t in threads:
            t.start()
        finished = 0
        buf = {}
        want = 0
        while finished < self.workers:
            i, b = q.get()
            if i is None:
                finished += 1
                continue
            buf[i] = b
            while want in buf:                 # restore deterministic order
                yield buf.pop(want)
                want += 1
        while want in buf:
            yield buf.pop(want)
            want += 1
