"""Minitron-8B — depth/width-pruned Nemotron [arXiv:2407.14679; hf]."""
from repro.configs.base import ModelConfig, register


@register("minitron-8b")
def minitron_8b(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="minitron-8b-smoke", family="dense", num_layers=2,
            d_model=64, num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
            attn_chunk=0, loss_chunk=0, remat="none")
    return ModelConfig(
        name="minitron-8b", family="dense", num_layers=32,
        d_model=4096, num_heads=32, num_kv_heads=8, d_ff=16384,
        vocab_size=256000, head_dim=128,
        attn_chunk=1024, loss_chunk=0, remat="dots",
        notes="GQA kv=8 (indivisible by model axis 16 → KV weights/cache "
              "replicated over TP, q-heads sharded; Megatron-style).")
