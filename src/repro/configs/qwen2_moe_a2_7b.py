"""Qwen1.5/2-MoE-A2.7B — 60 routed (top-4) + 4 shared experts
[hf:Qwen/Qwen1.5-MoE-A2.7B].  Experts padded 60→64 so EP=16 divides; the
router masks the pads."""
from repro.configs.base import ModelConfig, register


@register("qwen2-moe-a2.7b")
def qwen2_moe(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="qwen2-moe-smoke", family="moe", num_layers=2,
            d_model=64, num_heads=4, num_kv_heads=4, d_ff=96, vocab_size=256,
            num_experts=6, num_experts_padded=8, moe_top_k=2,
            num_shared_experts=4, shared_expert_ff=192,
            attn_chunk=0, loss_chunk=0, remat="none")
    return ModelConfig(
        name="qwen2-moe-a2.7b", family="moe", num_layers=24,
        d_model=2048, num_heads=16, num_kv_heads=16, d_ff=1408,
        vocab_size=151936, head_dim=128,
        num_experts=60, num_experts_padded=64, moe_top_k=4,
        num_shared_experts=4, shared_expert_ff=5632, capacity_factor=1.25,
        attn_chunk=1024, loss_chunk=0, remat="dots",
        notes="shared_expert_ff=4*1408=5632 (fused shared experts).")
