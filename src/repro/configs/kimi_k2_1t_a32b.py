"""Kimi K2 — trillion-param MoE, 384 experts top-8 [arXiv:2501.kimi2; unverified].

Assignment treats attention as GQA kv=8 (the release uses MLA; noted in
DESIGN.md §Arch-applicability).  1T total / ~32B active parameters.
Memory-critical settings: bf16 params + adafactor (factored second moment)
+ full remat — f32 Adam for 1T params cannot fit 256×16 GB HBM.
"""
from repro.configs.base import ModelConfig, register


@register("kimi-k2-1t-a32b")
def kimi_k2(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="kimi-k2-smoke", family="moe", num_layers=2,
            d_model=64, num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=256,
            num_experts=6, num_experts_padded=8, moe_top_k=2,
            num_shared_experts=1, shared_expert_ff=96,
            attn_chunk=0, loss_chunk=0, remat="none")
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe", num_layers=61,
        d_model=7168, num_heads=64, num_kv_heads=8, d_ff=2048,
        vocab_size=163840, head_dim=112,
        num_experts=384, num_experts_padded=384, moe_top_k=8,
        num_shared_experts=1, shared_expert_ff=2048, capacity_factor=1.25,
        param_dtype="bfloat16", optimizer="adafactor",
        attn_chunk=1024, loss_chunk=1024, remat="full",
        notes="~1.03e12 total params (61L·384e·3·7168·2048), ~32B active.")
