"""GLM4-9B — RoPE, extreme GQA (kv=2) [hf:THUDM/glm-4-9b]."""
from repro.configs.base import ModelConfig, register


@register("glm4-9b")
def glm4_9b(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="glm4-9b-smoke", family="dense", num_layers=2,
            d_model=64, num_heads=8, num_kv_heads=2, d_ff=128, vocab_size=256,
            attn_chunk=0, loss_chunk=0, remat="none")
    return ModelConfig(
        name="glm4-9b", family="dense", num_layers=40,
        d_model=4096, num_heads=32, num_kv_heads=2, d_ff=13696,
        vocab_size=151552, head_dim=128,
        attn_chunk=1024, loss_chunk=0, remat="dots",
        notes="kv=2: KV replicated over TP; decode cache sequence-sharded "
              "(kv_shard auto → sequence).")
