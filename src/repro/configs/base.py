"""Model / run configuration system.

Every assigned architecture gets one file in this package defining a
``ModelConfig`` at FULL scale (used only by the AOT dry-run — no allocation)
plus a ``smoke()`` reduced config of the same family for CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """A single composable description for all supported model families.

    family:
      dense   — decoder-only transformer (GQA, RoPE, optional qk-norm)
      moe     — decoder-only with routed-expert MLPs (+ shared experts)
      ssm     — attention-free Mamba2 (SSD) stack
      hybrid  — Mamba2 backbone + a weight-shared attention block (Zamba2)
      encdec  — encoder-decoder transformer (Whisper-style, frontend stubbed)
      vlm     — decoder-only with M-RoPE, patch embeddings stubbed
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0                  # 0 -> d_model // num_heads
    mlp_type: str = "swiglu"           # swiglu | relu2 | gelu
    qk_norm: bool = False
    rope_theta: float = 10000.0
    use_rope: bool = True
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # --- MoE ---
    num_experts: int = 0
    num_experts_padded: int = 0        # padded so EP axis divides (0 -> num_experts)
    moe_top_k: int = 0
    num_shared_experts: int = 0
    shared_expert_ff: int = 0          # fused shared-expert hidden dim
    capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0                 # N, state dim per head
    ssm_head_dim: int = 64             # P
    ssm_expand: int = 2                # d_inner = expand * d_model
    ssm_conv_width: int = 4
    ssm_chunk: int = 256               # SSD chunk length

    # --- hybrid (Zamba2) ---
    shared_attn_every: int = 6         # invoke the shared block every k ssm layers

    # --- encoder-decoder ---
    encoder_layers: int = 0
    encoder_seq: int = 0               # fixed frontend length (e.g. 1500 audio frames)

    # --- frontend stubs ---
    frontend: str = "none"             # none | audio | vision
    mrope_sections: Tuple[int, ...] = ()  # M-RoPE half-dim split (t, h, w)
    max_seq: int = 32768               # learned-pos-emb table size (no-rope archs)

    # --- numerics / perf knobs (threaded to the step functions) ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "none"                # none | dots | full
    scan_layers: bool = True
    attn_chunk: int = 0                # 0 -> plain attention; >0 -> chunked (flash-style)
    loss_chunk: int = 0                # 0 -> whole-seq loss; >0 -> chunked xent
    use_pallas: bool = False           # TPU kernel path (dry-run uses XLA-native)
    optimizer: str = "adamw"           # see train/optimizer.py
    learning_rate: float = 3e-4
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    # sharding policy knobs (see distributed/sharding.py)
    kv_shard: str = "auto"             # auto | heads | sequence | replicated
    shard_experts_fsdp: bool = True    # second-axis FSDP sharding of expert weights
    grad_accum: int = 1                # microbatches per step (memory knob)
    fsdp_params: bool = True           # ZeRO-3 param sharding over data;
                                       # False = TP-only (serving profile)
    pad_head_groups: bool = False      # zero-pad q-heads per kv group so the
                                       # flat head count divides the TP axis

    notes: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        if self.num_experts and not self.num_experts_padded:
            object.__setattr__(self, "num_experts_padded", self.num_experts)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter counting (for MODEL_FLOPS and memory budgeting) -------
    def param_count(self) -> int:
        D, H, Hkv, Dh, F, V = (self.d_model, self.num_heads, self.num_kv_heads,
                               self.head_dim, self.d_ff, self.vocab_size)
        emb = V * D * (1 if self.tie_embeddings else 2)
        attn = D * H * Dh + 2 * D * Hkv * Dh + H * Dh * D
        if self.mlp_type == "swiglu":
            mlp = 3 * D * F
        else:
            mlp = 2 * D * F
        if self.family == "ssm":
            per = self._ssm_params()
            return emb + self.num_layers * per
        if self.family == "hybrid":
            per = self._ssm_params()
            shared = attn + 3 * D * self.d_ff + 2 * D * D  # shared block + in/out proj
            return emb + self.num_layers * per + shared
        if self.family == "encdec":
            enc = self.encoder_layers * (attn + mlp)
            dec = self.num_layers * (attn + attn + mlp)  # self + cross
            return emb + enc + dec
        if self.is_moe:
            expert = 3 * D * F * self.num_experts
            shared = 3 * D * self.shared_expert_ff if self.shared_expert_ff else 0
            router = D * self.num_experts
            per = attn + expert + shared + router
            return emb + self.num_layers * per
        return emb + self.num_layers * (attn + mlp)

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: top-k + shared only)."""
        if not self.is_moe:
            if self.family == "hybrid":
                return self.param_count()  # shared block reused; all params active
            return self.param_count()
        D, F = self.d_model, self.d_ff
        H, Hkv, Dh = self.num_heads, self.num_kv_heads, self.head_dim
        attn = D * H * Dh + 2 * D * Hkv * Dh + H * Dh * D
        expert_active = 3 * D * F * self.moe_top_k
        shared = 3 * D * self.shared_expert_ff if self.shared_expert_ff else 0
        router = D * self.num_experts
        emb = self.vocab_size * D * (1 if self.tie_embeddings else 2)
        return emb + self.num_layers * (attn + expert_active + shared + router)

    def _ssm_params(self) -> int:
        D = self.d_model
        d_inner = self.ssm_expand * D
        nheads = d_inner // self.ssm_head_dim
        N = self.ssm_state
        conv_dim = d_inner + 2 * N * nheads if False else d_inner + 2 * N
        # in_proj: [D, 2*d_inner + 2*groups*N + nheads]; out_proj [d_inner, D]
        in_proj = D * (2 * d_inner + 2 * N + nheads)
        conv = self.ssm_conv_width * (d_inner + 2 * N)
        out_proj = d_inner * D
        extra = nheads * 3  # A_log, D, dt_bias
        return in_proj + conv + out_proj + extra


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def applicable_shapes(cfg: ModelConfig) -> List[ShapeConfig]:
    """long_500k needs sub-quadratic attention -> SSM/hybrid only."""
    shapes = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.family in ("ssm", "hybrid"):
        shapes.append(LONG_500K)
    return shapes


_REGISTRY: Dict[str, Any] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    import repro.configs as _pkg  # noqa: F401  (triggers arch module imports)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name](smoke=smoke)


def list_archs() -> List[str]:
    import repro.configs as _pkg  # noqa: F401
    return sorted(_REGISTRY)
