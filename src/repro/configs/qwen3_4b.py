"""Qwen3-4B — qk-norm + GQA [hf:Qwen/Qwen3-4B]."""
from repro.configs.base import ModelConfig, register


@register("qwen3-4b")
def qwen3_4b(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="qwen3-4b-smoke", family="dense", num_layers=2,
            d_model=64, num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
            qk_norm=True, attn_chunk=0, loss_chunk=0, remat="none")
    return ModelConfig(
        name="qwen3-4b", family="dense", num_layers=36,
        d_model=2560, num_heads=32, num_kv_heads=8, d_ff=9728,
        vocab_size=151936, head_dim=128, qk_norm=True, rope_theta=1000000.0,
        tie_embeddings=True,
        attn_chunk=1024, loss_chunk=0, remat="dots",
        notes="qk-norm RMSNorm on per-head q/k (Qwen3).")
