"""Llama-3.2-3B — small Llama3 [hf:meta-llama/Llama-3.2-3B; unverified]."""
from repro.configs.base import ModelConfig, register


@register("llama3.2-3b")
def llama3_2_3b(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="llama3.2-3b-smoke", family="dense", num_layers=2,
            d_model=64, num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
            attn_chunk=0, loss_chunk=0, remat="none", rope_theta=500000.0)
    return ModelConfig(
        name="llama3.2-3b", family="dense", num_layers=28,
        d_model=3072, num_heads=24, num_kv_heads=8, d_ff=8192,
        vocab_size=128256, head_dim=128, rope_theta=500000.0,
        tie_embeddings=True,
        attn_chunk=1024, loss_chunk=0, remat="dots",
        notes="24 q-heads indivisible by model axis 16 → attention runs "
              "FSDP-style (batch-sharded activations, ZeRO-gathered weights); "
              "MLP stays TP (8192 % 16 == 0).")
