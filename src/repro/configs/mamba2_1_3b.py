"""Mamba2-1.3B — SSD (state-space duality), attention-free [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig, register


@register("mamba2-1.3b")
def mamba2_1_3b(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="mamba2-smoke", family="ssm", num_layers=2,
            d_model=64, num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=256,
            ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=32,
            use_rope=False, attn_chunk=0, loss_chunk=0, remat="none")
    return ModelConfig(
        name="mamba2-1.3b", family="ssm", num_layers=48,
        d_model=2048, num_heads=0, num_kv_heads=0, d_ff=0,
        vocab_size=50280, use_rope=False,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
        loss_chunk=0, remat="dots",
        notes="attention-free; long_500k RUNS (O(1) decode state). "
              "64 SSD heads sharded over TP.")
