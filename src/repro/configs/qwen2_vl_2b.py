"""Qwen2-VL-2B — M-RoPE, dynamic-resolution vision (frontend stubbed)
[arXiv:2409.12191; hf].  input_specs supplies precomputed patch embeddings
over a fixed prefix + (t,h,w) position-id streams."""
from repro.configs.base import ModelConfig, register


@register("qwen2-vl-2b")
def qwen2_vl_2b(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="qwen2-vl-smoke", family="vlm", num_layers=2,
            d_model=64, num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
            head_dim=16, mrope_sections=(2, 3, 3), frontend="vision",
            attn_chunk=0, loss_chunk=0, remat="none")
    return ModelConfig(
        name="qwen2-vl-2b", family="vlm", num_layers=28,
        d_model=1536, num_heads=12, num_kv_heads=2, d_ff=8960,
        vocab_size=151936, head_dim=128, rope_theta=1000000.0,
        mrope_sections=(16, 24, 24), frontend="vision", tie_embeddings=True,
        attn_chunk=1024, loss_chunk=0, remat="dots",
        notes="12 q-heads indivisible by model axis → FSDP-style attention; "
              "M-RoPE sections (16,24,24) over head_dim/2=64.")
