"""Architecture registry.  Importing this package registers every assigned
architecture (``--arch <id>``) plus the paper's own GNN training configs."""
from repro.configs.base import (ModelConfig, ShapeConfig, get_config, list_archs,
                                register, ALL_SHAPES, SHAPES_BY_NAME,
                                applicable_shapes, TRAIN_4K, PREFILL_32K,
                                DECODE_32K, LONG_500K)

# arch modules register themselves on import
from repro.configs import (minitron_8b, glm4_9b, llama3_2_3b, qwen3_4b,  # noqa: F401
                           kimi_k2_1t_a32b, qwen2_moe_a2_7b, mamba2_1_3b,
                           zamba2_7b, whisper_medium, qwen2_vl_2b)
from repro.configs import gnn  # noqa: F401
