"""Zamba2-7B — Mamba2 backbone + weight-shared attention blocks
[arXiv:2411.15242; unverified]."""
from repro.configs.base import ModelConfig, register


@register("zamba2-7b")
def zamba2_7b(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="zamba2-smoke", family="hybrid", num_layers=5,
            d_model=64, num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
            ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=32,
            shared_attn_every=2,
            attn_chunk=0, loss_chunk=0, remat="none")
    return ModelConfig(
        name="zamba2-7b", family="hybrid", num_layers=81,
        d_model=3584, num_heads=32, num_kv_heads=32, d_ff=14336,
        vocab_size=32000, head_dim=112,
        ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
        shared_attn_every=6,
        attn_chunk=1024, loss_chunk=0, remat="dots",
        notes="81 Mamba2 layers; one shared attention+MLP block applied after "
              "every 6th layer (13 applications, 81//6).  long_500k RUNS "
              "(sub-quadratic; shared-attn KV cache sequence-sharded).")
