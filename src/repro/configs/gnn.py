"""GNN training configs — the paper's own workload (A³GNN).

One config per dataset family used in the paper's experiments (Tab. II /
Fig. 6), backed by synthetic power-law graphs with matched statistics
(offline container — see graph/synthetic.py and DESIGN.md §6.5).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Tuple

from repro.configs.base import register


@dataclass(frozen=True)
class GNNConfig:
    name: str
    family: str = "gnn"
    model: str = "graphsage"            # graphsage | gcn | gat
    num_layers: int = 3
    hidden: int = 256
    feat_dim: int = 602                 # reddit-like default
    num_classes: int = 41
    fanout: Tuple[int, ...] = (15, 10, 5)
    batch_size: int = 512
    # dataset (synthetic power-law generator parameters)
    num_nodes: int = 100_000
    num_edges: int = 2_000_000
    power_exp: float = 2.1              # degree power-law exponent
    # --- A3GNN knobs (Table I design space) ---
    bias_rate: float = 2.0              # γ ≥ 1; 1 → plain random sampling
    cache_volume_mb: float = 40.0       # Θ
    cache_policy: str = "static"        # static (hotness) | fifo
    sampling_device: str = "cpu"        # cpu | device
    workers: int = 2
    parallel_mode: str = "seq"          # seq | mode1 | mode2
    partitions: int = 1
    # training
    lr: float = 3e-3
    dropout: float = 0.0
    compute_dtype: str = "float32"

    def replace(self, **kw) -> "GNNConfig":
        return replace(self, **kw)


def _dataset(name, nodes, edges, feat, classes, exp=2.1):
    return dict(num_nodes=nodes, num_edges=edges, feat_dim=feat,
                num_classes=classes, power_exp=exp)


# Scaled-down synthetic twins of the paper's datasets (node/edge counts
# scaled ~25× down to fit the CPU container; density ratios preserved).
DATASETS = {
    "reddit": _dataset("reddit", 93_000, 4_600_000, 602, 41, 1.9),
    "products": _dataset("products", 98_000, 2_470_000, 100, 47, 2.2),
    "arxiv": _dataset("arxiv", 68_000, 466_000, 128, 40, 2.4),
    "amazon": _dataset("amazon", 63_000, 10_570_000, 200, 107, 1.8),
    "yelp": _dataset("yelp", 29_000, 800_000, 300, 100, 2.0),
}

# smoke-scale (unit tests / CI)
DATASETS_SMOKE = {
    k: dict(v, num_nodes=2_000, num_edges=20_000) for k, v in DATASETS.items()
}


def gnn_config(dataset: str = "products", smoke: bool = False, **kw) -> GNNConfig:
    ds = (DATASETS_SMOKE if smoke else DATASETS)[dataset]
    base = GNNConfig(name=f"graphsage-{dataset}" + ("-smoke" if smoke else ""),
                     **ds)
    if smoke:
        base = base.replace(hidden=32, batch_size=64, fanout=(5, 5),
                            num_layers=2, cache_volume_mb=1.0)
    return base.replace(**kw) if kw else base


@register("graphsage-products")
def _products(smoke: bool = False):
    return gnn_config("products", smoke)


@register("graphsage-reddit")
def _reddit(smoke: bool = False):
    return gnn_config("reddit", smoke)
