"""GNN training configs — the paper's own workload (A³GNN).

One config per dataset family used in the paper's experiments (Tab. II /
Fig. 6), backed by synthetic power-law graphs with matched statistics
(offline container — see graph/synthetic.py and DESIGN.md §6.5).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Tuple

from repro.configs.base import register


@dataclass(frozen=True)
class AutotuneConfig:
    """Online auto-tuning (paper §III-C) — episode schedule + objective.

    The controller (core/autotune/controller.py) runs ``episodes`` episodes
    of ``steps_per_episode`` real training steps each; between episodes it
    drains the pipeline and applies a new (γ, cache volume, parallel mode,
    workers) configuration proposed by the PPO policy against the surrogate.
    The reward is w·(throughput, -memory, accuracy) with a hard
    ``memory_limit_bytes`` constraint (Algo. 3's -inf reward)."""
    episodes: int = 4
    steps_per_episode: int = 10
    warmup_steps: int = 2            # absorbs jit compiles before episode 0
    eval_batches: int = 2            # accuracy measurement per episode
    # surrogate pre-warm (analytic perf/accuracy models → training points)
    presample: int = 96
    surrogate_trees: int = 24
    # objective weights + constraint
    w_throughput: float = 1.0
    w_memory: float = 1e-9
    w_accuracy: float = 0.5
    memory_limit_bytes: float = float("inf")
    # PPO exploration burst per episode
    ppo_updates: int = 3
    ppo_horizon: int = 8
    # episode design-space bounds (subset of Table I that is live-swappable)
    max_workers: int = 4
    max_cache_mb: float = 64.0
    max_bias_rate: float = 16.0
    # > 0 adds the `batch_size` knob (applies live via Pipeline.reconfigure)
    max_batch_size: int = 0
    # adds the `sampling_device` knob: live feature-plane swap (cpu ↔
    # device Pallas gather) without dropping a batch
    tune_sampling_device: bool = False
    # MEASURE-phase throughput: "modeled" (Eqs. 2/4 from measured stage
    # times — the only honest number on a 1-core host, where threads cannot
    # physically overlap), "wallclock" (PipelineStats.throughput_steps_per_s),
    # or "auto" — wall-clock when the process can use > 1 CPU (scheduler
    # affinity mask, so cgroup-pinned containers count as 1-core)
    throughput_source: str = "auto"
    # > 1 adds the `partitions` knob: applied through the restart-capable
    # path (checkpoint → rebuild trainer → restore), not a live swap
    max_partitions: int = 1
    # > 0 adds the `halo_budget` knob (bounded halo-feature exchange);
    # swaps LIVE — the plan is re-budgeted and slots rebuilt in place,
    # params/optimizer state never leave memory
    max_halo_budget: int = 0
    restart_dir: str = ""            # "" → a fresh temp dir per controller
    seed: int = 0

    def replace(self, **kw) -> "AutotuneConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class GNNConfig:
    name: str
    family: str = "gnn"
    model: str = "graphsage"            # graphsage | gcn | gat | gin
    num_layers: int = 3
    hidden: int = 256
    feat_dim: int = 602                 # reddit-like default
    num_classes: int = 41
    fanout: Tuple[int, ...] = (15, 10, 5)
    batch_size: int = 512
    # dataset (synthetic power-law generator parameters)
    num_nodes: int = 100_000
    num_edges: int = 2_000_000
    power_exp: float = 2.1              # degree power-law exponent
    # --- A3GNN knobs (Table I design space) ---
    bias_rate: float = 2.0              # γ ≥ 1; 1 → plain random sampling
    cache_volume_mb: float = 40.0       # Θ
    cache_policy: str = "static"        # static (hotness) | fifo
    sampling_device: str = "cpu"        # cpu | device | auto (probe jax.devices)
    # all-hop fused gather+aggregate (kernels/fused_gather_agg +
    # kernels/segment_agg.neighbor_agg): batch generation defers ALL
    # feature work to the train step, which resolves the input hop from
    # encoded cache slots + a miss sideband and runs every hop's
    # aggregation in place over the previous layer's output buffer —
    # level-capped buffers give ONE jit signature per (model,
    # level_caps).  Supported by all model families (graphsage/gcn/gat/
    # gin); bit-exact with the unfused path on cpu and device planes.
    fused_gather_agg: bool = False
    workers: int = 2
    parallel_mode: str = "seq"          # seq | mode1 | mode2
    partitions: int = 1
    # bounded halo exchange: top-k boundary features each partition keeps
    # (0 → drop cut edges entirely, the paper's no-remote-access setting)
    halo_budget: int = 0
    # streaming graphs: re-run the bounded halo exchange every N global
    # steps WHEN stale (a FeatureStore update touched a halo-resident row);
    # 0 → no periodic refresh (explicit refresh_halo_features() only)
    halo_refresh_interval: int = 0
    # dynamic topology: cut-fraction drift past the plan-time baseline that
    # triggers an incremental re-balance between global steps (boundary-
    # node migration, never a full repartition); ≤ 0 disables the trigger
    # (explicit rebalance_partitions() only)
    rebalance_drift: float = 0.0
    # cap on the fraction of nodes one incremental re-balance may migrate
    rebalance_max_move: float = 0.25
    # --- serving (serve/fabric.py) ---
    # target p99 end-to-end latency for SLO-aware admission; ≤ 0 disables
    # shedding (unconditional admission — queue wait unbounded past
    # saturation, the pre-SLO behavior)
    slo_p99_ms: float = 0.0
    # engines per partition behind the fabric's shared admission scheduler
    serve_replicas: int = 1
    # per-request fabric timeout (serve/transport.py seam): how long the
    # fabric waits on a dispatched replica before retrying the request on
    # another one (once) and then retiring it status=="timeout"; ≤ 0
    # disables — the fabric waits forever, the pre-seam behavior (safe
    # in-process, where a response cannot be lost)
    serve_timeout_ms: float = 0.0
    # training
    lr: float = 3e-3
    dropout: float = 0.0
    compute_dtype: str = "float32"
    # online auto-tuning (core/autotune/controller.py)
    autotune: AutotuneConfig = field(default_factory=AutotuneConfig)

    def replace(self, **kw) -> "GNNConfig":
        return replace(self, **kw)


def _dataset(name, nodes, edges, feat, classes, exp=2.1):
    return dict(num_nodes=nodes, num_edges=edges, feat_dim=feat,
                num_classes=classes, power_exp=exp)


# Scaled-down synthetic twins of the paper's datasets (node/edge counts
# scaled ~25× down to fit the CPU container; density ratios preserved).
DATASETS = {
    "reddit": _dataset("reddit", 93_000, 4_600_000, 602, 41, 1.9),
    "products": _dataset("products", 98_000, 2_470_000, 100, 47, 2.2),
    "arxiv": _dataset("arxiv", 68_000, 466_000, 128, 40, 2.4),
    "amazon": _dataset("amazon", 63_000, 10_570_000, 200, 107, 1.8),
    "yelp": _dataset("yelp", 29_000, 800_000, 300, 100, 2.0),
}

# smoke-scale (unit tests / CI)
DATASETS_SMOKE = {
    k: dict(v, num_nodes=2_000, num_edges=20_000) for k, v in DATASETS.items()
}


def gnn_config(dataset: str = "products", smoke: bool = False, **kw) -> GNNConfig:
    ds = (DATASETS_SMOKE if smoke else DATASETS)[dataset]
    base = GNNConfig(name=f"graphsage-{dataset}" + ("-smoke" if smoke else ""),
                     **ds)
    if smoke:
        base = base.replace(hidden=32, batch_size=64, fanout=(5, 5),
                            num_layers=2, cache_volume_mb=1.0)
    return base.replace(**kw) if kw else base


@register("graphsage-products")
def _products(smoke: bool = False):
    return gnn_config("products", smoke)


@register("graphsage-reddit")
def _reddit(smoke: bool = False):
    return gnn_config("reddit", smoke)
