"""Whisper-medium — encoder-decoder, conv/mel frontend stubbed
[arXiv:2212.04356; unverified].  input_specs supplies precomputed 1500-frame
embeddings; decoder uses learned positional embeddings, LayerNorm, GELU."""
from repro.configs.base import ModelConfig, register


@register("whisper-medium")
def whisper_medium(smoke: bool = False) -> ModelConfig:
    if smoke:
        return ModelConfig(
            name="whisper-smoke", family="encdec", num_layers=2,
            d_model=64, num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
            encoder_layers=2, encoder_seq=30, use_rope=False,
            mlp_type="gelu", max_seq=128,
            attn_chunk=0, loss_chunk=0, remat="none")
    return ModelConfig(
        name="whisper-medium", family="encdec", num_layers=24,
        d_model=1024, num_heads=16, num_kv_heads=16, d_ff=4096,
        vocab_size=51865, head_dim=64,
        encoder_layers=24, encoder_seq=1500, use_rope=False,
        mlp_type="gelu", max_seq=32768, tie_embeddings=True,
        attn_chunk=1024, loss_chunk=0, remat="dots",
        notes="decoder pos-emb table sized to 32k for the assigned decode_32k "
              "cell (the release caps at 448 — assignment shapes win).")
