"""Device-side feature cache (paper's feature-cache module).

Maintains a *device map* (node id → cache slot, -1 = miss) enabling both
O(1) lookup during batch generation and the locality-aware sampler's bias
(cached ids get weight γ).  Policies:

  * ``static``  — preload hottest nodes (out-degree order, PaGraph-style)
  * ``fifo``    — dynamic ring-buffer replacement (BGL/GNNavigator-style)

On the TPU adaptation the cache rows live in device HBM and misses are
host→device DMA; here storage is a pinned numpy array and we count
hit/miss traffic exactly (benchmarks derive PCIe-volume savings from it).
The Pallas gather kernel (kernels/gather) implements the device-side
cached-row gather for the real-TPU path.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.graph.storage import Graph


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    bytes_from_cache: int = 0
    bytes_from_host: int = 0

    @property
    def hit_rate(self) -> float:
        t = self.hits + self.misses
        return self.hits / t if t else 0.0

    def reset(self):
        self.hits = self.misses = self.evictions = 0
        self.bytes_from_cache = self.bytes_from_host = 0


class FeatureCache:
    def __init__(self, graph: Graph, volume_mb: float, policy: str = "static"):
        self.g = graph
        self.policy = policy
        self.stats = CacheStats()
        self._alloc(volume_mb)

    def _alloc(self, volume_mb: float):
        """(Re)allocate storage for ``volume_mb`` and warm it per policy.
        ``self.stats`` is untouched — hit/miss accounting survives resizes.
        ``version`` advances on every (re)allocation so device-resident
        mirrors (core/feature_plane.py DeviceFeaturePlane) know to re-sync;
        ``epoch`` advances too, marking a full invalidation — the buffers
        themselves were reallocated, so row-wise deltas from before this
        point are meaningless to a mirror."""
        graph = self.g
        self.version = getattr(self, "version", -1) + 1
        self.epoch = getattr(self, "epoch", -1) + 1
        self._delta_log = []            # [(version, dirty_slots, dirty_ids)]
        self._delta_floor = self.version  # oldest version deltas can bridge
        self._delta_rows = 0            # total rows across the log (bound)
        self.volume_mb = float(volume_mb)
        row_bytes = graph.feat_dim * 4
        self.capacity = max(int(volume_mb * 2**20 / row_bytes), 0)
        self.capacity = min(self.capacity, graph.num_nodes)
        self.device_map = -np.ones(graph.num_nodes, dtype=np.int32)
        self.storage = np.zeros((self.capacity, graph.feat_dim), np.float32)
        self.slot_owner = -np.ones(self.capacity, dtype=np.int64)
        self._fifo_head = 0
        if self.policy == "static" and self.capacity:
            hot = graph.hotness_order()[:self.capacity]
            self.storage[:len(hot)] = graph.features[hot]
            self.device_map[hot] = np.arange(len(hot), dtype=np.int32)
            self.slot_owner[:len(hot)] = hot

    def resize(self, volume_mb: float, keep_residents: bool = True):
        """Episode-boundary reconfiguration (autotune controller).

        Static policy re-warms from the hotness order at the new capacity.
        FIFO keeps the most-recently-inserted residents that still fit
        (``keep_residents``), so a shrink behaves like ``new_cap``
        evictions, not a cold restart.  Cumulative ``stats`` are preserved
        either way — the controller's measured hit rate spans episodes via
        ``stats.reset()`` at the boundary it chooses, not here.
        """
        if self.policy != "fifo" or not keep_residents:
            self._alloc(volume_mb)
            return
        # FIFO: snapshot residents in insertion order (oldest → newest)
        old_cap, head = self.capacity, self._fifo_head
        order = (np.arange(old_cap) + head) % old_cap if old_cap else \
            np.arange(0)
        residents = self.slot_owner[order]
        residents = residents[residents >= 0]
        self._alloc(volume_mb)
        if self.capacity and len(residents):
            keep = residents[-self.capacity:]
            n = len(keep)
            self.slot_owner[:n] = keep
            self.device_map[keep] = np.arange(n, dtype=np.int32)
            self.storage[:n] = self.g.features[keep]
            self._fifo_head = n % self.capacity

    # -- dirty-row delta log -------------------------------------------------
    def _record_delta(self, dirty_slots: np.ndarray, dirty_ids: np.ndarray):
        """Advance ``version`` by exactly one and remember WHICH rows moved.

        ``dirty_slots`` are storage rows whose contents changed;
        ``dirty_ids`` are node ids whose ``device_map`` entry changed.
        Device mirrors (core/feature_plane.py) consume the log through
        ``deltas_since`` to scatter only dirty rows instead of re-uploading
        the whole table.  The log is bounded: once it accumulates more
        dirty rows than the table holds, an incremental replay costs more
        than a full upload, so we drop it and raise ``_delta_floor`` —
        stale mirrors then fall back to a full re-upload."""
        self.version += 1
        self._delta_log.append((self.version,
                                np.asarray(dirty_slots, np.int32).copy(),
                                np.asarray(dirty_ids, np.int64).copy()))
        self._delta_rows += len(dirty_slots) + len(dirty_ids)
        if self._delta_rows > 2 * max(self.capacity, 1):
            self._delta_log = []
            self._delta_rows = 0
            self._delta_floor = self.version

    def deltas_since(self, version: int, epoch: int):
        """Cumulative dirty set between a mirror's ``(version, epoch)`` and
        now, or ``None`` if only a full re-upload can bridge the gap
        (reallocation, or the bounded log was dropped).  Returns
        ``(dirty_slots, dirty_ids)`` — unique, final-state row indices: the
        caller reads current ``storage``/``device_map`` contents, so replay
        order is irrelevant."""
        if epoch != self.epoch or version < self._delta_floor:
            return None
        slots = [s for v, s, _ in self._delta_log if v > version]
        ids = [i for v, _, i in self._delta_log if v > version]
        return (np.unique(np.concatenate(slots)) if slots
                else np.empty(0, np.int32),
                np.unique(np.concatenate(ids)) if ids
                else np.empty(0, np.int64))

    # -- streaming updates ---------------------------------------------------
    def patch_resident(self, ids: np.ndarray, rows: np.ndarray) -> int:
        """Overwrite the cache-resident copies among ``ids`` with the
        matching ``rows``, bumping ``version`` when anything changed so
        device mirrors (core/feature_plane.py) re-sync.  THE one place
        the resident-write → version invariant lives: both the push path
        (``FeaturePlane.fill_rows``) and the pull path (``refresh_rows``)
        delegate here.  Returns the number of resident rows patched."""
        if not self.capacity:
            return 0
        ids = np.asarray(ids, dtype=np.int64)
        # ids outside this cache's node universe (a full-graph stream
        # hitting a subgraph cache) have no slot here — not-resident, not
        # an indexing error
        in_universe = ids < len(self.device_map)
        if not in_universe.all():
            ids = ids[in_universe]
            rows = np.asarray(rows)[in_universe]
        slots = self.device_map[ids]
        hit = slots >= 0
        if hit.any():
            self.storage[slots[hit]] = rows[hit]
            # device mirrors must re-sync, but only the patched rows —
            # the slot map is untouched
            self._record_delta(slots[hit], np.empty(0, np.int64))
        return int(hit.sum())

    def refresh_rows(self, ids: np.ndarray) -> int:
        """Re-copy ``ids``'s rows from the host store into their resident
        cache slots after a streaming update (``graph/storage.py``
        ``FeatureStore.update_rows``) — the pull side for consumers that
        only learn WHICH rows moved."""
        ids = np.asarray(ids, dtype=np.int64)
        if not self.capacity:
            return 0
        return self.patch_resident(ids, self.g.features[ids])

    # -- lookups ------------------------------------------------------------
    def is_cached(self, ids: np.ndarray) -> np.ndarray:
        return self.device_map[ids] >= 0

    def volume_bytes(self) -> int:
        return self.storage.nbytes

    # -- fetch --------------------------------------------------------------
    def fetch(self, ids: np.ndarray) -> np.ndarray:
        """Gather features for ``ids`` through the cache, updating stats
        (and, for FIFO, inserting missed rows)."""
        ids = np.asarray(ids, dtype=np.int64)
        slots = self.device_map[ids]
        hit = slots >= 0
        out = np.empty((len(ids), self.g.feat_dim), np.float32)
        if hit.any():
            out[hit] = self.storage[slots[hit]]
        miss_ids = ids[~hit]
        if len(miss_ids):
            out[~hit] = self.g.features[miss_ids]
        self.account_fetch(hit, miss_ids)
        return out

    def account_fetch(self, hit: np.ndarray, miss_ids: np.ndarray):
        """Hit/miss/byte accounting + FIFO insertion for one fetch of
        ``len(hit)`` ids.  Shared by ``fetch`` and the device feature plane
        (core/feature_plane.py), which must stay stats-exact with it —
        keep every accounting change in THIS one place."""
        row_bytes = self.g.feat_dim * 4
        n_hit = int(hit.sum())
        self.stats.hits += n_hit
        self.stats.misses += int(len(hit) - n_hit)
        self.stats.bytes_from_cache += n_hit * row_bytes
        self.stats.bytes_from_host += int(len(miss_ids)) * row_bytes
        if self.policy == "fifo" and self.capacity and len(miss_ids):
            self._fifo_insert(np.unique(miss_ids))

    def _fifo_insert(self, ids: np.ndarray):
        dirty_slots = []
        dirty_ids = []                  # evicted owners AND inserted ids
        for v in ids:
            slot = self._fifo_head
            old = self.slot_owner[slot]
            if old >= 0:
                self.device_map[old] = -1
                self.stats.evictions += 1
                dirty_ids.append(old)
            self.slot_owner[slot] = v
            self.device_map[v] = slot
            self.storage[slot] = self.g.features[v]
            dirty_slots.append(slot)
            dirty_ids.append(v)
            self._fifo_head = (self._fifo_head + 1) % self.capacity
        # one version bump per insert batch → mirrors re-sync once
        self._record_delta(np.asarray(dirty_slots, np.int32),
                           np.asarray(dirty_ids, np.int64))
