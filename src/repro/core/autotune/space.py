"""Design space (paper Table I) — encode/decode/clip for the PPO agent.

Every knob is normalized to [0,1] for the agent; ``decode`` maps back to a
concrete configuration dict.  The same vector feeds the surrogate.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

MODES = ("seq", "mode1", "mode2")
DEVICES = ("cpu", "device")


@dataclass(frozen=True)
class Knob:
    name: str
    kind: str                    # int | float | cat | log
    lo: float = 0.0
    hi: float = 1.0
    choices: Tuple = ()

    def decode(self, u: float):
        u = float(np.clip(u, 0.0, 1.0))
        if self.kind == "cat":
            i = min(int(u * len(self.choices)), len(self.choices) - 1)
            return self.choices[i]
        if self.kind == "int":
            return int(round(self.lo + u * (self.hi - self.lo)))
        if self.kind == "log":
            return float(np.exp(np.log(self.lo) + u * (np.log(self.hi)
                                                       - np.log(self.lo))))
        return self.lo + u * (self.hi - self.lo)

    def encode(self, v) -> float:
        if self.kind == "cat":
            return (self.choices.index(v) + 0.5) / len(self.choices)
        if self.kind == "log":
            return float((np.log(v) - np.log(self.lo))
                         / (np.log(self.hi) - np.log(self.lo)))
        return float((v - self.lo) / (self.hi - self.lo))


def design_space(max_partitions: int = 8, max_workers: int = 8,
                 max_cache_mb: float = 512.0) -> List[Knob]:
    """Table I: general / sampling / feature / parallelism knobs."""
    return [
        Knob("batch_size", "int", 64, 1024),
        Knob("partitions", "int", 1, max_partitions),
        Knob("bias_rate", "log", 1.0, 16.0),
        Knob("sampling_device", "cat", choices=DEVICES),
        Knob("workers", "int", 1, max_workers),
        Knob("cache_volume_mb", "log", 1.0, max_cache_mb),
        Knob("parallel_mode", "cat", choices=MODES),
    ]


class Space:
    def __init__(self, knobs: List[Knob] | None = None):
        self.knobs = knobs or design_space()

    @property
    def dim(self) -> int:
        return len(self.knobs)

    def decode(self, u: np.ndarray) -> Dict:
        return {k.name: k.decode(x) for k, x in zip(self.knobs, u)}

    def encode(self, cfg: Dict) -> np.ndarray:
        return np.array([k.encode(cfg[k.name]) for k in self.knobs])

    def clip(self, u: np.ndarray) -> np.ndarray:
        return np.clip(u, 0.0, 1.0)

    def sample(self, rng: np.random.Generator, n: int = 1) -> np.ndarray:
        return rng.random((n, self.dim))

    def grid(self, points_per_dim: int = 3) -> np.ndarray:
        """Full-factorial grid (the paper's grid-search baseline)."""
        axes = [np.linspace(0.05, 0.95, points_per_dim)] * self.dim
        mesh = np.meshgrid(*axes, indexing="ij")
        return np.stack([m.ravel() for m in mesh], axis=-1)
