"""PPO-based design-space exploration (paper Algo. 3).

MDP: state s = [config p, predicted metrics m]; action a = bounded delta on
the normalized config vector; reward R = wᵀm (task-priority weights) with a
large negative penalty outside hardware constraints.  Gaussian policy +
value MLP in pure JAX, clipped-objective PPO with GAE(λ)/TD value targets.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.autotune.space import Space

VIOLATION_REWARD = -100.0      # "-inf" of Algo. 3, kept finite for stability


def _mlp_init(rng, sizes):
    params = []
    keys = jax.random.split(rng, len(sizes) - 1)
    for k, (i, o) in zip(keys, zip(sizes[:-1], sizes[1:])):
        params.append({"w": jax.random.normal(k, (i, o)) / np.sqrt(i),
                       "b": jnp.zeros(o)})
    return params


def _mlp(params, x):
    for i, p in enumerate(params):
        x = x @ p["w"] + p["b"]
        if i < len(params) - 1:
            x = jnp.tanh(x)
    return x


@dataclass
class PPOConfig:
    action_scale: float = 0.3
    clip_eps: float = 0.2
    gamma: float = 0.95
    lam: float = 0.9
    lr: float = 5e-3
    epochs_per_update: int = 4
    horizon: int = 16
    updates: int = 20
    hidden: int = 64
    init_log_std: float = -0.7
    seed: int = 0


class PPOAgent:
    """Explores the space against a (surrogate) evaluator.

    ``evaluate(cfg_dict) -> {"throughput","memory","accuracy"}``
    ``constraint(metrics) -> bool`` — True if feasible.
    """

    def __init__(self, space: Space, evaluate: Callable[[Dict], Dict],
                 w: Dict[str, float], constraint: Callable[[Dict], bool],
                 cfg: PPOConfig = PPOConfig()):
        self.space = space
        self.evaluate = evaluate
        self.w = w
        self.constraint = constraint
        self.cfg = cfg
        rng = jax.random.PRNGKey(cfg.seed)
        k1, k2, self._key = jax.random.split(rng, 3)
        sdim = space.dim + 3                      # state = config ⊕ metrics
        self.pi = _mlp_init(k1, [sdim, cfg.hidden, cfg.hidden, space.dim])
        self.log_std = jnp.full(space.dim, cfg.init_log_std)
        self.vf = _mlp_init(k2, [sdim, cfg.hidden, cfg.hidden, 1])
        self.best_cfg: Optional[Dict] = None
        self.best_u: Optional[np.ndarray] = None
        self.best_reward = -np.inf
        self.history: List[Tuple[Dict, Dict, float]] = []
        self.evals = 0

    # -- reward --------------------------------------------------------------
    def reward(self, metrics: Dict) -> float:
        if not self.constraint(metrics):
            return VIOLATION_REWARD
        m = np.array([metrics["throughput"], -metrics["memory"],
                      metrics["accuracy"]])
        wv = np.array([self.w.get("throughput", 0.0), self.w.get("memory", 0.0),
                       self.w.get("accuracy", 0.0)])
        return float(wv @ m)

    def _metrics_vec(self, metrics: Dict) -> np.ndarray:
        return np.array([np.log(max(metrics["throughput"], 1e-9)),
                         np.log(max(metrics["memory"], 1.0)) / 20.0,
                         metrics["accuracy"]])

    def _state(self, u: np.ndarray, metrics: Dict) -> np.ndarray:
        return np.concatenate([u, self._metrics_vec(metrics)])

    # -- rollout -------------------------------------------------------------
    def _rollout(self, u0: np.ndarray):
        cfgc = self.cfg
        states, actions, logps, rewards, values = [], [], [], [], []
        u = u0.copy()
        cfg0 = self.space.decode(u)
        metrics = self.evaluate(cfg0)
        self.evals += 1
        r0 = self.reward(metrics)
        self.history.append((cfg0, metrics, r0))
        if r0 > self.best_reward:
            self.best_reward, self.best_cfg, self.best_u = r0, cfg0, u.copy()
        for _ in range(cfgc.horizon):
            s = self._state(u, metrics)
            self._key, k = jax.random.split(self._key)
            mu = np.asarray(_mlp(self.pi, jnp.asarray(s)))
            std = np.exp(np.asarray(self.log_std))
            a = mu + std * np.asarray(jax.random.normal(k, (self.space.dim,)))
            logp = float(-0.5 * (((a - mu) / std) ** 2
                                 + 2 * np.log(std) + np.log(2 * np.pi)).sum())
            v = float(np.asarray(_mlp(self.vf, jnp.asarray(s)))[0])
            # apply action (Algo. 3 line 4: clip to valid range)
            u = self.space.clip(u + cfgc.action_scale * np.tanh(a))
            cfg_dict = self.space.decode(u)
            metrics = self.evaluate(cfg_dict)
            self.evals += 1
            r = self.reward(metrics)
            self.history.append((cfg_dict, metrics, r))
            if r > self.best_reward:
                self.best_reward, self.best_cfg = r, cfg_dict
                self.best_u = u.copy()
            states.append(s)
            actions.append(a)
            logps.append(logp)
            rewards.append(r)
            values.append(v)
        return (np.array(states), np.array(actions), np.array(logps),
                np.array(rewards), np.array(values))

    # -- PPO update ----------------------------------------------------------
    def _update(self, batch):
        s, a, logp_old, ret, adv = [jnp.asarray(x) for x in batch]
        cfgc = self.cfg

        def loss_fn(pi, log_std, vf):
            mu = jax.vmap(lambda x: _mlp(pi, x))(s)
            std = jnp.exp(log_std)
            logp = (-0.5 * (((a - mu) / std) ** 2 + 2 * log_std
                            + jnp.log(2 * jnp.pi))).sum(-1)
            ratio = jnp.exp(logp - logp_old)
            adv_n = (adv - adv.mean()) / (adv.std() + 1e-8)
            l_clip = -jnp.mean(jnp.minimum(
                ratio * adv_n,
                jnp.clip(ratio, 1 - cfgc.clip_eps, 1 + cfgc.clip_eps) * adv_n))
            v = jax.vmap(lambda x: _mlp(vf, x))(s)[:, 0]
            l_v = jnp.mean((v - ret) ** 2)
            return l_clip + 0.5 * l_v - 0.001 * jnp.mean(log_std)

        grads = jax.grad(loss_fn, argnums=(0, 1, 2))(self.pi, self.log_std,
                                                     self.vf)
        self.pi = jax.tree.map(lambda p, g: p - cfgc.lr * g, self.pi, grads[0])
        self.log_std = jnp.clip(self.log_std - cfgc.lr * grads[1], -2.5, 0.0)
        self.vf = jax.tree.map(lambda p, g: p - cfgc.lr * g, self.vf, grads[2])

    def _gae(self, rewards, values):
        cfgc = self.cfg
        adv = np.zeros_like(rewards)
        last = 0.0
        for t in reversed(range(len(rewards))):
            nxt = values[t + 1] if t + 1 < len(values) else 0.0
            delta = rewards[t] + cfgc.gamma * nxt - values[t]
            last = delta + cfgc.gamma * cfgc.lam * last
            adv[t] = last
        return adv, adv + values

    # -- main loop (Algo. 3) ---------------------------------------------------
    def run(self, rng: Optional[np.random.Generator] = None) -> Dict:
        rng = rng or np.random.default_rng(self.cfg.seed)
        for upd in range(self.cfg.updates):
            # explore from a fresh random config half the time; otherwise
            # continue the trajectory from the incumbent (Algo. 3 keeps
            # refining p* while the clipped policy update keeps exploring)
            if self.best_u is None or upd % 2 == 0:
                u0 = rng.random(self.space.dim)
            else:
                u0 = self.space.clip(self.best_u
                                     + 0.05 * rng.standard_normal(self.space.dim))
            s, a, logp, r, v = self._rollout(u0)
            adv, ret = self._gae(r, v)
            for _ in range(self.cfg.epochs_per_update):
                self._update((s, a, logp, ret, adv))
        return self.best_cfg
