"""Pareto-front extraction + T*/M*/balanced selection + grid-search baseline.

The paper reads T* (max throughput) and M* (min memory) off the two ends of
the Pareto front (Tab. II) and reports PPO exploring ~2.1× faster than grid
search for equal-quality configurations.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.core.autotune.space import Space


def pareto_front(points: np.ndarray) -> np.ndarray:
    """Indices of non-dominated points.  Convention: every column is
    maximized (negate memory before calling)."""
    n = len(points)
    keep = np.ones(n, bool)
    for i in range(n):
        if not keep[i]:
            continue
        dom = np.all(points >= points[i], axis=1) & np.any(points > points[i],
                                                           axis=1)
        if dom.any():
            keep[i] = False
    return np.where(keep)[0]


def front_from_history(history) -> List[int]:
    """history: list of (cfg, metrics, reward)."""
    pts = np.array([[m["throughput"], -m["memory"], m["accuracy"]]
                    for _, m, _ in history])
    return list(pareto_front(pts))


def select_endpoints(history) -> Dict[str, Tuple[Dict, Dict]]:
    """T* / M* / balanced configurations off the Pareto front."""
    idx = front_from_history(history)
    front = [history[i] for i in idx]
    t_star = max(front, key=lambda h: h[1]["throughput"])
    m_star = min(front, key=lambda h: h[1]["memory"])

    # balanced: max normalized geometric trade-off
    thr = np.array([h[1]["throughput"] for h in front])
    mem = np.array([h[1]["memory"] for h in front])
    acc = np.array([h[1]["accuracy"] for h in front])
    thr_n = (thr - thr.min()) / max(np.ptp(thr), 1e-9)
    mem_n = 1.0 - (mem - mem.min()) / max(np.ptp(mem), 1e-9)
    acc_n = (acc - acc.min()) / max(np.ptp(acc), 1e-9)
    bal = front[int(np.argmax(thr_n + mem_n + acc_n))]
    return {"T*": (t_star[0], t_star[1]), "M*": (m_star[0], m_star[1]),
            "balanced": (bal[0], bal[1])}


def grid_search(space: Space, evaluate: Callable[[Dict], Dict],
                reward: Callable[[Dict], float], points_per_dim: int = 3,
                target: float | None = None):
    """Full-factorial baseline.  Returns (best_cfg, best_reward, evals,
    evals_to_target)."""
    grid = space.grid(points_per_dim)
    best_cfg, best_r = None, -np.inf
    evals_to_target = None
    for i, u in enumerate(grid):
        cfg = space.decode(u)
        r = reward(evaluate(cfg))
        if r > best_r:
            best_r, best_cfg = r, cfg
        if target is not None and evals_to_target is None and r >= target:
            evals_to_target = i + 1
    return best_cfg, best_r, len(grid), evals_to_target
