"""Online auto-tuning controller — closes the paper's adaptive loop (§III-C).

``AutotuneController`` runs a LIVE ``A3GNNTrainer`` + ``Pipeline`` pair
through a sequence of tuning *episodes*.  Where the offline tools in this
package (``ppo.py``, ``surrogate.py``, ``pareto.py``) explore a design
space against a model, the controller applies each chosen configuration to
the running trainer and feeds *measured* points back — the
affordable/adaptive/automatic loop of the paper title.

Episode lifecycle
-----------------

Each episode ``e = 0, 1, …`` goes through four phases:

1. **PROPOSE** — episode 0 measures the fixed seed configuration (the
   baseline every later episode must beat).  Episodes ≥ 1 run a short PPO
   burst (Algo. 3) against the surrogate and take the burst's
   best-predicted configuration that has not been measured yet, so every
   episode visits a *new* point of the design space.
2. **RECONFIGURE** — the pipeline is drained (every in-flight mini-batch is
   trained; nothing is dropped), then the proposal is applied live:
   ``FeatureCache.resize`` (hit/miss accounting is preserved), the
   sampler's bias weight γ is swapped via a fresh ``bias_weight_fn``, and
   the executor switches parallel mode / worker count.  Training then
   resumes — parameters, optimizer state and step count all carry over.
3. **MEASURE** — ``steps_per_episode`` real training steps run under the
   new configuration.  Throughput comes from the wall clock
   (``PipelineStats.throughput_steps_per_s``) on multi-core hosts, where
   threads physically overlap; on a 1-core host it is modeled from the
   *measured* per-stage times via Eqs. 2/4 instead (overlap is impossible
   there, so the wall clock would under-report every parallel mode) —
   ``resolve_throughput_source`` picks per ``AutotuneConfig.
   throughput_source``.  Memory comes from Eqs. 3/5 with the measured
   peak batch size, accuracy from a held-out evaluation.
4. **FEEDBACK** — the measured (throughput, memory, accuracy) point is
   appended to the surrogate's training set (which was pre-warmed from the
   analytic models in ``core/perf_model.py`` + ``core/locality.py``) and
   the surrogate is refit, so the next episode's proposal sees every real
   measurement.  The Pareto frontier is maintained over MEASURED points
   only.

The recommendation (``AutotuneReport.best``) is the measured episode with
the highest reward ``w·(throughput, −memory, accuracy)`` subject to the
``memory_limit_bytes`` constraint; ``T*``/``M*`` endpoints come off the
measured Pareto front exactly as in Tab. II.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.gnn import AutotuneConfig
from repro.core.autotune.pareto import pareto_front
from repro.core.autotune.ppo import PPOAgent, PPOConfig, VIOLATION_REWARD
from repro.core.autotune.space import Knob, Space, DEVICES, MODES
from repro.core.autotune.surrogate import Surrogate
from repro.core.locality import accuracy_drop_model, expected_hit_rate
from repro.core.perf_model import (MemoryTerms, StageTimes,
                                   bottleneck_step_time, memory_mode1,
                                   memory_mode2, memory_seq)

# relative cost of a cache hit vs a host fetch during batch generation —
# scales the analytic t_batch estimate used only for surrogate pre-warming
HIT_SPEEDUP = 0.6
# prior for the device plane's batch-generation advantage (resident rows
# gathered in HBM instead of copied through host memory) — surrogate
# pre-warm only; MEASURE always uses the real pipeline
DEVICE_BATCH_SPEEDUP = 0.7


def available_cpus() -> int:
    """CPUs actually usable by THIS process: the scheduler affinity mask
    (respects cgroup/taskset pinning — a 1-CPU container on an 8-core host
    must count as 1), falling back to ``os.cpu_count()`` where affinity is
    not exposed (macOS)."""
    if hasattr(os, "sched_getaffinity"):
        return len(os.sched_getaffinity(0)) or 1
    return os.cpu_count() or 1


def resolve_throughput_source(acfg: AutotuneConfig) -> str:
    """MEASURE-phase throughput source: ``modeled`` (Eqs. 2/4 from measured
    stage times) or ``wallclock`` (``PipelineStats.throughput_steps_per_s``).
    ``auto`` picks wall-clock whenever the process can use more than one
    core — threads can physically overlap there, so the wall clock is the
    truth; on a 1-core host overlap is impossible and the model is the
    only honest multi-core prediction."""
    src = acfg.throughput_source
    if src == "auto":
        src = "wallclock" if available_cpus() > 1 else "modeled"
    if src not in ("modeled", "wallclock"):
        raise ValueError(f"unknown throughput_source: {src!r}")
    return src


def tuned_runtime_status() -> Dict[str, bool]:
    """Which scripts/env_tuned.sh host-tuning knobs are live in THIS
    process: ``tcmalloc`` (LD_PRELOAD carries a tcmalloc build — allocator
    lock contention shapes the multi-worker wall clock) and
    ``xla_host_flags`` (host platform pinned to one device, so jit
    dispatch cost is stable across runs).  Wall-clock MEASURE numbers are
    comparable only against numbers taken under the same runtime, so the
    controller stamps this onto every wall-clock episode."""
    tcmalloc = "tcmalloc" in os.environ.get("LD_PRELOAD", "")
    xla = "--xla_force_host_platform_device_count" in \
        os.environ.get("XLA_FLAGS", "")
    return {"tcmalloc": tcmalloc, "xla_host_flags": xla,
            "tuned": tcmalloc and xla}


def episode_space(acfg: AutotuneConfig) -> Space:
    """The tunable subset of Table I.  γ, Θ, mode, workers — and, when
    gated on, batch size, the sampling device (feature-plane backend) and
    the halo budget — swap live at an episode boundary; with
    ``max_partitions > 1`` the partition count joins the space and is
    applied through the restart-capable path (checkpoint → rebuild
    trainer → restore)."""
    knobs = [
        Knob("bias_rate", "log", 1.0, acfg.max_bias_rate),
        Knob("cache_volume_mb", "log", 0.05, acfg.max_cache_mb),
        Knob("parallel_mode", "cat", choices=MODES),
        Knob("workers", "int", 1, acfg.max_workers),
    ]
    if acfg.max_batch_size > 0:
        knobs.append(Knob("batch_size", "int",
                          min(16, acfg.max_batch_size), acfg.max_batch_size))
    if acfg.tune_sampling_device:
        knobs.append(Knob("sampling_device", "cat", choices=DEVICES))
    if acfg.max_partitions > 1:
        knobs.append(Knob("partitions", "int", 1, acfg.max_partitions))
    if acfg.max_halo_budget > 0:
        knobs.append(Knob("halo_budget", "int", 0, acfg.max_halo_budget))
    return Space(knobs)


def _cfg_key(cfg: Dict) -> Tuple:
    out = []
    for k in sorted(cfg):
        v = cfg[k]
        out.append((k, round(float(v), 2)
                    if isinstance(v, (int, float, np.floating)) else v))
    return tuple(out)


@dataclass
class Episode:
    index: int
    config: Dict                    # decoded episode-space knobs
    metrics: Dict[str, float]       # MEASURED {throughput, memory, accuracy}
    reward: float
    cache_hit_rate: float
    steps: int
    predicted: Optional[Dict[str, float]] = None   # surrogate view, ep ≥ 1
    # host-runtime stamp (tuned_runtime_status()) for wall-clock episodes;
    # None when throughput came from the model (runtime can't skew Eqs. 2/4)
    tuned_runtime: Optional[Dict[str, bool]] = None


@dataclass
class AutotuneReport:
    episodes: List[Episode] = field(default_factory=list)
    baseline: Optional[Episode] = None
    best: Optional[Episode] = None
    best_feasible: bool = True      # False ⇒ EVERY measured episode broke
                                    # the memory limit; best = least-memory
    final_trainer: Optional[object] = None  # the trainer left running the
                                    # recommendation — differs from the
                                    # caller's when a `partitions` restart
                                    # rebuilt it (use this one afterwards)

    @property
    def baseline_metrics(self) -> Dict[str, float]:
        return self.baseline.metrics

    @property
    def final_metrics(self) -> Dict[str, float]:
        return self.best.metrics

    def changed_knobs(self) -> Dict[str, set]:
        """Knob → set of distinct values visited across episodes."""
        out: Dict[str, set] = {}
        for ep in self.episodes:
            for k, v in ep.config.items():
                out.setdefault(k, set()).add(
                    round(v, 4) if isinstance(v, float) else v)
        return {k: v for k, v in out.items() if len(v) > 1}

    def pareto_points(self) -> List[Episode]:
        """Non-dominated measured episodes (throughput↑, memory↓, acc↑)."""
        if not self.episodes:
            return []
        pts = np.array([[e.metrics["throughput"], -e.metrics["memory"],
                         e.metrics["accuracy"]] for e in self.episodes])
        return [self.episodes[i] for i in pareto_front(pts)]


class AutotuneController:
    """Drives PROPOSE → RECONFIGURE → MEASURE → FEEDBACK episodes over a
    live (trainer, pipeline) pair.  See the module docstring."""

    def __init__(self, trainer, pipe, acfg: Optional[AutotuneConfig] = None):
        self.tr = trainer
        self.pipe = pipe
        self.acfg = acfg or trainer.cfg.autotune
        self.space = episode_space(self.acfg)
        self._knob_names = {k.name for k in self.space.knobs}
        self._restart_mgr = None        # lazy CheckpointManager (restart path)
        self.restarts = 0
        self.rng = np.random.default_rng(self.acfg.seed)
        self.surrogate = Surrogate(seed=self.acfg.seed,
                                   n_trees=self.acfg.surrogate_trees)
        self._X: List[np.ndarray] = []            # surrogate training set
        self._Y: Dict[str, List[float]] = {m: [] for m in
                                           ("throughput", "memory", "accuracy")}
        self._measured_keys: set = set()
        self.agent: Optional[PPOAgent] = None

    # -- objective -----------------------------------------------------------
    def reward(self, metrics: Dict[str, float]) -> float:
        if not self.feasible(metrics):
            return VIOLATION_REWARD
        a = self.acfg
        return (a.w_throughput * metrics["throughput"]
                - a.w_memory * metrics["memory"]
                + a.w_accuracy * metrics["accuracy"])

    def feasible(self, metrics: Dict[str, float]) -> bool:
        return metrics["memory"] <= self.acfg.memory_limit_bytes

    # -- surrogate pre-warm (analytic models → training points) --------------
    def prewarm(self, base_stats, base_acc: float):
        """Seed the surrogate from Eqs. 1-5 before any tuning episode.

        ``base_stats``: PipelineStats of the baseline episode — its measured
        per-stage times anchor the analytic throughput/memory predictions;
        ``accuracy_drop_model`` (Eq. 1) anchors accuracy."""
        st0 = base_stats.stage_times()
        base_hit = self._hit_model(self._current_config())
        for u in self.space.sample(self.rng, self.acfg.presample):
            cfg = self.space.decode(u)
            m = self._analytic_metrics(cfg, st0, base_hit, base_stats,
                                       base_acc)
            self._push_point(self.space.encode(cfg), m)
        self._refit()

    def _current_config(self) -> Dict:
        """The trainer's TRUE live knobs (cache_volume_mb may be 0 — a
        cache-less trainer; clamping to the space bounds happens only at
        encode time, see ``_encode``)."""
        c = self.tr.cfg
        cfg = {"bias_rate": c.bias_rate,
               "cache_volume_mb": (self.tr.cache.volume_mb
                                   if self.tr.cache is not None else 0.0),
               "parallel_mode": self.pipe.mode,
               "workers": self.pipe.workers_n}
        if "batch_size" in self._knob_names:
            cfg["batch_size"] = int(self.pipe.batch_size)
        if "sampling_device" in self._knob_names:
            cfg["sampling_device"] = str(self.pipe.sampling_device)
        if "partitions" in self._knob_names:
            cfg["partitions"] = int(c.partitions)
        if "halo_budget" in self._knob_names:
            cfg["halo_budget"] = int(getattr(c, "halo_budget", 0))
        return cfg

    def _encode(self, cfg: Dict) -> np.ndarray:
        """Encode for the surrogate, clamping out-of-space values (e.g. the
        cache-less baseline's Θ=0, or a seed workers count above
        ``max_workers``) onto the nearest space point."""
        clamped = dict(cfg)
        for k in self.space.knobs:
            if k.kind != "cat":
                clamped[k.name] = float(np.clip(cfg[k.name], k.lo, k.hi))
        return self.space.encode(clamped)

    def _hit_model(self, cfg: Dict) -> float:
        frac = self._cache_frac(cfg["cache_volume_mb"])
        return expected_hit_rate(frac, cfg["bias_rate"])

    def _cache_frac(self, volume_mb: float) -> float:
        g = self.tr.graph
        rows = volume_mb * 2**20 / (g.feat_dim * 4)
        return min(rows / g.num_nodes, 1.0)

    def _analytic_metrics(self, cfg: Dict, st0: StageTimes, base_hit: float,
                          base_stats, base_acc: float) -> Dict[str, float]:
        hit = self._hit_model(cfg)
        # batch generation is fetch-dominated: hits skip the host copy
        scale = (1.0 - HIT_SPEEDUP * hit) / max(1.0 - HIT_SPEEDUP * base_hit,
                                                1e-9)
        # device plane: resident rows gather in HBM instead of host memory
        if cfg.get("sampling_device") == "device":
            scale *= DEVICE_BATCH_SPEEDUP
        # per-step stage costs scale ~linearly with the mini-batch size
        cur_b = max(int(getattr(self.tr.cfg, "batch_size", 1)), 1)
        bscale = max(int(cfg.get("batch_size", cur_b)), 1) / cur_b
        st = StageTimes(st0.t_sample * bscale, st0.t_batch * scale * bscale,
                        st0.t_train * bscale)
        step_t = bottleneck_step_time(cfg["parallel_mode"], st,
                                      int(cfg["workers"]))
        # scale-out: p partitions each run the per-device pipeline, so
        # aggregate throughput AND fleet memory scale ~linearly with p,
        # while partition overlap η (Eq. 1) shrinks accuracy
        cur_p = max(int(getattr(self.tr.cfg, "partitions", 1)), 1)
        p = max(int(cfg.get("partitions", cur_p)), 1)
        budget = max(int(cfg.get("halo_budget",
                                 getattr(self.tr.cfg, "halo_budget", 0))), 0)
        mt = MemoryTerms(
            cache_bytes=cfg["cache_volume_mb"] * 2**20,
            batch_bytes=max(base_stats.peak_batch_bytes * bscale, 1),
            model_bytes=self.tr.model_bytes(base_stats),
            runtime_bytes=self.tr.runtime_bytes())
        mem = {"seq": memory_seq,
               "mode1": lambda t: memory_mode1(t, int(cfg["workers"])),
               "mode2": lambda t: memory_mode2(t, int(cfg["workers"])),
               }[cfg["parallel_mode"]](mt)
        # the halo budget widens each partition's effective overlap η (one
        # extra hop of boundary features) at the cost of replicated rows
        n_nodes = max(self.tr.full_graph.num_nodes, 1)
        eta = min(1.0, self.tr.eta * cur_p / p
                  + (budget / n_nodes if p > 1 else 0.0))
        halo_bytes = budget * self.tr.graph.feat_dim * 4 * (p if p > 1 else 0)
        drop = accuracy_drop_model(eta, cfg["bias_rate"],
                                   self.tr.graph.density(),
                                   self._cache_frac(cfg["cache_volume_mb"]))
        return {"throughput": p / max(step_t, 1e-9),
                "memory": float(mem) * p + halo_bytes,
                "accuracy": max(base_acc - drop, 0.0)}

    # -- surrogate bookkeeping ----------------------------------------------
    def _push_point(self, u: np.ndarray, metrics: Dict[str, float]):
        self._X.append(np.asarray(u, float))
        for m in self._Y:
            self._Y[m].append(float(metrics[m]))

    def _refit(self):
        X = np.stack(self._X)
        self.surrogate.fit(X, {m: np.asarray(v) for m, v in self._Y.items()})

    def _surrogate_eval(self, cfg: Dict) -> Dict[str, float]:
        pred = self.surrogate.predict(self.space.encode(cfg)[None])
        return {m: float(v[0]) for m, v in pred.items()}

    # -- PROPOSE -------------------------------------------------------------
    def propose(self) -> Tuple[Dict, Dict]:
        """PPO burst on the surrogate → best not-yet-measured config."""
        if self.agent is None:
            self.agent = PPOAgent(
                self.space, self._surrogate_eval,
                {"throughput": self.acfg.w_throughput,
                 "memory": self.acfg.w_memory,
                 "accuracy": self.acfg.w_accuracy},
                self.feasible,
                PPOConfig(updates=self.acfg.ppo_updates,
                          horizon=self.acfg.ppo_horizon,
                          seed=self.acfg.seed))
        start = len(self.agent.history)
        self.agent.run(self.rng)
        burst = self.agent.history[start:]
        ranked = sorted(burst, key=lambda h: h[2], reverse=True)
        for cfg, pred, _ in ranked:
            if _cfg_key(cfg) not in self._measured_keys:
                return cfg, pred
        # every burst point already measured — jitter to a fresh one
        for _ in range(64):
            cfg = self.space.decode(self.space.sample(self.rng)[0])
            if _cfg_key(cfg) not in self._measured_keys:
                return cfg, self._surrogate_eval(cfg)
        return ranked[0][0], ranked[0][1]

    # -- MEASURE -------------------------------------------------------------
    def measure(self, index: int, cfg: Dict,
                predicted: Optional[Dict] = None) -> Episode:
        for c in getattr(self.tr, "caches", [self.tr.cache]):
            if c is not None:
                c.stats.reset()
        stats = self.pipe.run(max_steps=self.acfg.steps_per_episode)
        runtime = None
        if resolve_throughput_source(self.acfg) == "wallclock":
            # real multi-core host: threads overlap, the wall clock is the
            # truth (stats.steps counts per-partition mini-batches, so this
            # is already the aggregate fleet rate) — stamped with the host
            # runtime (tcmalloc/XLA flags) it was taken under
            throughput = stats.throughput_steps_per_s()
            runtime = tuned_runtime_status()
        else:
            st = stats.stage_times()
            step_t = bottleneck_step_time(self.pipe.mode, st,
                                          self.pipe.workers_n)
            # multi-partition pipelines report aggregate (fleet) throughput
            throughput = getattr(self.pipe, "scale_factor", 1) \
                / max(step_t, 1e-9)
        metrics = {
            "throughput": throughput,
            "memory": self.tr.modeled_memory(stats, mode=self.pipe.mode,
                                             workers=self.pipe.workers_n),
            "accuracy": self.tr.evaluate(max_batches=self.acfg.eval_batches),
        }
        ep = Episode(index=index, config=dict(cfg), metrics=metrics,
                     reward=self.reward(metrics),
                     cache_hit_rate=getattr(
                         self.tr, "cache_hit_rate",
                         self.tr.cache.stats.hit_rate
                         if self.tr.cache else 0.0),
                     steps=stats.steps, predicted=predicted,
                     tuned_runtime=runtime)
        self._measured_keys.add(_cfg_key(cfg))
        self._push_point(self._encode(cfg), metrics)        # FEEDBACK
        self._refit()
        return ep

    # -- RECONFIGURE: restart-capable path for the `partitions` knob ---------
    def _proposed_partitions(self, cfg: Dict) -> int:
        return max(int(cfg.get("partitions",
                               getattr(self.tr.cfg, "partitions", 1))), 1)

    def _restart(self, new_partitions: int,
                 halo_budget: Optional[int] = None):
        """checkpoint → rebuild trainer at the new partition count → restore.

        Params and optimizer state round-trip through train/checkpoint.py
        (the same machinery a real elastic restart uses), so training
        resumes exactly where it left off on the new topology.  A proposed
        ``halo_budget`` rides along into the rebuild so the subsequent
        live-swap pass finds it already applied (one slot build, not two)."""
        import tempfile
        from repro.core.a3gnn import make_trainer
        from repro.train.checkpoint import CheckpointManager
        if self._restart_mgr is None:
            d = self.acfg.restart_dir or tempfile.mkdtemp(
                prefix="a3gnn_restart_")
            self._restart_mgr = CheckpointManager(d, keep=1, async_save=False)
        old_p = max(int(getattr(self.tr.cfg, "partitions", 1)), 1)
        self.restarts += 1
        # the trainer's own save() records the full manifest extra
        # (partitions, global_steps, cache accounting) so progress counters
        # survive the migration
        self.tr.save(self._restart_mgr, step=self.restarts)
        self.pipe.shutdown()
        new_cfg = self.tr.cfg.replace(partitions=new_partitions)
        if halo_budget is not None:
            new_cfg = new_cfg.replace(halo_budget=max(int(halo_budget), 0))
        # keep the assigner the caller chose (a bfs/hash trainer must not
        # silently migrate to the locality default mid-autotune)
        method = getattr(getattr(self.tr, "plan", None), "method", "locality")
        new_tr = make_trainer(self.tr.full_graph, new_cfg, seed=self.tr.seed,
                              partition_method=method)
        new_tr.restore(self._restart_mgr, step=self.restarts,
                       expect_partitions=old_p)
        # an attached FeatureStore follows the live trainer: the old
        # subscription is detached (updates must not route into the dead
        # topology) and the rebuilt trainer re-attaches to the same store
        store = getattr(self.tr, "feature_store", None)
        if store is not None:
            self.tr.detach_feature_store()
            new_tr.attach_feature_store(store)
        self.tr, self.pipe = new_tr, new_tr.make_pipeline()

    def _apply_config(self, cfg: Dict):
        """Full RECONFIGURE: restart if the partition count changed, then
        apply the live-swappable knobs to the (possibly new) trainer."""
        if self._proposed_partitions(cfg) != max(
                int(getattr(self.tr.cfg, "partitions", 1)), 1):
            self._restart(self._proposed_partitions(cfg),
                          halo_budget=cfg.get("halo_budget"))
        self.tr.apply_live_config(cfg, self.pipe)

    # -- main loop -----------------------------------------------------------
    def run(self) -> AutotuneReport:
        report = AutotuneReport()
        acfg = self.acfg
        if acfg.warmup_steps:
            self.pipe.run(mode="seq", max_steps=acfg.warmup_steps)
            self.pipe.reconfigure(mode=self.tr.cfg.parallel_mode)
        # episode 0: the fixed seed configuration = the baseline
        base_cfg = self._current_config()
        base = self.measure(0, base_cfg)
        report.episodes.append(base)
        report.baseline = base
        self.prewarm(self.pipe.stats, base.metrics["accuracy"])
        for e in range(1, acfg.episodes):
            cfg, pred = self.propose()
            self._apply_config(cfg)                         # RECONFIGURE
            report.episodes.append(self.measure(e, cfg, predicted=pred))
        feasible = [ep for ep in report.episodes
                    if self.feasible(ep.metrics)]
        if feasible:
            report.best = max(feasible, key=lambda ep: ep.reward)
        else:
            # nothing fit the budget — recommend the least-memory point and
            # flag it, rather than an arbitrary VIOLATION_REWARD tie-winner
            report.best = min(report.episodes,
                              key=lambda ep: ep.metrics["memory"])
            report.best_feasible = False
        # leave the trainer running the recommended configuration
        if _cfg_key(report.best.config) != _cfg_key(self._current_config()):
            self._apply_config(report.best.config)
        report.final_trainer = self.tr
        return report
