"""Performance-prediction surrogate (paper §III-C, Tab. III).

Gradient-boosted regression trees + ridge regression, implemented from
scratch in numpy (no XGBoost offline) — same role as the paper's
"XGBoost/Regression/Decision Trees" ensemble.  Predicts
[throughput, memory, accuracy] from (configuration ⊕ graph statistics);
R² is reported per metric exactly as Tab. III.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np


# ---------------------------------------------------------------------------
# Regression tree (exact greedy, variance reduction)
# ---------------------------------------------------------------------------

@dataclass
class _Node:
    feature: int = -1
    thresh: float = 0.0
    left: int = -1
    right: int = -1
    value: float = 0.0


class RegressionTree:
    def __init__(self, max_depth=4, min_samples_leaf=4, n_thresholds=16):
        self.max_depth = max_depth
        self.min_leaf = min_samples_leaf
        self.n_thr = n_thresholds
        self.nodes: List[_Node] = []

    def fit(self, X, y):
        self.nodes = []
        self._build(X, y, 0)
        return self

    def _build(self, X, y, depth) -> int:
        idx = len(self.nodes)
        self.nodes.append(_Node(value=float(y.mean()) if len(y) else 0.0))
        if depth >= self.max_depth or len(y) < 2 * self.min_leaf or y.std() < 1e-12:
            return idx
        best = None  # (sse, f, thr, maskL)
        for f in range(X.shape[1]):
            col = X[:, f]
            qs = np.quantile(col, np.linspace(0.08, 0.92, self.n_thr))
            for thr in np.unique(qs):
                mL = col <= thr
                nL = mL.sum()
                if nL < self.min_leaf or len(y) - nL < self.min_leaf:
                    continue
                yL, yR = y[mL], y[~mL]
                sse = ((yL - yL.mean()) ** 2).sum() + ((yR - yR.mean()) ** 2).sum()
                if best is None or sse < best[0]:
                    best = (sse, f, float(thr), mL)
        if best is None:
            return idx
        _, f, thr, mL = best
        self.nodes[idx].feature = f
        self.nodes[idx].thresh = thr
        self.nodes[idx].left = self._build(X[mL], y[mL], depth + 1)
        self.nodes[idx].right = self._build(X[~mL], y[~mL], depth + 1)
        return idx

    def predict(self, X):
        out = np.empty(len(X))
        for i, x in enumerate(X):
            n = 0
            while self.nodes[n].feature >= 0:
                n = (self.nodes[n].left if x[self.nodes[n].feature]
                     <= self.nodes[n].thresh else self.nodes[n].right)
            out[i] = self.nodes[n].value
        return out


class GBDT:
    """Gradient-boosted trees (squared loss)."""

    def __init__(self, n_trees=60, lr=0.15, max_depth=4, min_samples_leaf=4,
                 seed=0):
        self.n_trees, self.lr = n_trees, lr
        self.kw = dict(max_depth=max_depth, min_samples_leaf=min_samples_leaf)
        self.trees: List[RegressionTree] = []
        self.base = 0.0

    def fit(self, X, y):
        X, y = np.asarray(X, float), np.asarray(y, float)
        self.base = float(y.mean())
        pred = np.full(len(y), self.base)
        self.trees = []
        for _ in range(self.n_trees):
            t = RegressionTree(**self.kw).fit(X, y - pred)
            pred += self.lr * t.predict(X)
            self.trees.append(t)
        return self

    def predict(self, X):
        X = np.asarray(X, float)
        pred = np.full(len(X), self.base)
        for t in self.trees:
            pred += self.lr * t.predict(X)
        return pred


class Ridge:
    def __init__(self, alpha=1.0):
        self.alpha = alpha

    def fit(self, X, y):
        X = np.hstack([np.asarray(X, float), np.ones((len(X), 1))])
        A = X.T @ X + self.alpha * np.eye(X.shape[1])
        self.w = np.linalg.solve(A, X.T @ np.asarray(y, float))
        return self

    def predict(self, X):
        X = np.hstack([np.asarray(X, float), np.ones((len(X), 1))])
        return X @ self.w


def r2_score(y_true, y_pred) -> float:
    y_true, y_pred = np.asarray(y_true, float), np.asarray(y_pred, float)
    ss_res = ((y_true - y_pred) ** 2).sum()
    ss_tot = ((y_true - y_true.mean()) ** 2).sum()
    return float(1.0 - ss_res / max(ss_tot, 1e-12))


# ---------------------------------------------------------------------------
# Multi-metric surrogate
# ---------------------------------------------------------------------------

METRICS = ("throughput", "memory", "accuracy")


class Surrogate:
    """One boosted-tree + ridge blend per metric (log-space for thr/mem)."""

    def __init__(self, seed: int = 0, n_trees: int = 60):
        self.models = {m: GBDT(n_trees=n_trees, seed=seed) for m in METRICS}
        self.linear = {m: Ridge() for m in METRICS}
        self.blend = 0.85
        self.log_space = {"throughput": True, "memory": True, "accuracy": False}

    def _tf(self, m, y):
        return np.log(np.maximum(y, 1e-9)) if self.log_space[m] else y

    def _itf(self, m, y):
        return np.exp(y) if self.log_space[m] else y

    def fit(self, X, Y: dict):
        for m in METRICS:
            y = self._tf(m, np.asarray(Y[m], float))
            self.models[m].fit(X, y)
            self.linear[m].fit(X, y)
        return self

    def predict(self, X) -> dict:
        out = {}
        for m in METRICS:
            y = (self.blend * self.models[m].predict(X)
                 + (1 - self.blend) * self.linear[m].predict(X))
            out[m] = self._itf(m, y)
        return out

    def r2(self, X, Y: dict) -> dict:
        pred = self.predict(X)
        return {m: r2_score(Y[m], pred[m]) for m in METRICS}
