"""Locality-aware graph sampling (paper §III-A, Algo. 2).

Core mechanism: Efraimidis–Spirakis weighted reservoir sampling — key
k_j = u_j^{1/w_j}, keep the top-m keys.  Cached vertices get weight γ
(bias rate), uncached weight 1, so sampling is biased toward cache hits.

Two implementations with identical distribution:
  * ``reservoir_sample_ref``  — the paper's sequential Algo. 2 (oracle)
  * ``es_sample``             — vectorized keys + top-m (TPU-native shape;
    the Pallas kernel in kernels/reservoir mirrors this formulation)

``NeighborSampler`` builds multi-hop GraphSAGE-style blocks with fixed
fanout padding (static shapes → jit-friendly training batches).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.graph.storage import Graph


def reservoir_sample_ref(neighbors: np.ndarray, weights: np.ndarray, m: int,
                         rng: np.random.Generator) -> np.ndarray:
    """Algo. 2 verbatim: sequential weighted reservoir sampling."""
    if len(neighbors) <= m:
        return neighbors.copy()
    res_items = list(neighbors[:m])
    keys = list(rng.random(m) ** (1.0 / weights[:m]))
    for j in range(m, len(neighbors)):
        k_j = rng.random() ** (1.0 / weights[j])
        t = int(np.argmin(keys))
        if k_j > keys[t]:
            res_items[t] = neighbors[j]
            keys[t] = k_j
    return np.asarray(res_items, dtype=neighbors.dtype)


def es_keys(weights: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Efraimidis–Spirakis keys u^{1/w} (log-space for stability)."""
    u = rng.random(weights.shape)
    return np.log(np.maximum(u, 1e-300)) / np.maximum(weights, 1e-12)


def es_sample(neighbors: np.ndarray, weights: np.ndarray, m: int,
              rng: np.random.Generator) -> np.ndarray:
    """Vectorized top-m by ES keys — same distribution as Algo. 2."""
    if len(neighbors) <= m:
        return neighbors.copy()
    keys = es_keys(weights, rng)
    top = np.argpartition(-keys, m - 1)[:m]
    return neighbors[top]


@dataclass
class Block:
    """One hop: bipartite (src → dst) with fixed-fanout padding.

    ``neigh_idx[i, f]`` indexes ``src_ids``; -1 = padded slot."""
    src_ids: np.ndarray      # (n_src,) global node ids (dst ids are a prefix)
    dst_ids: np.ndarray      # (n_dst,)
    neigh_idx: np.ndarray    # (n_dst, fanout) int32, -1 padded


@dataclass
class MiniBatch:
    blocks: List[Block]          # input-hop first
    input_ids: np.ndarray        # node ids needing features (== blocks[0].src_ids)
    seeds: np.ndarray            # (batch,)
    labels: np.ndarray           # (batch,)
    features: Optional[np.ndarray] = None   # filled by batch generation
    # (stays None under GNNConfig.fused_gather_agg — the trainer resolves
    # the input hop at step time through FeaturePlane.fused_inputs)
    # graph topology version the batch was sampled at (dynamic graphs:
    # lets downstream consumers detect batches drawn before a mutation)
    topology_version: int = -1

    def num_input_nodes(self) -> int:
        return len(self.input_ids)


class NeighborSampler:
    """Multi-hop locality-aware sampler.

    ``weight_fn(ids) -> weights`` implements the bias: γ for cached ids,
    1 otherwise (see core/locality.py).  ``use_reference=True`` switches to
    the sequential Algo. 2 oracle (tests)."""

    def __init__(self, graph: Graph, fanouts: Sequence[int],
                 weight_fn: Optional[Callable[[np.ndarray], np.ndarray]] = None,
                 seed: int = 0, use_reference: bool = False):
        self.g = graph
        self.fanouts = tuple(fanouts)
        self.weight_fn = weight_fn
        self.rng = np.random.default_rng(seed)
        self.use_reference = use_reference

    def _sample_one_hop(self, dst_ids: np.ndarray, fanout: int) -> np.ndarray:
        """Returns sampled (n_dst, fanout) global ids with -1 pad."""
        g = self.g
        # both paths read through the merged base+overlay view, so edge
        # mutations are visible to the very next hop; for a frozen graph
        # adj() returns the base arrays untouched (bit-exact with the old
        # direct reads)
        indptr, indices = g.adj()
        out = -np.ones((len(dst_ids), fanout), dtype=np.int64)
        if self.use_reference:
            for i, v in enumerate(dst_ids):
                nb = indices[indptr[v]:indptr[v + 1]]
                if len(nb) == 0:
                    continue
                w = (np.ones(len(nb)) if self.weight_fn is None
                     else self.weight_fn(nb))
                picked = reservoir_sample_ref(nb, w, min(fanout, len(nb)),
                                              self.rng)
                out[i, :len(picked)] = picked
            return out
        # vectorized ES: one key computation over all edges of the hop, then
        # BUCKETED batched top-m (rows grouped by padded width) — all work is
        # large numpy ops that release the GIL, so sampler threads scale
        # (the host-side twin of the kernels/reservoir TPU formulation).
        starts = indptr[dst_ids]
        ends = indptr[dst_ids + 1]
        sizes = (ends - starts).astype(np.int64)
        total = int(sizes.sum())
        if total == 0:
            return out
        row_start = np.cumsum(sizes) - sizes
        offs = np.repeat(starts, sizes) + (np.arange(total)
                                           - np.repeat(row_start, sizes))
        nb_all = indices[offs]

        # rows with ≤ fanout neighbors: take everything (no keys needed)
        small = sizes <= fanout
        if small.any():
            rs = np.where(small)[0]
            w = int(sizes[rs].max()) if len(rs) else 0
            if w > 0:
                col = np.arange(w)
                valid = col[None, :] < sizes[rs, None]
                src = row_start[rs, None] + np.minimum(col[None, :],
                                                       sizes[rs, None] - 1)
                block = nb_all[src]
                row_idx = np.broadcast_to(rs[:, None], valid.shape)
                col_idx = np.broadcast_to(col[None, :], valid.shape)
                out[row_idx[valid], col_idx[valid]] = block[valid]

        big = ~small & (sizes > 0)
        if big.any():
            w_all = (np.ones(total) if self.weight_fn is None
                     else self.weight_fn(nb_all))
            keys = es_keys(w_all, self.rng)
            rows = np.where(big)[0]
            widths = 1 << np.ceil(np.log2(sizes[rows])).astype(int)
            for w in np.unique(widths):
                rs = rows[widths == w]
                col = np.arange(w)
                valid = col[None, :] < sizes[rs, None]
                src = row_start[rs, None] + np.minimum(col[None, :],
                                                       sizes[rs, None] - 1)
                km = np.where(valid, keys[src], -np.inf)
                top = np.argpartition(-km, fanout - 1, axis=1)[:, :fanout]
                out[rs[:, None], np.arange(fanout)[None, :]] = (
                    nb_all[np.take_along_axis(src, top, axis=1)])
        return out

    def sample(self, seeds: np.ndarray) -> MiniBatch:
        seeds = np.asarray(seeds, dtype=np.int64)
        blocks: List[Block] = []
        dst = seeds
        for fanout in self.fanouts:           # hop 1 = nearest to output
            nbrs = self._sample_one_hop(dst, fanout)
            # src set = dst ∪ sampled, with dst occupying the prefix positions
            valid = nbrs >= 0
            flat = nbrs[valid]
            src_sorted, inv = np.unique(np.concatenate([dst, flat]),
                                        return_inverse=True)
            dst_pos = inv[:len(dst)]                      # dst are unique
            in_dst = np.zeros(len(src_sorted), bool)
            in_dst[dst_pos] = True
            order = np.concatenate([dst_pos, np.where(~in_dst)[0]])
            src_ids = src_sorted[order]
            new_pos = np.empty(len(src_sorted), np.int32)
            new_pos[order] = np.arange(len(src_sorted), dtype=np.int32)
            neigh_idx = -np.ones_like(nbrs, dtype=np.int32)
            if valid.any():
                neigh_idx[valid] = new_pos[np.searchsorted(src_sorted, flat)]
            blocks.append(Block(src_ids=src_ids.astype(np.int64),
                                dst_ids=dst.astype(np.int64),
                                neigh_idx=neigh_idx))
            dst = src_ids
        blocks.reverse()                      # input hop first
        return MiniBatch(blocks=blocks, input_ids=blocks[0].src_ids,
                         seeds=seeds, labels=self.g.labels[seeds],
                         topology_version=self.g.topology_version)


def seed_loader(graph: Graph, batch_size: int, seed: int = 0,
                mask: Optional[np.ndarray] = None):
    """Iterate shuffled train-seed batches (drop last partial)."""
    ids = np.where(graph.train_mask if mask is None else mask)[0]
    rng = np.random.default_rng(seed)
    perm = rng.permutation(ids)
    for i in range(0, len(perm) - batch_size + 1, batch_size):
        yield perm[i:i + batch_size].astype(np.int64)
