"""Multi-partition data-parallel GNN training on the distributed substrate.

The paper's headline result is scale-OUT: many affordable devices, each
training on its own graph partition with no remote feature access, beat a
few expensive ones.  ``MultiPartitionTrainer`` reproduces that topology on
the existing substrate:

  * ``graph/partition.py`` assigns nodes with the locality-aware method
    (fewest cross-partition halo nodes — every cut edge is a feature the
    device would otherwise fetch remotely);
  * each partition owns a private ``FeatureCache`` + reconfigurable
    ``Pipeline`` (sampling bias γ, cache volume Θ, parallel mode all apply
    per partition, exactly as on a real device);
  * gradients synchronize through ``distributed/collectives.grad_allreduce``
    under a mesh from ``launch/mesh.make_partition_mesh`` — a real device
    mesh when the host has one device per partition, a ``HostSimMesh``
    (identical arithmetic, no topology) on the 1-CPU CI container;
  * with ``cfg.halo_budget > 0`` each partition's subgraph is augmented
    with its top-k boundary nodes (``PartitionPlan.halo_sets``) and their
    feature rows arrive through ``distributed/collectives.halo_all_to_all``
    — sampled batches reach one hop across the cut, and per-partition
    ``HaloStats`` count how many batch input nodes the halo served
    (checkpointed next to the cache hit accounting);
  * checkpoint/restore rides ``train/checkpoint.py`` (partition topology +
    per-partition cache hit accounting in the manifest) and restart/straggler
    handling rides ``train/fault_tolerance.py`` (``fit_supervised``);
  * streaming graphs: ``attach_feature_store`` subscribes the fleet to a
    ``graph/storage.py`` ``FeatureStore`` — owned-row updates land in the
    owning partition's feature plane immediately, stale halo copies are
    recovered by a bounded periodic halo re-fill
    (``cfg.halo_refresh_interval`` / ``refresh_halo_features``).

Interface-compatible with ``A3GNNTrainer`` where the autotune controller
needs it, so the episode space can tune ``partitions`` through the
checkpoint → rebuild → restore restart path.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs.gnn import GNNConfig
from repro.core.cache import FeatureCache
from repro.core.locality import accuracy_drop_model, bias_weight_fn
from repro.core.perf_model import (MemoryTerms, bottleneck_step_time,
                                   memory_mode1, memory_mode2, memory_seq)
from repro.core.pipeline import Pipeline, PipelineStats
from repro.core.sampling import NeighborSampler, seed_loader
from repro.distributed.collectives import grad_allreduce, halo_all_to_all
from repro.graph.batch import (generate_batch, batch_device_arrays,
                               compute_level_caps)
from repro.graph.partition import (PartitionPlan, RebalanceResult,
                                   assignment_cut_fraction,
                                   incremental_rebalance, plan_partitions)
from repro.graph.storage import FeatureStreamConsumer, Graph
from repro.launch.mesh import make_partition_mesh
from repro.models.gnn import (decls_gnn, make_apply_fn, make_eval_fn,
                              make_grad_fn, make_grad_fn_allfused)
from repro.models.params import init_params, param_bytes
from repro.train.checkpoint import CheckpointManager, TrainerCheckpointMixin
from repro.train.fault_tolerance import SupervisorReport, TrainSupervisor
from repro.train.optimizer import make_adamw

RUNTIME_BYTES = 16 * 2**20        # fixed per-worker runtime context (Eq. 3)


@dataclass
class HaloStats:
    """Per-partition halo accounting: how many batch input nodes fell in
    the halo region (local id ≥ owned count) — the information the bounded
    exchange recovered vs. PR 2's drop-cut-edges setting."""
    halo_hits: int = 0          # input nodes served from the halo region
    inputs: int = 0             # total batch input nodes seen
    batches: int = 0

    @property
    def hit_rate(self) -> float:
        return self.halo_hits / self.inputs if self.inputs else 0.0

    def reset(self):
        self.halo_hits = self.inputs = self.batches = 0


@dataclass
class PartitionSlot:
    """One partition's private training state (the per-device view)."""
    index: int
    graph: Graph
    eta: float
    n_owned: int = 0            # local ids ≥ n_owned are halo rows
    cache: Optional[FeatureCache] = None
    weight_fn: Optional[Callable] = None
    pipe: Optional[Pipeline] = None
    pending_grads: Optional[Dict] = None
    halo_stats: HaloStats = field(default_factory=HaloStats)
    _seed_iter: Optional[object] = None
    _epoch: int = 0


class MultiPipeline:
    """Pipeline-shaped view over all partition pipelines.

    Exposes the subset of the ``Pipeline`` contract the autotune controller
    drives (``run`` / ``reconfigure`` / ``begin_stats`` / ``stats`` /
    ``mode`` / ``workers_n`` / ``shutdown``); each ``run`` window executes
    gradient-synchronized GLOBAL steps, so ``stats.steps`` counts
    per-partition mini-batches (``scale_factor`` × global steps).
    """

    def __init__(self, trainer: "MultiPartitionTrainer"):
        self.tr = trainer
        self.stats = PipelineStats()

    @property
    def pipes(self) -> List[Pipeline]:
        return [s.pipe for s in self.tr.slots]

    @property
    def mode(self) -> str:
        return self.pipes[0].mode

    @property
    def workers_n(self) -> int:
        return self.pipes[0].workers_n

    @property
    def batch_size(self) -> int:
        return self.pipes[0].batch_size

    @property
    def sampling_device(self) -> str:
        return self.pipes[0].sampling_device

    @property
    def scale_factor(self) -> int:
        return len(self.tr.slots)

    def begin_stats(self) -> PipelineStats:
        self.stats = PipelineStats()
        for p in self.pipes:
            p.begin_stats()
        return self.stats

    def reconfigure(self, mode: Optional[str] = None,
                    workers: Optional[int] = None, cache=None, weight_fn=None,
                    batch_size: Optional[int] = None,
                    sampling_device: Optional[str] = None):
        """Drain + swap each partition pipeline.  Per-partition cache and
        bias always re-sync from the slots (they are per-partition state —
        the ``cache``/``weight_fn`` arguments of the single-pipeline
        contract are ignored here)."""
        del cache, weight_fn
        for slot in self.tr.slots:
            slot.pipe.reconfigure(mode=mode, workers=workers,
                                  cache=slot.cache, weight_fn=slot.weight_fn,
                                  batch_size=batch_size,
                                  sampling_device=sampling_device)

    def drain(self):
        for p in self.pipes:
            p.drain()

    def shutdown(self):
        for p in self.pipes:
            p.shutdown()

    def run(self, mode: Optional[str] = None, max_steps: Optional[int] = None,
            fail_worker: Optional[int] = None) -> PipelineStats:
        """Run ``max_steps`` gradient-synchronized global steps."""
        import time
        if mode is not None and mode != self.mode:
            self.reconfigure(mode=mode)
        tr = self.tr
        n = max_steps if max_steps is not None else tr.steps_per_epoch()
        stats = self.begin_stats()
        # submit every seed batch upfront: under mode1/mode2 the worker
        # pools prefetch ahead of the synchronized consumer, as on hardware
        for slot in tr.slots:
            seeds = [tr._next_seeds(slot) for _ in range(n)]
            slot.pipe.submit(seeds, fail_worker=(fail_worker
                                                 if slot.index == 0 else None))
        t0 = time.perf_counter()
        for _ in range(n):
            tr._consume_synced_step()
        stats.t_wall = time.perf_counter() - t0
        self._aggregate(stats)
        if fail_worker is not None:
            self.pipes[0]._stop_pool()      # injected-failure pool is poisoned
        return stats

    def _aggregate(self, agg: PipelineStats):
        for p in self.pipes:
            st = p.stats
            agg.steps += st.steps
            agg.t_sample += st.t_sample
            agg.t_batch += st.t_batch
            agg.t_train += st.t_train
            agg.losses += st.losses
            agg.accs += st.accs
            agg.reissued += st.reissued
            agg.peak_batch_bytes = max(agg.peak_batch_bytes,
                                       st.peak_batch_bytes)
            agg.queue_peak = max(agg.queue_peak, st.queue_peak)


class MultiPartitionTrainer(TrainerCheckpointMixin, FeatureStreamConsumer):
    """Data-parallel A³GNN over ``cfg.partitions`` graph partitions.

    Shared (params, opt_state); per-partition (subgraph, cache, sampler
    bias, pipeline).  ``batch_size`` is per partition — the effective
    global batch is ``partitions × batch_size``, matching the paper's
    fixed-per-device batching."""

    def __init__(self, graph: Graph, cfg: GNNConfig, seed: int = 0,
                 method: str = "locality"):
        if cfg.partitions < 1:
            raise ValueError(f"partitions must be ≥ 1, got {cfg.partitions}")
        self.full_graph = graph
        self.cfg = cfg
        self.seed = seed
        self.plan: PartitionPlan = plan_partitions(graph, cfg.partitions,
                                                   method, seed,
                                                   halo_budget=cfg.halo_budget)
        self.mesh = make_partition_mesh(self.plan.parts)
        self._allreduce = grad_allreduce(self.mesh)
        self._halo_exchange = halo_all_to_all(self.mesh)
        rng = jax.random.PRNGKey(seed)
        self.decls = decls_gnn(cfg)
        self.params = init_params(self.decls, rng)
        self.opt = make_adamw()
        self.opt_state = self.opt.init(self.params)
        self._grad = make_grad_fn(cfg)
        # one all-fused grad fn shared by every slot: the level caps are
        # slot-independent (cap growth only depends on batch × fanout,
        # clamped per-slot below), so slots share compiled signatures
        self._grad_allfused = (make_grad_fn_allfused(cfg)
                               if cfg.fused_gather_agg else None)
        self._apply = make_apply_fn(cfg, self.opt)
        self._eval = make_eval_fn(cfg)
        self.slots = [self._make_slot(p, sub) for p, sub in
                      enumerate(self.plan.subgraphs)]
        self.halo_exchange_bytes = self._fill_halo_features()
        self.eta = float(np.mean(self.plan.etas(graph)))
        self.global_steps = 0
        # streaming-update state (attach_feature_store)
        self.halo_refreshes = 0
        self._halo_dirty = False
        # dynamic-topology state: cut fraction at plan build (the drift
        # baseline) + rebalance accounting
        self._plan_cut_fraction = assignment_cut_fraction(graph,
                                                          self.plan.owner)
        self.rebalances = 0
        self.last_rebalance: Optional[RebalanceResult] = None

    # ------------------------------------------------------------------
    def _fill_halo_features(self) -> int:
        """Move the budgeted boundary feature rows through the partition
        mesh (``halo_all_to_all``): each subgraph's halo rows — zeroed by
        the plan, owned by another partition — are filled from the owner's
        feature store, THROUGH each partition's feature plane
        (``FeaturePlane.fill_rows``), so cache-resident copies update and
        device mirrors re-sync no matter which backend serves the next
        fetch.  Returns the exchange volume in bytes."""
        if self.plan.halo_rows == 0:
            return 0
        owned = [sub.features[:len(ns)] for sub, ns in
                 zip(self.plan.subgraphs, self.plan.node_sets)]
        halo_feats, volume = self._halo_exchange(self.plan, owned)
        for slot, ns, rows in zip(self.slots, self.plan.node_sets,
                                  halo_feats):
            if len(rows):
                local = np.arange(len(ns), len(ns) + len(rows))
                slot.pipe.plane.fill_rows(local, rows)
        return int(volume)

    # ------------------------------------------------------------------
    # streaming feature updates — attach/detach from FeatureStreamConsumer
    # (graph/storage.py); fleet routing: owner's plane now, halo later
    # ------------------------------------------------------------------
    def _owned_local(self) -> np.ndarray:
        """(N,) local id of each node WITHIN its owning partition — the
        plan's shared ownership-lookup index (``PartitionPlan.local_ids``),
        the same map the serving fabric routes queries through."""
        return self.plan.local_ids()

    def _local_id(self, p: int, node: int) -> int:
        """Local id of global ``node`` in partition p's subgraph (owned
        prefix or halo tail), -1 if absent.  Debug/test helper — the
        update path routes vectorized through ``plan.owner``."""
        if int(self.plan.owner[node]) == p:
            return int(self._owned_local()[node])
        if self.plan.halo_sets:
            pos = np.where(self.plan.halo_sets[p] == node)[0]
            if len(pos):
                return len(self.plan.node_sets[p]) + int(pos[0])
        return -1

    def _on_feature_update(self, ids: np.ndarray, rows: np.ndarray):
        """FeatureStore subscriber: updates are routed immediately to the
        OWNING partition's feature plane (cache-resident copies update,
        device mirrors invalidate); halo copies of updated rows on OTHER
        partitions only go stale — re-filling them is the bounded periodic
        exchange's job (``cfg.halo_refresh_interval`` /
        ``refresh_halo_features``): streaming updates must not turn every
        row write into cross-partition traffic."""
        ids = np.asarray(ids, dtype=np.int64)
        owners = self.plan.owner[ids]
        local = self._owned_local()[ids]
        for slot in self.slots:
            mine = owners == slot.index
            if mine.any():
                slot.pipe.plane.fill_rows(local[mine], rows[mine])
        if not self._halo_dirty:
            for hs in self.plan.halo_sets:
                if len(hs) and np.isin(ids, hs).any():
                    self._halo_dirty = True
                    break

    def refresh_halo_features(self) -> int:
        """Re-run the bounded halo exchange over the CURRENT budget: the
        same affinity-ranked rows move again through the mesh, through
        each partition's feature plane (mirror invalidation included), so
        halo copies catch up with streamed feature drift.  Returns the
        exchanged volume in bytes (0 with no halo)."""
        volume = self._fill_halo_features()
        self.halo_refreshes += 1
        self._halo_dirty = False
        return volume

    def _maybe_refresh_halo(self):
        every = getattr(self.cfg, "halo_refresh_interval", 0)
        if (every > 0 and self._halo_dirty
                and self.global_steps % every == 0):
            self.refresh_halo_features()

    def _make_slot(self, p: int, sub: Graph) -> PartitionSlot:
        cfg = self.cfg
        cache = (FeatureCache(sub, cfg.cache_volume_mb, cfg.cache_policy)
                 if cfg.cache_volume_mb > 0 else None)
        weight_fn = (bias_weight_fn(cache, cfg.bias_rate)
                     if (cache is not None and cfg.bias_rate > 1.0) else None)
        n_owned = len(self.plan.node_sets[p])
        # Eq. 1 overlap counts OWNED nodes only — halo leaves are borrowed
        # features, not partition membership
        slot = PartitionSlot(index=p, graph=sub,
                             eta=n_owned / max(self.full_graph.num_nodes, 1),
                             n_owned=n_owned,
                             cache=cache, weight_fn=weight_fn)
        slot.pipe = Pipeline(sub, cfg, self._slot_train_fn(slot), cache=cache,
                             weight_fn=weight_fn, seed=self.seed + p)
        return slot

    def _slot_train_fn(self, slot: PartitionSlot):
        """Per-partition "train" = local gradient computation; the shared
        update is applied after the cross-partition all-reduce."""
        def fn(mb, plane=None):
            hs = slot.halo_stats
            hs.halo_hits += int((mb.input_ids >= slot.n_owned).sum())
            hs.inputs += len(mb.input_ids)
            hs.batches += 1
            if (self._grad_allfused is not None and plane is not None
                    and mb.features is None and mb.blocks):
                # all-hop fused path (see A3GNNTrainer._train_fn)
                caps = compute_level_caps(len(mb.seeds), self.cfg.fanout,
                                          slot.graph.num_nodes)
                arrays = batch_device_arrays(mb, level_caps=caps)
                enc0, aux0, table = plane.fused_inputs(mb.input_ids,
                                                       arrays["pads"][0])
                grads, loss, acc = self._grad_allfused(
                    self.params, enc0, aux0, table,
                    arrays["neigh_idxs"], arrays["labels"])
            else:
                arrays = batch_device_arrays(mb)
                grads, loss, acc = self._grad(self.params,
                                              arrays["features"],
                                              arrays["neigh_idxs"],
                                              arrays["labels"])
            slot.pending_grads = grads
            return float(loss), float(acc)
        return fn

    def _next_seeds(self, slot: PartitionSlot) -> np.ndarray:
        for _ in range(2):
            if slot._seed_iter is None:
                slot._seed_iter = seed_loader(
                    slot.graph, self.cfg.batch_size,
                    self.seed + slot.index + 131 * slot._epoch)
            try:
                return next(slot._seed_iter)
            except StopIteration:
                slot._seed_iter = None
                slot._epoch += 1
        # partition smaller than one batch: sample train seeds w/ replacement
        ids = np.where(slot.graph.train_mask)[0]
        if len(ids) == 0:
            ids = np.arange(slot.graph.num_nodes)
        rng = np.random.default_rng(self.seed + slot.index
                                    + 131 * slot._epoch)
        slot._epoch += 1
        return rng.choice(ids, size=self.cfg.batch_size,
                          replace=True).astype(np.int64)

    # ------------------------------------------------------------------
    def _consume_synced_step(self):
        """Consume one submitted batch per partition, all-reduce the
        gradients, apply the single shared optimizer update."""
        grads = []
        for slot in self.slots:
            if not slot.pipe.step():
                raise RuntimeError(f"partition {slot.index}: no batch "
                                   f"in flight for the synced step")
            grads.append(slot.pending_grads)
            slot.pending_grads = None
        mean = self._allreduce(grads)
        self.params, self.opt_state = self._apply(self.params, self.opt_state,
                                                  mean)
        self.global_steps += 1
        self._maybe_refresh_halo()

    # ------------------------------------------------------------------
    # dynamic topology: cut-fraction drift tracking + incremental rebalance
    # ------------------------------------------------------------------
    def cut_drift(self) -> float:
        """How much the live cut fraction has degraded past the plan-time
        baseline: ``assignment_cut_fraction`` of the CURRENT adjacency
        (overlay included) minus the fraction at plan build.  0 while the
        graph's ``topology_version`` still matches the plan's (the cheap
        guard — no edge scan unless topology actually moved)."""
        if self.full_graph.topology_version == self.plan.topology_version:
            return 0.0
        cur = assignment_cut_fraction(self.full_graph, self.plan.owner)
        return max(cur - self._plan_cut_fraction, 0.0)

    def rebalance_partitions(self, pipe: Optional[MultiPipeline] = None,
                             max_move_frac: Optional[float] = None
                             ) -> RebalanceResult:
        """Incremental re-balance after topology drift: migrate boundary
        nodes only (``graph/partition.py:incremental_rebalance``), then
        rebuild the per-partition slots through the same in-place
        reconfigure discipline as ``set_halo_budget`` — drain, shutdown,
        new plan, new slots, halo refill.  Params and optimizer state are
        untouched (they are partition-independent); cache and halo
        accounting start FRESH because node ownership moved — the same
        invariant ``_after_restore`` enforces across a partition-count
        migration."""
        if max_move_frac is None:
            max_move_frac = getattr(self.cfg, "rebalance_max_move", 0.25)
        if pipe is not None:
            pipe.drain()
        for slot in self.slots:
            slot.pipe.shutdown()
        res = incremental_rebalance(self.full_graph, self.plan,
                                    max_move_frac=float(max_move_frac))
        self.plan = res.plan
        self.slots = [self._make_slot(p, sub) for p, sub in
                      enumerate(self.plan.subgraphs)]
        self.halo_exchange_bytes = self._fill_halo_features()
        self._halo_dirty = False         # every halo row was just refilled
        self._plan_cut_fraction = res.cut_after
        self.eta = float(np.mean(self.plan.etas(self.full_graph)))
        self.rebalances += 1
        self.last_rebalance = res
        return res

    def _maybe_rebalance(self):
        """Drift trigger, checked between global steps (never mid-window:
        ``MultiPipeline.run`` holds submitted batches in the slot pipes,
        and a rebalance replaces those pipes)."""
        thresh = getattr(self.cfg, "rebalance_drift", 0.0)
        if thresh > 0 and self.cut_drift() > thresh:
            self.rebalance_partitions()

    def global_step(self, fail_worker: Optional[int] = None):
        """One gradient-synchronized step: each partition samples + batches
        one mini-batch from its own subgraph through its own pipeline."""
        self._maybe_rebalance()
        for slot in self.slots:
            slot.pipe.submit([self._next_seeds(slot)],
                             fail_worker=(fail_worker if slot.index == 0
                                          else None))
        self._consume_synced_step()

    def synced_update(self, arrays_list: List[Dict]):
        """One data-parallel update from pre-generated per-partition device
        arrays (gradient-parity harness; bypasses sampling)."""
        grads, losses, accs = [], [], []
        for arrays in arrays_list:
            g, loss, acc = self._grad(self.params, arrays["features"],
                                      arrays["neigh_idxs"], arrays["labels"])
            grads.append(g)
            losses.append(float(loss))
            accs.append(float(acc))
        mean = self._allreduce(grads)
        self.params, self.opt_state = self._apply(self.params, self.opt_state,
                                                  mean)
        self.global_steps += 1
        self._maybe_refresh_halo()       # same contract as the synced step
        return float(np.mean(losses)), float(np.mean(accs))

    # ------------------------------------------------------------------
    # weight hand-off (trainer → serving replicas, SNIPPETS §2's
    # get/set-weights discipline): the exported tree is the live params
    # reference — jax trees are immutable and every optimizer step
    # REPLACES them, so a replica holding the export keeps a consistent
    # snapshot while the trainer moves on.  ``ServingFabric.refresh_
    # weights`` pulls this between engine steps (no in-flight request
    # ever sees a half-updated model).
    # ------------------------------------------------------------------
    def get_weights(self) -> Dict:
        return {"params": self.params}

    def set_weights(self, weights: Dict):
        self.params = weights["params"]

    # ------------------------------------------------------------------
    def make_pipeline(self) -> MultiPipeline:
        return MultiPipeline(self)

    def steps_per_epoch(self) -> int:
        """Global steps per epoch: the slowest partition sets the pace."""
        return max(max(int(s.graph.train_mask.sum()) // self.cfg.batch_size
                       for s in self.slots), 1)

    def run_epochs(self, epochs: int = 1,
                   max_steps_per_epoch: Optional[int] = None,
                   mode: Optional[str] = None,
                   fail_worker: Optional[int] = None,
                   warmup_steps: int = 0, simulate: bool = False):
        """Mirror of ``A3GNNTrainer.run_epochs`` over the partition fleet.
        ``simulate`` is accepted for signature parity (execution is already
        sequential-per-host on the CI container)."""
        del simulate
        from repro.core.a3gnn import A3GNNTrainer, RunResult
        pipe = self.make_pipeline()
        target_mode = mode or self.cfg.parallel_mode
        if warmup_steps:
            pipe.run(mode="seq", max_steps=warmup_steps)
            pipe.reconfigure(mode=target_mode)
            for c in self.caches:
                if c is not None:
                    c.stats.reset()
        try:
            # same per-epoch stats merge as the single-partition trainer
            agg = A3GNNTrainer._run_pipe_epochs(pipe, target_mode, epochs,
                                                max_steps_per_epoch,
                                                fail_worker)
        finally:
            pipe.shutdown()
        steps_per_epoch = (max_steps_per_epoch
                           if max_steps_per_epoch is not None
                           else self.steps_per_epoch())
        parts = self.plan.parts
        global_steps = max(agg.steps // parts, 1)
        sps = (global_steps * parts) / agg.t_wall if agg.t_wall else 0.0
        st = agg.stage_times()
        step_t = bottleneck_step_time(target_mode, st, self.cfg.workers)
        msps = parts / max(step_t, 1e-9)            # aggregate scale-out rate
        return RunResult(
            throughput_steps_s=sps,
            throughput_epochs_s=sps / max(steps_per_epoch * parts, 1),
            modeled_steps_s=msps,
            modeled_epochs_s=msps / max(steps_per_epoch * parts, 1),
            memory_bytes=self.modeled_memory(agg, mode=target_mode),
            test_acc=self.evaluate(),
            cache_hit_rate=self.cache_hit_rate,
            stats=agg, steps_per_epoch=steps_per_epoch)

    # ------------------------------------------------------------------
    @property
    def graph(self) -> Graph:
        """Partition 0's subgraph (the controller's per-device view)."""
        return self.slots[0].graph

    @property
    def cache(self) -> Optional[FeatureCache]:
        return self.slots[0].cache

    @property
    def caches(self) -> List[Optional[FeatureCache]]:
        return [s.cache for s in self.slots]

    @property
    def cache_hit_rate(self) -> float:
        hits = sum(c.stats.hits for c in self.caches if c is not None)
        total = hits + sum(c.stats.misses for c in self.caches
                           if c is not None)
        return hits / total if total else 0.0

    @property
    def halo_stats(self) -> List[HaloStats]:
        return [s.halo_stats for s in self.slots]

    @property
    def halo_hit_rate(self) -> float:
        """Fleet-wide fraction of batch input nodes served from the halo."""
        hits = sum(h.halo_hits for h in self.halo_stats)
        total = sum(h.inputs for h in self.halo_stats)
        return hits / total if total else 0.0

    def model_bytes(self, stats: PipelineStats) -> float:
        act_factor = max(3.0 * self.cfg.hidden * self.cfg.num_layers
                         / max(self.cfg.feat_dim, 1), 1.0)
        return 3 * param_bytes(self.decls) + stats.peak_batch_bytes * act_factor

    @staticmethod
    def runtime_bytes() -> float:
        return RUNTIME_BYTES

    def modeled_memory(self, stats: PipelineStats,
                       mode: Optional[str] = None,
                       workers: Optional[int] = None) -> float:
        """Fleet footprint: every partition replicates model + runtime and
        owns its cache/batches, so the Eq. 3/5 per-worker term × partitions."""
        cache_bytes = max((c.volume_bytes() for c in self.caches
                           if c is not None), default=0.0)
        mt = MemoryTerms(cache_bytes=cache_bytes,
                         batch_bytes=max(stats.peak_batch_bytes, 1),
                         model_bytes=self.model_bytes(stats),
                         runtime_bytes=RUNTIME_BYTES)
        mode = mode or self.cfg.parallel_mode
        workers = workers if workers is not None else self.cfg.workers
        per_part = {"mode1": lambda t: memory_mode1(t, workers),
                    "mode2": lambda t: memory_mode2(t, workers),
                    "seq": memory_seq}[mode](mt)
        # budgeted halo feature rows are replicated device-side state
        halo_bytes = self.plan.halo_rows * self.full_graph.feat_dim * 4
        return per_part * self.plan.parts + halo_bytes

    def predicted_accuracy_drop(self) -> float:
        cache_frac = ((self.cache.capacity / self.graph.num_nodes)
                      if self.cache else 0.0)
        return accuracy_drop_model(self.eta, self.cfg.bias_rate,
                                   self.full_graph.density(), cache_frac)

    # ------------------------------------------------------------------
    def set_halo_budget(self, budget: int,
                        pipe: Optional[MultiPipeline] = None):
        """LIVE halo-budget swap: re-budget the existing assignment
        (``PartitionPlan.with_halo_budget`` — owner/node_sets untouched, so
        no re-partition and no restart path), rebuild the per-partition
        slots in place, and refill halo rows through the mesh into each
        slot's feature plane.  Params,
        optimizer state and cache hit accounting carry over; in-flight
        batches are drained first (nothing dropped).  Halo accounting
        starts FRESH — it describes the current halo topology, and a
        budget change swaps that topology (the same invariant
        ``_after_restore`` enforces on the checkpoint path)."""
        budget = max(int(budget), 0)
        if budget == self.plan.halo_budget:
            self.cfg = self.cfg.replace(halo_budget=budget)
            return
        if pipe is not None:
            pipe.drain()
        old = self.slots
        for slot in old:
            slot.pipe.shutdown()
        self.plan = self.plan.with_halo_budget(self.full_graph, budget)
        self.cfg = self.cfg.replace(halo_budget=budget)
        self.slots = [self._make_slot(p, sub) for p, sub in
                      enumerate(self.plan.subgraphs)]
        self.halo_exchange_bytes = self._fill_halo_features()
        self._halo_dirty = False     # the re-budget refilled every halo row
        for new, prev in zip(self.slots, old):
            if new.cache is not None and prev.cache is not None:
                new.cache.stats = prev.cache.stats   # accounting survives

    def apply_live_config(self, knobs: Dict,
                          pipe: Optional[MultiPipeline] = None):
        """Episode-boundary reconfiguration, fanned out to every partition
        (same contract as ``A3GNNTrainer.apply_live_config``; the
        ``partitions`` knob itself needs the restart path instead, while
        ``halo_budget`` swaps live through ``set_halo_budget``)."""
        if "halo_budget" in knobs:
            self.set_halo_budget(int(knobs["halo_budget"]), pipe)
        updates = {k: knobs[k] for k in ("bias_rate", "cache_volume_mb",
                                         "parallel_mode", "workers",
                                         "batch_size", "sampling_device")
                   if k in knobs}
        if "workers" in updates:
            updates["workers"] = int(updates["workers"])
        if "batch_size" in updates:
            updates["batch_size"] = int(updates["batch_size"])
        self.cfg = self.cfg.replace(**updates)
        for slot in self.slots:
            if "cache_volume_mb" in updates:
                vol = float(updates["cache_volume_mb"])
                if vol <= 0:
                    slot.cache = None
                elif slot.cache is None:
                    slot.cache = FeatureCache(slot.graph, vol,
                                              self.cfg.cache_policy)
                else:
                    slot.cache.resize(vol)
            if "cache_volume_mb" in updates or "bias_rate" in updates:
                slot.weight_fn = (bias_weight_fn(slot.cache,
                                                 self.cfg.bias_rate)
                                  if (slot.cache is not None
                                      and self.cfg.bias_rate > 1.0) else None)
        if pipe is not None:
            pipe.reconfigure(mode=updates.get("parallel_mode"),
                             workers=updates.get("workers"),
                             batch_size=updates.get("batch_size"),
                             sampling_device=updates.get("sampling_device"))

    def fit_autotuned(self, autotune=None, seed: Optional[int] = None):
        """Online auto-tuning over the partition fleet (paper §III-C); with
        ``autotune.max_partitions > 1`` the controller also tunes the
        partition count through the checkpoint → rebuild → restore path."""
        from repro.core.autotune.controller import AutotuneController
        acfg = autotune or self.cfg.autotune
        if seed is not None:
            acfg = acfg.replace(seed=seed)
        ctrl = AutotuneController(self, self.make_pipeline(), acfg)
        try:
            report = ctrl.run()
            if ctrl.tr is not self:
                # a `partitions` restart rebuilt the trainer mid-run; keep
                # this object's params/opt state current — the rebuilt
                # topology lives in report.final_trainer
                self.load_state_dict(ctrl.tr.state_dict())
            return report
        finally:
            ctrl.pipe.shutdown()

    # ------------------------------------------------------------------
    def evaluate(self, max_batches: int = 8) -> float:
        """Test accuracy, averaged over per-partition held-out batches."""
        accs = []
        budget = max(max_batches // len(self.slots), 1)
        for slot in self.slots:
            if not slot.graph.test_mask.any():
                continue
            sampler = NeighborSampler(slot.graph, self.cfg.fanout,
                                      weight_fn=None,
                                      seed=self.seed + 12345 + slot.index)
            for i, seeds in enumerate(seed_loader(
                    slot.graph, self.cfg.batch_size, self.seed,
                    mask=slot.graph.test_mask)):
                if i >= budget:
                    break
                mb = generate_batch(sampler.sample(seeds), None, slot.graph)
                arrays = batch_device_arrays(mb)
                accs.append(float(self._eval(self.params, arrays["features"],
                                             arrays["neigh_idxs"],
                                             arrays["labels"])))
        return float(np.mean(accs)) if accs else 0.0

    # ------------------------------------------------------------------
    # checkpoint / restore — TrainerCheckpointMixin provides state_dict /
    # load_state_dict / save / restore (+ the partition-count guard)
    # ------------------------------------------------------------------
    def checkpoint_extra(self) -> Dict:
        """Manifest payload: topology + per-partition cache AND halo
        accounting, so a restore resumes with hit/miss history (and the
        restart path can verify what it is migrating)."""
        return {**super().checkpoint_extra(),
                "partition_method": self.plan.method,
                "halo_budget": int(self.plan.halo_budget),
                "topology_version": int(self.plan.topology_version),
                "rebalances": int(self.rebalances),
                "cache_stats": [dataclasses.asdict(s.cache.stats)
                                if s.cache is not None else None
                                for s in self.slots],
                "halo_stats": [dataclasses.asdict(s.halo_stats)
                               for s in self.slots]}

    def _after_restore(self, extra: Dict, step: int):
        self.global_steps = int(extra.get("global_steps", step))
        self.rebalances = int(extra.get("rebalances", 0))
        # cache/halo hit-accounting carries over only on a same-topology
        # restore (after a migration the per-partition objects are new)
        if int(extra.get("partitions", self.plan.parts)) == self.plan.parts:
            for slot, st in zip(self.slots, extra.get("cache_stats") or []):
                if slot.cache is not None and st:
                    for k, v in st.items():
                        setattr(slot.cache.stats, k, int(v))
            # ...and halo accounting additionally requires the same budget
            # (restoring budget>0 hits into a budget=0 topology would
            # report a halo hit rate on a fleet that has no halo)
            if int(extra.get("halo_budget",
                             self.plan.halo_budget)) == self.plan.halo_budget:
                for slot, st in zip(self.slots,
                                    extra.get("halo_stats") or []):
                    if st:
                        for k, v in st.items():
                            setattr(slot.halo_stats, k, int(v))

    def fit_supervised(self, steps: int, ckpt_dir, ckpt_every: int = 0,
                       max_restarts: int = 3,
                       fail_at_step: Optional[int] = None
                       ) -> SupervisorReport:
        """Train ``steps`` global steps under the fault-tolerance supervisor:
        periodic checkpoints, restore-and-resume on failure
        (``fail_at_step`` injects one for tests)."""
        ckpt = CheckpointManager(ckpt_dir, keep=2, async_save=False)
        sup = TrainSupervisor(ckpt, ckpt_every or max(steps // 2, 1),
                              max_restarts, extra_fn=self.checkpoint_extra)
        injected = {"armed": fail_at_step is not None}

        def step_fn(state, step):
            self.load_state_dict(state)      # supervisor may have restored
            if injected["armed"] and step == fail_at_step:
                injected["armed"] = False
                raise RuntimeError(f"injected node failure at step {step}")
            self.global_step()
            return self.state_dict()

        state, rep = sup.run(self.state_dict(), step_fn, steps)
        self.load_state_dict(state)
        return rep
