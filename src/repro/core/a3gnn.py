"""A³GNN — the paper's framework, assembled.

``A3GNNTrainer`` wires together the feature cache, the locality-aware
(bias-rate γ) weighted-reservoir sampler, the multi-level parallel pipeline
and the GNN train step; it reports the paper's three metrics
(throughput, memory footprint, accuracy).

Baseline adapters reproduce the comparison systems *as configurations*:
  * ``pyg_like``     — CPU sampling, no feature cache, sequential loop
  * ``quiver_like``  — device-biased static hotness cache, workers, no
    sampling/caching coordination (γ=1)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional

import jax
import numpy as np

from repro.configs.gnn import GNNConfig
from repro.core.cache import FeatureCache
from repro.core.locality import bias_weight_fn, accuracy_drop_model
from repro.core.pipeline import Pipeline, PipelineStats
from repro.core.perf_model import MemoryTerms, memory_seq, memory_mode1, memory_mode2
from repro.core.sampling import NeighborSampler, seed_loader
from repro.graph.batch import (generate_batch, batch_device_arrays,
                               compute_level_caps)
from repro.graph.partition import partition, overlap_ratio
from repro.graph.storage import FeatureStreamConsumer, Graph
from repro.models.gnn import (decls_gnn, make_train_step,
                              make_train_step_allfused, make_eval_fn)
from repro.models.params import init_params, param_bytes
from repro.train.checkpoint import TrainerCheckpointMixin
from repro.train.optimizer import make_adamw

RUNTIME_BYTES = 16 * 2**20        # fixed per-worker runtime context (Eq. 3)


@dataclass
class RunResult:
    throughput_steps_s: float     # wall-clock (1-core container: no overlap)
    throughput_epochs_s: float
    modeled_steps_s: float        # Eqs. 2/4 from measured stage times — the
    modeled_epochs_s: float       # multi-core CPU+accelerator prediction
    memory_bytes: float           # modeled peak (Eqs. 3/5)
    test_acc: float
    cache_hit_rate: float
    stats: PipelineStats
    steps_per_epoch: int

    def metrics(self) -> Dict[str, float]:
        return {"throughput": self.modeled_epochs_s,
                "memory": self.memory_bytes,
                "accuracy": self.test_acc}


def apply_baseline(cfg: GNNConfig, baseline: Optional[str]) -> GNNConfig:
    if baseline in (None, "a3gnn"):
        return cfg
    if baseline == "pyg_like":
        return cfg.replace(bias_rate=1.0, cache_volume_mb=0.0,
                           parallel_mode="seq", sampling_device="cpu",
                           workers=1)
    if baseline == "quiver_like":
        return cfg.replace(bias_rate=1.0, cache_policy="static",
                           parallel_mode="mode1", sampling_device="device",
                           workers=2)
    raise ValueError(baseline)


class A3GNNTrainer(TrainerCheckpointMixin, FeatureStreamConsumer):
    def __init__(self, graph: Graph, cfg: GNNConfig, seed: int = 0):
        self.full_graph = graph
        self.cfg = cfg
        self.seed = seed
        parts = partition(graph, cfg.partitions)
        self.graph = parts[0]                       # worker 0's partition
        self.eta = overlap_ratio(self.graph, graph)
        self.cache = (FeatureCache(self.graph, cfg.cache_volume_mb,
                                   cfg.cache_policy)
                      if cfg.cache_volume_mb > 0 else None)
        self.weight_fn = (bias_weight_fn(self.cache, cfg.bias_rate)
                          if (self.cache is not None and cfg.bias_rate > 1.0)
                          else None)
        rng = jax.random.PRNGKey(seed)
        self.decls = decls_gnn(cfg)
        self.params = init_params(self.decls, rng)
        self.opt = make_adamw()
        self.opt_state = self.opt.init(self.params)
        self._step = make_train_step(cfg, self.opt)
        self._step_allfused = (make_train_step_allfused(cfg, self.opt)
                               if cfg.fused_gather_agg else None)
        self._eval = make_eval_fn(cfg)

    # ------------------------------------------------------------------
    # streaming feature updates — attach/detach from FeatureStreamConsumer
    # (graph/storage.py); single-partition routing: refresh resident rows
    # ------------------------------------------------------------------
    def _check_feature_store_target(self):
        if self.graph is not self.full_graph:
            raise ValueError("attach_feature_store needs the undivided "
                             "graph (partitions=1); use "
                             "MultiPartitionTrainer for partition fleets")

    def _on_feature_update(self, ids, rows):
        # the store already wrote the host rows; pull resident copies
        # (device mirrors re-sync off FeatureCache.version), so the
        # trainer — and every serving engine sharing its plane — observes
        # the drift
        del rows
        if self.cache is not None:
            self.cache.refresh_rows(ids)

    # ------------------------------------------------------------------
    def _train_fn(self, mb, plane=None):
        if (self._step_allfused is not None and plane is not None
                and mb.features is None and mb.blocks):
            # all-hop fused path: level-capped buffers → one jit
            # signature per (model, level_caps); the input hop is
            # resolved at step time through the plane (encoded slots +
            # miss sideband — no feature tensor ever rides the batch)
            caps = compute_level_caps(len(mb.seeds), self.cfg.fanout,
                                      self.graph.num_nodes)
            arrays = batch_device_arrays(mb, level_caps=caps)
            enc0, aux0, table = plane.fused_inputs(mb.input_ids,
                                                   arrays["pads"][0])
            self.params, self.opt_state, loss, acc = self._step_allfused(
                self.params, self.opt_state, enc0, aux0, table,
                arrays["neigh_idxs"], arrays["labels"])
        else:
            arrays = batch_device_arrays(mb)
            self.params, self.opt_state, loss, acc = self._step(
                self.params, self.opt_state, arrays["features"],
                arrays["neigh_idxs"], arrays["labels"])
        return float(loss), float(acc)

    # ------------------------------------------------------------------
    def run_epochs(self, epochs: int = 1, max_steps_per_epoch: Optional[int] = None,
                   mode: Optional[str] = None,
                   fail_worker: Optional[int] = None,
                   warmup_steps: int = 0,
                   simulate: bool = False) -> RunResult:
        """``simulate=True`` executes the stages sequentially (uncontended
        stage-time measurement — required on a 1-core container) while the
        modeled throughput uses the CONFIGURED parallel mode via Eqs. 2/4."""
        target_mode = mode or self.cfg.parallel_mode
        exec_mode = "seq" if simulate else target_mode
        pipe = Pipeline(self.graph, self.cfg, self._train_fn,
                        cache=self.cache, weight_fn=self.weight_fn,
                        seed=self.seed)
        if warmup_steps:
            # absorb jit compiles (and FIFO cache warm) outside the timing
            pipe.run(mode="seq", max_steps=warmup_steps)
            if self.cache is not None:
                self.cache.stats.reset()
        agg: Optional[PipelineStats] = None
        try:
            agg = self._run_pipe_epochs(pipe, exec_mode, epochs,
                                        max_steps_per_epoch, fail_worker)
        finally:
            pipe.shutdown()
        steps_per_epoch = max(
            int(self.graph.train_mask.sum()) // self.cfg.batch_size, 1)
        sps = agg.throughput_steps_per_s()
        mem = self.modeled_memory(agg)
        # Eqs. 2/4 prediction from the measured per-stage times.  On this
        # 1-core container threads cannot physically overlap, so the modeled
        # number is the multi-core CPU+accelerator throughput; the structural
        # correctness of the model is tested in test_pipeline.py.
        from repro.core.perf_model import bottleneck_step_time
        step_t = bottleneck_step_time(target_mode, agg.stage_times(),
                                      self.cfg.workers)
        msps = 1.0 / max(step_t, 1e-9)
        return RunResult(
            throughput_steps_s=sps,
            throughput_epochs_s=sps / steps_per_epoch,
            modeled_steps_s=msps,
            modeled_epochs_s=msps / steps_per_epoch,
            memory_bytes=mem,
            test_acc=self.evaluate(),
            cache_hit_rate=(self.cache.stats.hit_rate if self.cache else 0.0),
            stats=agg, steps_per_epoch=steps_per_epoch)

    # ------------------------------------------------------------------
    @staticmethod
    def _run_pipe_epochs(pipe: Pipeline, exec_mode: str, epochs: int,
                         max_steps_per_epoch: Optional[int],
                         fail_worker: Optional[int]) -> PipelineStats:
        agg: Optional[PipelineStats] = None
        for ep in range(epochs):
            stats = pipe.run(mode=exec_mode, max_steps=max_steps_per_epoch,
                             fail_worker=fail_worker if ep == 0 else None)
            if agg is None:
                agg = stats
            else:
                agg.steps += stats.steps
                agg.t_sample += stats.t_sample
                agg.t_batch += stats.t_batch
                agg.t_train += stats.t_train
                agg.t_wall += stats.t_wall
                agg.losses += stats.losses
                agg.accs += stats.accs
                agg.reissued += stats.reissued
                agg.peak_batch_bytes = max(agg.peak_batch_bytes,
                                           stats.peak_batch_bytes)
        return agg

    # ------------------------------------------------------------------
    def model_bytes(self, stats: PipelineStats) -> float:
        # |M| of Eq. (3) = params+grads+opt + ACTIVATIONS; activations scale
        # with the deduplicated input-node count (∝ batch bytes) — this is
        # the memory the locality-aware sampler shrinks (§III-A).
        act_factor = max(3.0 * self.cfg.hidden * self.cfg.num_layers
                         / max(self.cfg.feat_dim, 1), 1.0)
        act_bytes = stats.peak_batch_bytes * act_factor
        return 3 * param_bytes(self.decls) + act_bytes

    @staticmethod
    def runtime_bytes() -> float:
        return RUNTIME_BYTES

    def modeled_memory(self, stats: PipelineStats,
                       mode: Optional[str] = None,
                       workers: Optional[int] = None) -> float:
        mt = MemoryTerms(
            cache_bytes=self.cache.volume_bytes() if self.cache else 0.0,
            batch_bytes=max(stats.peak_batch_bytes, 1),
            model_bytes=self.model_bytes(stats),
            runtime_bytes=RUNTIME_BYTES)
        mode = mode or self.cfg.parallel_mode
        workers = workers if workers is not None else self.cfg.workers
        if mode == "mode1":
            return memory_mode1(mt, workers)
        if mode == "mode2":
            return memory_mode2(mt, workers)
        return memory_seq(mt)

    # ------------------------------------------------------------------
    @property
    def caches(self):
        """Uniform per-partition cache view (single-partition: one entry);
        the autotune controller iterates this on both trainer kinds."""
        return [self.cache]

    @property
    def cache_hit_rate(self) -> float:
        return self.cache.stats.hit_rate if self.cache is not None else 0.0

    def make_pipeline(self) -> Pipeline:
        return Pipeline(self.graph, self.cfg, self._train_fn,
                        cache=self.cache, weight_fn=self.weight_fn,
                        seed=self.seed)

    # weight hand-off to serving replicas (same get/set-weights
    # discipline as MultiPartitionTrainer — jax trees are immutable, so
    # the export is a consistent snapshot the trainer replaces, never
    # mutates, as it keeps stepping)
    def get_weights(self) -> Dict:
        return {"params": self.params}

    def set_weights(self, weights: Dict):
        self.params = weights["params"]

    # checkpoint/restart interface: TrainerCheckpointMixin provides
    # state_dict/load_state_dict/save/restore (+ the partition-count guard)
    def checkpoint_extra(self) -> Dict:
        return {**super().checkpoint_extra(),
                "cache_stats": [dataclasses.asdict(self.cache.stats)
                                if self.cache is not None else None]}

    # ------------------------------------------------------------------
    def apply_live_config(self, knobs: Dict, pipe: Optional[Pipeline] = None):
        """Episode-boundary reconfiguration (autotune controller).

        Applies any of (bias_rate γ, cache_volume_mb Θ, parallel_mode,
        workers, batch_size, sampling_device) to the live trainer: the
        cache is resized with its hit/miss accounting intact, the sampler
        bias weight function is rebuilt for the new γ, and — when ``pipe``
        is given — the executor drains and swaps mode/workers/feature-plane
        backend without dropping a batch.  ``halo_budget`` is recorded but
        inert at one partition (no cut edges to recover; core/multipart.py
        implements the real swap)."""
        updates = {k: knobs[k] for k in ("bias_rate", "cache_volume_mb",
                                         "parallel_mode", "workers",
                                         "batch_size", "sampling_device")
                   if k in knobs}
        if "halo_budget" in knobs:
            self.cfg = self.cfg.replace(halo_budget=int(knobs["halo_budget"]))
        if "workers" in updates:
            updates["workers"] = int(updates["workers"])
        if "batch_size" in updates:
            updates["batch_size"] = int(updates["batch_size"])
        self.cfg = self.cfg.replace(**updates)
        if "cache_volume_mb" in updates:
            vol = float(updates["cache_volume_mb"])
            if vol <= 0:
                self.cache = None
            elif self.cache is None:
                self.cache = FeatureCache(self.graph, vol,
                                          self.cfg.cache_policy)
            else:
                self.cache.resize(vol)
        if "cache_volume_mb" in updates or "bias_rate" in updates:
            self.weight_fn = (bias_weight_fn(self.cache, self.cfg.bias_rate)
                              if (self.cache is not None
                                  and self.cfg.bias_rate > 1.0) else None)
        if pipe is not None:
            pipe.reconfigure(mode=updates.get("parallel_mode"),
                             workers=updates.get("workers"),
                             cache=self.cache, weight_fn=self.weight_fn,
                             batch_size=updates.get("batch_size"),
                             sampling_device=updates.get("sampling_device"))

    # ------------------------------------------------------------------
    def fit_autotuned(self, autotune=None, seed: Optional[int] = None):
        """Train under the online auto-tuner (paper §III-C, Algo. 3 live).

        Runs ``autotune.episodes`` PROPOSE → RECONFIGURE → MEASURE →
        FEEDBACK episodes (see core/autotune/controller.py) on a persistent
        pipeline and returns the ``AutotuneReport`` — measured Pareto
        points, per-episode configs/metrics, and the recommendation the
        trainer is left running."""
        from repro.core.autotune.controller import AutotuneController
        acfg = autotune or self.cfg.autotune
        if seed is not None:
            acfg = acfg.replace(seed=seed)
        ctrl = AutotuneController(self, self.make_pipeline(), acfg)
        try:
            report = ctrl.run()
            if ctrl.tr is not self:
                # a `partitions` restart rebuilt the trainer mid-run; keep
                # this object's params/opt state current — the rebuilt
                # topology lives in report.final_trainer
                self.load_state_dict(ctrl.tr.state_dict())
            return report
        finally:
            # the controller may have swapped (trainer, pipe) through the
            # partitions restart path — shut down whatever is current
            ctrl.pipe.shutdown()

    # ------------------------------------------------------------------
    def evaluate(self, max_batches: int = 8) -> float:
        sampler = NeighborSampler(self.graph, self.cfg.fanout, weight_fn=None,
                                  seed=self.seed + 12345)
        accs = []
        for i, seeds in enumerate(seed_loader(self.graph, self.cfg.batch_size,
                                              self.seed,
                                              mask=self.graph.test_mask)):
            if i >= max_batches:
                break
            mb = generate_batch(sampler.sample(seeds), None, self.graph)
            arrays = batch_device_arrays(mb)
            accs.append(float(self._eval(self.params, arrays["features"],
                                         arrays["neigh_idxs"],
                                         arrays["labels"])))
        return float(np.mean(accs)) if accs else 0.0

    # ------------------------------------------------------------------
    def predicted_accuracy_drop(self) -> float:
        cache_frac = ((self.cache.capacity / self.graph.num_nodes)
                      if self.cache else 0.0)
        return accuracy_drop_model(self.eta, self.cfg.bias_rate,
                                   self.graph.density(), cache_frac)


def make_trainer(graph: Graph, cfg: GNNConfig, seed: int = 0,
                 partition_method: str = "locality"):
    """Trainer factory: the multi-partition scale-out trainer when
    ``cfg.partitions > 1``, the classic single-partition ``A3GNNTrainer``
    otherwise.  Both share the checkpoint/restore + autotune interface."""
    if cfg.partitions > 1:
        from repro.core.multipart import MultiPartitionTrainer
        return MultiPartitionTrainer(graph, cfg, seed=seed,
                                     method=partition_method)
    return A3GNNTrainer(graph, cfg, seed=seed)


def run_config(graph: Graph, cfg: GNNConfig, baseline: Optional[str] = None,
               epochs: int = 1, max_steps: Optional[int] = 30,
               seed: int = 0, warmup_steps: int = 0,
               simulate: bool = False) -> RunResult:
    cfg = apply_baseline(cfg, baseline)
    tr = A3GNNTrainer(graph, cfg, seed=seed)
    return tr.run_epochs(epochs, max_steps_per_epoch=max_steps,
                         warmup_steps=warmup_steps, simulate=simulate)
