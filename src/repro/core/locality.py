"""Locality policy: bias-rate weighting + the Eq. (1) accuracy-drop model.

``ΔA = f1(η, γ, d(G), Θ)`` — fitted on profiled runs (the auto-tuner's
surrogate consumes the same features); the closed form below encodes the
paper's qualitative claims: ΔA grows with γ, is damped by cache volume Θ
and graph density d(G), and grows as partition overlap η shrinks.
"""
from __future__ import annotations

from typing import Callable

import numpy as np

from repro.core.cache import FeatureCache


def bias_weight_fn(cache: FeatureCache, gamma: float) -> Callable[[np.ndarray], np.ndarray]:
    """w(v) = γ if v cached else 1 (paper §III-A: higher weight → higher
    selection probability in the weighted reservoir)."""
    def fn(ids: np.ndarray) -> np.ndarray:
        return np.where(cache.device_map[ids] >= 0, float(gamma), 1.0)
    return fn


def accuracy_drop_model(eta: float, gamma: float, density: float,
                        cache_frac: float,
                        a=0.012, b=0.25, c=40.0, d=0.03) -> float:
    """ΔA (fraction, e.g. 0.01 = 1 point) — Eq. (1) closed form.

    * γ=1 → no drop from biasing (reverts to uniform sampling)
    * larger cache (Θ) ⇒ biased set covers more of the graph ⇒ smaller drop
    * denser graphs are more robust (paper: "robust graph topology")
    * partitioning (η<1) adds a separate loss term
    """
    bias_term = a * np.log(max(gamma, 1.0)) / (1.0 + b * cache_frac * 100.0)
    density_damp = 1.0 / (1.0 + c * density * 1e3)
    part_term = d * (1.0 - eta)
    return float(bias_term * density_damp + part_term)


def edge_locality_score(g, owner: np.ndarray) -> float:
    """Fraction of edges whose endpoints share a partition under ``owner``
    (node → partition id).  This is the objective the locality-aware
    partitioner maximizes: every cross-partition edge is a potential halo
    fetch, and 1 − score is the cut ratio that shrinks η in Eq. (1)."""
    src = np.repeat(np.arange(g.num_nodes), np.diff(g.indptr))
    if len(src) == 0:
        return 1.0
    return float((owner[src] == owner[g.indices]).mean())


def expected_hit_rate(cache_frac: float, gamma: float,
                      skew: float = 0.8) -> float:
    """Analytic hit-rate model used by the surrogate's feature set.

    Static hotness caching on a power-law graph already captures ``skew`` of
    traffic at small cache fractions; biasing multiplies the odds of picking
    a cached neighbor by γ."""
    base = skew * cache_frac ** 0.25 if cache_frac > 0 else 0.0
    base = min(base, 0.95)
    odds = base / max(1.0 - base, 1e-9) * gamma
    return odds / (1.0 + odds)
