"""Analytic throughput / memory models — Eqs. (2)–(5) of the paper.

These closed forms drive both the adaptive mode selection and the
auto-tuner's surrogate features.  Stage times come from profiling
(core/pipeline.py measures them per run).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class StageTimes:
    t_sample: float      # s per iteration
    t_batch: float
    t_train: float


def throughput_seq(st: StageTimes, iters_per_epoch: int) -> float:
    """Sequential mode: stages serialized."""
    return 1.0 / ((st.t_sample + st.t_batch + st.t_train) * iters_per_epoch)


def throughput_mode1(st: StageTimes, n_workers: int, iters_per_epoch: int) -> float:
    """Eq. (2): sampling+batchgen parallelized over n workers, overlapped
    with training — bottleneck is max(producer/n, consumer)."""
    bottleneck = max((st.t_sample + st.t_batch) / max(n_workers, 1), st.t_train)
    return 1.0 / (bottleneck * iters_per_epoch)


def throughput_mode2(st: StageTimes, n_workers: int, iters_per_epoch: int) -> float:
    """Eq. (4): only sampling parallelized; batchgen+train serialized."""
    bottleneck = max(st.t_sample / max(n_workers, 1), st.t_batch + st.t_train)
    return 1.0 / (bottleneck * iters_per_epoch)


@dataclass
class MemoryTerms:
    cache_bytes: float     # Θ per device
    batch_bytes: float     # B: generated mini-batch
    model_bytes: float     # |M|: params + activations + grads
    runtime_bytes: float   # fixed stream/context overhead


def memory_mode1(mt: MemoryTerms, n_workers: int, num_dev: int = 1) -> float:
    """Eq. (3): worker duplication multiplies the working set."""
    return (num_dev * mt.cache_bytes
            + n_workers * (mt.batch_bytes + mt.runtime_bytes)
            + mt.model_bytes)


def memory_mode2(mt: MemoryTerms, n_workers: int, num_dev: int = 1) -> float:
    """Eq. (5): batch generation serialized → single batch buffer, but the
    runtime context is still duplicated per sampling worker."""
    return (num_dev * mt.cache_bytes + mt.batch_bytes
            + n_workers * mt.runtime_bytes + mt.model_bytes)


def memory_seq(mt: MemoryTerms, num_dev: int = 1) -> float:
    return (num_dev * mt.cache_bytes + mt.batch_bytes + mt.runtime_bytes
            + mt.model_bytes)


def bottleneck_step_time(mode: str, st: StageTimes, n_workers: int) -> float:
    """Per-step wall time under the mode's overlap structure (Eqs. 2/4)."""
    if mode == "seq":
        return st.t_sample + st.t_batch + st.t_train
    if mode == "mode1":
        return max((st.t_sample + st.t_batch) / max(n_workers, 1), st.t_train)
    if mode == "mode2":
        return max(st.t_sample / max(n_workers, 1), st.t_batch + st.t_train)
    raise ValueError(mode)


def predict(mode: str, st: StageTimes, mt: MemoryTerms, n_workers: int,
            iters_per_epoch: int, num_dev: int = 1):
    """(epochs/s, bytes) for a candidate configuration."""
    if mode == "seq":
        return (throughput_seq(st, iters_per_epoch), memory_seq(mt, num_dev))
    if mode == "mode1":
        return (throughput_mode1(st, n_workers, iters_per_epoch),
                memory_mode1(mt, n_workers, num_dev))
    if mode == "mode2":
        return (throughput_mode2(st, n_workers, iters_per_epoch),
                memory_mode2(mt, n_workers, num_dev))
    raise ValueError(mode)
