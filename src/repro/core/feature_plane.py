"""FeaturePlane — the pluggable feature-fetch seam of the batch-generation
hot path (paper §III-A/B; the "gather" stage of sample → gather → transfer).
Training (core/pipeline.py) and online inference serving
(serve/gnn_engine.py) fetch through the SAME plane object, so the γ/Θ
cache and its hit/miss accounting carry across the train → serve boundary.

Every consumer of node features goes through ONE interface:

  * ``HostFeaturePlane``   — today's numpy path: ``FeatureCache.fetch``
    when a cache is configured, a direct host-store gather otherwise.
    Bit-exact with the pre-plane code (the regression anchor).
  * ``DeviceFeaturePlane`` — the cache table and the slot map (device map)
    live as jax device arrays; a batch fetch looks slots up on device and
    gathers resident rows with the Pallas kernel
    (``kernels/gather.cache_gather``), falling back to the host feature
    store for misses.  Accounting, FIFO insertion and resize semantics are
    delegated to the SAME ``FeatureCache`` bookkeeping, so the two planes
    are bit-exact and stats-exact on the same request stream.

``make_feature_plane`` picks the backend from
``GNNConfig.sampling_device`` (``cpu | device | auto`` — auto probes
``jax.devices()`` and chooses the device plane only when a non-CPU
accelerator is attached; the device plane still RUNS on CPU hosts through
the kernel's interpret mode, which is what the parity tests exercise).

Reconfiguration contract (the autotune controller's live swaps):

  * ``resize``/γ-swap — the underlying ``FeatureCache`` mutates in place;
    the device plane detects the mutation through ``FeatureCache.version``
    and re-uploads, DELETING the stale device buffers first (the donation
    step — a live Θ sweep must not accumulate dead cache tables in HBM).
  * plane swap — ``Pipeline.reconfigure(sampling_device=...)`` drains the
    executor and rebuilds the plane around the same cache object, so
    hit/miss accounting survives a cpu↔device migration.
"""
from __future__ import annotations

import threading
from typing import Optional

import numpy as np

from repro.core.cache import FeatureCache
from repro.graph.storage import Graph

# device-plane gather is issued in bounded row chunks: each distinct padded
# shape costs one jit trace (expensive in interpret mode), so chunking plus
# pow2 bucketing of the tail keeps the set of compiled shapes small and
# independent of the batch-size schedule
GATHER_CHUNK_ROWS = 2048
_MIN_ROWS = 8


def _bucket(n: int) -> int:
    """Round ``n`` up to a pow2 (≥ 8) so jit retraces stay bounded."""
    return max(1 << (n - 1).bit_length(), _MIN_ROWS)


class FeaturePlane:
    """Backend-pluggable feature-fetch interface (host implementation).

    ``fetch`` is the hot-path read (through the cache, with accounting);
    ``fill_rows`` is the write side used by the halo exchange — it updates
    the host store AND any cache-resident copy of the written rows, so a
    fill is visible no matter which backend serves the next fetch.
    """

    backend = "cpu"

    def __init__(self, graph: Graph, cache: Optional[FeatureCache] = None):
        self.graph = graph
        self.cache = cache
        self.store = None               # attached FeatureStore (subscribe_to)

    # -- reads ---------------------------------------------------------------
    def fetch(self, ids: np.ndarray) -> np.ndarray:
        """Gather features for ``ids`` (n,) → (n, F) float32."""
        if self.cache is not None:
            return self.cache.fetch(ids)
        return self.graph.features[np.asarray(ids, dtype=np.int64)]

    # -- writes (halo fills / streaming updates) -----------------------------
    def subscribe_to(self, store) -> "FeaturePlane":
        """Wire this plane into a ``graph/storage.py`` ``FeatureStore``:
        every streamed ``update_rows`` patches cache-resident copies and
        invalidates device mirrors (the store itself already wrote the
        host rows), so the serving engine (serve/gnn_engine.py) and a
        live trainer observe the same drift through the same seam.  Any
        previous subscription is detached first (a plane tracks at most
        one store); the store is recorded so a plane swap
        (``Pipeline.reconfigure``) can migrate the subscription to the
        successor plane."""
        self.detach_store()
        self.store = store
        store.subscribe(self._on_store_update)
        return self

    def detach_store(self):
        """Unsubscribe from the attached store — a REPLACED plane must
        detach or streamed updates keep routing into the dead object
        while its successor's cache silently drifts
        (``Pipeline.reconfigure`` migrates the subscription)."""
        if self.store is not None:
            self.store.unsubscribe(self._on_store_update)
            self.store = None

    def _on_store_update(self, ids: np.ndarray, rows: np.ndarray):
        """Store subscriber: the store wrote the host rows already, so
        only resident copies need patching (version bump → mirror
        re-sync) — no redundant host-store rewrite per subscribed plane."""
        c = self.cache
        if c is not None:
            c.patch_resident(np.asarray(ids, dtype=np.int64),
                             np.asarray(rows, dtype=np.float32))

    def fill_rows(self, ids: np.ndarray, rows: np.ndarray):
        """Overwrite feature rows ``ids`` in the host store, propagating to
        cache-resident copies (and, on the device plane, invalidating the
        device mirror)."""
        ids = np.asarray(ids, dtype=np.int64)
        self.graph.features[ids] = rows
        c = self.cache
        if c is not None:
            # resident-copy patch + version bump (mirror invalidation)
            # live in ONE place: FeatureCache.patch_resident
            c.patch_resident(ids, np.asarray(rows, dtype=np.float32))

    # -- reconfiguration -----------------------------------------------------
    def resize(self, volume_mb: float, keep_residents: bool = True):
        """Episode-boundary Θ swap, routed through the plane so backend
        state (device mirrors) tracks the cache."""
        if self.cache is not None:
            self.cache.resize(volume_mb, keep_residents=keep_residents)

    @property
    def stats(self):
        return self.cache.stats if self.cache is not None else None


# back-compat alias: the host plane IS the base implementation
HostFeaturePlane = FeaturePlane


class DeviceFeaturePlane(FeaturePlane):
    """Device-resident gather: slot map + cache table as jax arrays, batch
    lookup through the Pallas ``cache_gather`` kernel, miss fallback to the
    host feature store.

    The ``FeatureCache`` object stays the single source of truth for the
    slot assignment, the replacement policy and the hit/miss accounting —
    this plane mirrors (storage, device_map) to the device and re-uploads
    whenever ``cache.version`` moves (resize, FIFO insertion, halo fill).
    Stale device buffers are deleted before the re-upload so a live
    autotune sweep never holds two cache tables at once.  The static
    policy is the intended device configuration (read-only table between
    episodes); FIFO works but re-uploads after every inserting fetch.
    """

    backend = "device"

    def __init__(self, graph: Graph, cache: Optional[FeatureCache] = None,
                 use_pallas: bool = True, interpret: Optional[bool] = None):
        super().__init__(graph, cache)
        import jax
        self.use_pallas = use_pallas
        # interpret mode unless a real accelerator backs the default device
        self.interpret = (interpret if interpret is not None else
                          jax.devices()[0].platform not in ("tpu", "gpu"))
        self._dev_table = None
        self._dev_slots = None
        self._version = -1
        # mode1 batch-gen workers share the plane: the mirror delete +
        # re-upload must never race a gather in another thread (a deleted
        # buffer mid-kernel is fatal, unlike the host path's benign numpy
        # interleavings), so sync + gather + insert run under one lock
        self._lock = threading.Lock()

    # -- device mirror -------------------------------------------------------
    def _ensure_synced(self):
        c = self.cache
        if self._dev_table is not None and self._version == c.version:
            return
        import jax
        for buf in (self._dev_table, self._dev_slots):
            if buf is not None:
                buf.delete()             # donate the stale buffers
        self._dev_table = jax.device_put(c.storage)
        self._dev_slots = jax.device_put(c.device_map)
        self._version = c.version

    def device_bytes(self) -> int:
        """HBM footprint of the mirror (cache table + slot map)."""
        c = self.cache
        if c is None or not c.capacity:
            return 0
        return c.storage.nbytes + c.device_map.nbytes

    # -- reads ---------------------------------------------------------------
    def fetch(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids, dtype=np.int64)
        c = self.cache
        if c is None or not c.capacity:
            # nothing resident on device — same contract as the host plane
            return super().fetch(ids)
        with self._lock:
            return self._fetch_locked(ids, c)

    def _fetch_locked(self, ids: np.ndarray, c: FeatureCache) -> np.ndarray:
        import jax.numpy as jnp
        from repro.kernels.gather.ops import cache_gather
        self._ensure_synced()
        n = len(ids)
        out = np.empty((n, self.graph.feat_dim), np.float32)
        miss = np.empty(n, dtype=bool)
        for a in range(0, n, GATHER_CHUNK_ROWS):
            chunk = ids[a:a + GATHER_CHUNK_ROWS]
            m = len(chunk)
            mp = min(_bucket(m), GATHER_CHUNK_ROWS)
            # out-of-range pad ids resolve to slot -1 (a miss) on device
            pad = np.full(mp, self.graph.num_nodes, dtype=np.int64)
            pad[:m] = chunk
            slots = jnp.take(self._dev_slots, jnp.asarray(pad),
                             mode="fill", fill_value=-1)
            rows, miss_c = cache_gather(slots, self._dev_table,
                                        use_pallas=self.use_pallas,
                                        interpret=self.interpret)
            out[a:a + m] = np.asarray(rows)[:m]
            miss[a:a + m] = np.asarray(miss_c)[:m].astype(bool)
        miss_ids = ids[miss]
        if len(miss_ids):
            out[miss] = self.graph.features[miss_ids]
        # one accounting implementation for both planes (stats-exactness
        # is a tested invariant); a FIFO insert bumps version → re-sync
        c.account_fetch(~miss, miss_ids)
        return out

    def fill_rows(self, ids: np.ndarray, rows: np.ndarray):
        with self._lock:
            super().fill_rows(ids, rows)

    def _on_store_update(self, ids: np.ndarray, rows: np.ndarray):
        with self._lock:
            super()._on_store_update(ids, rows)

    def resize(self, volume_mb: float, keep_residents: bool = True):
        with self._lock:
            super().resize(volume_mb, keep_residents=keep_residents)


def make_feature_plane(graph: Graph, cache: Optional[FeatureCache],
                       sampling_device: str = "cpu") -> FeaturePlane:
    """Backend factory for the batch-generation gather stage.

    ``cpu`` → ``HostFeaturePlane``; ``device`` → ``DeviceFeaturePlane``;
    ``auto`` probes ``jax.devices()`` and picks the device plane only when
    a real accelerator (TPU/GPU) is attached.
    """
    if sampling_device == "auto":
        import jax
        has_accel = any(d.platform in ("tpu", "gpu") for d in jax.devices())
        sampling_device = "device" if has_accel else "cpu"
    if sampling_device == "device":
        return DeviceFeaturePlane(graph, cache)
    if sampling_device in ("cpu", "host"):
        return HostFeaturePlane(graph, cache)
    raise ValueError(f"unknown sampling_device: {sampling_device!r} "
                     f"(expected cpu | device | auto)")
